"""Serving layer: Router policies and the multi-replica Cluster — relQuery
affinity, spillover, merged reporting, and replica-scaling speedup."""
import copy

import pytest

from repro.core.latency_model import a100_opt13b
from repro.core.policies import SCHEDULERS
from repro.core.priority import BatchLimits, DPUConfig
from repro.core.relquery import make_relquery
from repro.data.trace import quick_trace
from repro.engine.prefix_cache import PrefixCache
from repro.engine.simulator import SimulatedExecutor
from repro.serving import Router, build_simulated_cluster, route_relquery


def _mk_cluster(n, scheduler="relserve", policy="affinity_spill"):
    return build_simulated_cluster(n, scheduler=scheduler, router_policy=policy)


# ---------------------------------------------------------------- router
def test_route_relquery_deterministic_and_in_range():
    for n in (1, 2, 4, 7):
        for rel_id in ("q0", "q1", "orders", "reviews"):
            r = route_relquery(rel_id, n)
            assert 0 <= r < n
            assert r == route_relquery(rel_id, n)   # stable


def test_router_policies():
    rq = make_relquery("q7", [[1] * 4], 0.0, 2)
    rr = Router(3, policy="round_robin")
    assert [rr.route(rq) for _ in range(4)] == [0, 1, 2, 0]

    ll = Router(3, policy="least_loaded")
    assert ll.route(rq, loads=[5, 1, 9]) == 1

    home = route_relquery("q7", 3)
    aff = Router(3, policy="affinity")
    assert aff.route(rq, loads=[1000, 1000, 1000]) == home

    spill = Router(3, policy="affinity_spill", spill_factor=2.0, spill_slack=0)
    loads = [0, 0, 0]
    assert spill.route(rq, loads) == home           # cold home: stay
    loads = [1, 1, 1]
    loads[home] = 100                               # hot home: spill to coldest
    routed = spill.route(rq, loads)
    assert routed != home and spill.stats["spilled"] == 1

    with pytest.raises(ValueError):
        Router(2, policy="bogus")


# ---------------------------------------------------------------- cluster
TRACE = quick_trace("rotten", num_relqueries=30, rate=1.5, seed=11, max_requests=40)


def test_cluster_relquery_affinity():
    """Every request of a relQuery lands on exactly one replica."""
    cluster = _mk_cluster(3, policy="affinity")
    result = cluster.run_trace(copy.deepcopy(TRACE))
    assert len(result.merged.latencies) == len(TRACE)
    assert set(result.assignments.values()) <= {0, 1, 2}
    for i, rep in enumerate(result.per_replica):
        for ev in rep.events:
            assert ev.replica == i
            for rel_id in ev.rel_ids:
                assert result.assignments[rel_id] == i
    # pure hashing matches the stable route function
    for rel_id, replica in result.assignments.items():
        assert replica == route_relquery(rel_id, 3)


def test_two_replicas_no_slower_than_one():
    """Paper-style loaded trace: 2 affine replicas beat (or match) 1."""
    heavy = quick_trace("rotten", num_relqueries=60, rate=1.0, seed=7,
                        max_requests=100, num_rows=10_000)
    rep1 = _mk_cluster(1).run_trace(copy.deepcopy(heavy)).merged
    rep2 = _mk_cluster(2).run_trace(copy.deepcopy(heavy)).merged
    assert len(rep1.latencies) == len(rep2.latencies) == len(heavy)
    assert rep2.avg_latency <= rep1.avg_latency


def test_single_replica_cluster_matches_serving_engine():
    from repro.engine.engine import ServingEngine
    lm = a100_opt13b()
    pc = PrefixCache(block_size=16)
    sched = SCHEDULERS["relserve"](limits=BatchLimits(), latency_model=lm,
                                   prefix_cache=pc, dpu_config=DPUConfig())
    eng = ServingEngine(sched, SimulatedExecutor(lm, prefix_cache=pc, seed=0))
    single = eng.run_trace(copy.deepcopy(TRACE))
    clustered = _mk_cluster(1).run_trace(copy.deepcopy(TRACE)).merged
    assert clustered.latencies == single.latencies
    assert clustered.end_to_end == pytest.approx(single.end_to_end)


def test_inflight_batch_counts_as_load():
    """Regression (review finding): a tick retires its batch at batch-start
    ordering, so an arrival landing inside a long in-flight batch must still
    see that replica as busy — not get routed onto it while an idle replica
    sits next door."""
    from repro.core.relquery import make_relquery

    cluster = _mk_cluster(2, policy="least_loaded")
    # A keeps replica 0 busy for a long stretch (long decode tail)
    a = make_relquery("A", [[1] * 50], 0.0, 400)
    # B arrives while A's first batches are in flight
    b = make_relquery("B", [[2] * 50], 0.5, 5)
    result = cluster.run_trace([a, b])
    assert result.assignments["A"] != result.assignments["B"], \
        "arrival during an in-flight batch was routed onto the busy replica"
    # B on the idle replica finishes promptly instead of queueing behind A
    assert result.merged.latencies["B"] < result.merged.latencies["A"]


def test_merged_report_consistency():
    cluster = _mk_cluster(4)
    result = cluster.run_trace(copy.deepcopy(TRACE))
    merged, parts = result.merged, result.per_replica
    assert sum(len(p.latencies) for p in parts) == len(merged.latencies)
    assert merged.end_to_end == max(p.end_to_end for p in parts)
    assert len(merged.events) == sum(len(p.events) for p in parts)
    starts = [e.start for e in merged.events]
    assert starts == sorted(starts)          # merged timeline is time-ordered
