"""Scheduler-core unit + property tests: Algorithm 1 decomposition, DPU
reuse/starvation, ABA case logic (Eq. 14-17), queue-state invariants."""
import pytest

from _hypothesis_compat import given, settings, st
from repro.core.arranger import AdaptiveBatchArranger
from repro.core.batch import Batch
from repro.core.latency_model import BatchLatencyModel, a100_opt13b, fit
from repro.core.priority import (
    BatchLimits, DPUConfig, DynamicPriorityUpdater, batch_decompose,
)
from repro.core.relquery import RequestState, make_relquery
from repro.core.scheduler import BatchResult, RelServeScheduler


# ---------------------------------------------------------------- Algorithm 1
@given(
    utoks=st.lists(st.integers(1, 4000), min_size=0, max_size=60),
    ol=st.integers(1, 50),
    running=st.integers(0, 10),
    mnbt=st.integers(128, 4096),
    mns=st.integers(2, 64),
    cap=st.integers(4096, 65536),
)
@settings(max_examples=300, deadline=None)
def test_batch_decompose_properties(utoks, ol, running, mnbt, mns, cap):
    limits = BatchLimits(max_num_batched_tokens=mnbt, max_num_seqs=mns, cap=cap)
    batches = batch_decompose(utoks, ol, running, limits)
    prefill = [b for b in batches if b.kind == "prefill"]
    decode = [b for b in batches if b.kind == "decode"]
    # every uncached token appears in exactly one prefill batch
    assert sum(b.utok for b in prefill) == sum(utoks)
    # decode batches never exceed the seq cap
    assert all(b.reqs <= max(mns, running) for b in decode)
    # decode iterations come in multiples of the output length
    assert len(decode) % ol == 0
    if utoks or running:
        assert len(decode) >= ol
    # prefill batches respect the token cap (single oversized request excepted)
    for b in prefill:
        assert b.utok <= max(mnbt, max(utoks, default=0))


# ---------------------------------------------------------------- DPU
def _mk_rq(rel_id, n_req, tok_len, ol, arrival=0.0):
    return make_relquery(rel_id, [[1] * tok_len] * n_req, arrival, ol)


def test_priority_reuse_for_waiting():
    dpu = DynamicPriorityUpdater(a100_opt13b(), BatchLimits())
    rq = _mk_rq("a", 10, 100, 10)
    dpu.update([rq], now=0.0)
    calls0 = dpu.stats["pem_calls"]
    dpu.update([rq], now=1.0)   # still fully waiting -> Eq. 12 reuse
    assert dpu.stats["pem_calls"] == calls0
    assert dpu.stats["reuses"] >= 1


def test_priority_drops_with_progress():
    dpu = DynamicPriorityUpdater(a100_opt13b(), BatchLimits())
    rq = _mk_rq("a", 10, 100, 10)
    dpu.update([rq], now=0.0)
    p0 = rq.priority
    for r in rq.requests[:9]:       # 90% of requests finish
        r.state = RequestState.FINISHED
    last = rq.requests[9]
    last.state = RequestState.RUNNING
    last.prefilled = True
    last.output_tokens = [1] * 8    # 2 decode iterations remain
    # state flipped outside the scheduler's transition methods — tell the
    # incremental DPU refresh the memoized phase probe is stale
    rq.note_phase_change()
    dpu.update([rq], now=1.0)
    assert rq.priority < p0 * 0.5, "priority must track remaining workload"
    # monotone: priority falls as generation progresses further (no state
    # change here — decode progress must be re-scored even on a memo hit)
    p1 = rq.priority
    last.output_tokens = [1] * 9
    dpu.update([rq], now=2.0)
    assert rq.priority <= p1


def test_starvation_promotion():
    dpu = DynamicPriorityUpdater(a100_opt13b(), BatchLimits(),
                                 DPUConfig(starvation_threshold=0.01))
    rq = _mk_rq("a", 4, 100, 10, arrival=0.0)
    dpu.update([rq], now=10.0)   # unit_waiting_time = 10/4 >> 0.01
    assert rq.priority == 0.0
    assert dpu.stats["starvation_promotions"] == 1


def test_cache_miss_ratio_sampling():
    class FakeCache:
        def peek_cached(self, tokens):
            return len(tokens) // 2
        def count_cached(self, tokens):
            return len(tokens) // 2
    dpu = DynamicPriorityUpdater(a100_opt13b(), BatchLimits(),
                                 DPUConfig(sample_size=4))
    rq = _mk_rq("a", 20, 100, 10)
    dpu.update([rq], now=0.0, prefix_cache=FakeCache())
    assert abs(rq.cache_miss_ratio - 0.5) < 1e-6
    assert dpu.stats["sampled_requests"] == 4   # sampled, not all 20


# ---------------------------------------------------------------- ABA
def _cand(reqs, utok=0, rq=None):
    return Batch.prefill(reqs, uncached_tokens=utok, relquery=rq)


def test_aba_cases():
    lm = a100_opt13b()
    aba = AdaptiveBatchArranger(lm)
    run_rq = _mk_rq("run", 4, 100, 10)
    wait_rq = _mk_rq("wait", 4, 100, 10)
    for r in run_rq.requests:
        r.state = RequestState.RUNNING
        r.prefilled = True
    prio = {"run": 5.0, "wait": 1.0}
    d = Batch.decode(run_rq.requests)
    p = _cand(wait_rq.requests, utok=400, rq=wait_rq)
    dec = aba.choose([p, d], [run_rq], [wait_rq], lambda r: prio[r.rel_id])
    assert dec.kind == "prefill" and dec.case == "preempt"    # m+ > m-

    prio = {"run": 1.0, "wait": 1.0}
    dec = aba.choose([p, d], [run_rq], [wait_rq], lambda r: prio[r.rel_id])
    assert dec.kind == "prefill" and dec.case == "internal"   # m+ == m-

    prio = {"run": 1.0, "wait": 5.0}
    dec = aba.choose([p, d], [run_rq], [wait_rq], lambda r: prio[r.rel_id])
    assert dec.case == "transitional"                          # m+ < m-
    assert dec.delta is not None


def test_aba_multi_candidate_mixed_beats_prefill():
    """Transitional case with three candidates: the chunked-mixed batch stalls
    the running relQuery less than a pure prefill pass (the decode rides
    along), so when Δ picks a winner it must be the mixed batch."""
    lm = a100_opt13b()
    aba = AdaptiveBatchArranger(lm)
    run_rq = _mk_rq("run", 4, 100, 20)
    for r in run_rq.requests:
        r.state = RequestState.RUNNING
        r.prefilled = True
    wait_rq = _mk_rq("wait", 8, 100, 20)
    p = _cand(wait_rq.requests, utok=800, rq=wait_rq)
    m = Batch.mixed(wait_rq.requests, run_rq.requests,
                    {r.req_id: r.num_prompt_tokens for r in wait_rq.requests},
                    uncached_tokens=800)
    d = Batch.decode(run_rq.requests)
    prio = {"run": 1.0, "wait": 5.0}                     # m+ < m-: transitional
    waiting = [_mk_rq(f"w{i}", 4, 100, 20) for i in range(30)]

    assert aba.delta_latency(m, [run_rq], waiting) < \
        aba.delta_latency(p, [run_rq], waiting) < 0
    dec = aba.choose([p, d, m], [run_rq], waiting, lambda r: prio[r.rel_id])
    assert dec.kind == "mixed" and dec.case == "transitional"
    assert aba.stats["transitional_mixed"] == 1

    # with nobody waiting to amortize, both prefill-side deltas are positive
    # and the arranger sticks to decoding
    dec = aba.choose([p, d, m], [run_rq], [], lambda r: prio[r.rel_id])
    assert dec.kind == "decode" and dec.case == "transitional"


def test_relserve_emits_mixed_on_loaded_trace():
    """End-to-end: the ABA actually schedules chunked-mixed batches (a case
    the pre-unification scheduler could not construct)."""
    import copy

    from repro.data.trace import quick_trace
    from repro.engine.engine import ServingEngine
    from repro.engine.prefix_cache import PrefixCache
    from repro.engine.simulator import SimulatedExecutor

    lm = a100_opt13b()
    pc = PrefixCache(block_size=16)
    sched = RelServeScheduler(limits=BatchLimits(), latency_model=lm,
                              prefix_cache=pc)
    trace = quick_trace("rotten", num_relqueries=25, rate=1.2, seed=11,
                        max_requests=40)
    report = ServingEngine(sched, SimulatedExecutor(lm, prefix_cache=pc)) \
        .run_trace(copy.deepcopy(trace))
    kinds = {e.kind for e in report.events}
    assert "mixed" in kinds, "ABA never chose a chunked-mixed batch"
    assert sched.aba.stats["transitional_mixed"] >= 1
    assert len(report.latencies) == len(trace)


def test_aba_delta_signs():
    """Many waiting relQueries -> combined decoding wins (delta < 0);
    no waiting relQueries -> prefill only costs (delta > 0)."""
    lm = a100_opt13b()
    aba = AdaptiveBatchArranger(lm)
    run_rq = _mk_rq("run", 4, 100, 20)
    for r in run_rq.requests:
        r.state = RequestState.RUNNING
        r.prefilled = True
    p_rq = _mk_rq("w0", 8, 100, 20)
    p = _cand(p_rq.requests, utok=800, rq=p_rq)
    waiting = [_mk_rq(f"w{i}", 4, 100, 20) for i in range(30)]
    assert aba.delta_latency(p, [run_rq], waiting) < 0
    assert aba.delta_latency(p, [run_rq], []) > 0


# ---------------------------------------------------------------- queue state
def test_scheduler_state_machine():
    lm = a100_opt13b()
    sched = RelServeScheduler(limits=BatchLimits(cap=10_000), latency_model=lm)
    rq = _mk_rq("a", 3, 50, 3)
    sched.add_relquery(rq, now=0.0)
    batch = sched.schedule(now=0.0)
    assert batch.kind == "prefill" and len(batch.requests) == 3
    outputs = {r.req_id: (5, False) for r in batch.requests}
    sched.complete_batch(batch, BatchResult(outputs), 0.0, 1.0)
    assert all(r.state == RequestState.RUNNING for r in rq.requests)
    assert rq.first_prefill_start == 0.0 and rq.last_prefill_end == 1.0
    assert sched.tokens_in_use == 3 * 51
    # decode to completion
    for i in range(2):
        batch = sched.schedule(now=1.0 + i)
        assert batch.kind == "decode"
        outputs = {r.req_id: (5, i == 1) for r in batch.requests}
        sched.complete_batch(batch, BatchResult(outputs), 1.0 + i, 2.0 + i)
    assert rq.is_finished() and rq.finish_time == 3.0
    assert sched.tokens_in_use == 0
    assert rq.latency() == 3.0
    assert rq.waiting_time() == 0.0
    assert rq.core_running_time() == 1.0
    assert rq.tail_running_time() == 2.0


def test_chunked_prefill_respects_kv_cap():
    """Regression: starting a chunked prefill commits the request's whole
    prompt+output KV footprint. Without the reservation, co-chunking a second
    request against the cap overcommits once both prompts complete."""
    from repro.core.policies import SarathiScheduler
    from repro.engine.engine import EngineCore
    from repro.engine.simulator import SimulatedExecutor

    lm = a100_opt13b()
    limits = BatchLimits(max_num_batched_tokens=32, max_num_seqs=8, cap=260)
    sched = SarathiScheduler(limits=limits, latency_model=lm)
    core = EngineCore(sched, SimulatedExecutor(lm))
    a = make_relquery("A", [[1] * 200], 0.0, 20)   # footprint 220
    b = make_relquery("B", [[2] * 100], 0.0, 20)   # footprint 120: can't coexist
    core.admit(a, 0.0)
    core.admit(b, 0.0)
    now, peak = 0.0, 0
    while core.has_work():
        ev = core.tick(now)
        now = ev.end
        peak = max(peak, sched.tokens_in_use)
        assert sched.tokens_in_use <= sched.committed_tokens <= limits.cap
    assert peak <= limits.cap
    assert sched.tokens_in_use == 0 and sched.committed_tokens == 0
    assert a.is_finished() and b.is_finished()


def test_prefill_admission_reserves_decode_growth():
    """Regression (review finding): admitting against *current* KV usage
    overcommits once running requests decode toward their output limit —
    admission must price the full prompt+output footprint."""
    from repro.core.policies import VLLMScheduler
    from repro.engine.engine import EngineCore
    from repro.engine.simulator import SimulatedExecutor

    lm = a100_opt13b()
    limits = BatchLimits(cap=100)
    sched = VLLMScheduler(limits=limits, latency_model=lm)
    core = EngineCore(sched, SimulatedExecutor(lm))
    # footprint 60 each: only one fits under cap=100 at a time
    core.admit(make_relquery("A", [[1] * 10], 0.0, 50), 0.0)
    core.admit(make_relquery("B", [[2] * 10], 0.0, 50), 0.0)
    now, peak = 0.0, 0
    while core.has_work():
        ev = core.tick(now)
        now = ev.end
        peak = max(peak, sched.tokens_in_use)
        assert sched.tokens_in_use <= sched.committed_tokens <= limits.cap
    assert peak <= limits.cap
    assert sched.committed_tokens == 0


def test_committed_request_not_deadlocked_behind_big_newcomer():
    """Regression (review finding): a partially-chunked request whose KV is
    already committed must stay schedulable when a too-big newcomer jumps
    ahead of it in the queue — not escalate to a spurious deadlock."""
    from repro.core.policies import VLLMScheduler
    from repro.engine.engine import EngineCore
    from repro.engine.simulator import SimulatedExecutor

    lm = a100_opt13b()
    sched = VLLMScheduler(limits=BatchLimits(cap=300), latency_model=lm)
    core = EngineCore(sched, SimulatedExecutor(lm))
    b = make_relquery("B", [[2] * 100], 0.0, 20)    # FCFS head, footprint 120
    a = make_relquery("A", [[1] * 200], 1.0, 20)    # footprint 220
    core.admit(b, 0.0)
    core.admit(a, 1.0)
    # A is mid-chunk: its whole footprint is committed, nothing is running
    ra = a.requests[0]
    ra.prefilled_tokens = 100
    sched.committed_tokens = 220
    # head-of-line B (120) does not fit on top of A's commitment (220+120>300),
    # but A itself is already committed -> must be offered, not deadlocked
    batch = sched.schedule(now=2.0)
    assert batch is not None and batch.kind == "prefill"
    assert batch.prefill_requests == [ra]
    # and the engine drains the whole backlog without raising
    now = 2.0
    while core.has_work():
        ev = core.tick(now)
        now = ev.end
        assert sched.tokens_in_use <= sched.committed_tokens <= sched.limits.cap
    assert a.is_finished() and b.is_finished()
    assert sched.committed_tokens == 0


def test_latency_model_fit_recovers_params():
    lm = BatchLatencyModel(2e-4, 0.05, 3e-4, 0.02)
    pre = [(x, lm.prefill_time(x)) for x in range(100, 3000, 100)]
    dec = [(x, lm.decode_time(x)) for x in range(1, 200, 10)]
    fitted = fit(pre, dec)
    assert abs(fitted.alpha_p - lm.alpha_p) / lm.alpha_p < 1e-6
    assert abs(fitted.beta_d - lm.beta_d) / lm.beta_d < 1e-6
