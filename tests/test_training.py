"""Training substrate: convergence, grad-accum equivalence, bf16 gradient
compression with error feedback, checkpoint roundtrip, elastic reshard."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.distributed.fault_tolerance import load_checkpoint, save_checkpoint
from repro.models.registry import build_model
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.train_step import TrainConfig, make_train_step

KEY = jax.random.PRNGKey(3)


def _setup(arch="qwen2-0.5b"):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init_params(KEY)
    batch = {
        "tokens": jax.random.randint(KEY, (4, 32), 0, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(9), (4, 32), 0,
                                     cfg.vocab_size),
    }
    return cfg, model, params, batch


def test_loss_decreases():
    cfg, model, params, batch = _setup()
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(model, TrainConfig(
        adamw=AdamWConfig(lr=3e-3))), donate_argnums=(0, 1))
    losses = []
    for _ in range(25):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8, f"no learning: {losses[0]} -> {losses[-1]}"
    assert np.isfinite(losses).all()


def test_grad_accum_equivalence():
    cfg, model, params, batch = _setup()
    tc1 = TrainConfig(grad_accum=1, remat=False)
    tc2 = TrainConfig(grad_accum=2, remat=False)
    opt1 = init_opt_state(params)
    opt2 = init_opt_state(params)
    p1, o1, m1 = jax.jit(make_train_step(model, tc1))(params, opt1, batch)
    p2, o2, m2 = jax.jit(make_train_step(model, tc2))(params, opt2, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-3
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))), p1, p2)
    assert max(jax.tree.leaves(d)) < 5e-2


def test_remat_matches_no_remat():
    cfg, model, params, batch = _setup()
    l1, _ = model.train_loss(params, batch, remat=False)
    l2, _ = model.train_loss(params, batch, remat=True)
    assert abs(float(l1) - float(l2)) < 1e-4


def test_compressed_grads_still_learn():
    cfg, model, params, batch = _setup()
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(model, TrainConfig(
        compress_grads=True, adamw=AdamWConfig(lr=3e-3))))
    losses = []
    for _ in range(25):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.85
    # error-feedback buffers exist and are finite
    errs = jax.tree.leaves(opt["err"])
    assert errs and all(bool(jnp.all(jnp.isfinite(e))) for e in errs)


def test_checkpoint_roundtrip_bitexact():
    cfg, model, params, batch = _setup()
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(model, TrainConfig()))
    params, opt, _ = step(params, opt, batch)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, {"params": params, "opt": opt})
        s, trees = load_checkpoint(d, template_trees={"params": params, "opt": opt})
        assert s == 1
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(trees["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(opt), jax.tree.leaves(trees["opt"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_resume_continues_identically():
    cfg, model, params, batch = _setup()
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(model, TrainConfig()))
    p1, o1 = params, opt
    for _ in range(3):
        p1, o1, _ = step(p1, o1, batch)
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 3, {"params": p1, "opt": o1})
        _, trees = load_checkpoint(d, template_trees={"params": p1, "opt": o1})
    p2, o2, m2 = step(trees["params"], trees["opt"], batch)
    p1, o1, m1 = step(p1, o1, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), abs=1e-6)
