"""Real-executor integration: RelServe drives actual JAX models token-by-token
through the full engine (prefix cache, slots/blocks, continuous batching).
Also the home of the dense-vs-paged backend equivalence pins: the same trace
through both KV backends must yield bit-identical token streams — plain,
under KV-pressure preemption, and with prefix sharing physically deduplicating
blocks."""
import copy
import functools

import jax
import pytest

from repro.configs import get_smoke_config
from repro.core.policies import SCHEDULERS
from repro.core.priority import BatchLimits
from repro.data.datasets import make_dataset
from repro.data.trace import TraceConfig, build_trace
from repro.engine.engine import ServingEngine
from repro.engine.executor import (
    PagedRealExecutor, RealExecutor, RequestCapacityError,
)
from repro.engine.prefix_cache import PrefixCache
from repro.engine.tokenizer import HashTokenizer
from repro.models.registry import build_model
from repro.serving import build_real_engine


@functools.lru_cache(maxsize=None)
def _model_and_params(arch: str):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    return cfg, model, model.init_params(jax.random.PRNGKey(0))


def _small_trace(cfg, n_rq=3, n_req=3, out=3, seed=2):
    tok = HashTokenizer(vocab_size=cfg.vocab_size - 2)
    ds = make_dataset("beer", num_rows=64, seed=1)
    trace = build_trace(ds, TraceConfig(num_relqueries=n_rq, rate=5.0, seed=seed,
                                        max_requests=n_req), tokenizer=tok)
    for rq in trace:
        rq.max_output_tokens = out
        for r in rq.requests:
            r.max_output_tokens = out
            r.sim_output_len = out
    return trace


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "granite-moe-3b-a800m"])
@pytest.mark.parametrize("sched_name", ["relserve", "vllm"])
def test_real_serving_end_to_end(arch, sched_name):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    trace = _small_trace(cfg)
    pc = PrefixCache(block_size=16)
    sched = SCHEDULERS[sched_name](limits=BatchLimits(cap=100_000), prefix_cache=pc)
    ex = RealExecutor(model, params, max_slots=16, max_len=512, prefix_cache=pc)
    report = ServingEngine(sched, ex).run_trace(trace)
    assert len(report.latencies) == len(trace)
    for rq in trace:
        for r in rq.requests:
            assert 1 <= len(r.output_tokens) <= r.max_output_tokens
    # calibration produced usable samples for the cost model (paper Fig. 7)
    fitted = ex.fitted_model()
    assert fitted.beta_p >= 0 and fitted.beta_d >= 0


# --------------------------------------------------------------------------
# dense vs paged backend equivalence
# --------------------------------------------------------------------------
def _backend_trace(cfg, *, n_rq=3, n_req=4, out=8, seed=4, rate=100.0,
                   num_templates=None):
    tok = HashTokenizer(vocab_size=cfg.vocab_size - 2)
    ds = make_dataset("beer", num_rows=64, seed=1)
    return build_trace(ds, TraceConfig(
        num_relqueries=n_rq, rate=rate, seed=seed, max_requests=n_req,
        output_token_cap=out, num_templates=num_templates), tokenizer=tok)


def _run_backend(backend, arch, trace, **engine_kw):
    cfg, model, params = _model_and_params(arch)
    trace = copy.deepcopy(trace)
    engine = build_real_engine(arch, "relserve", backend, model=model,
                               params=params, **engine_kw)
    engine.run_trace(trace)
    streams = [tuple(r.output_tokens) for rq in trace for r in rq.requests]
    return streams, engine


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "granite-moe-3b-a800m"])
def test_backend_equivalence_plain(arch):
    """Same trace, dense vs paged: bit-identical token streams, and the paged
    pool fully drains."""
    cfg, _, _ = _model_and_params(arch)
    trace = _backend_trace(cfg)
    kw = dict(limits=BatchLimits(cap=100_000), max_len=512)
    dense, _ = _run_backend("dense", arch, trace, **kw)
    paged, engine = _run_backend("paged", arch, trace, **kw)
    assert dense == paged
    ex = engine.executor
    ex.bm.check_invariants()
    assert ex.bm.free_blocks == ex.bm.num_blocks
    assert ex.kv_tokens_resident() == 0


def test_backend_equivalence_under_preemption():
    """A cap tight enough to force preemption (optimistic admission,
    recompute-style restarts) must not change either backend's token streams
    — and preemption must actually release paged blocks, not whole slots."""
    arch = "qwen3-1.7b"
    cfg, _, _ = _model_and_params(arch)
    trace = _backend_trace(cfg, n_rq=4, n_req=4, out=32, seed=4)
    max_fp = max(r.num_prompt_tokens + r.max_output_tokens
                 for rq in trace for r in rq.requests)
    kw = dict(limits=BatchLimits(cap=int(max_fp * 1.02)),
              kv_admission="optimistic", max_len=512)
    dense, d_eng = _run_backend("dense", arch, trace, **kw)
    paged, p_eng = _run_backend("paged", arch, trace, **kw)
    assert dense == paged
    assert d_eng.core.scheduler.preemptions > 0, \
        "cap not tight enough — dense run never preempted"
    assert p_eng.core.scheduler.preemptions > 0, \
        "cap not tight enough — paged run never preempted"
    ex = p_eng.executor
    ex.bm.check_invariants()
    assert ex.bm.free_blocks == ex.bm.num_blocks, \
        "preemption/finish leaked paged blocks"


def test_backend_equivalence_prefix_sharing():
    """Shared-template trace with prefix sharing on: streams identical across
    backends, and the paged executor physically deduplicates prefix blocks
    (ref-counted shared pages, counted once in the pool)."""
    arch = "qwen3-1.7b"
    cfg, _, _ = _model_and_params(arch)
    trace = _backend_trace(cfg, n_rq=4, n_req=4, out=8, seed=7,
                           num_templates=1)
    # the shared template prefix is ~13 tokens — block_size 8 makes it a
    # complete (shareable) block for both the ledger and the physical pool
    kw = dict(limits=BatchLimits(cap=100_000), prefix_sharing=True,
              max_len=512, block_size=8)
    dense, d_eng = _run_backend("dense", arch, trace, **kw)
    paged, p_eng = _run_backend("paged", arch, trace, **kw)
    assert dense == paged
    ex = p_eng.executor
    assert ex.share_prefix_blocks
    assert ex.shared_block_hits > 0, \
        "shared-template trace produced no physically shared blocks"
    assert d_eng.core.scheduler.shared_tokens_saved > 0
    ex.bm.check_invariants()
    assert ex.bm.free_blocks == ex.bm.num_blocks


def test_backend_equivalence_preemption_with_sharing():
    """The trickiest lifecycle: shared-template trace, sharing on, and a cap
    tight enough to preempt — restarts re-allocate over still-registered
    shared prefix blocks (prefill target includes preserved tokens). Streams
    must stay identical and the pool must drain."""
    arch = "qwen3-1.7b"
    cfg, _, _ = _model_and_params(arch)
    trace = _backend_trace(cfg, n_rq=4, n_req=4, out=32, seed=4,
                           num_templates=1)
    max_fp = max(r.num_prompt_tokens + r.max_output_tokens
                 for rq in trace for r in rq.requests)
    # ~2.5 footprints: enough headroom for concurrent residents (so leaders'
    # published blocks are live when followers allocate) while decode growth
    # still overflows the cap and forces preemption — a tighter cap
    # serializes execution and exercises neither path
    kw = dict(limits=BatchLimits(cap=int(max_fp * 2.5)),
              kv_admission="optimistic", prefix_sharing=True, max_len=512,
              block_size=8)
    dense, d_eng = _run_backend("dense", arch, trace, **kw)
    paged, p_eng = _run_backend("paged", arch, trace, **kw)
    assert dense == paged
    assert p_eng.core.scheduler.preemptions > 0, \
        "cap not tight enough — paged run never preempted"
    ex = p_eng.executor
    assert ex.shared_block_hits > 0, "sharing never physically deduplicated"
    ex.bm.check_invariants()
    assert ex.bm.free_blocks == ex.bm.num_blocks


def test_paged_copy_block_device_clone():
    """_copy_block (the device-side CoW clone) must copy one page across
    every layer's K and V pool, byte-for-byte, leaving all other pages
    untouched — pinned against a numpy oracle since the serving path only
    reaches it through forked sequences."""
    import numpy as np

    cfg, model, params = _model_and_params("qwen3-1.7b")
    ex = PagedRealExecutor(model, params, num_blocks=8, block_size=4,
                           max_len=64)
    rng = np.random.RandomState(0)
    filled = {
        name: rng.randn(*ex.pools[name].shape).astype(
            ex.pools[name].dtype) for name in ("k", "v")}
    ex.pools = {name: jax.numpy.asarray(filled[name]) for name in filled}
    src, dst = 2, 5
    expect = {name: filled[name].copy() for name in filled}
    for name in filled:
        expect[name][:, :, dst] = expect[name][:, :, src]
    ex._copy_block(src, dst)
    assert ex.cow_copies == 1
    for name in filled:
        np.testing.assert_array_equal(np.asarray(ex.pools[name]),
                                      expect[name])


# --------------------------------------------------------------------------
# admission-time capacity rejection
# --------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["dense", "paged"])
def test_overlong_request_rejected_at_admission(backend):
    """prompt + max_output > max_len used to overflow the dense slot buffer
    silently; both backends must now reject at admission with a clear error."""
    arch = "qwen3-1.7b"
    cfg, model, params = _model_and_params(arch)
    trace = _backend_trace(cfg, n_rq=1, n_req=1, out=8)
    rq = trace[0]
    r = rq.requests[0]
    engine = build_real_engine(arch, "relserve", backend, model=model,
                               params=params, max_len=len(r.tokens) + 4)
    with pytest.raises(RequestCapacityError, match="per-sequence capacity"):
        engine.core.admit(rq, 0.0)
    # nothing was admitted: the scheduler never saw the relQuery
    assert not engine.core.scheduler.relqueries
    # a fitting relQuery still admits fine
    ok = _backend_trace(cfg, n_rq=1, n_req=1, out=2, seed=9)[0]
    engine2 = build_real_engine(arch, "relserve", backend, model=model,
                                params=params, max_len=512)
    engine2.core.admit(ok, 0.0)
    assert ok.rel_id in engine2.core.scheduler.relqueries


def test_paged_pool_capacity_rejected_at_admission():
    """A pool smaller than one request's block footprint must reject at
    admission (RequestCapacityError), not crash with OutOfBlocks mid-prefill
    — max_len alone is not the binding constraint for a tiny pool."""
    cfg, model, params = _model_and_params("qwen3-1.7b")
    from repro.core.policies import SCHEDULERS
    from repro.engine.engine import ServingEngine
    ex = PagedRealExecutor(model, params, num_blocks=8, block_size=4,
                           max_len=128)
    engine = ServingEngine(SCHEDULERS["relserve"](), ex)
    trace = _backend_trace(cfg, n_rq=1, n_req=1, out=10)
    rq = trace[0]
    assert rq.requests[0].num_prompt_tokens + 10 <= 128  # passes max_len...
    with pytest.raises(RequestCapacityError, match="KV blocks"):
        engine.core.admit(rq, 0.0)  # ...but needs > 8 blocks of 4 tokens


def test_paged_backend_rejects_unsupported_arch():
    """Window/hybrid caches have no paged layout — constructing the paged
    executor for such an arch must fail loudly, steering to dense."""
    cfg = get_smoke_config("hymba-1.5b")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    with pytest.raises(NotImplementedError, match="paged"):
        PagedRealExecutor(model, params, num_blocks=64, max_len=256)


def test_real_executor_deterministic_outputs():
    """Greedy decoding through the engine is reproducible."""
    cfg = get_smoke_config("qwen3-1.7b")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))

    def run():
        trace = _small_trace(cfg, n_rq=2, n_req=2)
        pc = PrefixCache(block_size=16)
        sched = SCHEDULERS["relserve"](limits=BatchLimits(cap=100_000),
                                       prefix_cache=pc)
        ex = RealExecutor(model, params, max_slots=8, max_len=256, prefix_cache=pc)
        ServingEngine(sched, ex).run_trace(trace)
        return [tuple(r.output_tokens) for rq in trace for r in rq.requests]

    assert run() == run()
