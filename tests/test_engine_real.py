"""Real-executor integration: RelServe drives actual JAX models token-by-token
through the full engine (prefix cache, slots, continuous batching)."""
import copy

import jax
import pytest

from repro.configs import get_smoke_config
from repro.core.policies import SCHEDULERS
from repro.core.priority import BatchLimits
from repro.data.datasets import make_dataset
from repro.data.trace import TraceConfig, build_trace
from repro.engine.engine import ServingEngine
from repro.engine.executor import RealExecutor
from repro.engine.prefix_cache import PrefixCache
from repro.engine.tokenizer import HashTokenizer
from repro.models.registry import build_model


def _small_trace(cfg, n_rq=3, n_req=3, out=3, seed=2):
    tok = HashTokenizer(vocab_size=cfg.vocab_size - 2)
    ds = make_dataset("beer", num_rows=64, seed=1)
    trace = build_trace(ds, TraceConfig(num_relqueries=n_rq, rate=5.0, seed=seed,
                                        max_requests=n_req), tokenizer=tok)
    for rq in trace:
        rq.max_output_tokens = out
        for r in rq.requests:
            r.max_output_tokens = out
            r.sim_output_len = out
    return trace


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "granite-moe-3b-a800m"])
@pytest.mark.parametrize("sched_name", ["relserve", "vllm"])
def test_real_serving_end_to_end(arch, sched_name):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    trace = _small_trace(cfg)
    pc = PrefixCache(block_size=16)
    sched = SCHEDULERS[sched_name](limits=BatchLimits(cap=100_000), prefix_cache=pc)
    ex = RealExecutor(model, params, max_slots=16, max_len=512, prefix_cache=pc)
    report = ServingEngine(sched, ex).run_trace(trace)
    assert len(report.latencies) == len(trace)
    for rq in trace:
        for r in rq.requests:
            assert 1 <= len(r.output_tokens) <= r.max_output_tokens
    # calibration produced usable samples for the cost model (paper Fig. 7)
    fitted = ex.fitted_model()
    assert fitted.beta_p >= 0 and fitted.beta_d >= 0


def test_real_executor_deterministic_outputs():
    """Greedy decoding through the engine is reproducible."""
    cfg = get_smoke_config("qwen3-1.7b")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))

    def run():
        trace = _small_trace(cfg, n_rq=2, n_req=2)
        pc = PrefixCache(block_size=16)
        sched = SCHEDULERS["relserve"](limits=BatchLimits(cap=100_000),
                                       prefix_cache=pc)
        ex = RealExecutor(model, params, max_slots=8, max_len=256, prefix_cache=pc)
        ServingEngine(sched, ex).run_trace(trace)
        return [tuple(r.output_tokens) for rq in trace for r in rq.requests]

    assert run() == run()
