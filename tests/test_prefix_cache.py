"""Prefix cache + paged block manager invariants (unit + hypothesis)."""
import os
import subprocess
import sys

import pytest

from _hypothesis_compat import given, settings, st

from repro.engine.kv_cache import BlockManager, OutOfBlocks
from repro.engine.prefix_cache import PrefixCache, block_hashes


def test_block_hash_chaining():
    a = block_hashes([1, 2, 3, 4, 5, 6, 7, 8], 4)
    b = block_hashes([1, 2, 3, 4, 9, 9, 9, 9], 4)
    assert a[0] == b[0] and a[1] != b[1]          # shared first block only
    c = block_hashes([0, 2, 3, 4, 5, 6, 7, 8], 4)
    assert a[0] != c[0] and a[1] != c[1]          # chained: divergence propagates


def test_block_hashes_pinned_values():
    """Keys are a 64-bit chained crc32 pair, pinned: they feed scheduling
    order, the shared KV ledger and the router, so they may never drift (the
    old salted ``hash((h, blk))`` gave a different cache identity every
    process; a single 32-bit crc would birthday-collide at cache scale)."""
    assert block_hashes([1, 2, 3, 4, 5, 6, 7, 8], 4) == \
        [6380366929420061543, 9555590682644823222]
    assert block_hashes(list(range(32)), 16) == \
        [1103416033823968531, 1262309149209778443]
    assert block_hashes([40000, 7, 123456789, 0], 2) == \
        [7013585186073293444, 12469441396347363886]
    assert block_hashes([1, 2, 3], 4) == []          # no full block
    assert all(k < 2 ** 64 for k in block_hashes(list(range(64)), 8))


def test_block_hashes_stable_across_interpreters():
    """Regression for cross-process nondeterminism: a fresh interpreter (its
    own hash salt, forced different via PYTHONHASHSEED) must derive the exact
    keys this process did."""
    script = ("import sys; sys.path.insert(0, 'src'); "
              "from repro.engine.prefix_cache import block_hashes; "
              "print(block_hashes(list(range(64)), 16), "
              "block_hashes([9, 8, 7, 6, 5, 4], 3))")
    expected = f"{block_hashes(list(range(64)), 16)} " \
               f"{block_hashes([9, 8, 7, 6, 5, 4], 3)}"
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for seed in ("0", "12345"):
        out = subprocess.run(
            [sys.executable, "-c", script], capture_output=True, text=True,
            cwd=repo_root,
            env={"PYTHONHASHSEED": seed, "PATH": os.environ["PATH"]},
            check=True).stdout.strip()
        assert out == expected, f"keys drifted under PYTHONHASHSEED={seed}"


def test_prefix_cache_match_and_insert():
    pc = PrefixCache(block_size=4)
    toks = list(range(10))
    assert pc.count_cached(toks) == 0
    pc.insert(toks)
    assert pc.peek_cached(toks) == 8              # two full blocks (10 // 4 * 4)
    assert pc.peek_cached(list(range(6))) == 4    # shares the first block
    assert pc.peek_cached([9] + list(range(9))) == 0


def test_prefix_cache_lru_eviction():
    pc = PrefixCache(block_size=2, capacity_blocks=3)
    pc.insert([1, 2, 3, 4])       # 2 blocks
    pc.insert([5, 6, 7, 8])       # 2 more -> evicts oldest
    assert len(pc) == 3
    assert pc.evictions == 1
    assert pc.peek_cached([5, 6, 7, 8]) == 4      # newest survives


@given(st.lists(st.tuples(st.integers(1, 80), st.booleans()),
                min_size=1, max_size=40))
@settings(max_examples=100, deadline=None)
def test_block_manager_invariants(ops):
    bm = BlockManager(num_blocks=128, block_size=8)
    live = {}
    for i, (tokens, do_free) in enumerate(ops):
        sid = f"s{i}"
        try:
            bm.allocate(sid, tokens)
            live[sid] = tokens
        except OutOfBlocks:
            pass
        if do_free and live:
            victim = next(iter(live))
            bm.free(victim)
            del live[victim]
        bm.check_invariants()
    # tokens accounted exactly
    assert bm.tokens_in_use() == sum(live.values())
    for sid in list(live):
        bm.free(sid)
    assert bm.free_blocks == 128


def test_block_manager_prefix_sharing():
    bm = BlockManager(num_blocks=32, block_size=4)
    bm.allocate("a", 16)
    bm.register_prefix("a", [101, 102])           # first 2 blocks published
    before = bm.free_blocks
    alloc_b = bm.allocate("b", 16, prefix_keys=[101, 102, 999])
    assert alloc_b.shared_prefix_blocks == 2
    assert bm.free_blocks == before - 2           # only 2 fresh blocks
    bm.free("a")                                   # shared blocks stay (ref'd by b)
    assert bm.block_table("b")[0] == alloc_b.block_ids[0]
    bm.free("b")
    assert bm.free_blocks == 32
    bm.check_invariants()


def test_block_manager_decode_append():
    bm = BlockManager(num_blocks=8, block_size=4)
    bm.allocate("a", 4)                            # exactly one block
    assert bm.append_token("a") is not None        # crosses boundary -> new block
    for _ in range(3):
        assert bm.append_token("a") is None
    assert bm.context_len("a") == 8
    bm.check_invariants()
