"""Kernel parity suite for the paged KV path (CI fast lane, CPU interpret).

``paged_attention`` (Pallas, interpret=True) is pinned against the pure-jnp
oracle over the layouts the paged executor actually produces: fragmented
block tables, physically *shared* prefix blocks between sequences, ragged
context lengths, chunked multi-token queries, and CoW-forked sequences whose
tails diverged after sharing a prefix."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.engine.kv_cache import BlockManager
from repro.kernels.paged_attention import paged_attention
from repro.kernels.ref import paged_attention_ref

KEY = jax.random.PRNGKey(11)


def _rand(shape, dtype, k):
    return jax.random.normal(k, shape).astype(dtype)


def _assert_close(out, ref, dtype):
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def _dense_ref(q, k_seq, v_seq):
    """Straight softmax attention over a contiguous [T, KV, hd] sequence."""
    import math
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("gqh,tgh->gqt", q.astype(jnp.float32) * scale,
                   k_seq.astype(jnp.float32))
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("gqt,tgh->gqh", p, v_seq.astype(jnp.float32))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fragmented_and_shared_block_tables(dtype):
    """Two sequences share their leading pages (physically identical ids, as
    the prefix-sharing executor allocates them) while the rest of both tables
    is fragmented across the pool in arbitrary order."""
    B, KV, Qp, hd, page, maxp = 2, 2, 2, 32, 8, 6
    P = 24
    ks = jax.random.split(KEY, 3)
    q = _rand((B, KV, Qp, hd), dtype, ks[0])
    kp = _rand((P, page, KV, hd), dtype, ks[1])
    vp = _rand((P, page, KV, hd), dtype, ks[2])
    # shared prefix: both rows reference pages [17, 3]; suffixes fragmented
    bt = np.array([[17, 3, 11, 7, 2, 19],
                   [17, 3, 5, 13, 23, 0]], np.int32)
    cl = np.array([43, 38], np.int32)
    out = paged_attention(q, kp, vp, jnp.asarray(bt), jnp.asarray(cl),
                          interpret=True)
    ref = paged_attention_ref(q, kp, vp, jnp.asarray(bt), jnp.asarray(cl))
    _assert_close(out, ref, dtype)
    # the gathered-page computation must equal attention over the contiguous
    # sequence each table describes
    for b in range(B):
        k_seq = kp[bt[b]].reshape(-1, KV, hd)[: cl[b]]
        v_seq = vp[bt[b]].reshape(-1, KV, hd)[: cl[b]]
        dense = _dense_ref(q[b], k_seq, v_seq)
        _assert_close(out[b], dense, dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("seed", [0, 1])
def test_ragged_context_lengths(dtype, seed):
    B, KV, Qp, hd, page, maxp = 4, 2, 3, 64, 16, 5
    P = B * maxp + 3
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = _rand((B, KV, Qp, hd), dtype, ks[0])
    kp = _rand((P, page, KV, hd), dtype, ks[1])
    vp = _rand((P, page, KV, hd), dtype, ks[2])
    rng = np.random.RandomState(seed)
    bt = rng.permutation(P)[: B * maxp].reshape(B, maxp).astype(np.int32)
    # every raggedness regime: 1 token, mid-page, page boundary, full
    cl = np.array([1, page * 2 + 7, page * 3, page * maxp], np.int32)
    out = paged_attention(q, kp, vp, jnp.asarray(bt), jnp.asarray(cl),
                          interpret=True)
    ref = paged_attention_ref(q, kp, vp, jnp.asarray(bt), jnp.asarray(cl))
    _assert_close(out, ref, dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("num_q_tokens", [2, 4])
def test_chunked_queries(dtype, num_q_tokens):
    """Chunk mode: Qt query tokens per sequence, causally masked inside the
    kernel — token t sees positions <= ctx - Qt + t."""
    B, KV, Qp, hd, page, maxp = 2, 2, 2, 32, 8, 4
    P = 16
    ks = jax.random.split(KEY, 3)
    rows = num_q_tokens * Qp
    q = _rand((B, KV, rows, hd), dtype, ks[0])
    kp = _rand((P, page, KV, hd), dtype, ks[1])
    vp = _rand((P, page, KV, hd), dtype, ks[2])
    rng = np.random.RandomState(3)
    bt = rng.permutation(P)[: B * maxp].reshape(B, maxp).astype(np.int32)
    cl = np.array([page * 2 + 5, page * 4], np.int32)
    out = paged_attention(q, kp, vp, jnp.asarray(bt), jnp.asarray(cl),
                          interpret=True, num_q_tokens=num_q_tokens)
    ref = paged_attention_ref(q, kp, vp, jnp.asarray(bt), jnp.asarray(cl),
                              num_q_tokens=num_q_tokens)
    _assert_close(out, ref, dtype)
    # chunk causality: query token t must equal a Qt=1 call at ctx - Qt + 1 + t
    for t in range(num_q_tokens):
        qt = q[:, :, t * Qp:(t + 1) * Qp, :]
        cl_t = cl - num_q_tokens + 1 + t
        one = paged_attention_ref(qt, kp, vp, jnp.asarray(bt),
                                  jnp.asarray(cl_t))
        _assert_close(ref[:, :, t * Qp:(t + 1) * Qp, :], one, dtype)


def test_cow_forked_sequences():
    """A forked child shares its parent's pages until its first divergent
    append, which must land in a *private* copy: afterwards parent and child
    attend different tails while the shared prefix stays physically one."""
    page, KV, hd = 4, 2, 16
    bm = BlockManager(num_blocks=16, block_size=page)
    P = bm.num_blocks + 1
    scratch = P - 1

    rng = np.random.RandomState(0)
    kp = rng.randn(P, page, KV, hd).astype(np.float32)
    vp = rng.randn(P, page, KV, hd).astype(np.float32)

    bm.allocate("parent", 6)                      # 2 pages, tail half-full
    child_alloc = bm.fork("parent", "child")
    assert child_alloc.block_ids == bm.block_table("parent")
    assert child_alloc.num_tokens == 6

    # child's first append diverges -> CoW of the shared tail page
    new_blk, copy = bm.append_token_cow("child")
    assert copy is not None, "append into a shared tail must trigger CoW"
    src, dst = copy
    assert new_blk == dst
    assert bm.block_table("parent")[1] == src
    assert bm.block_table("child")[1] == dst
    kp[dst] = kp[src]                             # device-side page clone
    vp[dst] = vp[src]
    # divergent writes: child token 6, then parent token 6 — different values
    kp[dst, 2] = 1.0
    vp[dst, 2] = 1.0
    _, copy2 = bm.append_token_cow("parent")
    assert copy2 is None, "parent's tail is private after the child's CoW"
    kp[src, 2] = -1.0
    vp[src, 2] = -1.0
    bm.check_invariants()

    # both sequences now hold 7 tokens; identical prefix, divergent tail
    q = jnp.asarray(rng.randn(2, KV, 1, hd).astype(np.float32))
    q = jnp.concatenate([q[:1], q[:1]])           # same query for both rows
    maxp = 2
    bt = np.full((2, maxp), scratch, np.int32)
    bt[0, :2] = bm.block_table("parent")
    bt[1, :2] = bm.block_table("child")
    cl = np.array([7, 7], np.int32)
    out = paged_attention_ref(jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp),
                              jnp.asarray(bt), jnp.asarray(cl))
    pa = paged_attention(jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp),
                         jnp.asarray(bt), jnp.asarray(cl), interpret=True)
    _assert_close(pa, out, jnp.float32)
    parent_o, child_o = np.asarray(out[0]), np.asarray(out[1])
    assert not np.allclose(parent_o, child_o), \
        "divergent tails must produce different attention outputs"
    # re-run with the divergent token masked out: identical prefixes agree
    cl6 = np.array([6, 6], np.int32)
    out6 = paged_attention_ref(jnp.asarray(q), jnp.asarray(kp),
                               jnp.asarray(vp), jnp.asarray(bt),
                               jnp.asarray(cl6))
    np.testing.assert_allclose(np.asarray(out6[0]), np.asarray(out6[1]),
                               rtol=1e-6, atol=1e-6)

    bm.free("parent")
    bm.free("child")
    bm.check_invariants()
    assert bm.free_blocks == bm.num_blocks


def test_fork_conservation_under_churn():
    """fork/append/free churn never violates block conservation and CoW never
    lets two live sequences write the same page."""
    bm = BlockManager(num_blocks=64, block_size=4)
    bm.allocate("a", 10)
    bm.fork("a", "b")
    bm.fork("a", "c")
    writers = {}
    for seq in ("a", "b", "c"):
        for _ in range(6):
            bid, copy = bm.append_token_cow(seq)
            write_blk = bm.block_table(seq)[(bm.context_len(seq) - 1)
                                            // bm.block_size]
            owner = writers.get(write_blk)
            assert owner in (None, seq), \
                f"block {write_blk} written by {owner} and {seq}"
            writers[write_blk] = seq
            bm.check_invariants()
    bm.free("b")
    bm.check_invariants()
    bm.free("a")
    bm.free("c")
    bm.check_invariants()
    assert bm.free_blocks == bm.num_blocks
