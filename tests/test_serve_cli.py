"""launch/serve.py CLI: argument validation fails fast with clear messages,
and the --open-loop smoke mode exercises submit/stream/cancel/snapshot."""
import sys

import pytest

from repro.launch import serve


def _run(monkeypatch, *argv):
    monkeypatch.setattr(sys, "argv", ["serve", *argv])
    serve.main()


@pytest.mark.parametrize("argv,match", [
    (["--simulate", "--rate", "0"], "--rate must be > 0"),
    (["--simulate", "--rate", "-1.5"], "--rate must be > 0"),
    (["--simulate", "--num-relqueries", "0"], "--num-relqueries must be >= 1"),
    (["--simulate", "--max-requests", "0"], "--max-requests must be >= 1"),
    (["--simulate", "--num-replicas", "0"], "--num-replicas must be >= 1"),
    (["--simulate", "--kv-tiering", "on"],
     "--kv-tiering on requires a preempting admission"),
    (["--simulate", "--host-kv-cap", "4096"],
     "--host-kv-cap only applies with --kv-tiering on"),
    (["--simulate", "--swap-bandwidth", "16"],
     "--swap-bandwidth only applies with --kv-tiering on"),
    (["--simulate", "--kv-tiering", "on", "--kv-admission", "optimistic",
      "--host-kv-cap", "0"], "--host-kv-cap must be >= 1"),
    (["--simulate", "--kv-tiering", "on", "--kv-admission", "optimistic",
      "--swap-bandwidth", "0"], "--swap-bandwidth must be > 0 GB/s"),
])
def test_cli_validation(monkeypatch, argv, match):
    with pytest.raises(SystemExit, match=match):
        _run(monkeypatch, *argv)


def test_simulated_tiering_smoke(monkeypatch, capsys):
    """A tight --kv-cap plus --kv-tiering on actually swaps, reports the
    swap counters, and still completes the whole trace."""
    _run(monkeypatch, "--simulate", "--num-relqueries", "10", "--rate", "3.0",
         "--max-requests", "10", "--kv-admission", "optimistic",
         "--kv-cap", "400", "--kv-tiering", "on", "--debug-invariants")
    out = capsys.readouterr().out
    assert "kv-tiering=on" in out
    assert "[merged] relqueries=10" in out
    assert "kv-tiering:" in out and "swap-outs" in out


def test_predicted_admission_smoke(monkeypatch, capsys):
    _run(monkeypatch, "--simulate", "--num-relqueries", "8",
         "--max-requests", "8", "--rate", "4.0",
         "--kv-admission", "predicted")
    out = capsys.readouterr().out
    assert "[merged] relqueries=8" in out


def test_open_loop_smoke_simulated(monkeypatch, capsys):
    _run(monkeypatch, "--simulate", "--open-loop", "--num-relqueries", "12",
         "--rate", "3.0", "--max-requests", "10", "--num-replicas", "2")
    out = capsys.readouterr().out
    assert "OPEN-LOOP SMOKE OK" in out
    assert "cancelled" in out and "tokens streamed" in out


def test_closed_loop_simulated_still_works(monkeypatch, capsys):
    _run(monkeypatch, "--simulate", "--num-relqueries", "8",
         "--max-requests", "8", "--rate", "4.0")
    out = capsys.readouterr().out
    assert "[merged] relqueries=8" in out
