"""launch/serve.py CLI: argument validation fails fast with clear messages,
and the --open-loop smoke mode exercises submit/stream/cancel/snapshot."""
import sys

import pytest

from repro.launch import serve


def _run(monkeypatch, *argv):
    monkeypatch.setattr(sys, "argv", ["serve", *argv])
    serve.main()


@pytest.mark.parametrize("argv,match", [
    (["--simulate", "--rate", "0"], "--rate must be > 0"),
    (["--simulate", "--rate", "-1.5"], "--rate must be > 0"),
    (["--simulate", "--num-relqueries", "0"], "--num-relqueries must be >= 1"),
    (["--simulate", "--max-requests", "0"], "--max-requests must be >= 1"),
    (["--simulate", "--num-replicas", "0"], "--num-replicas must be >= 1"),
])
def test_cli_validation(monkeypatch, argv, match):
    with pytest.raises(SystemExit, match=match):
        _run(monkeypatch, *argv)


def test_open_loop_smoke_simulated(monkeypatch, capsys):
    _run(monkeypatch, "--simulate", "--open-loop", "--num-relqueries", "12",
         "--rate", "3.0", "--max-requests", "10", "--num-replicas", "2")
    out = capsys.readouterr().out
    assert "OPEN-LOOP SMOKE OK" in out
    assert "cancelled" in out and "tokens streamed" in out


def test_closed_loop_simulated_still_works(monkeypatch, capsys):
    _run(monkeypatch, "--simulate", "--num-relqueries", "8",
         "--max-requests", "8", "--rate", "4.0")
    out = capsys.readouterr().out
    assert "[merged] relqueries=8" in out
