"""``hypothesis`` shim: use the real library when installed, otherwise fall
back to a seeded-random sampler so the property tests still execute (with
less adversarial inputs and no shrinking) on bare environments.

Usage in tests:  ``from _hypothesis_compat import given, settings, st``
"""
import random

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # seeded-random fallback
    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

        def map(self, f):
            return _Strategy(lambda rng: f(self.draw(rng)))

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(seq):
            items = list(seq)
            return _Strategy(lambda rng: rng.choice(items))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            def draw(rng):
                n = rng.randint(min_size, max_size)
                return [elements.draw(rng) for _ in range(n)]
            return _Strategy(draw)

        @staticmethod
        def tuples(*elements):
            return _Strategy(lambda rng: tuple(e.draw(rng) for e in elements))

    st = _Strategies()

    def settings(max_examples=100, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*s_args, **s_kwargs):
        def deco(fn):
            def wrapper(*args, **kwargs):
                rng = random.Random(0xC0FFEE)
                for _ in range(getattr(fn, "_max_examples", 100)):
                    drawn = [s.draw(rng) for s in s_args]
                    named = {k: s.draw(rng) for k, s in s_kwargs.items()}
                    fn(*args, *drawn, **kwargs, **named)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper._max_examples = getattr(fn, "_max_examples", 100)
            return wrapper
        return deco
