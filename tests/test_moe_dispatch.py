"""Local expert-parallel MoE dispatch (shard_map) must match the unsharded
dispatch on a real multi-device mesh (§Perf cell B optimization)."""
import json
import os
import subprocess
import sys

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, __SRC__)
import json
import numpy as np
import jax, jax.numpy as jnp
from repro.distributed.sharding import ParallelConfig
from repro.launch.mesh import compat_make_mesh, compat_set_mesh
from repro.models.moe import moe_dispatch, moe_dispatch_local_ep

mesh = compat_make_mesh((2, 4), ("data", "model"))
compat_set_mesh(mesh)
pc = ParallelConfig.from_mesh(mesh)

rng = np.random.RandomState(0)
T, D, F, E, K = 32, 16, 24, 8, 2
x = jnp.asarray(rng.randn(T, D).astype(np.float32))
router = jnp.asarray(rng.randn(D, E).astype(np.float32))
wg = jnp.asarray(rng.randn(E, D, F).astype(np.float32) * 0.2)
wu = jnp.asarray(rng.randn(E, D, F).astype(np.float32) * 0.2)
wd = jnp.asarray(rng.randn(E, F, D).astype(np.float32) * 0.2)

# capacity == E x avg so nothing drops; local path uses per-shard capacity
out_ref, aux_ref = moe_dispatch(x, router, wg, wu, wd, top_k=K,
                                capacity_factor=float(E), act="silu")
with mesh:
    out_ep, aux_ep = jax.jit(lambda *a: moe_dispatch_local_ep(
        *a, top_k=K, capacity_factor=float(E), act="silu", mesh=mesh, pc=pc))(
        x, router, wg, wu, wd)
err = float(jnp.max(jnp.abs(out_ep - out_ref)))
aerr = abs(float(aux_ep) - float(aux_ref))
print("RESULT:" + json.dumps({"err": err, "aux_err": aerr,
                              "scale": float(jnp.max(jnp.abs(out_ref)))}))
"""


def test_local_ep_dispatch_matches_reference():
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    proc = subprocess.run([sys.executable, "-c", _SCRIPT.replace("__SRC__", repr(src))],
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")][-1]
    r = json.loads(line[len("RESULT:"):])
    assert r["err"] < 1e-4 * max(r["scale"], 1.0), r
    # aux is a local-mean vs global-mean of the same statistic; close but the
    # top-1 fractions are computed per shard — allow small deviation
    assert r["aux_err"] < 0.5, r
