"""Mini multi-device dry-run in a subprocess (device count is locked at jax
init, so the 512-device production dry-run cannot run inside this process).
Uses an 8-device (2x2x2) mesh and smoke configs — fast, exercises the exact
same cell/lowering/sharding machinery as the production dry-run."""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, {src!r})
import json
import jax
from repro.configs import get_smoke_config
from repro.launch.cells import build_cell, lower_cell
from repro.launch.hlo_stats import collective_stats, dot_flops
from repro.launch.mesh import compat_make_mesh

mesh = compat_make_mesh((2, 2, 2), ("pod", "data", "model"))
out = {{}}
for arch in {archs!r}:
    cfg = get_smoke_config(arch)
    for shape in ("train_4k", "decode_32k"):
        # shrink the assigned shape to smoke scale but keep its kind
        from repro.configs.base import ShapeConfig, SHAPES_BY_NAME
        base = SHAPES_BY_NAME[shape]
        small = ShapeConfig(base.name, base.kind, 64, 8)
        import repro.launch.cells as cells
        import repro.configs as C
        orig = C.SHAPES_BY_NAME[shape]
        C.SHAPES_BY_NAME[shape] = small
        try:
            cell = build_cell(arch, shape, mesh, cfg_override=cfg)
            compiled = lower_cell(cell, mesh).compile()
            txt = compiled.as_text()
            out[f"{{arch}}/{{shape}}"] = {{
                "ok": True,
                "dot_flops": dot_flops(txt),
                "collectives": dict(collective_stats(txt).counts),
            }}
        except Exception as e:
            out[f"{{arch}}/{{shape}}"] = {{"ok": False, "error": f"{{type(e).__name__}}: {{e}}"}}
        finally:
            C.SHAPES_BY_NAME[shape] = orig
print("RESULT:" + json.dumps(out))
"""


@pytest.mark.parametrize("archs", [["qwen3-1.7b", "rwkv6-7b", "qwen3-moe-30b-a3b"]])
def test_small_mesh_dryrun(archs):
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = _SCRIPT.format(src=os.path.abspath(src), archs=archs)
    proc = subprocess.run([sys.executable, "-c", script], capture_output=True,
                          text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-2000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")][-1]
    results = json.loads(line[len("RESULT:"):])
    for key, r in results.items():
        assert r["ok"], f"{key} failed: {r.get('error')}"
        assert r["dot_flops"] > 0
        # sharded models must communicate
        assert sum(r["collectives"].values()) > 0, f"{key}: no collectives?"
