"""Fault tolerance: engine snapshot/restore mid-trace; in-flight relQueries
replay their prefill (idempotent) and the service completes."""
import copy

import pytest

from repro.core.latency_model import a100_opt13b
from repro.core.policies import SCHEDULERS
from repro.core.priority import BatchLimits
from repro.core.relquery import RequestState
from repro.data.trace import quick_trace
from repro.distributed.fault_tolerance import restore_scheduler, snapshot_scheduler
from repro.engine.engine import ServingEngine
from repro.engine.prefix_cache import PrefixCache
from repro.engine.simulator import SimulatedExecutor, sim_output_len


def test_engine_crash_restart_completes():
    lm = a100_opt13b()
    trace = quick_trace("beer", num_relqueries=10, rate=2.0, seed=4, max_requests=20)

    # phase 1: run ~40 iterations then "crash"
    pc = PrefixCache(block_size=16)
    sched = SCHEDULERS["relserve"](limits=BatchLimits(), latency_model=lm,
                                   prefix_cache=pc)
    ex = SimulatedExecutor(lm, prefix_cache=pc)
    now = 0.0
    pending = sorted(trace, key=lambda r: r.arrival_time)
    idx = 0
    for _ in range(40):
        while idx < len(pending) and pending[idx].arrival_time <= now:
            sched.add_relquery(pending[idx], now)
            idx += 1
        batch = sched.schedule(now)
        if batch is None:
            if idx < len(pending):
                now = pending[idx].arrival_time
                continue
            break
        dur, result = ex.execute(batch, now)
        sched.complete_batch(batch, result, now, now + dur)
        now += dur
    snap = snapshot_scheduler(sched)
    n_running = len(sched.running_requests())

    # phase 2: fresh scheduler (KV lost), restore, finish remaining arrivals
    pc2 = PrefixCache(block_size=16)
    sched2 = SCHEDULERS["relserve"](limits=BatchLimits(), latency_model=lm,
                                    prefix_cache=pc2)
    restore_scheduler(sched2, snap)
    # RUNNING requests were demoted to WAITING for prefill replay
    assert not sched2.running_requests()
    ex2 = SimulatedExecutor(lm, prefix_cache=pc2)
    eng = ServingEngine(sched2, ex2)
    remaining = pending[idx:]
    for rq in remaining:
        rq2 = rq  # same objects, not yet submitted anywhere
    report = eng.run_trace(remaining)
    # every relQuery in the union finished
    all_rqs = list(sched2.relqueries.values())
    assert len(all_rqs) == len(trace)
    for rq in all_rqs:
        assert rq.is_finished(), f"{rq.rel_id} unfinished after restore"
        for r in rq.requests:
            target = min(getattr(r, "sim_output_len", None) or r.max_output_tokens,
                         r.max_output_tokens)
            assert len(r.output_tokens) == target
    assert sched2.tokens_in_use == 0


def test_snapshot_preserves_latency_bookkeeping():
    lm = a100_opt13b()
    trace = quick_trace("beer", num_relqueries=3, rate=5.0, seed=5, max_requests=5)
    pc = PrefixCache()
    sched = SCHEDULERS["relserve"](limits=BatchLimits(), latency_model=lm,
                                   prefix_cache=pc)
    eng = ServingEngine(sched, SimulatedExecutor(lm, prefix_cache=pc))
    eng.run_trace(copy.deepcopy(trace))
    snap = snapshot_scheduler(sched)
    sched2 = SCHEDULERS["relserve"](limits=BatchLimits(), latency_model=lm)
    restore_scheduler(sched2, snap)
    for rel_id, rq in sched.relqueries.items():
        rq2 = sched2.relqueries[rel_id]
        assert rq2.finish_time == rq.finish_time
        assert rq2.first_prefill_start == rq.first_prefill_start
        assert rq2.latency() == rq.latency()
