"""Fault tolerance: engine snapshot/restore mid-trace; in-flight relQueries
replay their prefill (idempotent) and the service completes. The round-trip
property suite stresses every request state the scheduler can produce
(preempted, mid-chunk prefill, swapped-out, cancelled) and pins that restored
replicas regenerate bit-identical token streams — both lossless
(``kv_lost=False``) and crash-semantics (``kv_lost=True``) restores — plus
the in-process Cluster failover, drain, and autoscaling built on top."""
import copy
import json
import math
from collections import defaultdict

import pytest

from repro.core.latency_model import a100_opt13b
from repro.core.policies import SCHEDULERS
from repro.core.priority import BatchLimits
from repro.core.relquery import RelQuery, Request, RequestState
from repro.data.trace import quick_trace
from repro.distributed.fault_tolerance import (restore_scheduler,
                                               snapshot_scheduler)
from repro.engine.engine import ServingEngine
from repro.engine.prefix_cache import PrefixCache
from repro.engine.simulator import SimulatedExecutor, sim_output_len


def test_engine_crash_restart_completes():
    lm = a100_opt13b()
    trace = quick_trace("beer", num_relqueries=10, rate=2.0, seed=4, max_requests=20)

    # phase 1: run ~40 iterations then "crash"
    pc = PrefixCache(block_size=16)
    sched = SCHEDULERS["relserve"](limits=BatchLimits(), latency_model=lm,
                                   prefix_cache=pc)
    ex = SimulatedExecutor(lm, prefix_cache=pc)
    now = 0.0
    pending = sorted(trace, key=lambda r: r.arrival_time)
    idx = 0
    for _ in range(40):
        while idx < len(pending) and pending[idx].arrival_time <= now:
            sched.add_relquery(pending[idx], now)
            idx += 1
        batch = sched.schedule(now)
        if batch is None:
            if idx < len(pending):
                now = pending[idx].arrival_time
                continue
            break
        dur, result = ex.execute(batch, now)
        sched.complete_batch(batch, result, now, now + dur)
        now += dur
    snap = snapshot_scheduler(sched)
    n_running = len(sched.running_requests())

    # phase 2: fresh scheduler (KV lost), restore, finish remaining arrivals
    pc2 = PrefixCache(block_size=16)
    sched2 = SCHEDULERS["relserve"](limits=BatchLimits(), latency_model=lm,
                                    prefix_cache=pc2)
    restore_scheduler(sched2, snap)
    # RUNNING requests were demoted to WAITING for prefill replay
    assert not sched2.running_requests()
    ex2 = SimulatedExecutor(lm, prefix_cache=pc2)
    eng = ServingEngine(sched2, ex2)
    remaining = pending[idx:]
    for rq in remaining:
        rq2 = rq  # same objects, not yet submitted anywhere
    report = eng.run_trace(remaining)
    # every relQuery in the union finished
    all_rqs = list(sched2.relqueries.values())
    assert len(all_rqs) == len(trace)
    for rq in all_rqs:
        assert rq.is_finished(), f"{rq.rel_id} unfinished after restore"
        for r in rq.requests:
            target = min(getattr(r, "sim_output_len", None) or r.max_output_tokens,
                         r.max_output_tokens)
            assert len(r.output_tokens) == target
    assert sched2.tokens_in_use == 0


def test_snapshot_preserves_latency_bookkeeping():
    lm = a100_opt13b()
    trace = quick_trace("beer", num_relqueries=3, rate=5.0, seed=5, max_requests=5)
    pc = PrefixCache()
    sched = SCHEDULERS["relserve"](limits=BatchLimits(), latency_model=lm,
                                   prefix_cache=pc)
    eng = ServingEngine(sched, SimulatedExecutor(lm, prefix_cache=pc))
    eng.run_trace(copy.deepcopy(trace))
    snap = snapshot_scheduler(sched)
    sched2 = SCHEDULERS["relserve"](limits=BatchLimits(), latency_model=lm)
    restore_scheduler(sched2, snap)
    for rel_id, rq in sched.relqueries.items():
        rq2 = sched2.relqueries[rel_id]
        assert rq2.finish_time == rq.finish_time
        assert rq2.first_prefill_start == rq.first_prefill_start
        assert rq2.latency() == rq.latency()


# ==========================================================================
# round-trip property suite: snapshot under pressure, restore, continue
# ==========================================================================
_BASE_TRACE = None


def _base_trace():
    """One trace shared (via deepcopy) by every run in this suite — req_ids
    are assigned from a process-global counter, so reference and restored
    runs must copy the *same* trace objects to stay comparable."""
    global _BASE_TRACE
    if _BASE_TRACE is None:
        _BASE_TRACE = quick_trace("beer", num_relqueries=8, rate=4.0, seed=3,
                                  max_requests=10)
    return copy.deepcopy(_BASE_TRACE)


def _stress_scheduler(name: str, trace, pc=None):
    """A scheduler under every kind of KV pressure at once: tight cap +
    optimistic admission, a small prefill chunk (mid-chunk WAITING requests),
    and an undersized host tier — so reclaim sometimes swaps (SWAPPED
    residents) and sometimes recomputes (PREEMPTED restarts)."""
    lm = a100_opt13b()
    max_fp = max(r.num_prompt_tokens + r.max_output_tokens
                 for rq in trace for r in rq.requests)
    cap = int(max_fp * 1.3)
    limits = BatchLimits(cap=cap, max_num_batched_tokens=96)
    pc = pc or PrefixCache(block_size=16)
    sched = SCHEDULERS[name](limits=limits, latency_model=lm, prefix_cache=pc,
                             kv_admission="optimistic", kv_tiering=True,
                             host_kv_cap=int(0.5 * cap))
    return sched, SimulatedExecutor(lm, prefix_cache=pc), pc


def _drive(sched, ex, pending, iterations, now=0.0, idx=0, cancel_at=None):
    """Manual engine loop for ``iterations`` batches; returns (now, idx)."""
    for it in range(iterations):
        while idx < len(pending) and pending[idx].arrival_time <= now:
            sched.add_relquery(pending[idx], now)
            idx += 1
        if cancel_at is not None and it == cancel_at and sched.relqueries:
            live = [rq for rq in sched.relqueries.values()
                    if rq.finish_time is None and rq.cancel_time is None]
            if live:
                sched.cancel_relquery(live[0].rel_id, now)
        batch = sched.schedule(now)
        if batch is None:
            if idx < len(pending):
                now = pending[idx].arrival_time
                continue
            break
        dur, result = ex.execute(batch, now)
        sched.complete_batch(batch, result, now, now + dur)
        now += dur
    return now, idx


_REFERENCE = {}


def _reference_streams(name: str):
    if name not in _REFERENCE:
        trace = _base_trace()
        sched, ex, pc = _stress_scheduler(name, trace)
        ServingEngine(sched, ex, debug_invariants=True).run_trace(trace)
        _REFERENCE[name] = {r.req_id: tuple(r.output_tokens)
                            for rq in trace for r in rq.requests}
    return _REFERENCE[name]


@pytest.mark.parametrize("name", ["relserve", "vllm"])
@pytest.mark.parametrize("kv_lost", [True, False])
@pytest.mark.parametrize("stop_after", [30, 400])
def test_roundtrip_under_pressure_continues_bitidentical(name, kv_lost,
                                                         stop_after):
    """Snapshot a scheduler mid-flight under cap pressure, restore into a
    fresh one (with and without the KV surviving), finish the workload, and
    require the final token streams to match a never-interrupted run."""
    reference = _reference_streams(name)

    trace = _base_trace()
    sched, ex, _ = _stress_scheduler(name, trace)
    pending = sorted(trace, key=lambda r: r.arrival_time)
    now, idx = _drive(sched, ex, pending, stop_after)
    sched.audit_ledgers(repair=False)        # ledgers conserved mid-flight
    snap = snapshot_scheduler(sched)
    snap = json.loads(json.dumps(snap))      # must survive a JSON round-trip

    sched2, ex2, _ = _stress_scheduler(name, trace)
    info = restore_scheduler(sched2, snap, kv_lost=kv_lost)
    assert set(info["delivered"]) == {r.req_id
                                      for rq in trace for r in rq.requests
                                      if rq.rel_id in sched.relqueries}
    sched2.audit_ledgers(repair=False)       # audited rebuild is consistent
    if kv_lost:
        # crash semantics: nothing resident, generated tokens preserved
        assert not sched2.running_requests()
        assert sched2.tokens_in_use == 0
        assert sched2.host_tokens_in_use == 0
        assert sched2.partial_prefill_tokens == 0
        for rq in sched2.relqueries.values():
            for r in rq.requests:
                assert r.state not in (RequestState.RUNNING,
                                       RequestState.SWAPPED)
                if r.state is RequestState.PREEMPTED:
                    assert r.preserved_output_tokens == len(r.output_tokens)
    else:
        # lossless: queues, states, mid-chunk progress, ledgers all exact
        assert [r.req_id for r in sched2._running] == \
            [r.req_id for r in sched._running]
        assert [r.req_id for r in sched2._swapped] == \
            [r.req_id for r in sched._swapped]
        assert {k: [r.req_id for r in v]
                for k, v in sched2._waiting_of.items()} == \
            {k: [r.req_id for r in v] for k, v in sched._waiting_of.items()}
        assert sched2._footprint_of == sched._footprint_of
        assert sched2.tokens_in_use == sched.tokens_in_use
        assert sched2.host_tokens_in_use == sched.host_tokens_in_use
        assert sched2.partial_prefill_tokens == sched.partial_prefill_tokens
        assert sched2.committed_tokens == sched.committed_tokens
        assert sched2.preemptions == sched.preemptions
        assert sched2.swap_outs == sched.swap_outs
        for rel_id, rq in sched.relqueries.items():
            for r, r2 in zip(rq.requests, sched2.relqueries[rel_id].requests):
                assert (r2.state, r2.prefilled_tokens, r2.output_tokens) == \
                    (r.state, r.prefilled_tokens, r.output_tokens)

    eng = ServingEngine(sched2, ex2, debug_invariants=True)
    eng.run_trace(pending[idx:])
    streams = {r.req_id: tuple(r.output_tokens)
               for rq in sched2.relqueries.values() for r in rq.requests}
    assert streams == reference, "restored run diverged from reference"
    assert sched2.tokens_in_use == 0 and sched2.host_tokens_in_use == 0


@pytest.mark.parametrize("name,expect", [
    # relserve chunks its prefill, so mid-chunk WAITING must appear too;
    # vllm prefills whole prompts and never leaves a partial chunk
    ("relserve", {RequestState.PREEMPTED, RequestState.SWAPPED, "mid_chunk"}),
    ("vllm", {RequestState.PREEMPTED, RequestState.SWAPPED}),
])
def test_stress_snapshot_is_nonvacuous(name, expect):
    """The pressure config must actually produce the states the round-trip
    suite claims to cover — otherwise those tests silently test nothing."""
    trace = _base_trace()
    sched, ex, _ = _stress_scheduler(name, trace)
    pending = sorted(trace, key=lambda r: r.arrival_time)
    seen = set()
    now, idx = 0.0, 0
    for _ in range(500):
        now, idx = _drive(sched, ex, pending, 1, now=now, idx=idx)
        for rq in sched.relqueries.values():
            for r in rq.requests:
                seen.add(r.state)
                if r.state is RequestState.WAITING and r.prefilled_tokens:
                    seen.add("mid_chunk")
    assert RequestState.RUNNING in seen
    missing = expect - seen
    assert not missing, f"stress config never produced {missing}"


def test_cancelled_relquery_roundtrip():
    trace = _base_trace()
    sched, ex, _ = _stress_scheduler("relserve", trace)
    pending = sorted(trace, key=lambda r: r.arrival_time)
    now, idx = _drive(sched, ex, pending, 20, cancel_at=10)
    cancelled = [rq for rq in sched.relqueries.values()
                 if rq.cancel_time is not None]
    assert cancelled, "driver never cancelled a relQuery"
    snap = json.loads(json.dumps(snapshot_scheduler(sched)))
    sched2, ex2, _ = _stress_scheduler("relserve", trace)
    restore_scheduler(sched2, snap)
    for rq in cancelled:
        rq2 = sched2.relqueries[rq.rel_id]
        assert rq2.cancel_time == rq.cancel_time
        assert all(r.state is RequestState.CANCELLED for r in rq2.requests
                   if r.state is not RequestState.FINISHED) or \
            all(r2.state == r.state for r, r2 in zip(rq.requests,
                                                     rq2.requests))
        assert rq2 not in sched2.finished_relqueries
    # cancelled work stays dead: finishing the trace never revives it
    ServingEngine(sched2, ex2).run_trace(pending[idx:])
    for rq in cancelled:
        assert sched2.relqueries[rq.rel_id].cancel_time is not None


def test_predictor_and_dpu_state_roundtrip():
    trace = _base_trace()
    sched, ex, _ = _stress_scheduler("relserve", trace)
    pending = sorted(trace, key=lambda r: r.arrival_time)
    _drive(sched, ex, pending, 30)
    snap = json.loads(json.dumps(snapshot_scheduler(sched)))
    sched2, _, _ = _stress_scheduler("relserve", trace)
    restore_scheduler(sched2, snap)
    if sched.predictor is not None:
        assert sched2.predictor._obs == sched.predictor._obs
        assert sched2.predictor.observations == sched.predictor.observations
        assert sched2.predictor.quantile == sched.predictor.quantile
    assert sched2.dpu._rng.getstate() == sched.dpu._rng.getstate()
    assert sched2.dpu._iteration == sched.dpu._iteration
    assert sched2.dpu._last_sampled == sched.dpu._last_sampled
    assert sched2.dpu.stats == sched.dpu.stats


def test_restore_refuses_bad_version_and_nonempty_scheduler():
    trace = quick_trace("beer", num_relqueries=2, rate=4.0, seed=1,
                        max_requests=4)
    sched, ex, _ = _stress_scheduler("relserve", trace)
    sched.add_relquery(trace[0], 0.0)
    snap = snapshot_scheduler(sched)
    with pytest.raises(ValueError, match="empty scheduler"):
        restore_scheduler(sched, snap)
    bad = dict(snap, version=1)
    sched2, _, _ = _stress_scheduler("relserve", trace)
    with pytest.raises(ValueError, match="version"):
        restore_scheduler(sched2, bad)


def test_audit_ledgers_detects_drift():
    trace = _base_trace()
    sched, ex, _ = _stress_scheduler("relserve", trace)
    pending = sorted(trace, key=lambda r: r.arrival_time)
    _drive(sched, ex, pending, 15)
    sched.audit_ledgers(repair=False)     # consistent mid-flight
    sched.tokens_in_use += 7              # inject drift
    with pytest.raises(AssertionError, match="ledger drift"):
        sched.audit_ledgers(repair=False)
    sched.audit_ledgers(repair=True)      # audited rebuild heals it
    sched.audit_ledgers(repair=False)


# ==========================================================================
# cluster failover / drain / autoscaling
# ==========================================================================
def _replay_cluster(trace, scheduler, engine_loop, *, crash_frac=None,
                    snapshot_every=0):
    """Frontend replay over a 2-replica cluster; optionally crash the
    busiest replica at ``crash_frac`` x the trace's end-to-end time.
    Returns (streams, delivered, crash_events, report)."""
    from repro.serving import Frontend, build_simulated_cluster
    cluster = build_simulated_cluster(2, scheduler=scheduler, seed=7,
                                      engine_loop=engine_loop,
                                      snapshot_every=snapshot_every,
                                      debug_invariants=True)
    ran = copy.deepcopy(trace)
    fe = Frontend(cluster)
    delivered = defaultdict(list)
    pending = sorted(ran, key=lambda r: r.arrival_time)
    idx, crash_at = 0, None
    if crash_frac is not None:
        crash_at = crash_frac * max(r.arrival_time for r in pending)
    crash_done = crash_at is None
    while True:
        nxt = fe.next_step_time()
        ns = math.inf if nxt is None else nxt
        na = pending[idx].arrival_time if idx < len(pending) else math.inf
        if not crash_done and min(ns, na) >= crash_at:
            victim = max(cluster.admitting_replicas(),
                         key=lambda i: (cluster.cores[i].load(), -i))
            cluster.crash_replica(victim, crash_at)
            crash_done = True
            continue
        if math.isinf(ns) and math.isinf(na):
            break
        if na <= ns:
            fe.submit(pending[idx], now=na,
                      on_token=lambda rid, tok: delivered[rid].append(tok))
            idx += 1
            continue
        fe.step()
    rep = cluster.report()
    streams = {r.req_id: tuple(r.output_tokens)
               for rq in ran for r in rq.requests}
    return streams, {k: tuple(v) for k, v in delivered.items()}, \
        list(rep.crash_events), rep


@pytest.mark.parametrize("scheduler", ["relserve", "vllm"])
@pytest.mark.parametrize("engine_loop", ["serial", "pipelined"])
def test_cluster_crash_failover_bitidentical(scheduler, engine_loop):
    """Kill one of two replicas mid-flight: the failed-over run must finish
    with byte-identical final streams and must never re-deliver a token the
    on_token callback already emitted."""
    trace = quick_trace("beer", num_relqueries=10, rate=3.0, seed=5,
                        max_requests=12)
    s_free, d_free, _, rep_free = _replay_cluster(trace, scheduler,
                                                  engine_loop)
    s_crash, d_crash, events, rep = _replay_cluster(
        trace, scheduler, engine_loop, crash_frac=1.2, snapshot_every=4)
    assert len(events) == 1 and events[0]["victims"] > 0, \
        "crash point missed the in-flight window — test is vacuous"
    assert events[0]["from_snapshot"] > 0
    assert s_crash == s_free, "post-crash streams diverged"
    for streams, dlv in ((s_free, d_free), (s_crash, d_crash)):
        assert dlv == {k: v for k, v in streams.items() if v}, \
            "a client saw duplicated or dropped tokens"
    assert len(rep.merged.latencies) == len(trace)
    assert rep.replica_states.count("dead") == 1


def test_cluster_drain_migrates_and_retires():
    from repro.serving import Frontend, build_simulated_cluster
    trace = quick_trace("beer", num_relqueries=16, rate=6.0, seed=5,
                        max_requests=10)
    cluster = build_simulated_cluster(3, scheduler="relserve", seed=7,
                                      debug_invariants=True)
    fe = Frontend(cluster)
    pending = sorted(copy.deepcopy(trace), key=lambda r: r.arrival_time)
    for rq in pending[:12]:
        fe.submit(rq, now=rq.arrival_time)
    for _ in range(8):
        fe.step()
    ev = cluster.drain_replica(1, fe.clock)
    assert cluster.replica_state[1] in ("draining", "dead")
    with pytest.raises(ValueError):
        cluster.drain_replica(1, fe.clock)     # already draining/dead
    for rq in pending[12:]:
        fe.submit(rq, now=max(rq.arrival_time, fe.clock))
    fe.drain()
    rep = cluster.report()
    assert len(rep.merged.latencies) == len(trace)
    assert rep.replica_states[1] == "dead"
    assert ev["action"] == "drain"
    # a dead replica never admits again
    assert 1 not in cluster.admitting_replicas()


def test_autoscaler_scales_up_and_finishes():
    from repro.serving import (AutoscaleConfig, Autoscaler, Frontend,
                               build_simulated_cluster)
    trace = quick_trace("beer", num_relqueries=20, rate=8.0, seed=5,
                        max_requests=10)
    cluster = build_simulated_cluster(1, scheduler="relserve", seed=7,
                                      debug_invariants=True)
    auto = Autoscaler(cluster, AutoscaleConfig(
        min_replicas=1, max_replicas=3, scale_up_queue=4.0,
        scale_down_queue=0.5, eval_interval_s=0.25, cooldown_s=1.0))
    cluster.attach_autoscaler(auto)
    Frontend(cluster).replay(copy.deepcopy(trace))
    rep = cluster.report()
    ups = [d for d in auto.decisions if d["action"] == "scale_up"]
    assert len(ups) >= 1, "burst never triggered a scale-up"
    assert len(cluster.cores) > 1
    assert len(rep.merged.latencies) == len(trace)
    for d in auto.decisions:
        assert d["signals"]["admitting"] >= 1
    # config validation rejects nonsense
    with pytest.raises(ValueError):
        AutoscaleConfig(min_replicas=3, max_replicas=1).validate()


def test_router_template_home_stats_and_evict():
    from repro.serving import Router

    def mk_rq(rel_id, template):
        r = Request(rel_id=rel_id, tokens=(1, 2, 3), max_output_tokens=4,
                    req_id=f"{rel_id}/0", eos_token=None)
        return RelQuery(rel_id=rel_id, requests=[r], arrival_time=0.0,
                        max_output_tokens=4, template_id=template)

    router = Router(2, policy="prefix_affinity")
    for i in range(4):
        router.route(mk_rq(f"q{i}", f"tmpl-{i % 2}"), loads=[0, 0])
    assert router.stats["template_homes"] == 2          # live map size
    assert router.stats["template_homes_created"] == 2  # cumulative
    # re-routing the same templates must not inflate either stat (the
    # pre-fix code bumped the live counter on every first-sight branch)
    for i in range(4):
        router.route(mk_rq(f"r{i}", f"tmpl-{i % 2}"), loads=[0, 0])
    assert router.stats["template_homes"] == 2
    assert router.stats["template_homes_created"] == 2
    # both templates homed on replica 0 (least-loaded, index tie-break);
    # killing it drops the live homes but not the cumulative count
    assert set(router._template_home.values()) == {0}
    assert router.evict_replica(0) == 2
    assert router.stats["template_homes"] == 0
    assert router.stats["template_homes_created"] == 2
    # next sight re-homes on a surviving replica and counts a fresh creation
    router.grow(3)
    router.route(mk_rq("s0", "tmpl-0"), loads=[0, 0, 0], eligible=[1, 2])
    assert router._template_home and \
        all(h in (1, 2) for h in router._template_home.values())
    assert router.stats["template_homes"] == 1
    assert router.stats["template_homes_created"] == 3
    with pytest.raises(ValueError):
        router.grow(1)      # shrinking via grow() is a bug


def test_save_checkpoint_stages_inside_target(tmp_path, monkeypatch):
    """Regression: the staging dir must be created *inside* the target path
    so the atomic publish is a same-filesystem rename (mkdtemp's default
    falls back to the system tmpdir and os.replace raises EXDEV) — which
    requires the target path to exist before mkdtemp runs."""
    import tempfile

    import numpy as np

    from repro.distributed.fault_tolerance import (latest_step,
                                                   load_checkpoint,
                                                   save_checkpoint)
    target = tmp_path / "nested" / "ckpts"     # does not exist yet
    staged_dirs = []
    real_mkdtemp = tempfile.mkdtemp

    def spying_mkdtemp(*a, **kw):
        staged_dirs.append(kw.get("dir"))
        return real_mkdtemp(*a, **kw)

    monkeypatch.setattr(tempfile, "mkdtemp", spying_mkdtemp)
    trees = {"params": {"w": np.arange(4.0).reshape(2, 2)}}
    final = save_checkpoint(str(target), 3, trees)
    assert staged_dirs == [str(target)], \
        "staging dir must live under the checkpoint path"
    assert latest_step(str(target)) == 3
    step, loaded = load_checkpoint(str(target), template_trees=trees)
    assert step == 3
    np.testing.assert_array_equal(loaded["params"]["w"], trees["params"]["w"])
    # no stray staging dirs survive the publish
    assert [d for d in target.iterdir() if d.name.startswith(".ckpt_tmp_")] \
        == []
