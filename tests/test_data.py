"""Workload construction: Table 4 statistics, Poisson arrivals, template
rendering, tokenizer determinism."""
import numpy as np
import pytest

from repro.data.datasets import ALL_DATASETS, DATASET_STATS, make_dataset
from repro.data.templates import OUTPUT_LIMITS
from repro.data.trace import TraceConfig, build_trace
from repro.engine.tokenizer import HashTokenizer


@pytest.mark.parametrize("name", ALL_DATASETS)
def test_dataset_token_stats_match_table4(name):
    ds = make_dataset(name, num_rows=800, seed=0)
    tok = HashTokenizer()
    lens = []
    for tpl in ds.templates:
        for row in ds.table.rows[:150]:
            lens.append(len(tok.encode(tpl.render(row))))
    target, _ = DATASET_STATS[name]
    avg = float(np.mean(lens))
    assert target * 0.6 < avg < target * 1.4, f"{name}: avg {avg} vs Table4 {target}"


def test_trace_poisson_and_sizes():
    ds = make_dataset("amazon", num_rows=2000, seed=1)
    cfg = TraceConfig(num_relqueries=200, rate=2.0, seed=3)
    trace = build_trace(ds, cfg)
    arr = [rq.arrival_time for rq in trace]
    assert all(b > a for a, b in zip(arr, arr[1:]))
    gaps = np.diff([0.0] + arr)
    assert abs(np.mean(gaps) - 0.5) < 0.1          # 1/rate
    sizes = [rq.num_requests for rq in trace]
    assert min(sizes) >= 1 and max(sizes) <= 100
    for rq in trace:
        assert rq.max_output_tokens in OUTPUT_LIMITS.values()
        for r in rq.requests:
            assert 1 <= r.sim_output_len <= rq.max_output_tokens


def test_shared_prefix_structure():
    """Requests of one relQuery share the template prefix; rows referencing the
    same catalog item share more — the structure Fig. 4 relies on."""
    ds = make_dataset("rotten", num_rows=3000, seed=0)
    tok = HashTokenizer()
    tpl = ds.templates[0]
    enc = [tok.encode(tpl.render(row)) for row in ds.table.rows[:400]]
    # template prefix shared by all
    first = enc[0]
    shared = 0
    for i in range(min(len(e) for e in enc[:50])):
        if all(e[i] == first[i] for e in enc[:50]):
            shared += 1
        else:
            break
    assert shared >= 5, "template prefix must be shared"
    # some pair shares far beyond the template (same catalog item)
    best = 0
    for e in enc[1:]:
        n = 0
        for a, b in zip(first, e):
            if a != b:
                break
            n += 1
        best = max(best, n)
    assert best > shared + 8, "catalog-value overlap missing"


def test_tokenizer_determinism():
    tok = HashTokenizer(vocab_size=1000)
    assert tok.encode("hello world") == tok.encode("hello world")
    assert tok.encode("hello world") != tok.encode("world hello")
    assert all(0 <= t < 1000 for t in tok.encode("a b c d"))
