"""Open-loop Frontend: submit/stream/cancel lifecycle, mid-flight snapshots,
and the equivalence pin — the run_trace compatibility shims must reproduce the
pre-frontend closed-loop results exactly."""
import copy
import math

import pytest

from repro.core.latency_model import a100_opt13b
from repro.core.policies import SCHEDULERS
from repro.core.priority import BatchLimits, DPUConfig
from repro.core.relquery import RequestState, make_relquery
from repro.data.datasets import make_dataset
from repro.data.trace import TraceConfig, build_trace, quick_trace
from repro.engine.engine import EngineCore, ServingEngine
from repro.engine.prefix_cache import PrefixCache
from repro.engine.simulator import SimulatedExecutor
from repro.serving import (Frontend, RelQueryCancelledError, RelQueryStatus,
                           build_simulated_cluster)


def _engine(scheduler="relserve", seed=0, limits=None, prefix_sharing=False):
    lm = a100_opt13b()
    pc = PrefixCache(block_size=16)
    kw = dict(limits=limits or BatchLimits(), latency_model=lm, prefix_cache=pc,
              prefix_sharing=prefix_sharing)
    if scheduler.startswith("relserve"):
        kw["dpu_config"] = DPUConfig(exact_probe=prefix_sharing)
    return ServingEngine(SCHEDULERS[scheduler](**kw),
                         SimulatedExecutor(lm, prefix_cache=pc, seed=seed))


def _default_trace(num_relqueries=100, max_requests=100, rate=1.0, seed=0):
    """The default --simulate trace (launch/serve.py defaults)."""
    ds = make_dataset("rotten", num_rows=10_000, seed=seed)
    return build_trace(ds, TraceConfig(num_relqueries=num_relqueries,
                                       rate=rate, seed=seed,
                                       max_requests=max_requests))


# ----------------------------------------------------------- equivalence pin
def _pinned_closed_loop(engine: ServingEngine, trace):
    """The pre-frontend ServingEngine.run_trace loop, verbatim — the shim
    must reproduce this trajectory batch for batch."""
    core = engine.core
    pending = sorted(trace, key=lambda r: r.arrival_time)
    now, idx = 0.0, 0
    while idx < len(pending) or core.has_work():
        while idx < len(pending) and pending[idx].arrival_time <= now:
            core.admit(pending[idx], now)
            idx += 1
        if not core.has_work():
            now = max(now, pending[idx].arrival_time)
            continue
        event = core.tick(now)
        now = event.end
    return core.report(now)


@pytest.mark.parametrize("sched_name", ["relserve", "vllm"])
def test_shim_reproduces_pre_frontend_run_trace(sched_name):
    """Acceptance pin: the default --simulate trace through the new
    Frontend-based shim gives the exact per-relQuery latencies of the pre-PR
    closed loop, for RelServe and a baseline."""
    trace = _default_trace()
    pinned = _pinned_closed_loop(_engine(sched_name), copy.deepcopy(trace))
    shimmed = _engine(sched_name).run_trace(copy.deepcopy(trace))
    assert shimmed.latencies == pinned.latencies
    assert shimmed.waiting == pinned.waiting
    assert shimmed.core == pinned.core
    assert shimmed.tail == pinned.tail
    assert shimmed.end_to_end == pinned.end_to_end
    assert len(shimmed.events) == len(pinned.events)


def test_cluster_shim_reproduces_pre_frontend_loop():
    """Same pin for the 2-replica cluster: the pre-PR Cluster.run_trace
    discrete-event loop, re-implemented here, vs the Frontend-based shim."""
    trace = quick_trace("rotten", num_relqueries=30, rate=1.5, seed=11,
                        max_requests=40)

    def pinned(trace):
        cluster = build_simulated_cluster(2)
        cores = cluster.cores
        pending = sorted(trace, key=lambda r: r.arrival_time)
        clocks = [0.0] * len(cores)
        idx = 0
        while True:
            busy = [i for i, c in enumerate(cores) if c.has_work()]
            next_step = min((clocks[i] for i in busy), default=math.inf)
            next_arrival = (pending[idx].arrival_time if idx < len(pending)
                            else math.inf)
            if math.isinf(next_step) and math.isinf(next_arrival):
                break
            if next_arrival <= next_step:
                rq = pending[idx]
                idx += 1
                loads = [c.load() + (1 if clocks[i] > rq.arrival_time else 0)
                         for i, c in enumerate(cores)]
                replica = cluster.router.route(rq, loads)
                core = cores[replica]
                if not core.has_work():
                    clocks[replica] = max(clocks[replica], rq.arrival_time)
                core.admit(rq, rq.arrival_time)
                continue
            i = min(busy, key=lambda j: clocks[j])
            event = cores[i].tick(clocks[i])
            if event is not None:
                clocks[i] = event.end
        from repro.engine.engine import merge_reports
        return merge_reports([c.report(clocks[i]) for i, c in enumerate(cores)])

    pin = pinned(copy.deepcopy(trace))
    shim = build_simulated_cluster(2).run_trace(copy.deepcopy(trace)).merged
    assert shim.latencies == pin.latencies
    assert shim.end_to_end == pin.end_to_end


def _shared_template_trace(num_relqueries=24, rate=4.0, seed=7,
                           max_requests=16):
    """A trace where relQueries share templates — the prefix-sharing regime."""
    ds = make_dataset("rotten", num_rows=2000, seed=seed)
    return build_trace(ds, TraceConfig(
        num_relqueries=num_relqueries, rate=rate, seed=seed,
        max_requests=max_requests, num_templates=2))


@pytest.mark.parametrize("sched_name", ["relserve", "vllm"])
def test_sharing_engine_shim_reproduces_open_loop(sched_name):
    """Equivalence pin with prefix sharing *on*: the Frontend-based replay
    shim still reproduces the pinned closed loop exactly — sharing changes
    scheduling, not the open-loop == closed-loop contract."""
    trace = _shared_template_trace()
    pinned = _pinned_closed_loop(_engine(sched_name, prefix_sharing=True),
                                 copy.deepcopy(trace))
    shimmed = _engine(sched_name, prefix_sharing=True).run_trace(
        copy.deepcopy(trace))
    assert shimmed.latencies == pinned.latencies
    assert shimmed.end_to_end == pinned.end_to_end
    assert shimmed.shared_kv_tokens == pinned.shared_kv_tokens
    assert shimmed.shared_kv_tokens > 0   # sharing actually engaged


def test_prefix_affinity_cluster_result_equals_single_replica():
    """Result pin for the prefix_affinity router: the same shared-template
    trace through 1 replica and through a 2-replica prefix_affinity cluster
    produces identical per-request token streams and the same finished set —
    routing and sharing may only move timing."""
    trace = _shared_template_trace()

    def run(num_replicas):
        t = copy.deepcopy(trace)
        cluster = build_simulated_cluster(
            num_replicas, router_policy="prefix_affinity",
            prefix_sharing=True)
        result = cluster.run_trace(t)
        streams = {r.req_id: list(r.output_tokens)
                   for rq in t for r in rq.requests}
        return result, streams

    single, streams_1 = run(1)
    double, streams_2 = run(2)
    assert streams_1 == streams_2
    assert set(single.merged.latencies) == set(double.merged.latencies)
    assert len(double.merged.latencies) == len(trace)
    # every relQuery of a template landed on that template's home replica
    # unless spilled; spilled or not, requests of one relQuery stay together
    assert set(double.assignments) == {rq.rel_id for rq in trace}
    assert double.router_stats["template_homes"] >= 1


# ----------------------------------------------------------- streaming
def test_on_token_streams_in_generation_order():
    trace = quick_trace("rotten", num_relqueries=3, rate=4.0, seed=2,
                        max_requests=5)
    fe = Frontend(_engine())
    streamed = {}

    def on_token(req_id, tok):
        streamed.setdefault(req_id, []).append(tok)

    handles = [fe.submit(rq, now=rq.arrival_time, on_token=on_token)
               for rq in sorted(trace, key=lambda r: r.arrival_time)]
    fe.drain()
    for h in handles:
        assert h.status() is RelQueryStatus.FINISHED
        for r in h.rq.requests:
            assert streamed[r.req_id] == r.output_tokens  # exact, in order
        assert h.partial_outputs() == {r.req_id: r.output_tokens
                                       for r in h.rq.requests}


def test_snapshot_midflight_is_consistent():
    trace = quick_trace("rotten", num_relqueries=12, rate=3.0, seed=4,
                        max_requests=20)
    fe = Frontend(_engine())
    for rq in sorted(trace, key=lambda r: r.arrival_time):
        fe.submit(rq, now=rq.arrival_time)
    for _ in range(40):                      # stop mid-flight
        fe.step()
    mid = fe.snapshot()
    assert fe.has_work()                     # genuinely mid-flight
    final = fe.drain()
    assert set(mid.latencies) <= set(final.latencies)
    assert mid.end_to_end <= final.end_to_end
    for rel_id, lat in mid.latencies.items():
        assert lat == final.latencies[rel_id]   # finished latencies are final
    assert len(final.latencies) == len(trace)


def test_result_and_status_lifecycle():
    rq = make_relquery("a", [[1] * 20] * 2, 0.0, 3)
    fe = Frontend(_engine())
    h = fe.submit(rq)
    assert h.status() is RelQueryStatus.QUEUED
    out = h.result()
    assert out is rq and h.status() is RelQueryStatus.FINISHED
    assert h.latency() is not None
    assert h.cancel() is False               # terminal: cancel is a no-op


# ----------------------------------------------------------- cancellation
def test_cancel_before_first_tick_matches_never_submitted():
    """A relQuery cancelled before it ever participates in a tick leaves the
    trajectory byte-identical to never submitting it (full no-op reclaim)."""
    base = quick_trace("rotten", num_relqueries=6, rate=3.0, seed=9,
                       max_requests=10)

    ref = Frontend(_engine())
    ref.replay([rq for rq in copy.deepcopy(base) if rq.rel_id != "q3"])
    ref_report = ref.snapshot()

    fe = Frontend(_engine())
    pending = sorted(copy.deepcopy(base), key=lambda r: r.arrival_time)
    handles = {}
    idx = 0
    while idx < len(pending) or fe.has_work():
        nxt = fe.next_step_time()
        if idx < len(pending) and (nxt is None or
                                   pending[idx].arrival_time <= nxt):
            rq = pending[idx]
            idx += 1
            handles[rq.rel_id] = fe.submit(rq, now=rq.arrival_time)
            if rq.rel_id == "q3":
                handles["q3"].cancel()       # before any tick sees it
            continue
        fe.step()
    report = fe.snapshot()
    assert handles["q3"].status() is RelQueryStatus.CANCELLED
    assert report.cancelled_rel_ids == ["q3"]
    assert report.latencies == ref_report.latencies
    assert report.end_to_end == ref_report.end_to_end


def test_cancel_midflight_reclaims_kv_and_drains():
    """Cancelling a relQuery mid-core-run reclaims its entire KV commitment
    immediately and the remaining relQueries finish (no deadlock)."""
    trace = quick_trace("rotten", num_relqueries=8, rate=4.0, seed=6,
                        max_requests=15)
    fe = Frontend(_engine())
    handles = [fe.submit(rq, now=rq.arrival_time)
               for rq in sorted(trace, key=lambda r: r.arrival_time)]
    victim = None
    for _ in range(10_000):
        fe.step()
        if victim is None:
            running = [h for h in handles
                       if h.status() is RelQueryStatus.RUNNING]
            if running:
                victim = running[-1]
                break
    assert victim is not None, "no relQuery reached RUNNING"
    sched = fe.cores[0].scheduler
    victim.cancel()
    others = [r for rel_id, rq in sched.relqueries.items() if not rq.cancelled
              for r in rq.requests]
    # KV accounting now reflects only the surviving requests
    expected_in_use = sum(r.total_tokens for r in others
                          if r.state == RequestState.RUNNING)
    expected_committed = sum(sched._kv_footprint(r) for r in others
                             if r.prefilled_tokens > 0
                             and r.state != RequestState.FINISHED)
    assert sched.tokens_in_use == expected_in_use
    assert sched.committed_tokens == expected_committed
    assert all(r.state is RequestState.CANCELLED
               for r in victim.rq.requests if not r.is_finished())

    report = fe.drain()
    assert victim.rel_id not in report.latencies
    assert victim.rel_id in report.cancelled_rel_ids
    assert len(report.latencies) == len(trace) - 1     # everyone else finished
    assert sched.tokens_in_use == 0 and sched.committed_tokens == 0
    with pytest.raises(RelQueryCancelledError):
        victim.result()


def test_cancel_on_two_replica_cluster():
    trace = quick_trace("rotten", num_relqueries=10, rate=3.0, seed=3,
                        max_requests=12)
    cluster = build_simulated_cluster(2)
    fe = Frontend(cluster)
    handles = [fe.submit(rq, now=rq.arrival_time)
               for rq in sorted(trace, key=lambda r: r.arrival_time)]
    for _ in range(12):
        fe.step()
    live = [h for h in handles if not h.done()]
    assert live, "everything finished before the cancel point"
    victim = live[0]
    assert victim.cancel() is True
    report = fe.drain()
    assert victim.rel_id in report.cancelled_rel_ids
    assert victim.rel_id not in report.latencies
    assert len(report.latencies) == len(trace) - 1
    for core in cluster.cores:
        assert core.scheduler.tokens_in_use == 0
        assert core.scheduler.committed_tokens == 0
    # the cancellation happened on the replica the router chose
    assert cluster.assignments[victim.rel_id] == victim.replica


def test_deadline_auto_cancels():
    long_rq = make_relquery("slow", [[1] * 50] * 4, 0.0, 400)
    quick_rq = make_relquery("quick", [[2] * 10], 0.0, 2)
    fe = Frontend(_engine())
    slow = fe.submit(long_rq, deadline=0.5)
    quick = fe.submit(quick_rq)
    report = fe.drain()
    assert slow.status() is RelQueryStatus.CANCELLED
    assert long_rq.cancel_time == 0.5
    assert quick.status() is RelQueryStatus.FINISHED
    assert report.cancelled_rel_ids == ["slow"]


def test_duplicate_submit_rejected():
    rq = make_relquery("a", [[1] * 5], 0.0, 2)
    fe = Frontend(_engine())
    fe.submit(rq)
    with pytest.raises(ValueError, match="already submitted"):
        fe.submit(rq)


def test_second_frontend_does_not_detach_streaming():
    """The deprecated shims build throwaway frontends over the same backend;
    they must chain onto (and on close, restore) the live frontend's batch
    listener instead of clobbering it."""
    engine = _engine()
    fe = Frontend(engine)
    streamed = {}
    h = fe.submit(make_relquery("a", [[1] * 20] * 2, 0.0, 6),
                  on_token=lambda rid, tok: streamed.setdefault(rid, []).append(tok))
    fe.step()                                # some tokens flow
    before = sum(len(v) for v in streamed.values())
    assert before > 0

    inner = Frontend(engine)                 # e.g. what run_trace would build
    fe.step()                                # streaming still reaches fe
    assert sum(len(v) for v in streamed.values()) > before
    inner.close()                            # restores fe's listener
    assert engine.core.on_batch is not None  # fe is still subscribed

    h.result()
    for r in h.rq.requests:
        assert streamed[r.req_id] == r.output_tokens


def test_closed_frontend_goes_inert_even_out_of_order():
    """Closing an older frontend while a newer one is chained on top cannot
    unhook its listener from the chain — but it must stop delivering."""
    engine = _engine()
    fe1 = Frontend(engine)
    streamed = []
    fe1.submit(make_relquery("a", [[1] * 20] * 2, 0.0, 8),
               on_token=lambda rid, tok: streamed.append(tok))
    fe2 = Frontend(engine)                   # chains over fe1's listener
    fe1.step()
    assert streamed                          # fe1 live: tokens flow
    n = len(streamed)
    fe1.close()                              # out of stacking order
    fe2.step()
    fe2.step()
    assert len(streamed) == n                # inert: no further delivery
    fe2.close()


def test_scheduler_cancel_is_idempotent():
    rq = make_relquery("a", [[1] * 5] * 2, 0.0, 4)
    core = _engine().core
    core.admit(rq, 0.0)
    assert len(core.cancel_relquery("a", 1.0)) == 2
    assert core.cancel_relquery("a", 2.0) == []      # already cancelled
    assert core.cancel_relquery("ghost", 0.0) == []  # unknown rel_id
    assert rq.cancel_time == 1.0
    assert not core.has_work()
