"""Workload-planner equivalence and lifecycle suite.

The planner may only change *what the engine executes*, never *what any
logical row receives*:

- **pass equivalence** — every plan mode x scheduler: planned per-row token
  streams are exactly the unplanned executor streams (and the simulator's
  canonical ``expected_stream``);
- **fan-out under cancellation / preemption** — dedup followers mirror their
  leader's partial stream when the stage is cancelled mid-flight, and
  preempt/re-prefill cycles under a tight optimistic cap never corrupt a
  fanned-out stream;
- **DAG lifecycle** — a dependent stage never enters the engine before every
  upstream is terminal; cancellation and deadlines propagate along DAG edges
  to submitted *and* not-yet-submitted stages;
- **reorder is a permutation** — property-tested over random request lists;
- **duplicate-heavy traces** — ``dup_row_fraction=0.0`` is byte-identical to
  the historical trace; ``> 0`` introduces exact duplicates (same prompt,
  same sampled output length);
- **render fails loudly** — a row missing a template attribute raises a
  ``KeyError`` naming template and attribute (a silent empty substitution
  would poison dedup keys and projection).
"""
import copy

import pytest
from _hypothesis_compat import given, settings, st

from repro.core.latency_model import a100_opt13b
from repro.core.policies import SCHEDULERS
from repro.core.priority import BatchLimits, DPUConfig
from repro.core.relquery import RequestState, make_relquery
from repro.data.datasets import make_dataset
from repro.data.templates import RelQueryTemplate
from repro.data.trace import TraceConfig, build_trace
from repro.engine.engine import ServingEngine
from repro.engine.prefix_cache import PrefixCache
from repro.engine.simulator import SimulatedExecutor, expected_stream
from repro.planner import (PLAN_MODES, PlanExecutor, Planner, QueryPlan,
                           dedup_requests, derive, reorder_requests,
                           request_identity, scan)
from repro.serving import Frontend, RelQueryStatus

SCHED_NAMES = ("relserve", "vllm")


def _trace(seed=11, num_relqueries=6, rate=3.0, max_requests=12,
           dup_row_fraction=0.5):
    ds = make_dataset("rotten", num_rows=2000, seed=seed)
    return build_trace(ds, TraceConfig(
        num_relqueries=num_relqueries, rate=rate, seed=seed,
        max_requests=max_requests, num_templates=2,
        dup_row_fraction=dup_row_fraction))


def _engine(scheduler="relserve", cap=100_000, kv_admission="optimistic"):
    lm = a100_opt13b()
    pc = PrefixCache(block_size=16)
    kw = dict(limits=BatchLimits(cap=cap), latency_model=lm, prefix_cache=pc,
              kv_admission=kv_admission, prefix_sharing=True)
    if scheduler.startswith("relserve"):
        kw["dpu_config"] = DPUConfig(exact_probe=True)
    sched = SCHEDULERS[scheduler](**kw)
    return ServingEngine(sched, SimulatedExecutor(lm, prefix_cache=pc)), sched


def _streams(trace):
    return {r.req_id: tuple(r.output_tokens)
            for rq in trace for r in rq.requests}


TPL_CLASSIFY = RelQueryTemplate(
    "t/classify", "classify",
    "Categorize the sentiment of the review {review} as Negative , "
    "Positive , or Neutral .")
TPL_FOLLOWUP = RelQueryTemplate(
    "t/summarize", "summarize",
    "Given the sentiment {answer} summarize the review {review} "
    "within 20 words .")


def _rows(n, distinct=3):
    return [{"review": f"review body number {i % distinct}",
             "extra": f"unused column {i}"} for i in range(n)]


# ------------------------------------------------------------- equivalence
@pytest.mark.parametrize("scheduler", SCHED_NAMES)
@pytest.mark.parametrize("mode", PLAN_MODES)
def test_planned_replay_matches_unplanned(scheduler, mode):
    """Every pass combination: planned per-row results exactly equal the
    unplanned executor streams, and the canonical expected streams."""
    trace = _trace()
    base = copy.deepcopy(trace)
    engine, _ = _engine(scheduler)
    engine.run_trace(base)
    unplanned = _streams(base)

    planned_trace = copy.deepcopy(trace)
    planner = Planner(mode)
    planned = planner.plan_trace(planned_trace)
    executor = PlanExecutor(Frontend(_engine(scheduler)[0]), planner)
    report = executor.replay(planned)

    got = {r.req_id: tuple(r.output_tokens)
           for p in planned for r in p.logical_requests}
    assert got == unplanned
    for p in planned:
        for r in p.logical_requests:
            assert r.is_finished()
            assert r.output_tokens == expected_stream(r)
    assert set(report.latencies) == {rq.rel_id for rq in trace}
    if planner.dedup:
        assert report.deduped_requests > 0, \
            "dup-heavy trace must produce dedup fan-out"
    else:
        assert report.deduped_requests == 0


def test_dedup_reduces_physical_requests():
    trace = _trace()
    planner = Planner("full")
    planned = planner.plan_trace(copy.deepcopy(trace))
    n_logical = sum(p.num_logical for p in planned)
    n_physical = sum(p.num_physical for p in planned)
    assert n_physical < n_logical
    assert sum(p.deduped_requests for p in planned) == n_logical - n_physical
    # leaders are the original request objects, in first-occurrence order
    for p in planned:
        ids = {r.req_id for r in p.logical_requests}
        for r in p.physical.requests:
            assert r.req_id in ids
        for leader_id, followers in p.fanout.items():
            leader = next(r for r in p.physical.requests
                          if r.req_id == leader_id)
            for f in followers:
                assert request_identity(f) == request_identity(leader)


def test_off_mode_is_zero_copy():
    trace = _trace(dup_row_fraction=0.0)
    planner = Planner("off")
    for rq, p in zip(trace, planner.plan_trace(trace)):
        assert p.physical is rq
        assert not p.fanout


# --------------------------------------------------- fan-out under eviction
def test_fanout_survives_cancellation():
    """Cancelling a stage mid-flight: every duplicate row lands CANCELLED
    with its partial stream mirroring the leader's."""
    engine, _ = _engine()
    executor = PlanExecutor(Frontend(engine), Planner("full"))
    node = scan("stage", _rows(12, distinct=3), TPL_CLASSIFY)
    handle = executor.submit_plan(QueryPlan([node], plan_id="cancel-test"))
    planned = handle.stage("stage")
    assert planned.deduped_requests > 0
    for _ in range(2):                    # some partial progress, not done
        executor.step()
    assert not handle.done()
    handle.cancel("stage")
    assert handle.status("stage") is RelQueryStatus.CANCELLED
    leaders = {r.req_id: r for r in planned.physical.requests}
    for leader_id, followers in planned.fanout.items():
        leader = leaders[leader_id]
        for f in followers:
            assert f.output_tokens == leader.output_tokens
            assert f.state == leader.state
    report = executor.snapshot()
    assert planned.rel_id in report.cancelled_rel_ids
    assert planned.rel_id not in report.latencies


def test_fanout_survives_preemption():
    """A cap tight enough to force preempt/re-prefill cycles under optimistic
    admission: fanned-out streams still bit-identical to unplanned."""
    trace = _trace(seed=13, num_relqueries=8, rate=6.0, max_requests=12)
    max_fp = max(r.num_prompt_tokens + r.max_output_tokens
                 for rq in trace for r in rq.requests)
    cap = int(max_fp * 1.3)

    base = copy.deepcopy(trace)
    engine, _ = _engine(cap=cap)
    rep_off = engine.run_trace(base)
    assert rep_off.preemptions > 0, "cap not tight enough to preempt"

    planner = Planner("full")
    planned = planner.plan_trace(copy.deepcopy(trace))
    executor = PlanExecutor(Frontend(_engine(cap=cap)[0]), planner)
    report = executor.replay(planned)
    got = {r.req_id: tuple(r.output_tokens)
           for p in planned for r in p.logical_requests}
    assert got == _streams(base)
    assert report.deduped_requests > 0


# ------------------------------------------------------------- DAG lifecycle
def test_dag_stage2_waits_for_stage1():
    engine, _ = _engine()
    executor = PlanExecutor(Frontend(engine), Planner("full"))
    s1 = scan("s1", _rows(8), TPL_CLASSIFY)
    plan = QueryPlan([s1, derive("s2", s1, TPL_FOLLOWUP)], plan_id="dag")
    handle = executor.submit_plan(plan)
    # while stage 1 runs, stage 2 must not have been submitted
    while not handle._live["s1"].settled:
        assert handle.stage_handle("s2") is None
        assert handle.status("s2") is RelQueryStatus.QUEUED
        assert executor.step()
    rq1 = handle.result("s1")
    rq2 = handle.result("s2")
    assert rq2.arrival_time >= rq1.finish_time
    # stage-2 prompts really bind stage-1 decoded answers
    planner = executor.planner
    for i, r in enumerate(handle.stage("s2").logical_requests):
        up = handle.stage("s1").logical_requests[i]
        rendered = TPL_FOLLOWUP.render(
            {**_rows(8)[i], "answer": planner.decode_output(up)})
        assert r.tokens == tuple(planner.tokenizer.encode(rendered)) or \
            list(r.tokens) == planner.tokenizer.encode(rendered)


def test_dag_cancel_propagates_downstream():
    engine, _ = _engine()
    executor = PlanExecutor(Frontend(engine), Planner("full"))
    s1 = scan("s1", _rows(6), TPL_CLASSIFY)
    s2 = derive("s2", s1, TPL_FOLLOWUP)
    plan = QueryPlan([s1, s2, derive("s3", s2, TPL_FOLLOWUP)], plan_id="dag")
    handle = executor.submit_plan(plan)
    cancelled = handle.cancel("s1")
    assert set(cancelled) == {"s1", "s2", "s3"}
    for nid in ("s1", "s2", "s3"):
        assert handle.status(nid) is RelQueryStatus.CANCELLED
    assert handle.done()
    # unsubmitted downstream stages never reached the engine
    assert handle.stage_handle("s2") is None
    assert handle.stage_handle("s3") is None
    for r in handle.stage("s2").logical_requests + \
            handle.stage("s3").logical_requests:
        assert r.state is RequestState.CANCELLED


def test_dag_deadline_propagates_downstream():
    """A deadline that kills stage 1 mid-flight must also kill stage 2
    before it is ever submitted."""
    engine, _ = _engine()
    frontend = Frontend(engine)
    executor = PlanExecutor(frontend, Planner("full"))
    s1 = scan("s1", _rows(10), TPL_CLASSIFY)
    plan = QueryPlan([s1, derive("s2", s1, TPL_FOLLOWUP)], plan_id="dl")
    handle = executor.submit_plan(plan, deadline=1e-6)
    while executor.step():
        pass
    assert handle.status("s1") is RelQueryStatus.CANCELLED
    assert handle.status("s2") is RelQueryStatus.CANCELLED
    assert handle.stage_handle("s2") is None
    assert handle.done()


def test_plan_validation():
    s1 = scan("a", _rows(3), TPL_CLASSIFY)
    with pytest.raises(ValueError, match="duplicate plan node id"):
        QueryPlan([s1, scan("a", _rows(3), TPL_CLASSIFY)])
    with pytest.raises(ValueError, match="unknown node"):
        QueryPlan([derive("b", "missing", TPL_FOLLOWUP)])
    with pytest.raises(ValueError, match="empty row set"):
        scan("empty", [], TPL_CLASSIFY)
    cyc_a = derive("x", "y", TPL_FOLLOWUP)
    cyc_b = derive("y", "x", TPL_FOLLOWUP)
    with pytest.raises(ValueError, match="cycle"):
        QueryPlan([cyc_a, cyc_b])


# ------------------------------------------------------------- reorder pass
@given(st.lists(st.tuples(st.lists(st.integers(0, 9), min_size=1, max_size=6),
                          st.integers(1, 8)),
                min_size=0, max_size=24))
@settings(max_examples=60, deadline=None)
def test_reorder_is_a_permutation(specs):
    rq = make_relquery(
        "q", [toks for toks, _ in specs] or [[1]], 0.0, 5, eos_token=0)
    for r, (_, ol) in zip(rq.requests, specs or [([1], 5)]):
        r.sim_output_len = ol
    reordered = reorder_requests(rq.requests)
    # exact multiset of the same objects, sorted by prompt
    assert sorted(map(id, reordered)) == sorted(map(id, rq.requests))
    assert [r.tokens for r in reordered] == \
        sorted(r.tokens for r in rq.requests)


def test_dedup_groups_by_exact_identity():
    rq = make_relquery("q", [[1, 2], [1, 2], [3], [1, 2]], 0.0, 5,
                       eos_token=0)
    for r, ol in zip(rq.requests, (4, 4, 4, 3)):
        r.sim_output_len = ol
    leaders, fanout = dedup_requests(rq.requests)
    # [1,2]/ol=4 repeats; [1,2]/ol=3 differs in identity and stays physical
    assert [r.tokens for r in leaders] == [(1, 2), (3,), (1, 2)]
    assert len(fanout) == 1
    (leader_id, followers), = fanout.items()
    assert leader_id == leaders[0].req_id
    assert [f.req_id for f in followers] == [rq.requests[1].req_id]


# --------------------------------------------------------- dup-heavy traces
def test_dup_row_fraction_zero_is_byte_identical():
    ds = make_dataset("rotten", num_rows=2000, seed=3)
    cfg = dict(num_relqueries=5, rate=3.0, seed=3, max_requests=10,
               num_templates=2)
    a = build_trace(ds, TraceConfig(**cfg))
    b = build_trace(ds, TraceConfig(**cfg, dup_row_fraction=0.0))
    assert len(a) == len(b)
    for rqa, rqb in zip(a, b):
        assert rqa.arrival_time == rqb.arrival_time
        assert [(r.tokens, r.sim_output_len) for r in rqa.requests] == \
            [(r.tokens, r.sim_output_len) for r in rqb.requests]


def test_dup_row_fraction_introduces_exact_duplicates():
    trace = _trace(dup_row_fraction=0.5, max_requests=20)
    dups = 0
    for rq in trace:
        seen = {}
        for r in rq.requests:
            key = request_identity(r)
            if key in seen:
                dups += 1
                assert r.tokens == seen[key].tokens
                assert r.sim_output_len == seen[key].sim_output_len
            else:
                seen[key] = r
    assert dups > 0
    # and the untouched arrival/ordering stream still matches 0.0
    base = _trace(dup_row_fraction=0.0, max_requests=20)
    assert [rq.arrival_time for rq in trace] == \
        [rq.arrival_time for rq in base]
    assert [len(rq.requests) for rq in trace] == \
        [len(rq.requests) for rq in base]


# ------------------------------------------------------------- render errors
def test_render_missing_attribute_raises_keyerror():
    with pytest.raises(KeyError, match=r"t/classify.*review"):
        TPL_CLASSIFY.render({"other": "value"})
    # complete rows still render
    assert "review body" in TPL_CLASSIFY.render({"review": "review body"})
