"""KV-pressure subsystem: optimistic admission + priority-aware preemption.

Covers the preemption lifecycle invariants (ledger conservation across
preempt→restart cycles, output preservation), cancellation of preempted
relQueries, the satellite accounting fixes (prefix-cache lookup volume under
chunked prefill, no fabricated decode outputs), and the exact-equivalence pin
that conservative admission (the default) reproduces the pre-subsystem
per-relQuery latencies bit-for-bit for both relserve and vllm.
"""
import copy

import pytest

from repro.core.batch import Batch
from repro.core.latency_model import a100_opt13b
from repro.core.policies import SCHEDULERS
from repro.core.priority import BatchLimits
from repro.core.relquery import RequestState, make_relquery
from repro.core.scheduler import BatchResult, RelServeScheduler
from repro.data.trace import quick_trace
from repro.engine.engine import (EngineCore, EngineDeadlockError,
                                 ServingEngine)
from repro.engine.prefix_cache import PrefixCache
from repro.engine.simulator import SimulatedExecutor


def _engine(name="relserve", cap=16384, mode="optimistic", pc=None, seed=0):
    lm = a100_opt13b()
    sched = SCHEDULERS[name](limits=BatchLimits(cap=cap), latency_model=lm,
                             prefix_cache=pc, kv_admission=mode)
    return EngineCore(sched, SimulatedExecutor(lm, prefix_cache=pc, seed=seed))


def _drain(core, now=0.0, max_iters=100_000):
    while core.has_work():
        ev = core.tick(now)
        now = ev.end
        yield ev


# ------------------------------------------------------------------ lifecycle
def test_optimistic_preempts_instead_of_deadlocking():
    """Workload whose combined worst case exceeds the cap: conservative
    serializes (or deadlocks), optimistic packs and preempts — everything
    still finishes, and the KV ledgers conserve to zero."""
    core = _engine(cap=260, mode="optimistic")
    sched = core.scheduler
    a = make_relquery("A", [[1] * 100] * 2, 0.0, 60)   # worst case 320 > cap
    b = make_relquery("B", [[2] * 60], 0.0, 30)
    core.admit(a, 0.0)
    core.admit(b, 0.0)
    for _ in _drain(core):
        # optimistic invariant: actually-resident KV never exceeds the cap
        assert sched.tokens_in_use + sched.partial_prefill_tokens \
            <= sched.limits.cap
    assert a.is_finished() and b.is_finished()
    assert sched.preemptions > 0
    assert sched.tokens_in_use == 0
    assert sched.committed_tokens == 0
    assert sched.partial_prefill_tokens == 0


def test_preempted_request_preserves_generation():
    """Recompute-style restart: tokens generated before the preemption stay
    in output_tokens, and the final stream equals the no-pressure stream."""
    trace = [make_relquery("A", [[1] * 50] * 2, 0.0, 30),
             make_relquery("B", [[2] * 50] * 2, 0.0, 30)]
    loose = _engine(cap=16384, mode="optimistic")
    tight = _engine(cap=220, mode="optimistic")
    t1, t2 = copy.deepcopy(trace), copy.deepcopy(trace)
    for rq in t1:
        loose.admit(rq, 0.0)
    for rq in t2:
        tight.admit(rq, 0.0)
    list(_drain(loose))
    list(_drain(tight))
    assert tight.scheduler.preemptions > 0
    for rq1, rq2 in zip(t1, t2):
        for r1, r2 in zip(rq1.requests, rq2.requests):
            # same req ids on both copies -> same deterministic sim tokens
            assert r1.output_tokens == r2.output_tokens, \
                "preemption altered the token stream"


def test_preemption_state_machine_and_ledger_conservation():
    """Drive one preempt→restart cycle by hand and check every ledger."""
    lm = a100_opt13b()
    sched = RelServeScheduler(limits=BatchLimits(cap=1000), latency_model=lm,
                              kv_admission="optimistic")
    rq = make_relquery("A", [[1] * 40], 0.0, 20)
    sched.add_relquery(rq, 0.0)
    r = rq.requests[0]
    batch = sched.schedule(0.0)
    assert batch.kind == "prefill"
    sched.complete_batch(batch, BatchResult({r.req_id: (5, False)}), 0.0, 1.0)
    assert r.state == RequestState.RUNNING
    assert sched.tokens_in_use == 41 and sched.committed_tokens == 60

    sched.preempt_request(r, 1.0)
    assert r.state == RequestState.PREEMPTED
    assert r.preserved_output_tokens == 1 and r.output_tokens == [5]
    assert r.prefilled_tokens == 0 and not r.prefilled
    assert sched.tokens_in_use == 0 and sched.committed_tokens == 0
    assert sched.preemptions == 1 and rq.preemptions == 1
    assert sched.drain_preempt_releases() == [r.req_id]
    assert sched.drain_preempt_releases() == []   # drained exactly once
    assert r.prefill_target_tokens == 41          # prompt + 1 preserved token
    assert r.prefill_token_ids() == tuple([1] * 40) + (5,)

    # restart rides the normal prefill candidate path
    batch = sched.schedule(2.0)
    assert batch.kind == "prefill" and batch.prefill_requests == [r]
    sched.complete_batch(batch, BatchResult({r.req_id: (7, False)}), 2.0, 3.0)
    assert r.state == RequestState.RUNNING
    assert r.output_tokens == [5, 7]              # preserved + new
    assert sched.tokens_in_use == 42              # 40 prompt + 2 outputs
    assert sched.committed_tokens == 60           # footprint re-committed

    # decode to completion: ledgers conserve back to zero
    while not rq.is_finished():
        batch = sched.schedule(4.0)
        outs = {x.req_id: (9, False) for x in batch.decode_requests}
        sched.complete_batch(batch, BatchResult(outs), 4.0, 5.0)
    assert sched.tokens_in_use == 0 and sched.committed_tokens == 0
    assert sched.partial_prefill_tokens == 0


def test_victim_is_lowest_priority_running_relquery():
    """Per the DPU, the running relQuery with the *highest* priority value
    (= least urgent) yields its KV first."""
    lm = a100_opt13b()
    sched = RelServeScheduler(limits=BatchLimits(cap=10_000), latency_model=lm,
                              kv_admission="optimistic")
    small = make_relquery("small", [[1] * 20], 0.0, 10)
    big = make_relquery("big", [[2] * 20] * 3, 0.0, 200)
    for rq in (small, big):
        sched.add_relquery(rq, 0.0)
        batch = sched.build_prefill_candidate(single_relquery=True)
        outs = {r.req_id: (5, False) for r in batch.prefill_requests}
        sched.complete_batch(batch, BatchResult(outs), 0.0, 1.0)
    assert len(sched.running_requests()) == 4
    sched.refresh_priorities(2.0)
    assert big.priority > small.priority          # more remaining work
    victim = sched._pick_preemption_victim()
    assert victim.rel_id == "big"


def test_cancel_while_preempted():
    """Cancelling a relQuery whose requests sit in PREEMPTED must be terminal
    and leak nothing (satellite: cancelled-while-preempted)."""
    lm = a100_opt13b()
    sched = RelServeScheduler(limits=BatchLimits(cap=1000), latency_model=lm,
                              kv_admission="optimistic")
    rq = make_relquery("A", [[1] * 40] * 2, 0.0, 20)
    sched.add_relquery(rq, 0.0)
    batch = sched.schedule(0.0)
    outs = {r.req_id: (5, False) for r in batch.prefill_requests}
    sched.complete_batch(batch, BatchResult(outs), 0.0, 1.0)
    for r in list(sched.running_requests()):
        sched.preempt_request(r, 1.0)
    assert all(r.state == RequestState.PREEMPTED for r in rq.requests)

    cancelled = sched.cancel_relquery("A", 2.0)
    assert sorted(r.req_id for r in cancelled) == \
        sorted(r.req_id for r in rq.requests)
    assert all(r.state == RequestState.CANCELLED for r in rq.requests)
    assert rq.cancelled and not sched.has_work()
    assert sched.tokens_in_use == 0 and sched.committed_tokens == 0
    assert sched.partial_prefill_tokens == 0
    # preserved outputs survive for partial-result consumers
    assert all(r.output_tokens for r in rq.requests)
    # terminal: nothing schedulable afterwards
    assert sched.schedule(3.0) is None


def test_deadlock_reserved_for_single_unfittable_request():
    """Optimistic mode only raises when one request can never fit."""
    core = _engine(cap=50, mode="optimistic")
    core.admit(make_relquery("huge", [[1] * 100], 0.0, 10), 0.0)
    with pytest.raises(EngineDeadlockError) as ei:
        core.tick(0.0)
    assert "huge" in ei.value.stuck_rel_ids


def test_real_executor_slots_released_on_preemption():
    """The engine frees RealExecutor-style decode slots for every preempted
    request (drain_preempt_releases handoff)."""
    released = []

    class SpyExecutor(SimulatedExecutor):
        def release_request(self, req_id):
            released.append(req_id)

    lm = a100_opt13b()
    sched = SCHEDULERS["vllm"](limits=BatchLimits(cap=230), latency_model=lm,
                               kv_admission="optimistic")
    core = EngineCore(sched, SpyExecutor(lm))
    core.admit(make_relquery("A", [[1] * 80] * 2, 0.0, 40), 0.0)
    list(_drain(core))
    assert sched.preemptions > 0
    assert len(released) == sched.preemptions


def test_optimistic_fallback_respects_cap_with_midchunk_request():
    """Review regression: the cap-blocked prefill fallback must not schedule a
    mid-chunk request's remaining prefill past the cap under optimistic
    admission (its remaining chunks are NOT yet resident, unlike the
    conservative pre-commitment the fallback was written for)."""
    lm = a100_opt13b()
    sched = SCHEDULERS["vllm"](limits=BatchLimits(cap=100), latency_model=lm,
                               kv_admission="optimistic")
    core = EngineCore(sched, SimulatedExecutor(lm))
    a = make_relquery("A", [[1] * 60], 0.0, 30)   # mid-chunk: 5 of 60 landed
    b = make_relquery("B", [[2] * 80], 1.0, 10)   # running: holds 81 tokens
    core.admit(a, 0.0)
    core.admit(b, 1.0)
    ra, rb = a.requests[0], b.requests[0]
    ra.prefilled_tokens = 5
    sched.partial_prefill_tokens += 5
    sched.committed_tokens += sched._kv_footprint(ra)
    sched.complete_batch(Batch.prefill([rb]), BatchResult({rb.req_id: (3, False)}),
                         1.0, 2.0)
    assert sched.kv_demand() == 81 + 5   # B resident (80+1) + A's landed chunk
    now = 2.0
    for _ in _drain(core, now):
        assert sched.tokens_in_use + sched.partial_prefill_tokens \
            <= sched.limits.cap, "fallback overshot the device cap"
    assert a.is_finished() and b.is_finished()
    assert sched.tokens_in_use == 0 and sched.partial_prefill_tokens == 0


def test_tick_reclaims_wedged_chunk_partials_instead_of_deadlock():
    """Review regression: two half-loaded prompts wedged against the cap with
    nothing running — the engine's preempt-and-retry must reclaim one's
    partial chunks and drain, not raise EngineDeadlockError."""
    lm = a100_opt13b()
    sched = SCHEDULERS["vllm"](limits=BatchLimits(cap=100), latency_model=lm,
                               kv_admission="optimistic")
    core = EngineCore(sched, SimulatedExecutor(lm))
    a = make_relquery("A", [[1] * 60], 0.0, 10)
    b = make_relquery("B", [[2] * 60], 1.0, 10)
    core.admit(a, 0.0)
    core.admit(b, 1.0)
    for rq in (a, b):   # 50 + 50 landed: demand == cap, neither remainder fits
        r = rq.requests[0]
        r.prefilled_tokens = 50
        sched.partial_prefill_tokens += 50
        sched.committed_tokens += sched._kv_footprint(r)
    assert sched.choose_batch(2.0) is None   # no candidate is constructible
    now = 2.0
    for _ in _drain(core, now):
        assert sched.tokens_in_use + sched.partial_prefill_tokens \
            <= sched.limits.cap
    assert a.is_finished() and b.is_finished()
    assert sched.preemptions >= 1          # the retry path actually fired
    assert b.preemptions >= 1              # FCFS victim: the later arrival
    assert sched.tokens_in_use == 0 and sched.committed_tokens == 0
    assert sched.partial_prefill_tokens == 0


def test_real_executor_preemption_end_to_end():
    """The real-JAX path survives preempt→re-prefill cycles: slots are
    recycled, restarts recompute prompt+generated, everything finishes."""
    import jax

    from repro.configs import get_smoke_config
    from repro.engine.executor import RealExecutor
    from repro.engine.tokenizer import HashTokenizer
    from repro.models.registry import build_model

    cfg = get_smoke_config("qwen3-1.7b")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    tok = HashTokenizer(vocab_size=cfg.vocab_size - 2)
    prompts = [tok.encode(f"row {i} of the relational table") for i in range(3)]
    out = 12
    rq = make_relquery("A", prompts, 0.0, out)
    max_fp = max(len(p) + out for p in prompts)
    lm = a100_opt13b()
    sched = SCHEDULERS["relserve"](limits=BatchLimits(cap=max_fp + len(prompts[0])),
                                   latency_model=lm, kv_admission="optimistic")
    ex = RealExecutor(model, params, max_slots=8, max_len=256)
    core = EngineCore(sched, ex)
    core.admit(rq, 0.0)
    list(_drain(core))
    assert rq.is_finished()
    assert sched.preemptions > 0, "cap was not tight enough to preempt"
    assert sched.tokens_in_use == 0 and sched.committed_tokens == 0
    assert all(s is None for s in ex.slots), "decode slots leaked"
    for r in rq.requests:
        assert 1 <= len(r.output_tokens) <= out


# ------------------------------------------------------------------ satellites
def test_chunked_prefill_lookup_volume_counts_prompt_once():
    """Satellite: _true_utok must probe the prefix cache with stats exactly
    once per prefill pass — hits+misses equals the prompt tokens looked up,
    no matter how many chunks the prompt is split into."""
    lm = a100_opt13b()
    prompt = [7] * 96

    def run(chunked: bool):
        pc = PrefixCache(block_size=16)
        ex = SimulatedExecutor(lm, prefix_cache=pc)
        rq = make_relquery("A", [prompt], 0.0, 4)
        r = rq.requests[0]
        if chunked:
            for _ in range(3):   # 3 chunks of 32
                b = Batch.mixed([r], [], {r.req_id: 32})
                ex.execute(b, 0.0)
                r.prefilled_tokens += 32
        else:
            ex.execute(Batch.prefill([r]), 0.0)
        return pc.hits + pc.misses

    assert run(chunked=False) == 96
    assert run(chunked=True) == 96, \
        "chunked prefill must not inflate prefix-cache lookup volume"


def test_chunked_prefill_hit_ratio_matches_unchunked():
    """End-to-end: sarathi (always-chunked) reports the same order of lookup
    volume as the prompt stream — the per-chunk double counting is gone."""
    lm = a100_opt13b()
    pc = PrefixCache(block_size=16)
    sched = SCHEDULERS["sarathi"](limits=BatchLimits(max_num_batched_tokens=64),
                                  latency_model=lm, prefix_cache=pc)
    engine = ServingEngine(sched, SimulatedExecutor(lm, prefix_cache=pc))
    trace = quick_trace("rotten", num_relqueries=6, rate=4.0, seed=5,
                        max_requests=6)
    total_prompt = sum(r.num_prompt_tokens for rq in trace for r in rq.requests)
    engine.run_trace(trace)
    assert pc.hits + pc.misses == total_prompt


def test_missing_decode_output_counted_not_fabricated():
    """Satellite: a decode request absent from BatchResult.outputs must not
    grow a phantom token / tokens_in_use — it is counted in a stat."""
    lm = a100_opt13b()
    sched = SCHEDULERS["vllm"](limits=BatchLimits(cap=10_000), latency_model=lm)
    rq = make_relquery("A", [[1] * 20] * 2, 0.0, 10)
    sched.add_relquery(rq, 0.0)
    batch = sched.schedule(0.0)
    outs = {r.req_id: (5, False) for r in batch.prefill_requests}
    sched.complete_batch(batch, BatchResult(outs), 0.0, 1.0)
    r1, r2 = rq.requests
    tiu = sched.tokens_in_use

    batch = sched.schedule(1.0)
    assert batch.kind == "decode"
    # executor "loses" r2: only r1 comes back
    sched.complete_batch(batch, BatchResult({r1.req_id: (6, False)}), 1.0, 2.0)
    assert r1.output_tokens == [5, 6]
    assert r2.output_tokens == [5], "phantom token fabricated for lost request"
    assert sched.tokens_in_use == tiu + 1
    assert sched.missing_decode_outputs == 1
    assert r2.state == RequestState.RUNNING   # reschedulable, not corrupted


# ------------------------------------------------------------------ pins
# Per-relQuery latencies recorded on the pre-subsystem engine (quick_trace
# rotten, n=12, rate=1.5, seed=7, max_requests=12, cap=4096). Conservative
# admission — the default — must reproduce them bit-for-bit.
_PINNED = {
    "relserve": {
        "q0": 1.53344, "q1": 0.171367695, "q2": 3.44116395, "q3": 3.450674754,
        "q4": 0.291090449, "q5": 0.197493264, "q6": 2.703840689,
        "q7": 2.852453798, "q8": 5.285475997, "q9": 0.865332399,
        "q10": 7.377775568, "q11": 3.467279223,
    },
    "vllm": {
        "q0": 1.61004, "q1": 0.171367695, "q2": 3.51814395, "q3": 3.468014754,
        "q4": 0.289490449, "q5": 0.220993264, "q6": 2.732420689,
        "q7": 3.072253798, "q8": 4.946735997, "q9": 2.134292399,
        "q10": 5.338875568, "q11": 5.759379223,
    },
}


@pytest.mark.parametrize("name", ["relserve", "vllm"])
def test_conservative_default_latencies_bit_identical(name):
    lm = a100_opt13b()
    pc = PrefixCache(block_size=16)
    sched = SCHEDULERS[name](limits=BatchLimits(cap=4096), latency_model=lm,
                             prefix_cache=pc)   # default admission mode
    assert sched.kv_admission == "conservative"
    engine = ServingEngine(sched, SimulatedExecutor(lm, prefix_cache=pc))
    trace = quick_trace("rotten", num_relqueries=12, rate=1.5, seed=7,
                        max_requests=12)
    report = engine.run_trace(trace)
    got = {k: round(v, 9) for k, v in report.latencies.items()}
    assert got == _PINNED[name]
    assert report.preemptions == 0


def test_invalid_admission_mode_rejected():
    with pytest.raises(ValueError, match="kv_admission"):
        RelServeScheduler(kv_admission="yolo")


# ------------------------------------------------------------------ kv tiering
def _tiered_sched(name="relserve", cap=1000, host_cap=100_000, **kw):
    lm = a100_opt13b()
    return SCHEDULERS[name](limits=BatchLimits(cap=cap), latency_model=lm,
                            kv_admission="optimistic", kv_tiering=True,
                            host_kv_cap=host_cap, **kw)


def test_swap_lifecycle_resumes_without_reprefill():
    """SWAPPED is not PREEMPTED: prefill progress and outputs survive the
    trip to the host tier, and the resume is a decode batch, not a
    re-prefill pass."""
    sched = _tiered_sched()
    rq = make_relquery("A", [[1] * 40], 0.0, 20)
    sched.add_relquery(rq, 0.0)
    r = rq.requests[0]
    batch = sched.schedule(0.0)
    sched.complete_batch(batch, BatchResult({r.req_id: (5, False)}), 0.0, 1.0)
    tokens = r.total_tokens                        # 40 prompt + 1 output

    sched.swap_out_request(r, 1.0)
    assert r.state == RequestState.SWAPPED
    assert r.prefilled and r.prefilled_tokens == 40   # progress kept
    assert r.output_tokens == [5]
    assert sched.tokens_in_use == 0 and sched.committed_tokens == 0
    assert sched.host_tokens_in_use == tokens
    assert sched.preemptions == 0                  # a swap is not a preempt
    assert sched.swap_outs == 1 and sched.swapped_out_tokens == tokens
    assert sched.drain_swap_ops() == [("out", r.req_id, tokens)]
    assert sched.drain_swap_ops() == []            # drained exactly once

    # next schedule swaps it straight back in and decodes
    batch = sched.schedule(2.0)
    assert r.state == RequestState.RUNNING
    assert batch.kind == "decode" and batch.decode_requests == [r]
    assert sched.host_tokens_in_use == 0
    assert sched.tokens_in_use == tokens
    assert sched.swap_ins == 1 and sched.swapped_in_tokens == tokens
    assert sched.drain_swap_ops() == [("in", r.req_id, tokens)]
    sched.complete_batch(batch, BatchResult({r.req_id: (7, False)}), 2.0, 3.0)
    assert r.output_tokens == [5, 7]               # decode continued in place


def test_reclaim_cost_model_swap_vs_recompute():
    """Per-victim reclaim: swap when the modeled round trip beats re-prefill,
    recompute-preempt when the host link is too slow or the host tier full."""
    fast = _tiered_sched()                                  # 32 GB/s default
    slow = _tiered_sched(swap_bandwidth_gbps=0.001)         # ~67s round trip
    full = _tiered_sched(host_cap=10)                       # victim won't fit
    for sched in (fast, slow, full):
        rq = make_relquery("A", [[1] * 40], 0.0, 20)
        sched.add_relquery(rq, 0.0)
        r = rq.requests[0]
        b = sched.schedule(0.0)
        sched.complete_batch(b, BatchResult({r.req_id: (5, False)}), 0.0, 1.0)
        sched._reclaim(r, 1.0)
    assert fast.reclaim_swap_decisions == 1 and fast.swap_outs == 1
    assert fast.preemptions == 0
    assert slow.reclaim_recompute_decisions == 1 and slow.preemptions == 1
    assert slow.swap_outs == 0
    assert full.reclaim_recompute_decisions == 1 and full.swap_outs == 0


def test_cancel_while_swapped_drains_everything():
    """Cancelling a relQuery parked on the host tier must zero the host
    ledger AND purge its undrained swap ops — the engine releases executor
    state directly; mirroring a stale op would touch a freed request."""
    sched = _tiered_sched()
    rq = make_relquery("A", [[1] * 40] * 2, 0.0, 20)
    sched.add_relquery(rq, 0.0)
    batch = sched.schedule(0.0)
    outs = {r.req_id: (5, False) for r in batch.prefill_requests}
    sched.complete_batch(batch, BatchResult(outs), 0.0, 1.0)
    for r in list(sched.running_requests()):
        sched.swap_out_request(r, 1.0)
    assert all(r.state == RequestState.SWAPPED for r in rq.requests)
    assert sched.host_tokens_in_use == sum(r.total_tokens for r in rq.requests)

    cancelled = sched.cancel_relquery("A", 2.0)
    assert sorted(x.req_id for x in cancelled) == \
        sorted(x.req_id for x in rq.requests)
    assert all(r.state == RequestState.CANCELLED for r in rq.requests)
    assert sched.host_tokens_in_use == 0 and not sched.has_work()
    assert sched.tokens_in_use == 0 and sched.committed_tokens == 0
    assert sched.drain_swap_ops() == []     # stale "out" ops purged
    assert sched.schedule(3.0) is None


@pytest.mark.parametrize("name", ["relserve", "vllm"])
def test_tiering_streams_identical_under_pressure(name):
    """End-to-end at a cap tight enough to force reclaim on every policy:
    tiering-on actually swaps (and swaps everything back), yet every token
    stream is bit-identical to the recompute-only run."""
    trace = quick_trace("rotten", num_relqueries=10, rate=3.0, seed=3,
                        max_requests=10)
    max_fp = max(r.num_prompt_tokens + r.max_output_tokens
                 for rq in trace for r in rq.requests)
    cap = int(max_fp * 1.2)

    def run(tiering):
        lm = a100_opt13b()
        kw = dict(limits=BatchLimits(cap=cap), latency_model=lm,
                  kv_admission="optimistic")
        if tiering:
            kw.update(kv_tiering=True, host_kv_cap=8 * cap)
        sched = SCHEDULERS[name](**kw)
        ran = copy.deepcopy(trace)
        ServingEngine(sched, SimulatedExecutor(lm)).run_trace(ran)
        return sched, {r.req_id: tuple(r.output_tokens)
                       for rq in ran for r in rq.requests}

    off_sched, off_streams = run(False)
    on_sched, on_streams = run(True)
    assert off_sched.preemptions > 0, "cap not tight enough to reclaim"
    assert on_sched.swap_outs > 0, "tiering never engaged"
    assert on_sched.swap_ins == on_sched.swap_outs   # everything came back
    assert on_streams == off_streams
    assert on_sched.host_tokens_in_use == 0
    assert on_sched.tokens_in_use == 0 and on_sched.committed_tokens == 0


def test_tiering_param_validation():
    with pytest.raises(ValueError, match="conservative"):
        RelServeScheduler(kv_tiering=True, host_kv_cap=100)
    with pytest.raises(ValueError, match="host_kv_cap"):
        RelServeScheduler(kv_admission="optimistic", kv_tiering=True,
                          host_kv_cap=0)
    with pytest.raises(ValueError, match="swap_bandwidth"):
        RelServeScheduler(kv_admission="optimistic", kv_tiering=True,
                          host_kv_cap=100, swap_bandwidth_gbps=0.0)


# ------------------------------------------------------------ proactive tiering
def test_proactive_param_validation():
    with pytest.raises(ValueError, match="proactive_offload requires"):
        RelServeScheduler(kv_admission="optimistic", proactive_offload=True)
    with pytest.raises(ValueError, match="swap_prefetch requires"):
        RelServeScheduler(kv_admission="optimistic", swap_prefetch=True)
    with pytest.raises(ValueError, match="idle_horizon_s"):
        _tiered_sched(idle_horizon_s=5.0)          # without proactive_offload
    with pytest.raises(ValueError, match="idle_horizon_s"):
        _tiered_sched(proactive_offload=True, idle_horizon_s=0.0)
    # a sane straggler horizon attaches by default when proactive is on
    sched = _tiered_sched(proactive_offload=True)
    assert sched.idle_horizon_s == 8.0
    assert _tiered_sched().idle_horizon_s is None  # reactive: no horizon


def test_parked_relquery_proactively_offloaded_until_unparked():
    """Class-1 victim: a parked relQuery's device KV is dead weight — the
    proactive tick swaps it out, the resume scan passes over it while
    parked, and unparking resumes the exact decode (no re-prefill)."""
    sched = _tiered_sched(proactive_offload=True)
    rq = make_relquery("A", [[1] * 40], 0.0, 20)
    sched.add_relquery(rq, 0.0)
    r = rq.requests[0]
    b = sched.schedule(0.0)
    sched.complete_batch(b, BatchResult({r.req_id: (5, False)}), 0.0, 1.0)
    assert sched.drain_swap_ops() == []

    rq.parked = True
    assert sched.schedule(1.0) is None
    assert r.state == RequestState.SWAPPED
    assert sched.proactive_offloads == 1
    assert sched.host_tokens_in_use == r.total_tokens
    assert sched.drain_swap_ops() == [("out", r.req_id, r.total_tokens)]
    assert sched.schedule(2.0) is None            # parked: resume blocked
    assert r.state == RequestState.SWAPPED

    rq.parked = False
    b = sched.schedule(3.0)
    assert r.state == RequestState.RUNNING
    assert b.kind == "decode" and b.decode_requests == [r]
    assert sched.drain_swap_ops() == [("in", r.req_id, r.total_tokens)]
    assert sched.proactive_offloads == 1          # resumed, not re-offloaded
    sched.complete_batch(b, BatchResult({r.req_id: (7, False)}), 3.0, 4.0)
    assert r.output_tokens == [5, 7]              # generation survived


def test_idle_horizon_offload_makes_headroom_for_admission():
    """Class-3 victim: under pre-pressure (head-of-line admission need does
    not fit the cap) the running request with the largest predicted
    remaining work is offloaded before the batch is chosen, so the prefill
    is admitted this tick instead of waiting for a forced reclaim."""
    sched = _tiered_sched(cap=1200, proactive_offload=True,
                          idle_horizon_s=1e-3)
    rq_a = make_relquery("A", [[1] * 600], 0.0, 300)
    sched.add_relquery(rq_a, 0.0)
    a = rq_a.requests[0]
    b1 = sched.schedule(0.0)
    sched.complete_batch(b1, BatchResult({a.req_id: (5, False)}), 0.0, 1.0)

    rq_b = make_relquery("B", [[2] * 600], 1.0, 300)
    sched.add_relquery(rq_b, 1.0)
    b2 = sched.schedule(1.0)
    assert a.state == RequestState.SWAPPED        # straggler offloaded first
    assert sched.proactive_offloads == 1
    assert b2 is not None and rq_b.requests[0] in b2.prefill_requests
    assert a.req_id not in {r.req_id for r in b2.all_requests()}


def _prefetch_pair(**kw):
    """Two single-request relQueries driven to RUNNING, then the cap shrunk
    to just cover the resident pair: swapping A out afterwards leaves it
    unable to resume beside B (fits needs +growth headroom the cap now
    denies) — the canonical 'prefetch pending' setup."""
    sched = _tiered_sched(cap=100_000, **kw)
    reqs = {}
    for rel_id, fill in (("A", 1), ("B", 2)):
        rq = make_relquery(rel_id, [[fill] * 400], 0.0, 20)
        sched.add_relquery(rq, 0.0)
        reqs[rel_id] = rq.requests[0]
    now = 0.0
    while not all(r.prefilled and r.output_tokens for r in reqs.values()):
        batch = sched.schedule(now)
        assert batch is not None
        sched.complete_batch(batch, BatchResult(
            {r.req_id: (5, False) for r in batch.all_requests()}),
            now, now + 1.0)
        now += 1.0
    sched.drain_swap_ops()
    sched.limits = BatchLimits(cap=sched.kv_demand() + 1)
    return sched, reqs["A"], reqs["B"], now


def test_swap_prefetch_issued_tick_early_and_consumed_on_resume():
    """The resume candidate's host->device copy is issued the tick before
    its swap-in: one ("prefetch", ...) op while it still cannot fit, then a
    single ("in", ...) op — with no second prefetch — when it resumes."""
    sched, a, b, now = _prefetch_pair(swap_prefetch=True)
    sched.swap_out_request(a, now)
    assert sched.drain_swap_ops() == [("out", a.req_id, a.total_tokens)]

    batch = sched.schedule(now + 1)
    assert a.state == RequestState.SWAPPED        # cannot fit beside B
    assert batch.kind == "decode" and batch.decode_requests == [b]
    assert sched.swap_prefetches == 1
    assert sched.drain_swap_ops() == [("prefetch", a.req_id, a.total_tokens)]
    sched.complete_batch(batch, BatchResult({b.req_id: (6, True)}),
                         now + 1, now + 2)

    batch = sched.schedule(now + 3)               # B done: A resumes
    assert a.state == RequestState.RUNNING
    assert batch.decode_requests == [a]
    assert sched.drain_swap_ops() == [("in", a.req_id, a.total_tokens)]
    assert sched.swap_prefetches == 1             # prefetch not re-issued
    assert not sched._prefetch_inflight


def test_cancel_while_prefetching_releases_and_refunds():
    """Satellite regression (beside the cancel-while-swapped lane): a
    relQuery cancelled between prefetch issue and swap-in commit must emit a
    ("prefetch_cancel", ...) op for the executor's staged copy, refund the
    tick's bandwidth ledger, and leave every ledger drained."""
    sched, a, b, now = _prefetch_pair(swap_prefetch=True)
    sched.swap_out_request(a, now)
    sched.drain_swap_ops()
    batch = sched.schedule(now + 1)
    assert sched.swap_prefetches == 1
    assert sched.drain_swap_ops() == [("prefetch", a.req_id, a.total_tokens)]

    queued_before = sched._tick_swap_queue_s
    cancelled = sched.cancel_relquery("A", now + 1.5)
    assert [r.req_id for r in cancelled] == [a.req_id]
    assert sched.prefetch_cancelled == 1
    assert not sched._prefetch_inflight
    assert sched._tick_swap_queue_s <= queued_before
    assert sched._tick_swap_queue_s >= 0.0
    assert sched.drain_swap_ops() == \
        [("prefetch_cancel", a.req_id, a.total_tokens)]
    assert sched.host_tokens_in_use == 0
    sched.complete_batch(batch, BatchResult({b.req_id: (6, True)}),
                         now + 1, now + 2)
    assert sched.schedule(now + 3) is None and not sched.has_work()
    assert sched.tokens_in_use == 0 and sched.committed_tokens == 0


def test_cancel_before_prefetch_op_drained_purges_it():
    """If the cancel lands before the engine mirrored the prefetch op, the
    op is purged outright — the executor never staged anything, so no
    ("prefetch_cancel", ...) must reach it either."""
    sched, a, b, now = _prefetch_pair(swap_prefetch=True)
    sched.swap_out_request(a, now)
    sched.drain_swap_ops()
    sched.schedule(now + 1)                       # prefetch op NOT drained
    assert sched.swap_prefetches == 1
    sched.cancel_relquery("A", now + 1.5)
    assert sched.prefetch_cancelled == 1
    assert sched.drain_swap_ops() == []           # purged, nothing to undo
    assert sched.host_tokens_in_use == 0


def test_cancel_while_prefetching_refunds_bandwidth_ledger():
    """SimulatedExecutor side of the regression: cancelling a staged copy
    rolls the shared channel back — bytes that never moved are not billed —
    while a copy another op already queued behind stays sunk cost. The
    busy-seconds x budget == bytes-moved conservation law holds throughout."""
    ex = SimulatedExecutor(a100_opt13b(), swap_bandwidth_gbps=8.0)
    bw = ex.swap_bandwidth_bytes

    def conserved():
        led = ex.swap_ledger()
        assert led["busy_s"] >= 0.0 and led["bytes"] >= 0.0
        assert abs(led["busy_s"] * bw - led["bytes"]) < 1e-3
        return led

    ex.begin_swap_tick(0.0)
    ex.swap_out("a", 400)
    before = conserved()
    assert ex.prefetch_swap_in("a", 400) == 0.0   # issue bills nothing
    conserved()
    assert ex.cancel_swap_prefetch("a", 400) == 0.0
    after = conserved()
    assert after["channel_free_at"] == before["channel_free_at"]  # full refund
    assert after["bytes"] == before["bytes"]
    assert after["prefetch_cancels"] == 1

    # queued-behind case: another op lands after the staged copy, so the
    # cancel cannot reclaim the channel time — sunk, but still conserved
    ex.prefetch_swap_in("b", 400)
    ex.swap_out("c", 100)
    mid = conserved()
    ex.cancel_swap_prefetch("b", 400)
    sunk = conserved()
    assert sunk["channel_free_at"] == mid["channel_free_at"]
    assert sunk["bytes"] == mid["bytes"]


@pytest.mark.parametrize("name", ["relserve", "vllm"])
@pytest.mark.parametrize("loop", ["serial", "pipelined"])
def test_proactive_prefetch_streams_identical(name, loop):
    """Proactive offload + swap-in prefetch are timing-only: across both
    schedulers and both engine loops the token streams are bit-identical to
    the reactive tiered run, with the prefetch machinery demonstrably
    engaged (issues > 0 and zero-stall hits > 0)."""
    trace = quick_trace("rotten", num_relqueries=10, rate=3.0, seed=3,
                        max_requests=10)
    max_fp = max(r.num_prompt_tokens + r.max_output_tokens
                 for rq in trace for r in rq.requests)
    cap = int(max_fp * 1.2)

    def run(proactive):
        lm = a100_opt13b()
        kw = dict(limits=BatchLimits(cap=cap), latency_model=lm,
                  kv_admission="optimistic", kv_tiering=True,
                  host_kv_cap=8 * cap)
        if proactive:
            kw.update(proactive_offload=True, swap_prefetch=True)
        sched = SCHEDULERS[name](**kw)
        engine = ServingEngine(sched, SimulatedExecutor(lm),
                               engine_loop=loop, debug_invariants=True)
        ran = copy.deepcopy(trace)
        report = engine.run_trace(ran)
        return sched, report, {r.req_id: tuple(r.output_tokens)
                               for rq in ran for r in rq.requests}

    off_sched, _, off_streams = run(False)
    on_sched, on_report, on_streams = run(True)
    assert off_sched.swap_outs > 0, "cap not tight enough to tier"
    assert on_sched.swap_prefetches > 0, "prefetch never engaged"
    assert on_report.prefetch_hits > 0, "no prefetch landed zero-stall"
    assert on_streams == off_streams
    assert on_sched.host_tokens_in_use == 0
    assert on_sched.tokens_in_use == 0 and on_sched.committed_tokens == 0


# ------------------------------------------------------- predicted admission
def test_predicted_admission_charges_predicted_footprint():
    """The per-template predictor shrinks the admission charge from the
    worst case to prompt + predicted OL, clamped to [resident+1, worst]."""
    lm = a100_opt13b()
    sched = SCHEDULERS["vllm"](limits=BatchLimits(cap=10_000),
                               latency_model=lm, kv_admission="predicted")
    assert sched.predictor is not None      # auto-attached in predicted mode
    rq = make_relquery("A", [[7] * 20], 0.0, 100)
    sched.add_relquery(rq, 0.0)
    r = rq.requests[0]
    assert sched._kv_footprint(r) == 120    # no history -> worst case
    key = sched._template_key(r)
    for _ in range(8):
        sched.predictor.observe(key, 10)
    assert sched._kv_footprint(r) == 30     # prompt 20 + predicted OL 10
    # a wild over-prediction never charges above the worst case
    big = SCHEDULERS["vllm"](limits=BatchLimits(cap=10_000), latency_model=lm,
                             kv_admission="predicted")
    big.add_relquery(copy.deepcopy(rq), 0.0)
    r2 = big.relqueries["A"].requests[0]
    for _ in range(8):
        big.predictor.observe(big._template_key(r2), 1000)
    assert big._kv_footprint(r2) == 120


def test_predicted_underprediction_rescued_by_valve():
    """Predicted admission packs two requests whose true growth busts the
    cap; the resident-measure pressure valve preempts instead of
    deadlocking, and everything finishes under the cap."""
    lm = a100_opt13b()
    sched = SCHEDULERS["vllm"](limits=BatchLimits(cap=260), latency_model=lm,
                               kv_admission="predicted")
    core = EngineCore(sched, SimulatedExecutor(lm))
    a = make_relquery("A", [[7] * 100] * 2, 0.0, 60)   # true fp 161 each
    core.admit(a, 0.0)
    for _ in range(6):                                 # predicted fp 102 each
        sched.predictor.observe(sched._template_key(a.requests[0]), 2)
    for _ in _drain(core):
        assert sched.tokens_in_use + sched.partial_prefill_tokens \
            <= sched.limits.cap, "predicted admission overshot resident KV"
    assert a.is_finished()
    assert sched.preemptions > 0, "valve never fired — cap was not stressed"
    assert sched.tokens_in_use == 0 and sched.committed_tokens == 0


# --------------------------------------------------------- real executor swap
@pytest.mark.parametrize("backend", ["dense", "paged"])
def test_real_executor_swap_roundtrip_preserves_stream(backend):
    """Force a mid-run device->host->device round trip on the real JAX
    backends: the restored KV must continue the exact greedy stream of an
    undisturbed run (per-position comparison — req_ids are process-global)."""
    import jax

    from repro.configs import get_smoke_config
    from repro.engine.executor import make_real_executor
    from repro.engine.tokenizer import HashTokenizer
    from repro.models.registry import build_model

    cfg = get_smoke_config("qwen3-1.7b")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    tok = HashTokenizer(vocab_size=cfg.vocab_size - 2)
    prompts = [tok.encode(f"row {i} of the relational table") for i in range(2)]

    def run(force_swap):
        rq = make_relquery("A", [list(p) for p in prompts], 0.0, 8)
        sched = _tiered_sched(cap=4096)
        ex = make_real_executor(backend, model, params, max_slots=8,
                                max_len=256, num_blocks=128, block_size=16,
                                num_host_blocks=128)
        core = EngineCore(sched, ex, debug_invariants=True)
        core.admit(rq, 0.0)
        now, steps = 0.0, 0
        while core.has_work():
            ev = core.tick(now)
            now = ev.end
            steps += 1
            if force_swap and steps == 2 and sched._running:
                sched.swap_out_request(sched._running[-1], now)
                core._apply_swaps()
        assert rq.is_finished()
        return sched, [list(r.output_tokens) for r in rq.requests]

    base_sched, base = run(False)
    swap_sched, swapped = run(True)
    assert base_sched.swap_outs == 0
    assert swap_sched.swap_outs >= 1
    assert swap_sched.swap_ins == swap_sched.swap_outs
    assert swapped == base, "host round trip corrupted the restored KV"
