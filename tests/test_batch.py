"""Unit tests for the unified Batch abstraction: cost() against the linear
latency model for every kind, chunk semantics, and the legacy aliases."""
import pytest

from repro.core.batch import Batch, CandidateBatch, ScheduledBatch
from repro.core.latency_model import a100_opt13b
from repro.core.relquery import make_relquery


def _rq(rel_id="a", n=4, tok=50, ol=8):
    return make_relquery(rel_id, [[1] * tok] * n, 0.0, ol)


def test_cost_matches_latency_model_per_kind():
    lm = a100_opt13b()
    rq = _rq()
    p = Batch.prefill(rq.requests, uncached_tokens=120, relquery=rq)
    assert p.cost(lm) == pytest.approx(lm.prefill_time(120))
    d = Batch.decode(rq.requests)
    assert d.cost(lm) == pytest.approx(lm.decode_time(4))
    m = Batch.mixed(rq.requests[:2], rq.requests[2:],
                    {r.req_id: 10 for r in rq.requests[:2]}, uncached_tokens=20)
    assert m.cost(lm) == pytest.approx(lm.mixed_time(20, 2))
    # executors substitute the measured uncached count
    assert p.cost(lm, true_uncached=40) == pytest.approx(lm.prefill_time(40))
    assert m.cost(lm, true_uncached=5) == pytest.approx(lm.mixed_time(5, 2))


def test_chunk_semantics():
    rq = _rq(tok=100)
    r = rq.requests[0]
    full = Batch.prefill([r], uncached_tokens=100)
    assert full.chunk_of(r) == 100 and full.completes_prompt(r)
    part = Batch.mixed([r], [], {r.req_id: 30}, uncached_tokens=30)
    assert part.chunk_of(r) == 30 and not part.completes_prompt(r)
    r.prefilled_tokens = 70
    assert part.completes_prompt(r)          # 70 + 30 covers the prompt
    assert full.chunk_of(r) == 30            # default chunk = remaining prompt


def test_views_and_priorities():
    rq = _rq()
    m = Batch.mixed(rq.requests[:1], rq.requests[1:], {})
    assert m.num_requests == 4
    assert m.all_requests() == rq.requests
    assert m.rel_ids() == ("a",)
    prio = {r.req_id: float(i) for i, r in enumerate(rq.requests)}
    assert m.min_priority(lambda r: prio[r.req_id]) == 0.0
    assert m.min_prefill_priority(lambda r: prio[r.req_id]) == 0.0
    d = Batch.decode(rq.requests)
    assert d.requests == rq.requests         # legacy primary-list view
    with pytest.raises(ValueError):
        Batch("bogus")


def test_legacy_aliases_build_unified_batches():
    rq = _rq()
    c = CandidateBatch(rq.requests, uncached_tokens=7, relquery=rq)
    assert isinstance(c, Batch) and c.kind == "prefill"
    assert c.uncached_tokens == 7 and c.relquery is rq

    s = ScheduledBatch("decode", rq.requests)
    assert isinstance(s, Batch) and s.decode_requests == rq.requests
    mixed = ScheduledBatch("mixed", rq.requests[:2], uncached_tokens=3,
                           decode_requests=rq.requests[2:],
                           prefill_chunks={rq.requests[0].req_id: 3})
    assert mixed.kind == "mixed" and mixed.num_requests == 4
    assert mixed.prefill_chunks[rq.requests[0].req_id] == 3
