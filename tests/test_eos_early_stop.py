"""EOS early termination: a request that hits EOS (or its simulated actual
output length) before max_output_tokens finishes early in BOTH executors,
frees its KV footprint, and the relQuery's tail latency reflects it."""
import jax
import pytest

from repro.configs import get_smoke_config
from repro.core.latency_model import a100_opt13b
from repro.core.policies import SCHEDULERS
from repro.core.priority import BatchLimits
from repro.core.relquery import make_relquery
from repro.engine.engine import ServingEngine
from repro.engine.executor import RealExecutor
from repro.engine.prefix_cache import PrefixCache
from repro.engine.simulator import SimulatedExecutor
from repro.models.registry import build_model


EOS = 7


def _sim_run(sim_output_len, max_output=12):
    lm = a100_opt13b()
    rq = make_relquery("q", [[1, 2, 3] * 8] * 3, 0.0, max_output, eos_token=EOS)
    for r in rq.requests:
        r.sim_output_len = sim_output_len
    sched = SCHEDULERS["relserve"](latency_model=lm)
    engine = ServingEngine(sched, SimulatedExecutor(lm))
    report = engine.run_trace([rq])
    return rq, sched, report


def test_simulated_executor_eos_early_stop():
    rq, sched, _ = _sim_run(sim_output_len=3)
    for r in rq.requests:
        assert len(r.output_tokens) == 3          # stopped well before OL=12
        assert r.output_tokens[-1] == EOS         # the final token is EOS
    # KV footprint fully released
    assert sched.tokens_in_use == 0
    assert sched.committed_tokens == 0

    full_rq, _, _ = _sim_run(sim_output_len=12)
    assert rq.latency() < full_rq.latency()       # tail latency reflects EOS
    assert rq.tail_running_time() < full_rq.tail_running_time()


@pytest.fixture(scope="module")
def qwen_model():
    cfg = get_smoke_config("qwen3-1.7b")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def _real_run(model, params, eos_token, max_output=6):
    prompts = [[11, 12, 13, 14, 15], [21, 22, 23, 24]]
    rq = make_relquery("q", prompts, 0.0, max_output, eos_token=eos_token)
    sched = SCHEDULERS["relserve"](limits=BatchLimits(cap=100_000))
    ex = RealExecutor(model, params, max_slots=8, max_len=256,
                      prefix_cache=PrefixCache(block_size=16))
    ServingEngine(sched, ex).run_trace([rq])
    return rq, sched, ex


def test_real_executor_eos_early_stop(qwen_model):
    _, model, params = qwen_model
    # Greedy decoding is deterministic: learn the token stream without EOS,
    # then declare the second generated token to *be* EOS and re-run.
    probe_rq, _, _ = _real_run(model, params, eos_token=None)
    probe = probe_rq.requests[0]
    assert len(probe.output_tokens) == probe.max_output_tokens  # full length
    eos = probe.output_tokens[1]

    rq, sched, ex = _real_run(model, params, eos_token=eos)
    early = rq.requests[0]
    assert len(early.output_tokens) < early.max_output_tokens
    assert early.output_tokens[-1] == eos         # stopped exactly at EOS
    # engine-side KV and executor-side decode slots fully released
    assert sched.tokens_in_use == 0
    assert sched.committed_tokens == 0
    assert ex._slot_of == {}
    assert all(s is None for s in ex.slots)
    # the relQuery's latency bookkeeping reflects the early finish
    assert rq.finish_time is not None
    assert rq.latency() <= probe_rq.latency()


def test_real_executor_honors_exact_output_budget(qwen_model):
    """Regression for the decode off-by-one: with no EOS configured a request
    must produce exactly max_output_tokens, not one fewer."""
    _, model, params = qwen_model
    rq, _, _ = _real_run(model, params, eos_token=None, max_output=4)
    for r in rq.requests:
        assert len(r.output_tokens) == 4
