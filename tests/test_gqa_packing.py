"""GQA TP head-packing exactness + layout properties (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.distributed.sharding import (
    gqa_layout, pack_kv_weight, pack_q_weight, unpack_q_output,
)


@given(kv=st.integers(1, 16), qpk=st.integers(1, 8),
       tp=st.sampled_from([1, 2, 4, 8, 16]))
@settings(max_examples=200, deadline=None)
def test_layout_properties(kv, qpk, tp):
    H = kv * qpk
    lay = gqa_layout(H, kv, tp)
    # slots divisible by tp
    assert lay.kv_slots % max(tp, 1) == 0 or tp == 1
    # every true q head appears exactly once
    seen = [q for row in lay.q_map for q in row if q >= 0]
    assert sorted(seen) == list(range(H))
    # q head in slot s belongs to the kv head stored in slot s
    for s, row in enumerate(lay.q_map):
        for q in row:
            if q >= 0:
                assert q // qpk == lay.dup_map[s]
    # dup_map covers every kv head, monotone
    assert sorted(set(lay.dup_map)) == list(range(kv))
    assert list(lay.dup_map) == sorted(lay.dup_map)


def _canonical_gqa(x, wq, wk, wv, wo, H, KV, hd):
    """Reference attention with canonical [H]-major weights."""
    qpk = H // KV
    q = jnp.einsum("bd,dhk->bhk", x, wq)
    k = jnp.einsum("bd,dgk->bgk", x, wk)
    v = jnp.einsum("bd,dgk->bgk", x, wv)
    kq = jnp.repeat(k, qpk, axis=1)   # map kv->q heads
    vq = jnp.repeat(v, qpk, axis=1)
    s = jax.nn.softmax(jnp.einsum("bhk,chk->bhc", q, kq) / np.sqrt(hd), axis=-1)
    o = jnp.einsum("bhc,chk->bhk", s, vq)
    return jnp.einsum("bhk,hkd->bd", o, wo)


@pytest.mark.parametrize("H,KV,tp", [(4, 2, 4), (14, 2, 16), (40, 8, 16),
                                     (25, 5, 16), (8, 8, 16)])
def test_packed_attention_exact(H, KV, tp):
    """Packed (duplicated-KV, padded-Q) layout computes the same attention as
    the canonical layout — with zero pad weights the math is exact."""
    hd, D, B = 8, 16, 3
    lay = gqa_layout(H, KV, tp)
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(B, D), jnp.float32)
    wq = rng.randn(D, H, hd).astype(np.float32)
    wk = rng.randn(D, KV, hd).astype(np.float32)
    wv = rng.randn(D, KV, hd).astype(np.float32)
    wo = rng.randn(H, hd, D).astype(np.float32)

    ref = _canonical_gqa(x, jnp.asarray(wq), jnp.asarray(wk), jnp.asarray(wv),
                         jnp.asarray(wo), H, KV, hd)

    wq_p = pack_q_weight(wq, lay, head_axis=1)        # [D, KVs*Qp, hd]
    wo_p = pack_q_weight(wo, lay, head_axis=0)        # [KVs*Qp, hd, D]
    wk_p = pack_kv_weight(wk, lay, head_axis=1)       # [D, KVs, hd]
    wv_p = pack_kv_weight(wv, lay, head_axis=1)
    G, Qp = lay.kv_slots, lay.q_per_slot
    q = jnp.einsum("bd,dgqk->bgqk", x, jnp.asarray(wq_p.reshape(D, G, Qp, hd)))
    k = jnp.einsum("bd,dgk->bgk", x, jnp.asarray(wk_p))
    v = jnp.einsum("bd,dgk->bgk", x, jnp.asarray(wv_p))
    s = jax.nn.softmax(jnp.einsum("bgqk,cgk->bgqc", q, k) / np.sqrt(hd), axis=-1)
    o = jnp.einsum("bgqc,cgk->bgqk", s, v)
    out = jnp.einsum("bgqk,gqkd->bd", o, jnp.asarray(wo_p.reshape(G, Qp, hd, D)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4)


def test_unpack_roundtrip():
    H, KV, tp, hd = 25, 5, 16, 4
    lay = gqa_layout(H, KV, tp)
    w = np.random.RandomState(1).randn(3, H, hd).astype(np.float32)
    packed = pack_q_weight(w, lay, head_axis=1)
    back = unpack_q_output(packed, lay, head_axis=1)
    np.testing.assert_array_equal(back, w)
