"""EngineCore step semantics: caller-owned clock, one batch per tick, idle
signalling, and the descriptive deadlock error replacing silent drops."""
import pytest

from repro.core.latency_model import a100_opt13b
from repro.core.policies import SCHEDULERS
from repro.core.priority import BatchLimits
from repro.core.relquery import make_relquery
from repro.engine.engine import EngineCore, EngineDeadlockError, ServingEngine
from repro.engine.simulator import SimulatedExecutor


def _core(cap=16384, sched_name="relserve"):
    lm = a100_opt13b()
    sched = SCHEDULERS[sched_name](limits=BatchLimits(cap=cap), latency_model=lm)
    return EngineCore(sched, SimulatedExecutor(lm))


def test_tick_steps_one_batch_at_a_time():
    core = _core()
    assert core.tick(0.0) is None            # nothing admitted -> idle
    rq = make_relquery("a", [[1] * 40] * 3, 0.0, 2)
    core.admit(rq, 0.0)
    assert core.has_work() and core.load() == 3

    ev = core.tick(5.0)                      # caller chose the clock
    assert ev.kind == "prefill" and ev.start == 5.0 and ev.end > 5.0
    assert rq.first_prefill_start == 5.0

    ev2 = core.tick(ev.end)
    assert ev2.kind == "decode" and ev2.start == ev.end
    assert not core.has_work()               # OL=2: prefill tok + 1 decode tok
    assert core.tick(ev2.end) is None        # drained -> idle again
    assert core.iterations == 2
    assert rq.latency() == pytest.approx(ev2.end)


def test_tick_raises_descriptive_deadlock():
    core = _core(cap=50)                     # request needs 100 + 10 > 50
    rq = make_relquery("stuck", [[1] * 100], 0.0, 10)
    core.admit(rq, 0.0)
    with pytest.raises(EngineDeadlockError) as ei:
        core.tick(0.0)
    err = ei.value
    assert err.tokens_in_use == 0 and err.cap == 50
    assert err.stuck_rel_ids == ["stuck"]
    assert "stuck" in str(err) and "cap=50" in str(err)


def test_run_trace_surfaces_deadlock_instead_of_silent_drop():
    lm = a100_opt13b()
    sched = SCHEDULERS["vllm"](limits=BatchLimits(cap=64), latency_model=lm)
    engine = ServingEngine(sched, SimulatedExecutor(lm))
    ok = make_relquery("fits", [[1] * 10], 0.0, 4)
    bad = make_relquery("too-big", [[1] * 200], 1.0, 4)
    with pytest.raises(EngineDeadlockError) as ei:
        engine.run_trace([ok, bad])
    assert "too-big" in ei.value.stuck_rel_ids


def test_run_trace_equivalent_to_manual_ticks():
    """ServingEngine is exactly the EngineCore step loop."""
    trace = [make_relquery("a", [[1] * 30] * 2, 0.0, 3),
             make_relquery("b", [[2] * 25] * 2, 0.1, 3)]
    import copy
    t1, t2 = copy.deepcopy(trace), copy.deepcopy(trace)

    lm = a100_opt13b()
    eng = ServingEngine(SCHEDULERS["relserve"](latency_model=lm),
                        SimulatedExecutor(lm))
    rep = eng.run_trace(t1)

    core = EngineCore(SCHEDULERS["relserve"](latency_model=lm),
                      SimulatedExecutor(lm))
    now, idx = 0.0, 0
    pending = sorted(t2, key=lambda r: r.arrival_time)
    while idx < len(pending) or core.has_work():
        while idx < len(pending) and pending[idx].arrival_time <= now:
            core.admit(pending[idx], now)
            idx += 1
        if not core.has_work():
            now = pending[idx].arrival_time
            continue
        ev = core.tick(now)
        now = ev.end
    manual = core.report(now)
    assert manual.latencies == rep.latencies
    assert manual.end_to_end == rep.end_to_end
