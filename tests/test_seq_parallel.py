"""Sequence-parallel decode (beyond-paper optimization) correctness: on a real
multi-device mesh (subprocess, 8 host devices), the SP decode step must
reproduce the baseline packed-TP decode step given equivalent weights and a
resharded cache."""
import json
import os
import subprocess
import sys

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, __SRC__)
import json
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_smoke_config
from repro.distributed.sharding import ParallelConfig, pack_q_weight, pack_kv_weight
from repro.launch.mesh import compat_make_mesh, compat_set_mesh
from repro.models.transformer import DenseTransformer
from repro.models.seq_parallel import SeqParallelDenseTransformer, reshard_cache_from_packed

mesh = compat_make_mesh((2, 4), ("data", "model"))
compat_set_mesh(mesh)
pc = ParallelConfig.from_mesh(mesh)
cfg = get_smoke_config("qwen3-1.7b").replace(num_layers=2)
base = DenseTransformer(cfg, pc)
sp = SeqParallelDenseTransformer(cfg, pc, mesh=mesh)

rng = np.random.RandomState(0)
D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
G = cfg.num_layers

# canonical attention weights -> both layouts
def mk(*shape, scale=0.1):
    return rng.randn(*shape).astype(np.float32) * scale

params_sp = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), sp.abstract_params())
params_b = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), base.abstract_params())

shared = {}
for name in ("ln1", "ln2", "q_norm", "k_norm", "w_gate", "w_up", "w_down"):
    shared[name] = mk(*params_sp["blocks"][name].shape)
emb = mk(*params_sp["embed"].shape)
fin = mk(*params_sp["final_norm"].shape)

wq_c = mk(G, 1, D, H, hd)
wk_c = mk(G, 1, D, KV, hd)
wv_c = mk(G, 1, D, KV, hd)
wo_c = mk(G, 1, H, hd, D)

pb = dict(params_b["blocks"])
lay = base.layout
pb["wq"] = jnp.asarray(np.stack([np.stack([
    pack_q_weight(wq_c[g, 0], lay, head_axis=1).reshape(D, lay.kv_slots, lay.q_per_slot, hd)
    for _ in range(1)]) for g in range(G)]), jnp.bfloat16)
pb["wk"] = jnp.asarray(np.stack([np.stack([
    pack_kv_weight(wk_c[g, 0], lay, head_axis=1) for _ in range(1)]) for g in range(G)]), jnp.bfloat16)
pb["wv"] = jnp.asarray(np.stack([np.stack([
    pack_kv_weight(wv_c[g, 0], lay, head_axis=1) for _ in range(1)]) for g in range(G)]), jnp.bfloat16)
pb["wo"] = jnp.asarray(np.stack([np.stack([
    pack_q_weight(wo_c[g, 0], lay, head_axis=0).reshape(lay.kv_slots, lay.q_per_slot, hd, D)
    for _ in range(1)]) for g in range(G)]), jnp.bfloat16)
for name, v in shared.items():
    pb[name] = jnp.asarray(v, jnp.bfloat16)
params_b = {"embed": jnp.asarray(emb, jnp.bfloat16), "blocks": pb, "final_norm": jnp.asarray(fin, jnp.bfloat16)}

ps = dict(params_sp["blocks"])
ps["wq"] = jnp.asarray(wq_c, jnp.bfloat16)
ps["wk"] = jnp.asarray(wk_c, jnp.bfloat16)
ps["wv"] = jnp.asarray(wv_c, jnp.bfloat16)
ps["wo"] = jnp.asarray(wo_c.reshape(G, 1, H * hd, D), jnp.bfloat16)
for name, v in shared.items():
    ps[name] = jnp.asarray(v, jnp.bfloat16)
params_sp = {"embed": jnp.asarray(emb, jnp.bfloat16), "blocks": ps, "final_norm": jnp.asarray(fin, jnp.bfloat16)}

# prefill on baseline -> decode on both
B, S, MAX = 2, 12, 16
toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)), jnp.int32)
_, cache_b = base.prefill(params_b, toks, max_len=MAX)
cache_sp = reshard_cache_from_packed(cache_b, base, sp)

new_tok = jnp.asarray(rng.randint(0, cfg.vocab_size, (B,)), jnp.int32)
pos = jnp.full((B,), S, jnp.int32)
lg_b, _ = base.decode_step(params_b, cache_b, new_tok, pos)

with mesh:
    step = jax.jit(sp.decode_step)
    lg_sp, cache_sp2 = step(params_sp, cache_sp, new_tok, pos)

err = float(jnp.max(jnp.abs(lg_sp.astype(jnp.float32) - lg_b.astype(jnp.float32))))
scale = float(jnp.max(jnp.abs(lg_b)))
# second decode step: cache write must have landed in the right chunk
lg_sp2, _ = step(params_sp, cache_sp2, new_tok, pos + 1)
_, cb2 = base.decode_step(params_b, cache_b, new_tok, pos)
lg_b2, _ = base.decode_step(params_b, cb2, new_tok, pos + 1)
err2 = float(jnp.max(jnp.abs(lg_sp2.astype(jnp.float32) - lg_b2.astype(jnp.float32))))
print("RESULT:" + json.dumps({"err": err, "err2": err2, "scale": scale}))
"""


def test_seq_parallel_decode_matches_baseline():
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    proc = subprocess.run([sys.executable, "-c", _SCRIPT.replace("__SRC__", repr(src))],
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")][-1]
    r = json.loads(line[len("RESULT:"):])
    tol = 0.02 * max(r["scale"], 1.0)
    assert r["err"] < tol, f"first decode mismatch: {r}"
    assert r["err2"] < tol, f"second decode mismatch (cache write broken): {r}"
