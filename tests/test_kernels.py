"""Pallas kernels vs pure-jnp oracles (interpret=True), sweeping shapes/dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_prefill import flash_prefill
from repro.kernels.paged_attention import paged_attention
from repro.kernels.ref import (
    flash_prefill_ref, paged_attention_ref, rwkv6_chunk_ref,
)
from repro.kernels.rwkv6_chunk import rwkv6_chunk

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,KV,Qp,hd,page,maxp", [
    (2, 2, 1, 32, 8, 4),
    (4, 2, 3, 64, 16, 6),
    (1, 4, 2, 128, 16, 3),
])
def test_paged_attention(B, KV, Qp, hd, page, maxp, dtype):
    P = B * maxp + 2
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, KV, Qp, hd)).astype(dtype)
    kp = jax.random.normal(ks[1], (P, page, KV, hd)).astype(dtype)
    vp = jax.random.normal(ks[2], (P, page, KV, hd)).astype(dtype)
    rng = np.random.RandomState(0)
    bt = rng.permutation(P)[: B * maxp].reshape(B, maxp).astype(np.int32)
    cl = rng.randint(1, page * maxp + 1, size=(B,)).astype(np.int32)
    out = paged_attention(q, kp, vp, jnp.asarray(bt), jnp.asarray(cl), interpret=True)
    ref = paged_attention_ref(q, kp, vp, jnp.asarray(bt), jnp.asarray(cl))
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,G,S,R,hd,T,causal,window,qoff", [
    (2, 2, 64, 2, 32, 64, True, 0, 0),
    (1, 3, 128, 1, 64, 128, True, 0, 0),
    (2, 2, 64, 2, 32, 64, True, 16, 0),     # sliding window
    (1, 2, 32, 3, 64, 96, True, 0, 64),     # prefix-cache offset
    (2, 1, 64, 1, 32, 64, False, 0, 0),     # non-causal (whisper encoder)
])
def test_flash_prefill(B, G, S, R, hd, T, causal, window, qoff, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, G, S, R, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, G, T, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, G, T, hd)).astype(dtype)
    out = flash_prefill(q, k, v, causal=causal, window=window, q_offset=qoff,
                        q_block=32, kv_block=32, interpret=True)
    ref = flash_prefill_ref(q, k, v, causal=causal, window=window, q_offset=qoff)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("B,c,H,K", [(2, 16, 2, 16), (1, 32, 4, 32), (2, 64, 2, 64)])
def test_rwkv6_chunk(B, c, H, K):
    ks = jax.random.split(KEY, 6)
    r = jax.random.normal(ks[0], (B, c, H, K))
    k = jax.random.normal(ks[1], (B, c, H, K))
    v = jax.random.normal(ks[2], (B, c, H, K))
    logw = -jnp.exp(jax.random.normal(ks[3], (B, c, H, K)) * 0.5)
    u = jax.random.normal(ks[4], (H, K)) * 0.1
    s0 = jax.random.normal(ks[5], (B, H, K, K))
    o, s = rwkv6_chunk(r, k, v, logw, u, s0, interpret=True)
    o_ref, s_ref = rwkv6_chunk_ref(r, k, v, logw, u, s0)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), atol=5e-4, rtol=5e-4)


def test_rwkv6_chunk_chain_matches_long_recurrence():
    """Chaining chunk kernels across a sequence == one long recurrence."""
    B, c, H, K, nchunks = 1, 16, 2, 16, 4
    ks = jax.random.split(KEY, 5)
    T = c * nchunks
    r = jax.random.normal(ks[0], (B, T, H, K))
    k = jax.random.normal(ks[1], (B, T, H, K))
    v = jax.random.normal(ks[2], (B, T, H, K))
    logw = -jnp.exp(jax.random.normal(ks[3], (B, T, H, K)) * 0.5)
    u = jax.random.normal(ks[4], (H, K)) * 0.1
    s = jnp.zeros((B, H, K, K))
    outs = []
    for i in range(nchunks):
        sl = slice(i * c, (i + 1) * c)
        o, s = rwkv6_chunk(r[:, sl], k[:, sl], v[:, sl], logw[:, sl], u, s,
                           interpret=True)
        outs.append(o)
    o_all = jnp.concatenate(outs, axis=1)
    o_ref, s_ref = rwkv6_chunk_ref(r, k, v, logw, u, jnp.zeros((B, H, K, K)))
    np.testing.assert_allclose(np.asarray(o_all), np.asarray(o_ref),
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               atol=1e-3, rtol=1e-3)
