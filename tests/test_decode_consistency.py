"""Cache-correctness: decode_step continuing a prefix must reproduce the
last-token logits of a one-longer prefill, for every architecture — the
invariant that makes continuous batching exact. Also checks pad-masked prefill
(bucketed executor) against exact-length prefill."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models.registry import build_model

KEY = jax.random.PRNGKey(1)
B, S = 2, 16


def _rel_err(a, b):
    scale = float(jnp.max(jnp.abs(b))) or 1.0
    return float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))) / scale


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_prefill(arch):
    cfg = get_smoke_config(arch)
    m = build_model(cfg)
    params = m.init_params(KEY)
    tk = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab_size)
    if cfg.family == "audio":
        frames = jax.random.normal(KEY, (B, 12, cfg.d_model))
        _, cache = m.prefill(params, tk[:, :8], frames=frames)
        lg, _ = m.decode_step(params, cache, tk[:, 8], jnp.full((B,), 8, jnp.int32))
        ref, _ = m.prefill(params, tk[:, :9], frames=frames)
    else:
        _, cache = m.prefill(params, tk[:, :S], max_len=S + 4)
        lg, _ = m.decode_step(params, cache, tk[:, S], jnp.full((B,), S, jnp.int32))
        ref, _ = m.prefill(params, tk[:, :S + 1], max_len=S + 5)
    assert _rel_err(lg, ref) < 0.02, f"{arch}: decode diverges from prefill"


@pytest.mark.parametrize("arch", ["rwkv6-7b", "hymba-1.5b", "gemma3-12b", "qwen3-1.7b"])
def test_padded_prefill_matches_exact(arch):
    cfg = get_smoke_config(arch)
    m = build_model(cfg)
    params = m.init_params(KEY)
    n, pad_to = 13, 32
    tk = jax.random.randint(KEY, (B, n + 1), 0, cfg.vocab_size)
    sl = jnp.full((B,), n, jnp.int32)
    toks_p = jnp.zeros((B, pad_to), jnp.int32).at[:, :n].set(tk[:, :n])
    lg_pad, cache = m.prefill(params, toks_p, seq_lens=sl, max_len=64)
    lg_exact, _ = m.prefill(params, tk[:, :n], max_len=64)
    # bf16 noise from different block shapes; gemma3's sqrt(d) embed scale
    # amplifies magnitudes, so allow ~1 bf16 ulp of relative error
    assert _rel_err(lg_pad, lg_exact) < 1e-2, f"{arch}: pad-masked prefill differs"
    lg_d, _ = m.decode_step(params, cache, tk[:, n], sl)
    lg_ref, _ = m.prefill(params, tk[:, :n + 1], max_len=64)
    assert _rel_err(lg_d, lg_ref) < 0.02, f"{arch}: decode after padded prefill differs"


def test_ragged_batch_decode():
    """Two sequences with different lengths in one slot batch stay independent."""
    cfg = get_smoke_config("qwen3-1.7b")
    m = build_model(cfg)
    params = m.init_params(KEY)
    n1, n2 = 9, 14
    tk = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    sl = jnp.asarray([n1, n2], jnp.int32)
    toks = jnp.zeros((2, 16), jnp.int32)
    toks = toks.at[0, :n1].set(tk[0, :n1]).at[1, :n2].set(tk[1, :n2])
    _, cache = m.prefill(params, toks, seq_lens=sl, max_len=32)
    lg, _ = m.decode_step(params, cache, tk[:, 0], sl)
    # reference: each sequence alone
    _, c1 = m.prefill(params, tk[:1, :n1], max_len=32)
    r1, _ = m.decode_step(params, c1, tk[:1, 0], jnp.asarray([n1], jnp.int32))
    _, c2 = m.prefill(params, tk[1:, :n2], max_len=32)
    r2, _ = m.decode_step(params, c2, tk[1:, 0], jnp.asarray([n2], jnp.int32))
    assert _rel_err(lg[:1], r1) < 0.02
    assert _rel_err(lg[1:], r2) < 0.02
