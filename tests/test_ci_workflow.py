"""Fast-lane drift guard for ``.github/workflows/ci.yml``.

The CI fast job runs an explicit file list (plus the multi-device sharding
trio as its own step), and the full job sweeps everything. That split only
stays honest if every new test module is consciously placed: either added to
the fast lane or recorded in the explicit full-job-only allowlist below.
A module in neither is silent drift — it would run nowhere until the full
job happens to pick it up, with no record of why it skipped the fast lane.

Parsed with regexes, not a yaml library — the workflow is hand-maintained
and the dependency footprint stays zero.
"""
from __future__ import annotations

import re
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
WORKFLOW = REPO / ".github" / "workflows" / "ci.yml"

# The multi-device sharding trio runs as its own fast-job step (subprocess,
# XLA_FLAGS host devices) — still the fast lane, just not the main list.
SHARDING_TRIO = {
    "test_dryrun_small.py",
    "test_moe_dispatch.py",
    "test_seq_parallel.py",
}

# Modules that deliberately run ONLY in the full job: they compile real
# models / kernels and would blow the fast lane's budget. Adding a test
# module to neither the fast list nor this allowlist fails this guard —
# the placement decision must be explicit.
FULL_JOB_ONLY = {
    "test_decode_consistency.py",   # real-model greedy decode parity
    "test_engine_real.py",          # real executor end-to-end
    "test_eos_early_stop.py",       # real-model EOS handling
    "test_gqa_packing.py",          # attention head-packing kernels
    "test_kernels.py",              # pallas kernel suite
    "test_models_smoke.py",         # every registry arch compiles + runs
    "test_roofline_accounting.py",  # flop/byte accounting on real models
    "test_training.py",             # training-loop smoke
}


def _workflow_text() -> str:
    assert WORKFLOW.exists(), f"workflow file moved? {WORKFLOW}"
    return WORKFLOW.read_text(encoding="utf-8")


def _fast_lane_modules(text: str) -> set:
    """Every tests/test_*.py named anywhere in the workflow. Only the fast
    job lists individual test files (smoke runs CLIs/benches, full sweeps
    the whole suite), so this is exactly the fast lane + sharding trio."""
    return {m.rsplit("/", 1)[1]
            for m in re.findall(r"tests/test_\w+\.py", text)}


def test_every_test_module_has_an_explicit_lane():
    on_disk = {p.name for p in (REPO / "tests").glob("test_*.py")}
    listed = _fast_lane_modules(_workflow_text())
    placed = listed | FULL_JOB_ONLY
    drifted = sorted(on_disk - placed)
    assert not drifted, (
        f"test modules in no CI lane: {drifted} — add them to the fast-job "
        f"list in {WORKFLOW} or to FULL_JOB_ONLY in {__file__} (with a "
        f"reason)")


def test_sharding_trio_step_is_intact():
    listed = _fast_lane_modules(_workflow_text())
    missing = sorted(SHARDING_TRIO - listed)
    assert not missing, (
        f"sharding-trio modules vanished from the workflow: {missing}")


def test_full_only_allowlist_is_not_stale():
    on_disk = {p.name for p in (REPO / "tests").glob("test_*.py")}
    gone = sorted(FULL_JOB_ONLY - on_disk)
    assert not gone, f"FULL_JOB_ONLY names deleted modules: {gone}"
    listed = _fast_lane_modules(_workflow_text())
    both = sorted(FULL_JOB_ONLY & listed)
    assert not both, (
        f"modules both in the fast lane and FULL_JOB_ONLY: {both} — drop "
        f"them from the allowlist")


def test_this_guard_runs_in_the_fast_lane():
    # the guard is useless if it only runs in the full sweep
    assert "test_ci_workflow.py" in _fast_lane_modules(_workflow_text())


def test_nightly_lane_covers_slow_marker_and_bench_smokes():
    text = _workflow_text()
    nightly = text[text.index("nightly:"):]
    assert re.search(r"-m slow", nightly), \
        "nightly job must run the -m slow lanes"
    for bench in ("kv_pressure", "prefix_sharing", "real_executor",
                  "async_engine", "planner", "fault_recovery"):
        assert f"benchmarks.{bench} --smoke" in nightly, \
            f"nightly job lost the {bench} --smoke entry point"
    assert "check_regression" in nightly, \
        "nightly job must gate fresh artifacts against the baselines"
