"""Per-arch reduced-config smoke tests (deliverable f): one forward/train step
on CPU asserting output shapes + no NaNs, for every assigned architecture."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models.registry import build_model

KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def _train_batch(cfg):
    if cfg.family == "audio":
        return {
            "frames": jax.random.normal(KEY, (B, S, cfg.d_model)),
            "tokens": jnp.ones((B, 8), jnp.int32),
            "labels": jnp.ones((B, 8), jnp.int32),
        }
    if cfg.family == "vlm":
        P = cfg.num_vision_patches
        return {
            "tokens": jnp.ones((B, S), jnp.int32),
            "labels": jnp.ones((B, S + P), jnp.int32),
            "extra_embeds": jax.random.normal(KEY, (B, P, cfg.d_model)),
        }
    return {"tokens": jnp.ones((B, S), jnp.int32),
            "labels": jnp.ones((B, S), jnp.int32)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init_params(KEY)
    loss, metrics = model.train_loss(params, _train_batch(cfg), remat=False)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch} loss not finite"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init_params(KEY)
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    if cfg.family == "audio":
        frames = jax.random.normal(KEY, (B, 12, cfg.d_model))
        logits, cache = model.prefill(params, toks[:, :8], frames=frames)
        pos = jnp.full((B,), 8, jnp.int32)
    else:
        logits, cache = model.prefill(params, toks, max_len=S + 4)
        pos = jnp.full((B,), S, jnp.int32)
    assert logits.shape[0] == B
    lg2, cache2 = model.decode_step(params, cache, jnp.ones((B,), jnp.int32), pos)
    assert lg2.shape[0] == B and lg2.shape[-1] >= cfg.vocab_size
    assert bool(jnp.all(jnp.isfinite(lg2))), f"{arch} decode logits not finite"
    assert jax.tree.structure(cache2) == jax.tree.structure(cache)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_sanity(arch):
    """Full configs only build abstract params (dry-run exercises them)."""
    cfg = get_config(arch)
    model = build_model(cfg)
    abstract = model.abstract_params()
    n = model.param_count()
    assert n > 0
    # every declared leaf is a proper ShapeDtypeStruct
    for leaf in jax.tree.leaves(abstract):
        assert isinstance(leaf, jax.ShapeDtypeStruct)
