"""Validate the roofline harness's scan-body composition and dot parsing on
single-device lowering: corrected totals must match a fully-unrolled model."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.launch.hlo_stats import collective_stats, dot_flops
from repro.models.registry import build_model


def _prefill_dotflops(cfg, B=2, S=32):
    model = build_model(cfg)
    params = model.abstract_params()
    toks = jax.ShapeDtypeStruct((B, S), jnp.int32)

    def f(p, t):
        return model.prefill(p, t, max_len=S)

    compiled = jax.jit(f).lower(params, toks).compile()
    return dot_flops(compiled.as_text()), model


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "gemma3-12b", "rwkv6-7b"])
def test_scan_composition_exact(arch):
    """full(L) + (G-1)·[1group - 0group] == model with layers unrolled...
    verified by linearity: stats(L groups) - stats(0) must be G x body."""
    cfg = get_smoke_config(arch)
    group = cfg.local_global_pattern + 1 if cfg.attn_kind == "local_global" else 1
    f_full, model = _prefill_dotflops(cfg)
    f_1, _ = _prefill_dotflops(cfg.replace(num_layers=group))
    f_0, _ = _prefill_dotflops(cfg.replace(num_layers=0))
    body = f_1 - f_0
    corrected = f_full + (model.scan_trip_count - 1) * body
    expected = f_0 + model.scan_trip_count * body
    assert corrected == pytest.approx(expected, rel=1e-6)
    # and the scan really does hide (G-1) bodies from the raw count
    assert f_full == pytest.approx(f_0 + body, rel=1e-6)


def test_dot_flops_matches_analytic_matmul():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    compiled = jax.jit(lambda x, y: x @ y).lower(a, b).compile()
    assert dot_flops(compiled.as_text()) == pytest.approx(2 * 64 * 128 * 256, rel=1e-6)


def test_dot_flops_counts_scan_body_once():
    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        out, _ = jax.lax.scan(body, x, ws)
        return out

    x = jax.ShapeDtypeStruct((8, 32), jnp.float32)
    for L in (1, 4):
        ws = jax.ShapeDtypeStruct((L, 32, 32), jnp.float32)
        compiled = jax.jit(f).lower(x, ws).compile()
        flops = dot_flops(compiled.as_text())
        assert flops == pytest.approx(2 * 8 * 32 * 32, rel=1e-6), \
            "scan body must be counted once (the premise of the correction)"


def test_collective_parser_on_sharded_matmul():
    """Needs >1 device to produce collectives; runs in-process only if the
    default device count permits — otherwise exercised by the dry-run suite."""
    if len(jax.devices()) < 2:
        pytest.skip("single-device process; covered by launch.dryrun")
