"""Property-based invariant suite for the KV subsystem: ``BlockManager``
(paged device blocks), ``PrefixCache`` (hash-block LRU) and
``SharedPrefixLedger`` (shared-block admission accounting).

Each test drives a random operation sequence — alloc / extend (decode
append) / fork (shared-prefix alloc) / free — and checks the conservation
law the rest of the system leans on:

- every device block is either free or referenced: free + allocated +
  shared == num_blocks at every step (shared blocks counted once);
- LRU eviction never drops a ref-counted (pinned) block;
- ``match_blocks`` always returns a chain prefix of the query's block hashes;
- ``can_allocate`` never admits an allocation that would cross the watermark;
- the ledger's discount always equals Σ max(0, ref-1)·block_size and drains
  to zero.
"""
import random

from _hypothesis_compat import given, settings, st

from repro.engine.kv_cache import BlockManager, OutOfBlocks, SharedPrefixLedger
from repro.engine.prefix_cache import PrefixCache, block_hashes

OPS = st.lists(
    st.tuples(st.sampled_from(["alloc", "fork", "extend", "free"]),
              st.integers(1, 120), st.integers(0, 7)),
    min_size=1, max_size=60)


def _conservation(bm: BlockManager) -> None:
    bm.check_invariants()
    in_use = set()
    for sid in list(bm._seqs):
        in_use.update(bm.block_table(sid))
    assert bm.free_blocks + len(in_use) == bm.num_blocks


@given(OPS)
@settings(max_examples=60, deadline=None)
def test_block_manager_random_lifecycle_conserves_blocks(ops):
    """alloc/extend/fork/free in any order: free + allocated + shared ==
    num_blocks, with shared prefix blocks appearing once however many
    sequences reference them."""
    bm = BlockManager(num_blocks=96, block_size=8)
    rng = random.Random(0xBEEF)
    live = []                      # seq ids with an allocation
    published = []                 # (keys,) published prefixes to fork from
    counter = [0]

    def fresh_sid():
        counter[0] += 1
        return f"s{counter[0]}"

    for op, tokens, pick in ops:
        if op == "alloc":
            sid = fresh_sid()
            try:
                bm.allocate(sid, tokens)
                live.append(sid)
                # publish this sequence's full blocks as a shareable prefix
                keys = [hash_key for hash_key in
                        block_hashes(list(range(tokens)), 8)]
                bm.register_prefix(sid, keys)
                if keys:
                    published.append(keys)
            except OutOfBlocks:
                pass
        elif op == "fork" and published:
            sid = fresh_sid()
            keys = published[pick % len(published)]
            want = max(tokens, len(keys) * 8)
            # the publishing sequence may have been freed since: only the
            # still-resident leading run of the chain is reusable
            resident = 0
            for key in keys:
                if key in bm._prefix_blocks:
                    resident += 1
                else:
                    break
            if bm.can_allocate(want, cached_blocks=resident):
                alloc = bm.allocate(sid, want, prefix_keys=keys)
                live.append(sid)
                assert alloc.shared_prefix_blocks == resident
        elif op == "extend" and live:
            sid = live[pick % len(live)]
            try:
                bm.append_token(sid)
            except OutOfBlocks:
                pass
        elif op == "free" and live:
            sid = live.pop(pick % len(live))
            bm.free(sid)
        _conservation(bm)

    for sid in list(live):
        bm.free(sid)
    _conservation(bm)
    assert bm.free_blocks == 96, "blocks leaked after freeing every sequence"


@given(OPS)
@settings(max_examples=40, deadline=None)
def test_can_allocate_never_admits_past_watermark(ops):
    """Whenever ``can_allocate`` says yes, performing that allocation leaves
    at least ``watermark_blocks`` free."""
    bm = BlockManager(num_blocks=64, block_size=8, watermark=0.1)
    live = []
    counter = [0]
    for op, tokens, pick in ops:
        admitted = bm.can_allocate(tokens)
        if admitted:
            counter[0] += 1
            sid = f"s{counter[0]}"
            bm.allocate(sid, tokens)    # must not raise: admission was checked
            live.append(sid)
            assert bm.free_blocks >= bm.watermark_blocks, \
                "can_allocate admitted past the watermark"
        elif op == "free" and live:
            bm.free(live.pop(pick % len(live)))
        bm.check_invariants()


@given(st.lists(st.lists(st.integers(0, 50), min_size=1, max_size=40),
                min_size=1, max_size=30),
       st.lists(st.integers(0, 50), min_size=1, max_size=40))
@settings(max_examples=60, deadline=None)
def test_match_blocks_is_always_a_chain_prefix(seqs, query):
    """``match_blocks`` returns exactly the leading run of the query's own
    chained hashes — never a hole, never a foreign key."""
    pc = PrefixCache(block_size=4, capacity_blocks=16)
    for seq in seqs:
        pc.insert(seq)
        matched = pc.match_blocks(query)
        full = block_hashes(query, 4)
        assert matched == full[:len(matched)]
        assert pc.peek_cached(query) == len(matched) * 4


@given(st.lists(st.tuples(st.lists(st.integers(0, 30), min_size=4, max_size=24),
                          st.booleans()),
                min_size=1, max_size=40))
@settings(max_examples=60, deadline=None)
def test_lru_eviction_never_drops_refcounted_block(inserts):
    """Random insert/acquire traffic over a tiny cache: every block some
    live sequence still references (ref_count > 0) survives eviction, even
    when that means temporarily exceeding capacity."""
    pc = PrefixCache(block_size=4, capacity_blocks=6)
    acquired = []                  # key chains currently pinned
    for seq, do_acquire in inserts:
        keys = block_hashes(seq, 4)
        if do_acquire and keys:
            pc.acquire_blocks(keys)
            acquired.append(keys)
        pc.insert(seq)
        for chain in acquired:
            for key in chain:
                assert pc.has_block(key) or pc._pins.get(key, 0) > 0, \
                    "LRU evicted a ref-counted block"
        # pinned blocks may push the cache over capacity; unpinned may not
        unpinned = sum(1 for k, b in pc._blocks.items() if b.ref_count == 0)
        if len(pc) > pc.capacity_blocks:
            assert unpinned == 0 or len(pc) - unpinned <= pc.capacity_blocks
    for chain in acquired:
        pc.release_blocks(chain)
    pc.insert(list(range(7 * 4)))  # one oversized insert forces eviction
    assert len(pc) <= pc.capacity_blocks, \
        "cache stayed over capacity after every pin was released"


@given(st.lists(st.tuples(st.integers(0, 5), st.integers(1, 6), st.booleans()),
                min_size=1, max_size=40))
@settings(max_examples=60, deadline=None)
def test_shared_ledger_discount_invariant(ops):
    """Random acquire/release of overlapping chains: the discount always
    equals Σ max(0, ref-1)·block_size, shared_tokens is a leading run, and a
    fully released ledger is empty with zero discount."""
    ledger = SharedPrefixLedger(block_size=8)
    # chains share prefixes by construction: chain i = first (i+1) keys of a
    # common sequence (exactly how chained block hashes behave)
    base = block_hashes(list(range(6 * 8)), 8)
    held = []
    for which, length, release in ops:
        keys = base[:min(length, len(base))]
        if release and held:
            ledger.release(held.pop(which % len(held)))
        else:
            saved = ledger.acquire(keys)
            held.append(keys)
            assert saved % 8 == 0 and 0 <= saved <= len(keys) * 8
        ledger.check_invariants()
        assert ledger.discount >= 0
        # shared_tokens sees a leading run: if key i is shared, so is i-1
        shared = ledger.shared_tokens(base)
        assert shared % 8 == 0
        for i, k in enumerate(base):
            if not ledger.contains(k):
                assert shared <= i * 8
                break
    for keys in held:
        ledger.release(keys)
    assert ledger.discount == 0 and len(ledger) == 0


TIER_OPS = st.lists(
    st.tuples(st.sampled_from(["alloc", "fork", "extend", "swap_out",
                               "swap_in", "free"]),
              st.integers(1, 120), st.integers(0, 7)),
    min_size=1, max_size=80)


@given(TIER_OPS)
@settings(max_examples=60, deadline=None)
def test_three_tier_random_lifecycle_conserves_blocks(ops):
    """alloc/fork/extend/swap_out/swap_in/free in any order across the
    device and host tiers: both conservation laws hold at every step (device
    free + in-use == num_blocks with shared blocks counted once; host free +
    in-use == num_host_blocks with host blocks never shared), swapping a
    forked sequence never frees a device block its sibling still references,
    and a swapped-in sequence resumes at its exact token count."""
    bm = BlockManager(num_blocks=64, block_size=8, num_host_blocks=48)
    rng = random.Random(0xF00D)
    device, swapped = [], []       # seq ids per tier
    counter = [0]

    def fresh_sid():
        counter[0] += 1
        return f"s{counter[0]}"

    for op, tokens, pick in ops:
        if op == "alloc":
            sid = fresh_sid()
            try:
                bm.allocate(sid, tokens)
                device.append(sid)
            except OutOfBlocks:
                pass
        elif op == "fork" and device:
            parent = device[pick % len(device)]
            child = fresh_sid()
            if bm.free_blocks >= 1:   # CoW appends may need headroom later
                bm.fork(parent, child)
                device.append(child)
        elif op == "extend" and device:
            sid = device[pick % len(device)]
            try:
                bm.append_token(sid)
            except OutOfBlocks:
                pass
        elif op == "swap_out" and device:
            sid = device[pick % len(device)]
            siblings = {s: list(bm.block_table(s)) for s in device if s != sid}
            if bm.can_swap_out(sid):
                ntok = bm.context_len(sid)
                bm.swap_out(sid)
                device.remove(sid)
                swapped.append((sid, ntok))
                assert bm.is_swapped(sid)
                # shared blocks a sibling still references stayed resident
                for s, table in siblings.items():
                    assert bm.block_table(s) == table
        elif op == "swap_in" and swapped:
            sid, ntok = swapped[pick % len(swapped)]
            if bm.can_swap_in(sid):
                plan = bm.swap_in(sid)
                swapped.remove((sid, ntok))
                device.append(sid)
                assert bm.context_len(sid) == ntok
                assert len(plan) == len(bm.block_table(sid))
        elif op == "free":
            pool = device + [s for s, _ in swapped]
            if not pool:
                continue
            sid = pool[pick % len(pool)]
            bm.free(sid)            # lenient: frees whichever tier holds it
            if sid in device:
                device.remove(sid)
            else:
                swapped = [(s, n) for s, n in swapped if s != sid]
        _conservation(bm)
        host_used = sum(len(bm.host_block_table(s)) for s, _ in swapped)
        assert bm.host_free_blocks + host_used == bm.num_host_blocks

    for sid in device + [s for s, _ in swapped]:
        bm.free(sid)
    _conservation(bm)
    assert bm.free_blocks == 64 and bm.host_free_blocks == 48, \
        "blocks leaked across the tiers after freeing every sequence"


def test_cancel_while_prefetching_releases_staged_device_blocks():
    """PR-10 regression: a sequence cancelled between prefetch issue and
    swap-in commit must return its staged device blocks to the free pool —
    via the explicit ``cancel_prefetch`` or the blanket ``free`` (the
    executor's release path) — and a staged-then-committed sequence resumes
    at its exact token count with no block leaked on either tier."""
    bm = BlockManager(num_blocks=16, block_size=8, num_host_blocks=8)
    bm.allocate("a", 24)                           # 3 blocks
    bm.swap_out("a")
    free0 = bm.free_blocks
    plan = bm.prefetch_swap_in("a")
    assert plan is not None and len(plan) == 3
    assert bm.free_blocks == free0 - 3             # staged blocks held
    bm.check_invariants()
    assert bm.prefetch_swap_in("a") is None        # already staged: no-op
    bm.free("a")                                   # cancel path (release)
    bm.check_invariants()
    assert bm.free_blocks == 16 and bm.host_free_blocks == 8

    # explicit cancel: host image survives, only the staging is undone
    bm.allocate("b", 24)
    bm.swap_out("b")
    assert bm.prefetch_swap_in("b") is not None
    bm.cancel_prefetch("b")
    bm.cancel_prefetch("b")                        # idempotent
    bm.check_invariants()
    assert bm.free_blocks == 16                    # staging fully undone
    assert bm.is_swapped("b")                      # still resumable
    bm.prefetch_swap_in("b")
    bm.swap_in("b")                                # commits the staged copy
    assert bm.context_len("b") == 24
    bm.check_invariants()
    bm.free("b")
    assert bm.free_blocks == 16 and bm.host_free_blocks == 8


def test_swap_out_of_fork_keeps_sibling_blocks_alive():
    """Deterministic pin of the shared-sibling rule: swapping out a CoW fork
    moves a self-contained copy to the host and drops only the fork's
    references — the parent keeps every shared device block; freeing the
    parent afterwards releases them exactly once."""
    bm = BlockManager(num_blocks=16, block_size=8, num_host_blocks=8)
    bm.allocate("parent", 24)                      # 3 blocks
    parent_table = list(bm.block_table("parent"))
    bm.fork("parent", "child")
    free_before = bm.free_blocks
    plan = bm.swap_out("child")
    assert [d for d, _ in plan] == parent_table    # full self-contained copy
    assert bm.block_table("parent") == parent_table
    assert bm.free_blocks == free_before           # all blocks still shared
    bm.check_invariants()
    # swap back in: fresh private blocks, disjoint from the parent's
    bm.swap_in("child")
    assert not set(bm.block_table("child")) & set(parent_table)
    assert bm.context_len("child") == 24
    bm.free("parent")
    bm.free("child")
    bm.check_invariants()
    assert bm.free_blocks == 16 and bm.host_free_blocks == 8


_PIPELINED_TRACE = None


def _pipelined_stack():
    """A small preemption-prone serving stack on the pipelined engine loop
    (tight cap, optimistic admission, sharing on), plus a deepcopy of the
    canonical trace. The trace is built once and copied per example."""
    import copy

    from repro.core.latency_model import a100_opt13b
    from repro.core.policies import SCHEDULERS
    from repro.core.priority import BatchLimits, DPUConfig
    from repro.data.datasets import make_dataset
    from repro.data.trace import TraceConfig, build_trace
    from repro.engine.engine import ServingEngine
    from repro.engine.simulator import SimulatedExecutor

    global _PIPELINED_TRACE
    if _PIPELINED_TRACE is None:
        ds = make_dataset("rotten", num_rows=800, seed=21)
        _PIPELINED_TRACE = build_trace(ds, TraceConfig(
            num_relqueries=5, rate=5.0, seed=21, max_requests=6,
            num_templates=2))
    trace = copy.deepcopy(_PIPELINED_TRACE)
    max_fp = max(r.num_prompt_tokens + r.max_output_tokens
                 for rq in trace for r in rq.requests)
    lm = a100_opt13b()
    pc = PrefixCache(block_size=16)
    sched = SCHEDULERS["relserve"](
        limits=BatchLimits(cap=int(max_fp * 1.4)), latency_model=lm,
        prefix_cache=pc, kv_admission="optimistic", prefix_sharing=True,
        dpu_config=DPUConfig(exact_probe=True))
    engine = ServingEngine(sched, SimulatedExecutor(lm, prefix_cache=pc),
                           engine_loop="pipelined")
    return engine, sched, trace


@given(st.lists(st.tuples(st.integers(0, 4), st.integers(0, 4)),
                min_size=1, max_size=4))
@settings(max_examples=12, deadline=None)
def test_pipelined_cancel_interleavings_conserve_ledgers(script):
    """Random (step, cancel) interleavings against the pipelined engine loop
    with a speculative window open between ticks: every cancel flushes the
    in-flight plan, and after the drain all KV ledgers — tokens_in_use,
    committed, partial-chunk, shared discount — are exactly zero, with no
    speculative placeholder left in any surviving stream."""
    from repro.serving.frontend import Frontend

    engine, sched, trace = _pipelined_stack()
    fe = Frontend(engine)
    try:
        handles = [fe.submit(rq, now=rq.arrival_time) for rq in trace]
        for steps, pick in script:
            for _ in range(steps):
                fe.step()
            fe.cancel(handles[pick % len(handles)])
        fe.drain()
    finally:
        fe.close()
    assert sched.tokens_in_use == 0
    assert sched.committed_tokens == 0
    assert sched.partial_prefill_tokens == 0
    assert sched._shared_ledger.discount == 0
    assert len(sched._shared_ledger) == 0
    for rq in trace:
        for r in rq.requests:
            assert all(t >= 0 for t in r.output_tokens), \
                "speculative placeholder token survived cancel/drain"


# ----------------------------------------------------- proactive tiering (PR 10)
SWAP_LEDGER_OPS = st.lists(
    st.tuples(st.sampled_from(["tick", "out", "in", "prefetch", "cancel"]),
              st.integers(1, 5000), st.integers(0, 7)),
    min_size=1, max_size=80)


@given(SWAP_LEDGER_OPS)
@settings(max_examples=50, deadline=None)
def test_swap_bandwidth_ledger_conservation(ops):
    """Random swap traffic over the shared per-tick bandwidth budget: every
    synchronous charge covers at least the raw transfer (bytes moved /
    budget), charges and ledgers are never negative, and busy-seconds x
    budget == bytes-moved holds after every op — including prefetch issues
    (billed nothing up front) and cancels (refunds roll both sides back)."""
    from repro.core.latency_model import a100_opt13b
    from repro.engine.simulator import SimulatedExecutor

    ex = SimulatedExecutor(a100_opt13b(), swap_bandwidth_gbps=8.0)
    bw = ex.swap_bandwidth_bytes
    now, counter = 0.0, [0]
    ex.begin_swap_tick(now)
    swapped, staged = [], []       # (req_id, tokens) per state
    for op, tokens, pick in ops:
        if op == "tick":
            now += tokens / 1000.0
            ex.begin_swap_tick(now)
        elif op == "out":
            counter[0] += 1
            rid = f"r{counter[0]}"
            charge = ex.swap_out(rid, tokens)
            assert charge >= tokens * ex.kv_bytes_per_token / bw - 1e-9
            swapped.append((rid, tokens))
        elif op == "in" and swapped:
            rid, tok = swapped.pop(pick % len(swapped))
            assert ex.swap_in(rid, tok) >= 0.0
            staged = [(r, t) for r, t in staged if r != rid]
        elif op == "prefetch" and swapped:
            rid, tok = swapped[pick % len(swapped)]
            assert ex.prefetch_swap_in(rid, tok) == 0.0
            if all(r != rid for r, _ in staged):
                staged.append((rid, tok))
        elif op == "cancel" and staged:
            rid, tok = staged.pop(pick % len(staged))
            swapped = [(r, t) for r, t in swapped if r != rid]
            assert ex.cancel_swap_prefetch(rid, tok) == 0.0
        led = ex.swap_ledger()
        assert led["busy_s"] >= -1e-9 and led["bytes"] >= -1e-9
        assert led["tick_charged_s"] >= -1e-9
        assert abs(led["busy_s"] * bw - led["bytes"]) < 1e-3, \
            "bandwidth ledger out of conservation: busy x budget != bytes"


@given(st.integers(0, 7), st.sampled_from(["relserve", "vllm"]),
       st.floats(0.01, 4.0))
@settings(max_examples=10, deadline=None)
def test_proactive_offload_never_evicts_scheduled_request(seed, name, horizon):
    """Whatever the trace, scheduler and idle horizon: a request the current
    tick's chosen batch schedules is never a proactive-offload victim (the
    offload pass runs before batch choice and removes victims from the
    running list, so the batch cannot contain one — this pins that ordering
    against regression)."""
    import copy

    from repro.core.latency_model import a100_opt13b
    from repro.core.policies import SCHEDULERS
    from repro.core.priority import BatchLimits
    from repro.data.trace import quick_trace
    from repro.engine.engine import ServingEngine
    from repro.engine.simulator import SimulatedExecutor

    trace = quick_trace("rotten", num_relqueries=4, rate=4.0, seed=seed,
                        max_requests=6)
    max_fp = max(r.num_prompt_tokens + r.max_output_tokens
                 for rq in trace for r in rq.requests)
    cap = int(max_fp * 1.2)

    class Guard(SCHEDULERS[name]):
        def schedule(self, now):
            before = set(self._proactive_out)
            batch = super().schedule(now)
            victims = self._proactive_out - before
            if batch is not None and victims:
                ids = {r.req_id for r in batch.all_requests()}
                assert not (victims & ids), \
                    "proactive offload evicted a scheduled request"
            return batch

    lm = a100_opt13b()
    sched = Guard(limits=BatchLimits(cap=cap), latency_model=lm,
                  kv_admission="optimistic", kv_tiering=True,
                  host_kv_cap=8 * cap, proactive_offload=True,
                  idle_horizon_s=horizon, swap_prefetch=True)
    ServingEngine(sched, SimulatedExecutor(lm),
                  debug_invariants=True).run_trace(copy.deepcopy(trace))
    assert sched.tokens_in_use == 0 and sched.committed_tokens == 0
    assert sched.host_tokens_in_use == 0


def test_shared_ledger_victim_never_frees_sibling_blocks():
    """PR-3 interaction pin: when a victim releases its chain, blocks its
    siblings still reference stay counted (discount shrinks by exactly the
    overlap, and the survivors' raw charges keep the blocks covered)."""
    ledger = SharedPrefixLedger(block_size=16)
    chain = block_hashes(list(range(64)), 16)        # 4 blocks
    assert ledger.acquire(chain) == 0                # leader pays full
    assert ledger.acquire(chain) == 64               # follower discounts all
    assert ledger.discount == 64
    ledger.release(chain)                            # preempt the leader
    assert ledger.discount == 0                      # survivor now pays raw
    assert all(ledger.contains(k) for k in chain)    # blocks still charged
    ledger.release(chain)
    assert ledger.discount == 0 and len(ledger) == 0
