"""End-to-end simulated serving across every scheduler: completion,
conservation, phase identities, and the paper's qualitative ordering."""
import copy

import pytest

from repro.core.latency_model import a100_opt13b
from repro.core.policies import SCHEDULERS
from repro.core.priority import BatchLimits, DPUConfig
from repro.data.trace import quick_trace
from repro.engine.engine import ServingEngine
from repro.engine.prefix_cache import PrefixCache
from repro.engine.simulator import SimulatedExecutor, sim_output_len


def _run(name, trace, **dpu_kw):
    lm = a100_opt13b()
    pc = PrefixCache(block_size=16)
    kw = dict(limits=BatchLimits(), latency_model=lm, prefix_cache=pc)
    if name.startswith("relserve") and dpu_kw:
        kw["dpu_config"] = DPUConfig(**dpu_kw)
    sched = SCHEDULERS[name](**kw)
    eng = ServingEngine(sched, SimulatedExecutor(lm, prefix_cache=pc))
    report = eng.run_trace(trace)
    return report, sched


TRACE = quick_trace("rotten", num_relqueries=25, rate=1.2, seed=11, max_requests=40)


@pytest.mark.parametrize("name", list(SCHEDULERS))
def test_all_relqueries_complete(name):
    trace = copy.deepcopy(TRACE)
    report, sched = _run(name, trace)
    assert len(report.latencies) == len(trace), f"{name} lost relQueries"
    for rq in trace:
        for r in rq.requests:
            target = min(sim_output_len(r), r.max_output_tokens)
            assert len(r.output_tokens) == target, \
                f"{name}: {r.req_id} produced {len(r.output_tokens)} != {target}"
    assert sched.tokens_in_use == 0, f"{name} leaked KV accounting"


@pytest.mark.parametrize("name", list(SCHEDULERS))
def test_phase_identity(name):
    """waiting + core + tail == total latency (Definition 2.2)."""
    trace = copy.deepcopy(TRACE)
    report, _ = _run(name, trace)
    for rq in trace:
        total = rq.latency()
        parts = rq.waiting_time() + rq.core_running_time() + rq.tail_running_time()
        assert abs(total - parts) < 1e-9, f"{name}: phases don't sum for {rq.rel_id}"
        assert rq.waiting_time() >= 0 and rq.core_running_time() >= 0
        assert rq.tail_running_time() >= -1e-12


def test_relserve_beats_vllm_under_load():
    """The paper's headline: priority scheduling beats FCFS under load. Needs
    a genuinely loaded trace (heterogeneous relQuery sizes, rate ~ capacity)."""
    heavy = quick_trace("rotten", num_relqueries=60, rate=1.0, seed=7,
                        max_requests=100, num_rows=10_000)
    rep_v, _ = _run("vllm", copy.deepcopy(heavy))
    rep_r, _ = _run("relserve", copy.deepcopy(heavy))
    assert rep_r.avg_latency < rep_v.avg_latency * 0.75, \
        f"relserve {rep_r.avg_latency:.1f}s !<< vllm {rep_v.avg_latency:.1f}s"


def test_starvation_threshold_bounds_max_latency():
    t_off = copy.deepcopy(TRACE)
    t_on = copy.deepcopy(TRACE)
    rep_off, _ = _run("relserve", t_off)
    rep_on, sched_on = _run("relserve", t_on, starvation_threshold=0.05)
    assert sched_on.dpu.stats["starvation_promotions"] > 0
    assert rep_on.max_latency <= rep_off.max_latency + 1e-9


def test_deterministic_replay():
    r1, _ = _run("relserve", copy.deepcopy(TRACE))
    r2, _ = _run("relserve", copy.deepcopy(TRACE))
    assert r1.latencies == r2.latencies


def test_straggler_hedging_reduces_latency():
    lm = a100_opt13b()
    import copy as _c
    base = _c.deepcopy(TRACE)
    hedged = _c.deepcopy(TRACE)

    def run(trace, hedge):
        pc = PrefixCache(block_size=16)
        sched = SCHEDULERS["relserve"](limits=BatchLimits(), latency_model=lm,
                                       prefix_cache=pc)
        ex = SimulatedExecutor(lm, prefix_cache=pc, straggler_prob=0.05,
                               straggler_slowdown=20.0,
                               hedge_threshold=3.0 if hedge else None, seed=3)
        return ServingEngine(sched, ex).run_trace(trace), ex

    rep_n, ex_n = run(base, False)
    rep_h, ex_h = run(hedged, True)
    assert ex_n.stragglers_seen > 0
    assert rep_h.avg_latency < rep_n.avg_latency
