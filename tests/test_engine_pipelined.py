"""Serial vs pipelined engine-loop equivalence suite.

The pipelined loop (``EngineCore(engine_loop="pipelined")``) overlaps
scheduling with device compute: batch N is dispatched, then batch N+1 is
planned against a speculatively-completed ledger while N "runs", and the
speculation is committed or rolled back when the wait lands. None of that
may be observable from outside the engine:

- **bit-identical token streams** — every request generates exactly the
  serial loop's tokens, across every policy × admission mode × sharing
  setting, including preemption-heavy configurations;
- **bit-identical reports** — simulated-clock latencies, waiting/core/tail
  breakdowns and the full batch event stream match the serial run;
- **flush on observation** — cancel / submit / snapshot between ticks see
  the exact serial state even with a speculative window open;
- **ledger conservation** — after a drain every KV ledger is zero, same as
  the serial invariants in test_scheduler_metamorphic.py;
- **real executors too** — the dense and paged JAX backends produce
  identical streams and event tuples under either loop (slow lane).

The suite also pins that the pipelining actually engages (nonzero
``overlap_hidden_time``) and that the incremental DynamicPriorityUpdater
refresh changes no priority decision.
"""
import copy

import pytest

from repro.core.latency_model import a100_opt13b
from repro.core.policies import SCHEDULERS
from repro.core.priority import BatchLimits, DPUConfig
from repro.data.datasets import make_dataset
from repro.data.trace import TraceConfig, build_trace
from repro.engine.engine import ServiceReport, ServingEngine, merge_reports
from repro.engine.prefix_cache import PrefixCache
from repro.engine.simulator import SimulatedExecutor, expected_stream
from repro.serving.frontend import Frontend

POLICIES = tuple(SCHEDULERS)
MODES = ("conservative", "optimistic")
LOOPS = ("serial", "pipelined")


def _trace(seed, num_relqueries=8, rate=3.0, max_requests=10):
    ds = make_dataset("rotten", num_rows=2000, seed=seed)
    return build_trace(ds, TraceConfig(
        num_relqueries=num_relqueries, rate=rate, seed=seed,
        max_requests=max_requests, num_templates=2))


def _cap_for(trace, slack=2.0):
    max_fp = max(r.num_prompt_tokens + r.max_output_tokens
                 for rq in trace for r in rq.requests)
    return int(max_fp * slack)


def _build(policy, mode, trace, *, loop, prefix_sharing=False, slack=2.0,
           dpu_config=None, exec_seed=0, tiering=False):
    lm = a100_opt13b()
    pc = PrefixCache(block_size=16)
    cap = _cap_for(trace, slack=slack)
    kw = dict(limits=BatchLimits(cap=cap),
              latency_model=lm, prefix_cache=pc, kv_admission=mode,
              prefix_sharing=prefix_sharing)
    if tiering:
        kw.update(kv_tiering=True, host_kv_cap=8 * cap)
    if policy.startswith("relserve"):
        kw["dpu_config"] = dpu_config or DPUConfig(exact_probe=prefix_sharing)
    sched = SCHEDULERS[policy](**kw)
    engine = ServingEngine(sched, SimulatedExecutor(lm, prefix_cache=pc,
                                                    seed=exec_seed),
                           engine_loop=loop)
    return engine, sched


def _run(policy, mode, trace, *, loop, prefix_sharing=False, slack=2.0,
         dpu_config=None, tiering=False):
    trace = copy.deepcopy(trace)
    engine, sched = _build(policy, mode, trace, loop=loop,
                           prefix_sharing=prefix_sharing, slack=slack,
                           dpu_config=dpu_config, tiering=tiering)
    report = engine.run_trace(trace)
    return report, sched, trace


def _streams(trace):
    return {r.req_id: tuple(r.output_tokens)
            for rq in trace for r in rq.requests}


def _expected_stream(r):
    return expected_stream(r)


def _events(report):
    return [(e.kind, e.start, e.end, e.num_requests, e.uncached_tokens,
             e.rel_ids) for e in report.events]


def _assert_conserved(sched):
    assert sched.tokens_in_use == 0, "tokens_in_use leaked"
    assert sched.committed_tokens == 0, "committed_tokens leaked"
    assert sched.partial_prefill_tokens == 0, "partial chunk ledger leaked"
    if sched._shared_ledger is not None:
        assert sched._shared_ledger.discount == 0, "shared discount leaked"
        assert len(sched._shared_ledger) == 0, "shared ledger holds chains"


def _assert_reports_match(rep_s, rep_p):
    assert rep_s.latencies == rep_p.latencies
    assert rep_s.waiting == rep_p.waiting
    assert rep_s.core == rep_p.core
    assert rep_s.tail == rep_p.tail
    assert _events(rep_s) == _events(rep_p)
    assert rep_s.preemptions == rep_p.preemptions
    assert rep_s.cancelled_rel_ids == rep_p.cancelled_rel_ids


# --------------------------------------------------------------- sim clock
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("policy", POLICIES)
def test_pipelined_matches_serial(policy, mode):
    """Every policy × admission mode: identical streams, latencies and
    batch event tuples on the simulated clock, with conserved ledgers."""
    trace = _trace(seed=3)
    rep_s, _, ran_s = _run(policy, mode, trace, loop="serial")
    rep_p, sched_p, ran_p = _run(policy, mode, trace, loop="pipelined")
    assert _streams(ran_s) == _streams(ran_p)
    _assert_reports_match(rep_s, rep_p)
    _assert_conserved(sched_p)
    for rq in ran_p:
        for r in rq.requests:
            assert r.output_tokens == _expected_stream(r)


@pytest.mark.parametrize("policy", POLICIES)
def test_pipelined_matches_serial_with_sharing(policy):
    """Prefix-sharing-aware scheduling under the pipelined loop: the shared
    ledger replay/rollback must stay exact."""
    trace = _trace(seed=7)
    rep_s, _, ran_s = _run(policy, "optimistic", trace, loop="serial",
                           prefix_sharing=True)
    rep_p, sched_p, ran_p = _run(policy, "optimistic", trace,
                                 loop="pipelined", prefix_sharing=True)
    assert _streams(ran_s) == _streams(ran_p)
    _assert_reports_match(rep_s, rep_p)
    _assert_conserved(sched_p)


@pytest.mark.parametrize("mode", MODES)
def test_pipelined_same_seed_identical_events(mode):
    trace = _trace(seed=5)
    rep_a, _, _ = _run("relserve", mode, trace, loop="pipelined")
    rep_b, _, _ = _run("relserve", mode, trace, loop="pipelined")
    assert _events(rep_a) == _events(rep_b)


def test_pipelined_preemption_heavy_equivalence():
    """A cap tight enough to force hundreds of preempt/re-prefill cycles:
    speculative completion + rollback across victim selection must not
    diverge from serial by a single token or event."""
    trace = _trace(seed=13, num_relqueries=10, rate=6.0, max_requests=12)
    rep_s, _, ran_s = _run("relserve", "optimistic", trace, loop="serial",
                           prefix_sharing=True, slack=1.3,
                           dpu_config=DPUConfig(exact_probe=True))
    rep_p, sched_p, ran_p = _run("relserve", "optimistic", trace,
                                 loop="pipelined", prefix_sharing=True,
                                 slack=1.3,
                                 dpu_config=DPUConfig(exact_probe=True))
    assert rep_s.preemptions > 0, "cap not tight enough to exercise preemption"
    assert _streams(ran_s) == _streams(ran_p)
    _assert_reports_match(rep_s, rep_p)
    _assert_conserved(sched_p)


@pytest.mark.parametrize("policy", POLICIES)
def test_pipelined_tiering_matches_serial(policy):
    """KV tiering under the pipelined loop: swap decisions journaled while a
    batch is in flight (speculative planning) must commit or roll back to
    the exact serial behavior — same streams, same event timing (serial and
    pipelined both charge the modeled swap seconds to the deciding tick),
    same swap counters, host ledger drained."""
    trace = _trace(seed=13, num_relqueries=10, rate=6.0, max_requests=12)
    rep_s, sched_s, ran_s = _run(policy, "optimistic", trace, loop="serial",
                                 slack=1.2, tiering=True)
    rep_p, sched_p, ran_p = _run(policy, "optimistic", trace,
                                 loop="pipelined", slack=1.2, tiering=True)
    if policy in ("relserve", "vllm"):
        assert sched_s.swap_outs > 0, "cap not tight enough to swap"
    assert _streams(ran_s) == _streams(ran_p)
    _assert_reports_match(rep_s, rep_p)
    assert (sched_s.swap_outs, sched_s.swap_ins, sched_s.swap_bytes_moved) \
        == (sched_p.swap_outs, sched_p.swap_ins, sched_p.swap_bytes_moved)
    assert sched_p.host_tokens_in_use == 0
    _assert_conserved(sched_p)


def test_pipelined_tiering_predicted_matches_serial():
    """Predicted admission + tiering: the predictor's speculative-observation
    journal and the swap-op journal roll back together."""
    trace = _trace(seed=5, num_relqueries=10, rate=5.0, max_requests=12)
    rep_s, sched_s, ran_s = _run("relserve", "predicted", trace,
                                 loop="serial", slack=1.3, tiering=True)
    rep_p, sched_p, ran_p = _run("relserve", "predicted", trace,
                                 loop="pipelined", slack=1.3, tiering=True)
    assert _streams(ran_s) == _streams(ran_p)
    _assert_reports_match(rep_s, rep_p)
    assert sched_s.predictor.observations == sched_p.predictor.observations
    _assert_conserved(sched_p)


def test_cancel_with_tiering_pipelined_matches_serial():
    """Cancel between ticks with tiering on and a speculative window open:
    swapped/parked requests drain to the identical serial state."""
    trace = _trace(seed=11, num_relqueries=6, rate=4.0, max_requests=8)

    def script(loop):
        ran = copy.deepcopy(trace)
        engine, sched = _build("relserve", "optimistic", ran, loop=loop,
                               slack=1.2, tiering=True)
        fe = Frontend(engine)
        try:
            handles = [fe.submit(rq, now=rq.arrival_time) for rq in ran]
            for _ in range(4):
                fe.step()
            fe.cancel(handles[2])
            final = fe.drain()
        finally:
            fe.close()
        return _streams(ran), final, sched

    st_s, fin_s, sched_s = script("serial")
    st_p, fin_p, sched_p = script("pipelined")
    assert sched_s.swap_outs > 0, "tiering never engaged in the script"
    assert st_s == st_p
    _assert_reports_match(fin_s, fin_p)
    assert sched_s.host_tokens_in_use == 0 and sched_p.host_tokens_in_use == 0
    _assert_conserved(sched_s)
    _assert_conserved(sched_p)


def test_pipelined_actually_overlaps():
    """Guard against the pipelined loop silently degrading to serial: on a
    policy eligible for speculation the engine must report scheduler time
    hidden behind (simulated) device compute."""
    trace = _trace(seed=3)
    rep, _, _ = _run("relserve", "conservative", trace, loop="pipelined")
    assert rep.overlap_hidden_time > 0.0, "speculation never engaged"


def test_unknown_engine_loop_rejected():
    trace = _trace(seed=3, num_relqueries=2, max_requests=2)
    with pytest.raises(ValueError):
        _build("relserve", "conservative", trace, loop="warp-speed")


# ----------------------------------------------------- frontend interleaving
def _scripted(loop, trace, cancel_after, cancel_idx):
    """Submit everything up front, step ``cancel_after`` batches, cancel one
    relQuery mid-flight, snapshot, then drain — the same script on either
    loop. Returns (streams, mid_report, final_report, sched, trace)."""
    trace = copy.deepcopy(trace)
    engine, sched = _build("relserve", "optimistic", trace, loop=loop,
                           prefix_sharing=True)
    fe = Frontend(engine)
    try:
        handles = [fe.submit(rq, now=rq.arrival_time) for rq in trace]
        for _ in range(cancel_after):
            fe.step()
        fe.cancel(handles[cancel_idx % len(handles)])
        mid = fe.snapshot()
        final = fe.drain()
    finally:
        fe.close()
    return _streams(trace), mid, final, sched, trace


@pytest.mark.parametrize("cancel_after,cancel_idx", [(0, 0), (3, 2), (7, 5)])
def test_cancel_while_in_flight_matches_serial(cancel_after, cancel_idx):
    """Cancelling between ticks with a speculative window open must flush to
    the exact serial state: same surviving streams, same cancelled set, same
    mid-flight snapshot, zeroed ledgers."""
    trace = _trace(seed=11, num_relqueries=6, rate=4.0, max_requests=8)
    st_s, mid_s, fin_s, sched_s, _ = _scripted("serial", trace,
                                               cancel_after, cancel_idx)
    st_p, mid_p, fin_p, sched_p, _ = _scripted("pipelined", trace,
                                               cancel_after, cancel_idx)
    assert st_s == st_p
    assert mid_s.latencies == mid_p.latencies
    assert mid_s.cancelled_rel_ids == mid_p.cancelled_rel_ids
    _assert_reports_match(fin_s, fin_p)
    _assert_conserved(sched_s)
    _assert_conserved(sched_p)


def test_snapshot_mid_flight_sees_no_placeholders():
    """A snapshot taken while a plan is staged must never observe the
    speculative sentinel values (negative tokens, -inf timestamps)."""
    trace = _trace(seed=9, num_relqueries=5, max_requests=6)
    ran = copy.deepcopy(trace)
    engine, _ = _build("relserve", "conservative", ran, loop="pipelined")
    fe = Frontend(engine)
    try:
        for rq in ran:
            fe.submit(rq, now=rq.arrival_time)
        steps = 0
        while fe.step() is not None:
            steps += 1
            rep = fe.snapshot()
            for v in rep.latencies.values():
                assert v == v and v != float("-inf")   # not NaN, not sentinel
            for rq in ran:
                for r in rq.requests:
                    assert all(t >= 0 for t in r.output_tokens), \
                        "speculative placeholder token leaked to a snapshot"
            if steps > 10_000:
                pytest.fail("drain did not terminate")
    finally:
        fe.close()


# --------------------------------------------------- incremental DPU refresh
def test_incremental_dpu_changes_no_decision():
    """Phase-memoized DPU refresh must reproduce the full-rescan run bit for
    bit — same events, same streams, same priority stats — while actually
    serving probes from the memo."""
    trace = _trace(seed=17, num_relqueries=10, rate=4.0)
    rep_full, sched_full, ran_full = _run(
        "relserve", "optimistic", trace, loop="serial",
        dpu_config=DPUConfig(incremental=False))
    rep_inc, sched_inc, ran_inc = _run(
        "relserve", "optimistic", trace, loop="serial",
        dpu_config=DPUConfig(incremental=True))
    assert _streams(ran_full) == _streams(ran_inc)
    assert _events(rep_full) == _events(rep_inc)
    assert rep_full.latencies == rep_inc.latencies
    assert sched_inc.dpu.stats["phase_memo_hits"] > 0, "memo never used"
    # the non-incremental path never consults (or populates) the memo
    assert sched_full.dpu.stats["phase_probes"] == 0
    assert sched_full.dpu.stats["phase_memo_hits"] == 0


def test_incremental_dpu_identical_under_pipelined():
    """Memo versioning must survive checkpoint/rollback: a pipelined run
    with incremental refresh still matches serial-full-rescan exactly."""
    trace = _trace(seed=17, num_relqueries=10, rate=4.0)
    rep_full, _, ran_full = _run("relserve", "optimistic", trace,
                                 loop="serial",
                                 dpu_config=DPUConfig(incremental=False))
    rep_inc, sched_p, ran_inc = _run("relserve", "optimistic", trace,
                                     loop="pipelined",
                                     dpu_config=DPUConfig(incremental=True))
    assert _streams(ran_full) == _streams(ran_inc)
    assert _events(rep_full) == _events(rep_inc)
    _assert_conserved(sched_p)


# ------------------------------------------------------- report plumbing
def test_report_merge_sums_pipeline_counters():
    a = ServiceReport(latencies={"a": 1.0}, waiting={}, core={}, tail={},
                      events=[], end_to_end=1.0,
                      schedule_retry_time=0.25, overlap_hidden_time=1.5,
                      schedule_retries=3)
    b = ServiceReport(latencies={"b": 2.0}, waiting={}, core={}, tail={},
                      events=[], end_to_end=2.0,
                      schedule_retry_time=0.5, overlap_hidden_time=0.75,
                      schedule_retries=2)
    merged = merge_reports([a, b])
    assert merged.schedule_retry_time == pytest.approx(0.75)
    assert merged.overlap_hidden_time == pytest.approx(2.25)
    assert merged.schedule_retries == 5


# --------------------------------------------------------- real executors
def _real_fixture(model_cache={}):
    """Shared smoke model/params plus ONE canonical trace (deepcopied per
    run — req_ids come from a process-global counter, so rebuilding the
    trace would break cross-run stream comparison)."""
    import jax

    from repro.configs import get_smoke_config
    from repro.engine.tokenizer import HashTokenizer

    if "m" not in model_cache:
        from repro.models.registry import build_model
        cfg = get_smoke_config("qwen3-1.7b")
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        ds = make_dataset("beer", num_rows=400, seed=4)
        trace = build_trace(ds, TraceConfig(
            num_relqueries=3, rate=100.0, seed=4, max_requests=3,
            num_templates=2, output_token_cap=6),
            tokenizer=HashTokenizer(cfg.vocab_size))
        model_cache["m"] = (model, params, trace)
    return model_cache["m"]


def _real_streams_and_events(backend, loop):
    from repro.serving.factory import build_real_engine

    model, params, trace = _real_fixture()
    trace = copy.deepcopy(trace)
    engine = build_real_engine("qwen3-1.7b", "relserve", backend,
                               limits=BatchLimits(cap=100_000), max_len=512,
                               model=model, params=params, engine_loop=loop)
    rep = engine.run_trace(trace)
    return _streams(trace), [(e.kind, e.num_requests, e.rel_ids)
                             for e in rep.events]


@pytest.mark.slow
@pytest.mark.parametrize("backend", ["dense", "paged"])
def test_real_backend_pipelined_matches_serial(backend):
    """Dense and paged real JAX executors: split dispatch/wait under the
    pipelined loop yields bit-identical token streams and batch composition
    vs the serial loop (timing differs — wall clock is real here)."""
    st_s, ev_s = _real_streams_and_events(backend, "serial")
    st_p, ev_p = _real_streams_and_events(backend, "pipelined")
    assert st_s == st_p, f"{backend}: pipelined altered a token stream"
    assert ev_s == ev_p, f"{backend}: pipelined altered batch composition"
