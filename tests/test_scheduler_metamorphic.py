"""Cross-policy scheduler metamorphic suite.

Every scheduling policy × KV-admission mode is run over randomized
shared-template traces and checked against properties that must hold no
matter what order batches were arranged in:

- **token-ledger conservation** — after the queue drains, every KV ledger
  (tokens_in_use, committed_tokens, partial_prefill_tokens, the shared-block
  discount) is exactly zero;
- **no fabricated outputs** — each request's generated stream is exactly the
  simulated executor's deterministic sequence for that req_id (right tokens,
  right length, EOS where the trace says), and nothing was invented for
  requests missing from a batch;
- **same seed ⇒ same events** — re-running an identical configuration yields
  a bit-identical batch event stream;
- **prefix sharing is timing-only** — enabling prefix-sharing-aware
  scheduling changes when work runs, never what any request generates.
"""
import copy

import pytest

from repro.core.latency_model import a100_opt13b
from repro.core.policies import SCHEDULERS
from repro.core.priority import BatchLimits, DPUConfig
from repro.data.datasets import make_dataset
from repro.data.trace import TraceConfig, build_trace
from repro.engine.engine import ServingEngine
from repro.engine.prefix_cache import PrefixCache
from repro.engine.simulator import SimulatedExecutor, expected_stream

POLICIES = tuple(SCHEDULERS)
MODES = ("conservative", "optimistic")


def _trace(seed, num_relqueries=8, rate=3.0, max_requests=10):
    ds = make_dataset("rotten", num_rows=2000, seed=seed)
    return build_trace(ds, TraceConfig(
        num_relqueries=num_relqueries, rate=rate, seed=seed,
        max_requests=max_requests, num_templates=2))


def _cap_for(trace, slack=2.0):
    """A cap tight enough to exercise admission/preemption but guaranteed to
    fit every single request (no legitimate deadlock)."""
    max_fp = max(r.num_prompt_tokens + r.max_output_tokens
                 for rq in trace for r in rq.requests)
    return int(max_fp * slack)


def _run(policy, mode, trace, prefix_sharing=False, exec_seed=0,
         engine_loop="serial"):
    trace = copy.deepcopy(trace)
    lm = a100_opt13b()
    pc = PrefixCache(block_size=16)
    kw = dict(limits=BatchLimits(cap=_cap_for(trace)), latency_model=lm,
              prefix_cache=pc, kv_admission=mode, prefix_sharing=prefix_sharing)
    if policy.startswith("relserve"):
        kw["dpu_config"] = DPUConfig(exact_probe=prefix_sharing)
    sched = SCHEDULERS[policy](**kw)
    engine = ServingEngine(sched, SimulatedExecutor(lm, prefix_cache=pc,
                                                    seed=exec_seed),
                           engine_loop=engine_loop)
    report = engine.run_trace(trace)
    return report, sched, trace


def _expected_stream(r):
    """The simulated executor's deterministic output for request ``r``
    (the canonical formula lives in repro.engine.simulator)."""
    return expected_stream(r)


def _streams(trace):
    return {r.req_id: tuple(r.output_tokens)
            for rq in trace for r in rq.requests}


def _assert_conserved_and_faithful(report, sched, trace):
    assert sched.tokens_in_use == 0, "tokens_in_use leaked"
    assert sched.committed_tokens == 0, "committed_tokens leaked"
    assert sched.partial_prefill_tokens == 0, "partial chunk ledger leaked"
    if sched._shared_ledger is not None:
        assert sched._shared_ledger.discount == 0, "shared discount leaked"
        assert len(sched._shared_ledger) == 0, "shared ledger holds chains"
    assert report.missing_decode_outputs == 0
    assert len(report.latencies) == len(trace)
    for rq in trace:
        for r in rq.requests:
            assert r.is_finished()
            assert r.output_tokens == _expected_stream(r), \
                f"fabricated/garbled output for {r.req_id}"


@pytest.mark.parametrize("engine_loop", ("serial", "pipelined"))
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("policy", POLICIES)
def test_ledger_conservation_and_faithful_outputs(policy, mode, engine_loop):
    trace = _trace(seed=3)
    report, sched, ran = _run(policy, mode, trace, engine_loop=engine_loop)
    _assert_conserved_and_faithful(report, sched, ran)


@pytest.mark.slow
@pytest.mark.parametrize("seed", [1, 9, 17])
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("policy", POLICIES)
def test_ledger_conservation_wider_seeds(policy, mode, seed):
    trace = _trace(seed=seed, num_relqueries=10, rate=4.0)
    report, sched, ran = _run(policy, mode, trace,
                              prefix_sharing=bool(seed % 2))
    _assert_conserved_and_faithful(report, sched, ran)


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("policy", POLICIES)
def test_same_seed_gives_identical_event_stream(policy, mode):
    trace = _trace(seed=5)
    rep_a, _, _ = _run(policy, mode, trace)
    rep_b, _, _ = _run(policy, mode, trace)
    ev_a = [(e.kind, e.start, e.end, e.num_requests, e.uncached_tokens,
             e.rel_ids) for e in rep_a.events]
    ev_b = [(e.kind, e.start, e.end, e.num_requests, e.uncached_tokens,
             e.rel_ids) for e in rep_b.events]
    assert ev_a == ev_b, "same seed produced different event streams"


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("policy", POLICIES)
def test_prefix_sharing_changes_timing_only(policy, mode):
    """Sharing on vs off: identical per-request token streams (only batch
    composition/timing may differ), and the sharing run's ledgers conserve."""
    trace = _trace(seed=7)
    rep_off, _, ran_off = _run(policy, mode, trace, prefix_sharing=False)
    rep_on, sched_on, ran_on = _run(policy, mode, trace, prefix_sharing=True)
    assert _streams(ran_off) == _streams(ran_on), \
        "prefix sharing altered a token stream"
    _assert_conserved_and_faithful(rep_on, sched_on, ran_on)
    assert set(rep_off.latencies) == set(rep_on.latencies)


def test_preemption_under_sharing_preserves_streams():
    """Optimistic admission at a cap tight enough to force preemptions, with
    sharing on: preempt/re-prefill cycles must not corrupt outputs and the
    shared ledger must track victim releases exactly."""
    trace = _trace(seed=13, num_relqueries=10, rate=6.0, max_requests=12)
    ran = copy.deepcopy(trace)
    lm = a100_opt13b()
    pc = PrefixCache(block_size=16)
    sched = SCHEDULERS["relserve"](
        limits=BatchLimits(cap=_cap_for(ran, slack=1.3)), latency_model=lm,
        prefix_cache=pc, kv_admission="optimistic", prefix_sharing=True,
        dpu_config=DPUConfig(exact_probe=True))
    engine = ServingEngine(sched, SimulatedExecutor(lm, prefix_cache=pc))
    report = engine.run_trace(ran)
    assert report.preemptions > 0, "cap not tight enough to exercise preemption"
    _assert_conserved_and_faithful(report, sched, ran)
