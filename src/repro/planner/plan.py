"""Query-plan IR: a DAG of templated relQuery stages over tables.

The data layer builds *flat* relQueries (one rendered request per table row);
this IR sits one level above it, describing the workload **before** any
request is rendered, so the planner can rewrite it:

* ``PlanNode`` — one templated LLM call over a row set. A *root* node carries
  its rows (a ``Table`` slice or raw row dicts); a *dependent* node carries
  none — its rows are materialized at execution time by joining each upstream
  node's per-row decoded outputs into the upstream rows (AugServe-style
  multi-stage requests: a stage-2 prompt rendered from stage-1 answers).
* ``QueryPlan`` — a validated DAG of nodes (unique ids, acyclic, dependents
  reference existing upstreams), iterable in topological order.

The planner's passes (`repro.planner.passes`) rewrite the *compiled* request
lists; the executor (`repro.planner.executor`) walks the DAG through the
open-loop ``Frontend``, submitting each stage as its dependencies complete.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.data.tables import Table
from repro.data.templates import RelQueryTemplate

# Attribute name an upstream node's decoded output binds to in downstream
# rows when the edge does not name one explicitly.
DEFAULT_OUTPUT_ATTR = "answer"


@dataclass
class PlanNode:
    """One templated relQuery stage.

    ``depends_on`` is a list of ``(upstream_node_id, bind_attr)`` edges: the
    node's rows are the first upstream's rows, each extended with every
    upstream's decoded per-row output under its ``bind_attr``. All upstreams
    of one node must produce the same number of rows (they are joined by row
    index — the relational reading: same table, new derived columns).
    """

    node_id: str
    template: RelQueryTemplate
    rows: Optional[List[Dict[str, str]]] = None
    depends_on: List[Tuple[str, str]] = field(default_factory=list)
    arrival_time: float = 0.0
    output_token_cap: Optional[int] = None

    @property
    def is_dependent(self) -> bool:
        return bool(self.depends_on)

    @property
    def max_output_tokens(self) -> int:
        ol = self.template.max_output_tokens
        if self.output_token_cap is not None:
            ol = max(1, min(ol, self.output_token_cap))
        return ol


def scan(node_id: str, source: Union[Table, Sequence[Dict[str, str]]],
         template: RelQueryTemplate, arrival_time: float = 0.0,
         output_token_cap: Optional[int] = None) -> PlanNode:
    """Root node: render ``template`` over every row of ``source``."""
    rows = list(source.rows) if isinstance(source, Table) else list(source)
    if not rows:
        raise ValueError(f"plan node {node_id!r}: empty row set")
    return PlanNode(node_id, template, rows=rows, arrival_time=arrival_time,
                    output_token_cap=output_token_cap)


def derive(node_id: str,
           upstream: Union[str, PlanNode,
                           Sequence[Union[str, PlanNode, Tuple[str, str]]]],
           template: RelQueryTemplate,
           output_token_cap: Optional[int] = None) -> PlanNode:
    """Dependent node: render ``template`` over the upstream rows extended
    with the upstream outputs. ``upstream`` is a node (or its id), or a list
    of nodes / ids / ``(node_id, bind_attr)`` pairs for multi-parent joins."""
    if isinstance(upstream, (str, PlanNode)):
        upstream = [upstream]
    edges: List[Tuple[str, str]] = []
    for up in upstream:
        if isinstance(up, PlanNode):
            edges.append((up.node_id, DEFAULT_OUTPUT_ATTR))
        elif isinstance(up, str):
            edges.append((up, DEFAULT_OUTPUT_ATTR))
        else:
            edges.append((up[0], up[1]))
    if not edges:
        raise ValueError(f"plan node {node_id!r}: dependent node needs at "
                         f"least one upstream")
    attrs = [a for _, a in edges]
    if len(set(attrs)) != len(attrs):
        raise ValueError(f"plan node {node_id!r}: duplicate bind attr in "
                         f"{attrs}")
    return PlanNode(node_id, template, rows=None, depends_on=edges,
                    output_token_cap=output_token_cap)


class QueryPlan:
    """A validated DAG of ``PlanNode``s, iterable in topological order."""

    def __init__(self, nodes: Sequence[PlanNode], plan_id: str = "plan"):
        self.plan_id = plan_id
        self.nodes: Dict[str, PlanNode] = {}
        for node in nodes:
            if node.node_id in self.nodes:
                raise ValueError(f"duplicate plan node id {node.node_id!r}")
            self.nodes[node.node_id] = node
        for node in nodes:
            if node.is_dependent and node.rows is not None:
                raise ValueError(f"plan node {node.node_id!r}: dependent "
                                 f"nodes render their rows from upstream "
                                 f"outputs, not a static row set")
            if not node.is_dependent and node.rows is None:
                raise ValueError(f"plan node {node.node_id!r}: root node "
                                 f"without rows")
            for up, _ in node.depends_on:
                if up not in self.nodes:
                    raise ValueError(f"plan node {node.node_id!r} depends on "
                                     f"unknown node {up!r}")
        self._topo = self._toposort()

    def _toposort(self) -> List[str]:
        order: List[str] = []
        state: Dict[str, int] = {}   # 0=unvisited 1=visiting 2=done

        def visit(nid: str, chain: Tuple[str, ...]) -> None:
            if state.get(nid) == 2:
                return
            if state.get(nid) == 1:
                raise ValueError(f"query plan has a cycle through {nid!r} "
                                 f"(path {' -> '.join(chain + (nid,))})")
            state[nid] = 1
            for up, _ in self.nodes[nid].depends_on:
                visit(up, chain + (nid,))
            state[nid] = 2
            order.append(nid)

        for nid in self.nodes:
            visit(nid, ())
        return order

    def topological(self) -> List[PlanNode]:
        return [self.nodes[nid] for nid in self._topo]

    def roots(self) -> List[PlanNode]:
        return [n for n in self.topological() if not n.is_dependent]

    def dependents(self) -> List[PlanNode]:
        return [n for n in self.topological() if n.is_dependent]

    def downstream_of(self, node_id: str) -> List[str]:
        """Transitive closure of nodes depending on ``node_id`` — the set a
        cancellation must propagate to."""
        out, frontier = set(), {node_id}
        while frontier:
            nxt = {n.node_id for n in self.nodes.values()
                   if any(up in frontier for up, _ in n.depends_on)}
            nxt -= out
            out |= nxt
            frontier = nxt
        return [nid for nid in self._topo if nid in out]

    def __len__(self) -> int:
        return len(self.nodes)
