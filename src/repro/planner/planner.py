"""The planner: applies rewrite passes to plan nodes / trace relQueries,
producing ``PlannedQuery`` units the ``PlanExecutor`` submits.

``mode`` selects the pass pipeline (mirrors ``launch/serve.py --plan``):

==========  ==========================================================
``off``     no rewrite — the physical relQuery *is* the logical one
``dedup``   projection + exact-duplicate dedup (answer once, fan out)
``reorder`` projection + prefix-maximizing row reorder
``full``    projection + dedup + reorder
==========  ==========================================================

Planning wall-clock accumulates in ``Planner.plan_time`` so the overhead is
visible in reports next to schedule/dpu time (``ServiceReport.plan_time``).
"""
from __future__ import annotations

import time as _time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.core.relquery import RelQuery, Request, make_relquery
from repro.data.templates import RelQueryTemplate
from repro.engine.tokenizer import HashTokenizer
from repro.planner.passes import (FanoutMap, dedup_requests, project_rows,
                                  reorder_requests)
from repro.planner.plan import PlanNode

PLAN_MODES = ("off", "dedup", "reorder", "full")


@dataclass
class PlannedQuery:
    """One plan stage, compiled: the logical per-row view plus the physical
    relQuery actually submitted.

    ``logical_requests`` is one request per input row, in row order. The
    physical relQuery's requests are a subset (dedup leaders), possibly
    reordered; leaders are the *same objects* as their logical counterparts,
    so per-row handles resolve directly for them, while followers (in
    ``fanout``) are materialized by copying the leader's stream when the
    physical relQuery completes (or is cancelled)."""

    rel_id: str
    logical: RelQuery              # per-row view the caller observes
    physical: Optional[RelQuery]   # what the Frontend actually schedules
    logical_requests: List[Request]
    fanout: FanoutMap = field(default_factory=dict)
    node: Optional[PlanNode] = None
    rows: Optional[List[dict]] = None   # source rows (un-projected), if any

    @property
    def num_logical(self) -> int:
        return len(self.logical_requests)

    @property
    def num_physical(self) -> int:
        return len(self.physical.requests) if self.physical is not None else 0

    @property
    def deduped_requests(self) -> int:
        """Logical requests answered by fan-out instead of execution."""
        return (self.num_logical - self.num_physical
                if self.physical is not None else 0)

    def request_for_row(self, row_idx: int) -> Request:
        return self.logical_requests[row_idx]


class Planner:
    """Rule-based workload planner. Stateless between calls except for the
    cumulative ``plan_time`` clock."""

    def __init__(self, mode: str = "full",
                 tokenizer: Optional[HashTokenizer] = None):
        if mode not in PLAN_MODES:
            raise ValueError(f"plan mode must be one of {PLAN_MODES} "
                             f"(got {mode!r})")
        self.mode = mode
        self.tokenizer = tokenizer or HashTokenizer()
        self.plan_time = 0.0

    @property
    def dedup(self) -> bool:
        return self.mode in ("dedup", "full")

    @property
    def reorder(self) -> bool:
        return self.mode in ("reorder", "full")

    # ------------------------------------------------------------- requests
    def plan_relquery(self, rq: RelQuery,
                      node: Optional[PlanNode] = None) -> PlannedQuery:
        """Compile one already-rendered relQuery (a trace entry, or a DAG
        stage whose rows just materialized) into a planned unit."""
        t0 = _time.perf_counter()
        requests = list(rq.requests)
        fanout: FanoutMap = {}
        leaders = requests
        if self.dedup:
            leaders, fanout = dedup_requests(requests)
        if self.reorder:
            leaders = reorder_requests(leaders)
        if len(leaders) == len(requests) and \
                all(a is b for a, b in zip(leaders, requests)):
            physical = rq                  # nothing changed: zero-copy
        else:
            physical = RelQuery(rel_id=rq.rel_id, requests=leaders,
                                arrival_time=rq.arrival_time,
                                max_output_tokens=rq.max_output_tokens,
                                template_id=rq.template_id)
        planned = PlannedQuery(rel_id=rq.rel_id, logical=rq,
                               physical=physical, logical_requests=requests,
                               fanout=fanout, node=node)
        self.plan_time += _time.perf_counter() - t0
        return planned

    def plan_trace(self, trace: Sequence[RelQuery]) -> List[PlannedQuery]:
        """Compile a flat arrival trace (the serve.py / benchmark path)."""
        return [self.plan_relquery(rq) for rq in trace]

    # ------------------------------------------------------------- plan nodes
    def compile_node(self, node: PlanNode, rows: Sequence[dict],
                     rel_id: Optional[str] = None,
                     arrival_time: Optional[float] = None) -> PlannedQuery:
        """Render ``node``'s template over ``rows`` and compile. Projection
        runs first so dedup keys ignore columns the template never reads."""
        t0 = _time.perf_counter()
        projected = project_rows(rows, node.template)
        prompts = [self.tokenizer.encode(node.template.render(row))
                   for row in projected]
        ol = node.max_output_tokens
        rq = make_relquery(rel_id or node.node_id, prompts,
                           node.arrival_time if arrival_time is None
                           else arrival_time,
                           ol, template_id=node.template.template_id,
                           eos_token=self.tokenizer.eos)
        self.plan_time += _time.perf_counter() - t0
        planned = self.plan_relquery(rq, node=node)
        planned.rows = list(rows)
        return planned

    # ------------------------------------------------------------- outputs
    def decode_output(self, r: Request) -> str:
        """Decode a finished request's stream into the text a downstream
        template binds (the EOS terminator, if any, is stripped)."""
        toks = list(r.output_tokens)
        if toks and r.eos_token is not None and toks[-1] == r.eos_token:
            toks = toks[:-1]
        return self.tokenizer.decode(toks)


def fan_out(planned: PlannedQuery, now: Optional[float] = None) -> int:
    """Materialize follower requests from their leaders after the physical
    relQuery reached a terminal state (finished *or* cancelled): copy the
    stream and terminal markers so every logical row resolves. Also mirrors
    the physical relQuery's terminal timestamps onto the logical view.
    Returns the number of follower requests materialized."""
    phys, logical = planned.physical, planned.logical
    copied = 0
    leaders = {r.req_id: r for r in phys.requests}
    for leader_id, followers in planned.fanout.items():
        leader = leaders[leader_id]
        for f in followers:
            f.output_tokens = list(leader.output_tokens)
            f.prefilled = leader.prefilled
            f.prefilled_tokens = leader.prefilled_tokens
            f.state = leader.state
            f.finish_time = leader.finish_time
            copied += 1
    if logical is not phys:
        logical.first_prefill_start = phys.first_prefill_start
        logical.last_prefill_end = phys.last_prefill_end
        logical.finish_time = phys.finish_time
        logical.cancel_time = phys.cancel_time
        logical.preemptions = phys.preemptions
        logical.note_phase_change()
    return copied
