"""Plan execution over the open-loop ``Frontend``.

``PlanExecutor`` is the layer that turns planned units into live serving
traffic: it submits each stage's *physical* relQuery through
``Frontend.submit``, steps the engine, fans dedup leaders' streams out to
their follower rows on completion, and — for dependent-query DAGs —
materializes a downstream stage's rows from its upstreams' decoded outputs
the moment the last dependency completes, submitting it mid-flight (the
open-loop API is what makes this possible at all: dependent stages arrive
while earlier stages are still decoding).

Lifecycle guarantees:

* a dependent stage is **never** submitted before every upstream stage is
  terminal (its arrival time is the service time its last dependency
  finished at);
* cancellation propagates along DAG edges: cancelling a stage (explicitly,
  or via a deadline) cancels every transitive downstream stage — submitted
  ones through ``Frontend.cancel``, unsubmitted ones before they ever reach
  the engine;
* deadlines propagate: ``submit_plan(deadline=...)`` applies the same
  absolute service-time deadline to every stage, including stages submitted
  later by the DAG walk;
* reports stay honest about logical vs physical work: ``snapshot()`` /
  ``drain()`` return the engine's ``ServiceReport`` with
  ``deduped_requests`` (logical rows answered by fan-out, not execution)
  and ``plan_time`` (planner wall-clock) stamped on.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence

from repro.core.relquery import RelQuery, RequestState
from repro.engine.engine import ServiceReport
from repro.planner.plan import PlanNode, QueryPlan
from repro.planner.planner import Planner, PlannedQuery, fan_out
from repro.serving.frontend import Frontend, RelQueryHandle, RelQueryStatus


class _LiveQuery:
    """Book-keeping for one planned unit in flight."""

    def __init__(self, planned: PlannedQuery):
        self.planned = planned
        self.handle: Optional[RelQueryHandle] = None
        self.settled = False        # terminal + fanned out

    @property
    def submitted(self) -> bool:
        return self.handle is not None


class PlanHandle:
    """Caller-facing handle for one submitted ``QueryPlan``: per-stage
    status, per-row partial outputs, whole-DAG cancel."""

    def __init__(self, executor: "PlanExecutor", plan: QueryPlan,
                 live: Dict[str, _LiveQuery]):
        self.executor = executor
        self.plan = plan
        self._live = live
        self.deadline: Optional[float] = None

    def stage(self, node_id: str) -> PlannedQuery:
        return self._live[node_id].planned

    def stage_handle(self, node_id: str) -> Optional[RelQueryHandle]:
        return self._live[node_id].handle

    def status(self, node_id: str) -> RelQueryStatus:
        lq = self._live[node_id]
        if lq.handle is not None:
            return lq.handle.status()
        if lq.planned.logical.cancelled:
            return RelQueryStatus.CANCELLED
        return RelQueryStatus.QUEUED       # awaiting upstream completion

    def done(self) -> bool:
        return all(self.status(nid) in (RelQueryStatus.FINISHED,
                                        RelQueryStatus.CANCELLED)
                   for nid in self._live)

    def partial_outputs(self, node_id: str) -> Dict[str, List[int]]:
        """Per-logical-row streams so far. Follower rows mirror their dedup
        leader live (fan-out copies lazily here, terminally in ``fan_out``)."""
        lq = self._live[node_id]
        phys = {r.req_id: list(r.output_tokens)
                for r in lq.planned.physical.requests}
        out = {}
        for r in lq.planned.logical_requests:
            if r.req_id in phys:
                out[r.req_id] = phys[r.req_id]
        for leader_id, followers in lq.planned.fanout.items():
            for f in followers:
                out[f.req_id] = list(phys[leader_id])
        return out

    def result(self, node_id: str) -> RelQuery:
        """Drive the whole plan until ``node_id`` is terminal; return its
        logical relQuery (every row resolved)."""
        lq = self._live[node_id]
        while not lq.settled:
            if not self.executor.step() and not lq.settled:
                raise RuntimeError(
                    f"plan stage {node_id!r} cannot finish: engine is idle "
                    f"and no dependency can unblock it")
        return lq.planned.logical

    def cancel(self, node_id: Optional[str] = None) -> List[str]:
        """Cancel a stage (default: every root → the whole plan) and all its
        transitive downstream stages. Returns the cancelled node ids."""
        if node_id is None:
            targets = list(self._live)
        else:
            targets = [node_id] + self.plan.downstream_of(node_id)
        cancelled = []
        for nid in targets:
            if self.executor._cancel_stage(self._live[nid]):
                cancelled.append(nid)
        return cancelled


class PlanExecutor:
    """Submits planned work through a ``Frontend`` and walks DAG edges."""

    def __init__(self, frontend: Frontend, planner: Optional[Planner] = None):
        self.frontend = frontend
        self.planner = planner or Planner("full")
        self._live: List[_LiveQuery] = []
        self._plans: List[PlanHandle] = []

    # ------------------------------------------------------------- flat traces
    def replay(self, planned: Sequence[PlannedQuery],
               max_iterations: int = 2_000_000) -> ServiceReport:
        """Closed-loop replay of a planned flat trace: submit each physical
        relQuery at its recorded arrival, interleaved with engine steps in
        global time order (the planner-aware twin of ``Frontend.replay``),
        fanning out dedup followers as stages finish. Returns the drained,
        planner-stamped report."""
        pending = sorted(planned, key=lambda p: p.physical.arrival_time)
        live = [_LiveQuery(p) for p in pending]
        self._live.extend(live)
        idx, it = 0, 0
        while True:
            f = self.frontend.next_step_time()
            next_step = math.inf if f is None else f
            next_arrival = (pending[idx].physical.arrival_time
                            if idx < len(pending) else math.inf)
            if math.isinf(next_step) and math.isinf(next_arrival):
                break
            if next_arrival <= next_step:
                live[idx].handle = self.frontend.submit(
                    pending[idx].physical, now=next_arrival)
                idx += 1
                continue
            self.frontend.step()
            self._poll()
            it += 1
            if it >= max_iterations:
                raise RuntimeError("planned replay exceeded max_iterations "
                                   "— likely livelock")
        self._poll()
        return self.snapshot()

    # ------------------------------------------------------------- DAG plans
    def submit_plan(self, plan: QueryPlan, now: Optional[float] = None,
                    deadline: Optional[float] = None) -> PlanHandle:
        """Compile and submit a DAG plan: root stages enter the engine now,
        dependent stages as their dependencies complete (via ``step``)."""
        live: Dict[str, _LiveQuery] = {}
        for node in plan.topological():
            if node.is_dependent:
                # compiled later, when upstream outputs exist; placeholder
                # carries the node so cancellation can reach it pre-submit
                planned = PlannedQuery(
                    rel_id=f"{plan.plan_id}/{node.node_id}",
                    logical=RelQuery(rel_id=f"{plan.plan_id}/{node.node_id}",
                                     requests=[], arrival_time=0.0,
                                     max_output_tokens=node.max_output_tokens,
                                     template_id=node.template.template_id),
                    physical=None, logical_requests=[], node=node)
                live[node.node_id] = _LiveQuery(planned)
            else:
                planned = self.planner.compile_node(
                    node, node.rows, rel_id=f"{plan.plan_id}/{node.node_id}",
                    arrival_time=now)
                lq = _LiveQuery(planned)
                lq.handle = self.frontend.submit(planned.physical, now=now,
                                                 deadline=deadline)
                live[node.node_id] = lq
        handle = PlanHandle(self, plan, live)
        handle.deadline = deadline
        self._plans.append(handle)
        self._live.extend(live.values())
        return handle

    def run_plan(self, plan: QueryPlan, now: Optional[float] = None,
                 deadline: Optional[float] = None,
                 max_iterations: int = 2_000_000) -> PlanHandle:
        """Submit and drive a plan to completion (every stage terminal)."""
        handle = self.submit_plan(plan, now=now, deadline=deadline)
        it = 0
        while not handle.done():
            if not self.step() and not handle.done():
                raise RuntimeError("plan cannot finish: engine is idle with "
                                   "unfinished stages")
            it += 1
            if it >= max_iterations:
                raise RuntimeError("run_plan exceeded max_iterations")
        return handle

    # ------------------------------------------------------------- stepping
    def step(self) -> bool:
        """One engine step + DAG/fan-out poll. Returns False when the engine
        was idle *and* the poll released no new work."""
        event = self.frontend.step()
        released = self._poll()
        return event is not None or released

    def _poll(self) -> bool:
        """Fan out newly terminal stages; submit dependent stages whose
        upstreams are all terminal. Returns True if anything was released."""
        progressed = False
        for lq in self._live:
            if lq.settled or lq.handle is None:
                continue
            if lq.handle.done():
                fan_out(lq.planned)
                lq.settled = True
                progressed = True
        for handle in self._plans:
            progressed |= self._release_dependents(handle)
        return progressed

    def _release_dependents(self, handle: PlanHandle) -> bool:
        released = False
        for node in handle.plan.dependents():
            lq = handle._live[node.node_id]
            if lq.submitted or lq.settled or lq.planned.logical.cancelled:
                continue
            ups = [handle._live[up] for up, _ in node.depends_on]
            if not all(u.settled for u in ups):
                continue
            if any(u.planned.logical.cancelled for u in ups):
                # upstream died (cancel or deadline): propagate, never submit
                self._cancel_stage(lq)
                released = True
                continue
            rows = self._dependent_rows(node, handle)
            now = self.frontend.now
            planned = self.planner.compile_node(
                node, rows, rel_id=lq.planned.rel_id, arrival_time=now)
            lq.planned = planned
            lq.handle = self.frontend.submit(planned.physical, now=now,
                                             deadline=handle.deadline)
            released = True
        return released

    def _dependent_rows(self, node: PlanNode,
                        handle: PlanHandle) -> List[dict]:
        """Join each upstream's per-row decoded outputs into the first
        upstream's rows (by row index — same table, new derived columns).
        The base rows are the upstream's *source* rows (un-projected: a
        downstream template may reference columns the upstream's own
        projection dropped)."""
        base: Optional[List[dict]] = None
        counts = {up_id: handle._live[up_id].planned.num_logical
                  for up_id, _ in node.depends_on}
        if len(set(counts.values())) > 1:
            raise ValueError(
                f"plan stage {node.node_id!r}: upstream row counts differ "
                f"({counts}) — dependent stages join by row index")
        for up_id, attr in node.depends_on:
            up = handle._live[up_id].planned
            up_rows = (up.rows if up.rows is not None
                       else [{} for _ in up.logical_requests])
            if base is None:
                base = [dict(row) for row in up_rows]
            for i, r in enumerate(up.logical_requests):
                base[i][attr] = self.planner.decode_output(r)
        return base or []

    # ------------------------------------------------------------- lifecycle
    def _cancel_stage(self, lq: _LiveQuery) -> bool:
        """Cancel one stage: through the Frontend when submitted, locally
        (before the engine ever saw it) otherwise. Fan-out still runs so
        follower rows mirror whatever the leaders produced before eviction."""
        planned = lq.planned
        if lq.handle is not None:
            was_live = lq.handle.cancel()
            if not lq.settled:
                fan_out(planned)
                lq.settled = True
            return was_live
        if planned.logical.cancelled:
            return False
        planned.logical.cancel_time = self.frontend.now
        for r in planned.logical_requests:
            r.state = RequestState.CANCELLED
        planned.logical.note_phase_change()
        lq.settled = True
        return True

    # ------------------------------------------------------------- reporting
    @property
    def deduped_requests(self) -> int:
        return sum(lq.planned.deduped_requests for lq in self._live
                   if lq.planned.physical is not None)

    def snapshot(self) -> ServiceReport:
        """The engine's consistent report with the planner's logical-vs-
        physical accounting stamped on."""
        rep = self.frontend.snapshot()
        rep.deduped_requests = self.deduped_requests
        rep.plan_time = self.planner.plan_time
        return rep

    def drain(self, max_iterations: int = 2_000_000) -> ServiceReport:
        it = 0
        while self.frontend.has_work() or self._poll():
            self.step()
            it += 1
            if it >= max_iterations:
                raise RuntimeError("drain exceeded max_iterations")
        self._poll()
        return self.snapshot()
