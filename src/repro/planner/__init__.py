"""Relational query planner: workload-level optimization in front of the
scheduler.

The layer between the data layer (tables / templates / traces) and
``Frontend.submit``: a ``QueryPlan`` DAG IR over (table, template) inputs, a
rule-based ``Planner`` (exact-duplicate dedup with answer fan-out, column
projection, prefix-maximizing row reorder) and a ``PlanExecutor`` that walks
dependent-query DAGs through the open-loop serving API.
"""
from repro.planner.executor import PlanExecutor, PlanHandle
from repro.planner.passes import (dedup_requests, project_rows,
                                  reorder_requests, request_identity)
from repro.planner.plan import PlanNode, QueryPlan, derive, scan
from repro.planner.planner import (PLAN_MODES, PlannedQuery, Planner, fan_out)

__all__ = ["PLAN_MODES", "PlanExecutor", "PlanHandle", "PlanNode",
           "PlannedQuery", "Planner", "QueryPlan", "dedup_requests", "derive",
           "fan_out", "project_rows", "reorder_requests", "request_identity",
           "scan"]
