"""Workload-level rewrite passes (Berkeley "Optimizing LLM Queries in
Relational Workloads"): rule-based rewrites over a stage's *request list*
that cut cost before the scheduler ever sees the work.

All passes are answer-preserving by construction:

* ``dedup_requests`` — exact-duplicate elimination. Two requests are
  duplicates only when *everything* that determines their token stream is
  equal: prompt token ids, output limit, EOS id and (for simulated traces)
  the EOS-terminated ``sim_output_len``. The first occurrence becomes the
  *leader* (the one physical request); followers are answered by fan-out —
  the executors are content-deterministic, so the leader's stream is
  bit-identical to what each follower would have produced alone.
* ``reorder_requests`` — prefix-maximizing row reorder: a stable sort by
  prompt token sequence, so rows sharing a prompt prefix (same template, same
  shared column values) become adjacent. The PR-4 warm-then-follow scheduler
  and the ``SharedPrefixLedger`` then see maximal leader→follower chains, and
  the plain LRU prefix cache sees hits before eviction. A permutation: no
  request is lost or duplicated (property-tested).
* ``project_rows`` — column projection: drop every column the template never
  references, *before* dedup keys are built. Rows that differ only in unused
  columns (a row_id, say) render identical prompts, so projection is what
  lets dedup see through incidental per-row noise. Referenced-but-missing
  columns are not silently tolerated — ``RelQueryTemplate.render`` raises.
"""
from __future__ import annotations

from typing import Dict, Hashable, List, Sequence, Tuple

from repro.core.relquery import Request
from repro.data.templates import RelQueryTemplate

FanoutMap = Dict[str, List[Request]]   # leader req_id -> follower Requests


def request_identity(r: Request) -> Hashable:
    """The dedup key: every request property that determines its output
    stream. ``sim_output_len`` is included because simulated traces terminate
    generation at that (per-request) length — two rows with equal prompts but
    different sampled EOS points are *not* exact duplicates."""
    return (r.tokens, r.max_output_tokens, r.eos_token,
            getattr(r, "sim_output_len", None))


def dedup_requests(requests: Sequence[Request]) -> Tuple[List[Request], FanoutMap]:
    """Exact-duplicate dedup: returns (leaders in first-occurrence order,
    leader req_id -> follower requests). Leaders are the original ``Request``
    objects — they carry their outputs natively; followers receive copies at
    fan-out time."""
    leaders: List[Request] = []
    by_key: Dict[Hashable, Request] = {}
    fanout: FanoutMap = {}
    for r in requests:
        key = request_identity(r)
        leader = by_key.get(key)
        if leader is None:
            by_key[key] = r
            leaders.append(r)
            fanout[r.req_id] = []
        else:
            fanout[leader.req_id].append(r)
    return leaders, {k: v for k, v in fanout.items() if v}


def reorder_requests(requests: Sequence[Request]) -> List[Request]:
    """Prefix-maximizing row reorder: stable sort by prompt token sequence
    (prefix-lexicographic — rows sharing the longest prompt prefixes become
    neighbours). Stability keeps the original order among exact ties, so the
    result is always a permutation of the input."""
    return sorted(requests, key=lambda r: r.tokens)


def project_rows(rows: Sequence[Dict[str, str]],
                 template: RelQueryTemplate) -> List[Dict[str, str]]:
    """Project each row onto the columns the template references. Missing
    referenced columns are kept missing (``render`` raises a clear KeyError
    naming the template and attribute — the planner depends on accurate
    attribute extraction, not on silent empty substitution)."""
    attrs = template.attributes
    return [{a: row[a] for a in attrs if a in row} for row in rows]
