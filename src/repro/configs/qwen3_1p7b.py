"""qwen3-1.7b — dense, GQA, qk_norm. [hf:Qwen/Qwen3-8B; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    family="dense",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=6144,
    vocab_size=151936,
    attn_kind="full",
    qk_norm=True,
    qkv_bias=False,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    source="hf:Qwen/Qwen3-8B; hf",
)

SMOKE_CONFIG = CONFIG.replace(
    name="qwen3-1.7b-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
)
