"""rwkv6-7b (Finch) — attention-free, data-dependent decay. [arXiv:2404.05892; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=64,                # wkv heads = d_model / rwkv_head_dim
    num_kv_heads=64,
    head_dim=64,
    d_ff=14336,                  # channel-mix hidden (3.5x)
    vocab_size=65536,
    attn_kind="linear",
    rwkv_head_dim=64,
    rwkv_decay_lora=64,
    rwkv_mix_lora=32,
    act="relu2",                 # channel-mix uses squared ReLU
    source="arXiv:2404.05892; hf",
)

SMOKE_CONFIG = CONFIG.replace(
    name="rwkv6-7b-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    rwkv_head_dim=16,
    d_ff=224,
    vocab_size=256,
    rwkv_decay_lora=8,
    rwkv_mix_lora=4,
)
