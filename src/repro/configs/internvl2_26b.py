"""internvl2-26b — VLM: InternViT (stub) + InternLM2 backbone. [arXiv:2404.16821; hf]

The vision frontend is a stub per the assignment: ``input_specs()`` provides
precomputed patch embeddings (``num_vision_patches`` per request) which the LM
consumes prepended to the token sequence.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    attn_kind="full",
    rope_theta=1_000_000.0,
    num_vision_patches=1024,
    source="arXiv:2404.16821; hf",
)

SMOKE_CONFIG = CONFIG.replace(
    name="internvl2-26b-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    num_vision_patches=8,
)
