"""Config registry: ``get_config(arch_id)`` / ``get_smoke_config(arch_id)``."""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import (
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    SHAPES_BY_NAME,
    TRAIN_4K,
    ModelConfig,
    ShapeConfig,
)

from repro.configs import (  # noqa: E402
    gemma3_12b,
    granite_moe_3b,
    hymba_1p5b,
    internvl2_26b,
    qwen2_0p5b,
    qwen2p5_32b,
    qwen3_1p7b,
    qwen3_moe_30b,
    rwkv6_7b,
    whisper_base,
)

_MODULES = {
    "qwen3-1.7b": qwen3_1p7b,
    "qwen2-0.5b": qwen2_0p5b,
    "gemma3-12b": gemma3_12b,
    "qwen2.5-32b": qwen2p5_32b,
    "hymba-1.5b": hymba_1p5b,
    "rwkv6-7b": rwkv6_7b,
    "qwen3-moe-30b-a3b": qwen3_moe_30b,
    "granite-moe-3b-a800m": granite_moe_3b,
    "whisper-base": whisper_base,
    "internvl2-26b": internvl2_26b,
}

ARCH_IDS: List[str] = list(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return _MODULES[arch].CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return _MODULES[arch].SMOKE_CONFIG


def get_shape(name: str) -> ShapeConfig:
    return SHAPES_BY_NAME[name]


def all_cells() -> List[tuple]:
    """The 40 assigned (arch, shape) cells, with skip annotations."""
    cells = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in ALL_SHAPES:
            cells.append((arch, shape.name, cfg.supports_shape(shape)))
    return cells
