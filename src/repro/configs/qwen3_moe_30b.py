"""qwen3-moe-30b-a3b — MoE 128 experts top-8, GQA. [hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,                    # per-expert intermediate size
    vocab_size=151936,
    attn_kind="full",
    qk_norm=True,
    num_experts=128,
    num_experts_per_tok=8,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-30B-A3B; hf",
)

SMOKE_CONFIG = CONFIG.replace(
    name="qwen3-moe-30b-a3b-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=32,
    vocab_size=256,
    num_experts=8,
    num_experts_per_tok=2,
    moe_capacity_factor=8.0,     # == num_experts: zero capacity drops (exactness tests)
)
