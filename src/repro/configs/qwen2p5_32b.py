"""qwen2.5-32b — dense, GQA, QKV bias. [hf:Qwen/Qwen2.5-0.5B; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=27648,
    vocab_size=152064,
    attn_kind="full",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen2.5-0.5B; hf",
)

SMOKE_CONFIG = CONFIG.replace(
    name="qwen2.5-32b-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=160,
    vocab_size=256,
)
