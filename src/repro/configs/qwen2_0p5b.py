"""qwen2-0.5b — dense, GQA (kv=2), QKV bias. [arXiv:2407.10671; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151936,
    attn_kind="full",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    source="arXiv:2407.10671; hf",
)

SMOKE_CONFIG = CONFIG.replace(
    name="qwen2-0.5b-smoke",
    num_layers=2,
    d_model=48,
    num_heads=3,   # deliberately non-power-of-two, mirrors 14-head oddness
    num_kv_heads=1,
    head_dim=16,
    d_ff=96,
    vocab_size=256,
)
