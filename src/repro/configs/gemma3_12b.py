"""gemma3-12b — dense, 5:1 local:global attention, 128k. [hf:google/gemma-3-1b-pt; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=15360,
    vocab_size=262144,
    attn_kind="local_global",
    local_global_pattern=5,      # 5 sliding-window layers : 1 global layer
    sliding_window=1024,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    act="gelu",
    source="hf:google/gemma-3-1b-pt; unverified",
)

SMOKE_CONFIG = CONFIG.replace(
    name="gemma3-12b-smoke",
    num_layers=6,                # one full 5:1 local:global group
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    sliding_window=8,
)
