"""hymba-1.5b — hybrid: parallel attention + mamba heads in each layer. [arXiv:2411.13676; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    attn_kind="swa",             # attention branch is sliding-window (long-context viable)
    sliding_window=1024,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    rope_theta=10_000.0,
    source="arXiv:2411.13676; hf",
)

SMOKE_CONFIG = CONFIG.replace(
    name="hymba-1.5b-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    sliding_window=8,
    ssm_state=4,
)
