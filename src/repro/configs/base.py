"""Architecture/config system.

Every assigned architecture is a frozen ``ModelConfig``; shapes are ``ShapeConfig``.
Configs are pure data — no jax imports here so they can be loaded anywhere
(launchers, schedulers, docs tooling) without touching device state.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Sequence


@dataclass(frozen=True)
class ModelConfig:
    """Static model architecture description (one per assigned arch)."""

    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # attention flavor
    attn_kind: str = "full"  # full | local_global | swa | linear | none
    qk_norm: bool = False
    qkv_bias: bool = False
    sliding_window: int = 0          # window size for swa/local layers
    local_global_pattern: int = 0    # N local layers per 1 global (gemma3: 5)
    rope_theta: float = 1_000_000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    act: str = "silu"                # silu | gelu

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_capacity_factor: float = 1.25

    # SSM / hybrid (hymba) / rwkv
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    rwkv_head_dim: int = 64
    rwkv_decay_lora: int = 64
    rwkv_mix_lora: int = 32

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    max_target_len: int = 448

    # vlm
    num_vision_patches: int = 0      # patch embeddings prepended by the stub frontend

    dtype: str = "bfloat16"
    source: str = ""                 # provenance tag from the assignment table

    # ---- derived helpers ------------------------------------------------
    @property
    def q_per_kv(self) -> int:
        return max(1, self.num_heads // max(1, self.num_kv_heads))

    @property
    def attention_free(self) -> bool:
        return self.attn_kind == "none" or self.attn_kind == "linear"

    def padded_heads(self, tp: int) -> int:
        """Q heads physically padded to a TP multiple (zero-weight padding)."""
        return _round_up(self.num_heads, tp)

    def padded_kv_heads(self, tp: int) -> int:
        """KV heads padded to a TP multiple when sharded; replicated if tp == 1."""
        if tp <= 1:
            return self.num_kv_heads
        return _round_up(self.num_kv_heads, tp)

    def padded_vocab(self, tp: int) -> int:
        """Vocab rows padded to a TP multiple (pad logits masked to -inf)."""
        return _round_up(self.vocab_size, tp) if tp > 1 else self.vocab_size

    def num_params(self) -> int:
        """Total parameter count N (analytic, unpadded, used for MODEL_FLOPS)."""
        return _param_count(self, active_only=False)

    def num_active_params(self) -> int:
        """Active-per-token parameter count (== num_params for dense)."""
        return _param_count(self, active_only=True)

    def supports_shape(self, shape: "ShapeConfig") -> bool:
        if shape.kind == "long_decode":
            # only sub-quadratic archs run 500k contexts
            return self.attn_kind in ("local_global", "swa", "linear", "none") or (
                self.family in ("ssm", "hybrid")
            )
        return True

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    kind: str          # train | prefill | decode | long_decode
    seq_len: int
    global_batch: int
    grad_accum: int = 1   # training microbatching (fit-to-HBM knob)

    @property
    def is_training(self) -> bool:
        return self.kind == "train"

    @property
    def is_decode(self) -> bool:
        return self.kind in ("decode", "long_decode")


TRAIN_4K = ShapeConfig("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32768, 128)
LONG_500K = ShapeConfig("long_500k", "long_decode", 524288, 1)

ALL_SHAPES: Sequence[ShapeConfig] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _param_count(cfg: ModelConfig, active_only: bool) -> int:
    """Analytic parameter count; for MoE ``active_only`` counts top-k experts."""
    d, hd = cfg.d_model, cfg.head_dim
    attn = d * (cfg.num_heads * hd) + 2 * d * (cfg.num_kv_heads * hd) + (cfg.num_heads * hd) * d
    if cfg.attn_kind == "linear":  # rwkv6 time-mix replaces attention
        # r,k,v,g,o projections + decay/mix loras (approx; exact counted from params)
        attn = 5 * d * d + d * (2 * cfg.rwkv_decay_lora) + 5 * d * (2 * cfg.rwkv_mix_lora)
    if cfg.num_experts > 0:
        e = cfg.num_experts_per_tok if active_only else cfg.num_experts
        ffn = e * (3 * d * cfg.d_ff) + d * cfg.num_experts  # router
    else:
        ffn = 3 * d * cfg.d_ff if cfg.act in ("silu",) else 2 * d * cfg.d_ff
    if cfg.family == "hybrid":
        # parallel mamba branch per layer (in/out proj + conv + ssm params)
        d_in = cfg.ssm_expand * d
        attn += 2 * d * d_in + d_in * cfg.ssm_conv + d_in * (2 * cfg.ssm_state + 2) + d_in * d
    layer = attn + ffn
    total = cfg.num_layers * layer
    total += cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    if cfg.is_encoder_decoder:
        # encoder layers: self-attn + mlp; decoder already counted (adds cross-attn)
        enc_layer = 4 * d * d + 2 * d * cfg.d_ff
        total += cfg.num_encoder_layers * enc_layer + cfg.num_layers * 4 * d * d
    return int(total)
