"""granite-moe-3b-a800m — MoE 40 experts top-8, GQA. [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,                    # per-expert intermediate size
    vocab_size=49155,
    attn_kind="full",
    num_experts=40,
    num_experts_per_tok=8,
    rope_theta=10_000.0,
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
)

SMOKE_CONFIG = CONFIG.replace(
    name="granite-moe-3b-a800m-smoke",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=32,
    vocab_size=256,
    num_experts=5,               # deliberately non-divisible by smoke TP
    num_experts_per_tok=2,
    moe_capacity_factor=5.0,     # == num_experts: zero capacity drops (exactness tests)
)
