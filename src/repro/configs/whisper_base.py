"""whisper-base — encoder-decoder audio backbone; conv frontend is a stub. [arXiv:2212.04356; unverified]

Shape interpretation (see DESIGN.md §5): ``seq_len`` is the number of encoder
*frame embeddings* (supplied precomputed by the stub frontend); the decoder side
is capped at ``max_target_len`` text tokens. ``decode_*`` shapes decode one text
token against a cross-attention KV of ``seq_len`` frames.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,                # decoder layers
    num_encoder_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    attn_kind="full",
    is_encoder_decoder=True,
    max_target_len=448,
    act="gelu",
    rope_theta=0.0,              # whisper uses learned/sinusoidal positions, not rope
    source="arXiv:2212.04356; unverified",
)

SMOKE_CONFIG = CONFIG.replace(
    name="whisper-base-smoke",
    num_layers=2,
    num_encoder_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    max_target_len=16,
)
