"""Open-loop serving frontend: the public submission surface of the system.

``Frontend`` turns the steppable ``EngineCore``/``Cluster`` stack into a
session-oriented serving API: callers *submit* relQueries while the engine is
running, *stream* tokens as they are generated, *cancel* mid-flight work (or
attach a deadline), and take consistent mid-flight ``snapshot()`` reports —
the request-lifecycle shape online serving systems (FastServe, vLLM's
AsyncLLMEngine) expose, rather than closed-loop trace replay.

The frontend owns the clock: on the simulated executor the clock is simulated
time advanced batch-by-batch, on the real JAX executor the same loop advances
over measured wall durations — identical code path either way. Trace replay is
now just one driver of this API (``replay``), and the legacy
``ServingEngine.run_trace`` / ``Cluster.run_trace`` entry points are thin
shims over it that reproduce their historical reports exactly.

Lifecycle of one relQuery::

    submit(rq) ─► QUEUED ──first prefill──► RUNNING ──last request──► FINISHED
                     │                        │ ▲  ╲
                     │       on_token(req_id, tok)  ╲ handle.cancel() /
                     │                        │ │    ╲ deadline exceeded
                     │                 KV pressure re-prefill
                     │                 (requests PREEMPTED,     ╲
                     │                  generation preserved)    ╲
                     └───────────────────────────────► CANCELLED
                                       (queue + KV commitment reclaimed)

Under ``kv_admission="optimistic"`` individual requests of a RUNNING relQuery
may be preempted (KV reclaimed, restart by re-prefill of prompt + generation
so far) — the handle stays RUNNING throughout; ``handle.preemptions`` counts
the cycles and ``snapshot().preemptions`` aggregates them fleet-wide.
"""
from __future__ import annotations

import enum
import math
from typing import Callable, Dict, List, Optional, Sequence, Union

from repro.core.relquery import RelQuery
from repro.engine.engine import (BatchEvent, EngineCore, ServiceReport,
                                 ServingEngine, merge_reports)

TokenCallback = Callable[[str, int], None]   # (req_id, token)


class RelQueryStatus(enum.Enum):
    QUEUED = "queued"          # submitted, no prefill started yet
    RUNNING = "running"        # at least one request prefilling/decoding
    FINISHED = "finished"      # every request finished; latency is final
    CANCELLED = "cancelled"    # terminal: evicted, excluded from stats


TERMINAL_STATUSES = (RelQueryStatus.FINISHED, RelQueryStatus.CANCELLED)


class RelQueryCancelledError(RuntimeError):
    """Raised by ``RelQueryHandle.result()`` when the relQuery was cancelled."""


class RelQueryHandle:
    """Caller-facing handle for one submitted relQuery."""

    def __init__(self, frontend: "Frontend", rq: RelQuery, replica: int,
                 deadline: Optional[float] = None,
                 on_token: Optional[TokenCallback] = None):
        self.frontend = frontend
        self.rq = rq
        self.replica = replica
        self.deadline = deadline
        self._on_token = on_token
        self._delivered: Dict[str, int] = {r.req_id: 0 for r in rq.requests}

    @property
    def rel_id(self) -> str:
        return self.rq.rel_id

    def status(self) -> RelQueryStatus:
        if self.rq.cancelled:
            return RelQueryStatus.CANCELLED
        if self.rq.finish_time is not None:
            return RelQueryStatus.FINISHED
        if self.rq.first_prefill_start is not None:
            return RelQueryStatus.RUNNING
        return RelQueryStatus.QUEUED

    def done(self) -> bool:
        return self.status() in TERMINAL_STATUSES

    def partial_outputs(self) -> Dict[str, List[int]]:
        """Per-request generated tokens so far (generation order), at any
        point of the lifecycle — including after cancellation. Preemption
        never rolls these back: preserved tokens survive the restart."""
        return {r.req_id: list(r.output_tokens) for r in self.rq.requests}

    @property
    def preemptions(self) -> int:
        """Preempt→restart cycles this relQuery's requests went through under
        KV pressure (0 under conservative admission)."""
        return self.rq.preemptions

    def latency(self) -> Optional[float]:
        return self.rq.latency()

    def result(self, max_iterations: int = 2_000_000) -> RelQuery:
        """Drive the engine until this relQuery is terminal; return the
        relQuery (outputs live on its requests). Raises
        ``RelQueryCancelledError`` if it was cancelled first."""
        it = 0
        while not self.done():
            if self.frontend.step() is None and not self.done():
                raise RuntimeError(
                    f"relQuery {self.rel_id!r} cannot finish: engine is idle")
            it += 1
            if it >= max_iterations:
                raise RuntimeError("result() exceeded max_iterations — likely livelock")
        if self.status() is RelQueryStatus.CANCELLED:
            raise RelQueryCancelledError(
                f"relQuery {self.rel_id!r} was cancelled at t={self.rq.cancel_time}")
        return self.rq

    def cancel(self) -> bool:
        """Cancel this relQuery; True if it was live. Safe on terminal handles."""
        return self.frontend.cancel(self)

    # ------------------------------------------------------------- internal
    def _deliver_new_tokens(self) -> None:
        """Stream the not-yet-delivered suffix of every request's outputs —
        exactly the tokens the scheduler appended, in generation order."""
        if self._on_token is None:
            return
        for r in self.rq.requests:
            sent = self._delivered[r.req_id]
            toks = r.output_tokens
            while sent < len(toks):
                self._on_token(r.req_id, toks[sent])
                sent += 1
            self._delivered[r.req_id] = sent


class _SingleCoreBackend:
    """Adapts one ``EngineCore`` to the backend protocol ``Cluster`` natively
    implements (submit / step / frontier / end_time / cancel / reports)."""

    def __init__(self, core: EngineCore):
        self.cores = [core]
        self.clocks = [0.0]

    def submit(self, rq: RelQuery, now: float) -> int:
        core = self.cores[0]
        if not core.has_work():          # replica idled until this arrival
            self.clocks[0] = max(self.clocks[0], now)
        core.admit(rq, now)
        return 0

    def step(self) -> Optional[BatchEvent]:
        core = self.cores[0]
        if not core.has_work():
            return None
        event = core.tick(self.clocks[0])   # raises on true deadlock
        if event is not None:
            self.clocks[0] = event.end
        return event

    def has_work(self) -> bool:
        return self.cores[0].has_work()

    def frontier(self) -> Optional[float]:
        return self.clocks[0] if self.cores[0].has_work() else None

    def end_time(self) -> float:
        return self.clocks[0]

    def cancel_relquery(self, rel_id: str, now: float):
        return self.cores[0].cancel_relquery(rel_id, now)

    def reports(self) -> List[ServiceReport]:
        return [self.cores[0].report(self.clocks[0])]


def _make_backend(target):
    if isinstance(target, ServingEngine):
        target = target.core
    if isinstance(target, EngineCore):
        return _SingleCoreBackend(target)
    required = ("submit", "step", "has_work", "frontier", "end_time",
                "cancel_relquery", "reports", "cores")
    missing = [m for m in required if not hasattr(target, m)]
    if missing:
        raise TypeError(f"{type(target).__name__} does not implement the "
                        f"frontend backend protocol (missing {missing})")
    return target


class Frontend:
    """Session-oriented open-loop API over an ``EngineCore``, ``ServingEngine``
    or ``Cluster``. One frontend owns one backend's clock; interleave
    ``submit`` and ``step`` freely (a real async server would run the step
    loop on a task and feed submissions from network handlers)."""

    def __init__(self, backend: Union[EngineCore, ServingEngine, "object"]):
        self.backend = _make_backend(backend)
        self.handles: Dict[str, RelQueryHandle] = {}
        self._deadline_handles: List[RelQueryHandle] = []
        self._closed = False
        # Chain onto (don't clobber) any already-installed batch listener, so
        # a second Frontend over the same backend — e.g. the deprecated
        # run_trace shims — never detaches a live frontend's streaming.
        self._prev_on_batch = []
        self._installed = []
        for core in self.backend.cores:
            self._install_listener(core)
        # Elastic backends (Cluster) mint replicas after construction; the
        # hook keeps new cores streaming through this frontend too.
        hooks = getattr(self.backend, "core_added_hooks", None)
        if hooks is not None:
            hooks.append(self._install_listener)

    def _install_listener(self, core) -> None:
        prev = core.on_batch
        listener = self._chained(prev)
        core.on_batch = listener
        self._prev_on_batch.append(prev)
        self._installed.append(listener)

    def _chained(self, prev):
        def listener(event, batch, result):
            if prev is not None:
                prev(event, batch, result)
            self._on_batch(event, batch, result)
        return listener

    def close(self) -> None:
        """Deactivate this frontend's streaming and detach its batch
        listeners where possible, restoring whatever was installed before
        (idempotent). When frontends are closed out of stacking order the
        listener may still sit inside a newer frontend's chain — the
        ``_closed`` flag makes it inert there regardless. The deprecated
        run_trace shims call this so their throwaway frontends don't outlive
        the replay."""
        self._closed = True
        hooks = getattr(self.backend, "core_added_hooks", None)
        if hooks is not None and self._install_listener in hooks:
            hooks.remove(self._install_listener)
        for core, prev, mine in zip(self.backend.cores, self._prev_on_batch,
                                    self._installed):
            if core.on_batch is mine:
                core.on_batch = prev

    # ------------------------------------------------------------- clock views
    @property
    def now(self) -> float:
        """Current service time: the next batch-start frontier while busy,
        else the time everything already settled at."""
        f = self.backend.frontier()
        return self.backend.end_time() if f is None else f

    @property
    def clock(self) -> float:
        """The settled clock: max per-replica frontier (report end time)."""
        return self.backend.end_time()

    @property
    def cores(self) -> Sequence[EngineCore]:
        return self.backend.cores

    def has_work(self) -> bool:
        return self.backend.has_work()

    def next_step_time(self) -> Optional[float]:
        """Simulated start time of the next tick, or None when idle."""
        return self.backend.frontier()

    # ------------------------------------------------------------- lifecycle
    def submit(self, rq: RelQuery, *, deadline: Optional[float] = None,
               on_token: Optional[TokenCallback] = None,
               now: Optional[float] = None) -> RelQueryHandle:
        """Submit a relQuery at service time ``now`` (default: the current
        frontier — "arrives now"). ``deadline`` is an absolute service time
        after which the relQuery is auto-cancelled (checked at batch
        boundaries); ``on_token`` streams (req_id, token) in generation
        order. Returns the lifecycle handle."""
        if rq.rel_id in self.handles:
            raise ValueError(f"relQuery {rq.rel_id!r} already submitted")
        if now is None:
            # Interactive submission: the relQuery arrives "now", and latency
            # is measured from here. Trace replay passes the recorded arrival
            # explicitly instead, leaving the (shareable) trace untouched.
            now = self.now
            rq.arrival_time = now
        replica = self.backend.submit(rq, now)
        handle = RelQueryHandle(self, rq, replica, deadline=deadline,
                                on_token=on_token)
        self.handles[rq.rel_id] = handle
        if deadline is not None:
            self._deadline_handles.append(handle)
        return handle

    def attach(self, rq: RelQuery, *, replica: int = 0,
               on_token: Optional[TokenCallback] = None,
               delivered: Optional[Dict[str, int]] = None) -> RelQueryHandle:
        """Adopt a relQuery that is *already admitted* in the backend — the
        restart path: a replica restored via ``restore_scheduler`` comes up
        holding relQueries this (new) frontend never saw. ``delivered`` seeds
        the per-request streamed-token high-water marks (the restore result's
        ``delivered`` map), so re-prefilled generation is recomputed but
        never re-emitted to the client. Tokens already on the requests are
        treated as delivered when no floor is given."""
        if rq.rel_id in self.handles:
            raise ValueError(f"relQuery {rq.rel_id!r} already has a handle")
        handle = RelQueryHandle(self, rq, replica, on_token=on_token)
        floors = delivered or {}
        for r in rq.requests:
            handle._delivered[r.req_id] = floors.get(
                r.req_id, len(r.output_tokens))
        self.handles[rq.rel_id] = handle
        return handle

    def step(self) -> Optional[BatchEvent]:
        """Advance the backend by one batch (the earliest busy replica).
        Returns the executed ``BatchEvent``, or None when idle. Deadline
        cancellations are applied before the batch is scheduled."""
        t = self.backend.frontier()
        if t is None:
            return None
        self._expire_deadlines(t)
        return self.backend.step()

    def cancel(self, handle_or_rel_id: Union[RelQueryHandle, str],
               now: Optional[float] = None) -> bool:
        """Cancel a live relQuery: evict its waiting/running requests, reclaim
        their KV commitment and executor slots, and mark the handle terminal.
        Returns False (no-op) for finished/cancelled/unknown relQueries."""
        if isinstance(handle_or_rel_id, RelQueryHandle):
            handle = handle_or_rel_id
        else:
            h = self.handles.get(handle_or_rel_id)
            if h is None:
                return False
            handle = h
        if handle.done():
            return False
        t = self.now if now is None else now
        self.backend.cancel_relquery(handle.rel_id, t)
        return True

    def drain(self, max_iterations: int = 2_000_000) -> ServiceReport:
        """Run the engine until every submitted relQuery is terminal; return
        the final merged report."""
        it = 0
        while self.backend.has_work():
            self.step()
            it += 1
            if it >= max_iterations:
                raise RuntimeError("drain exceeded max_iterations — likely livelock")
        return self.snapshot()

    def snapshot(self) -> ServiceReport:
        """Consistent service report at the current clock — safe mid-flight:
        finished relQueries carry final latencies, unfinished ones simply have
        no latency entry yet, cancelled ones are listed separately. On the
        pipelined engine loop this (like ``cancel`` and ``submit``) flushes
        any speculative window first, so the view is always the exact serial
        state."""
        return merge_reports(self.backend.reports())

    # ------------------------------------------------------------- drivers
    def replay(self, trace: Sequence[RelQuery],
               max_iterations: int = 2_000_000, *,
               on_token: Optional[TokenCallback] = None) -> "Frontend":
        """Closed-loop trace replay expressed as an open-loop driver: submit
        each relQuery at its recorded arrival time, interleaved with engine
        steps in global time order. This is byte-for-byte the scheduling
        sequence of the legacy ``run_trace`` loops (the compatibility shims
        call this)."""
        pending = sorted(trace, key=lambda r: r.arrival_time)
        idx = 0
        it = 0
        while True:
            f = self.backend.frontier()
            next_step = math.inf if f is None else f
            next_arrival = (pending[idx].arrival_time if idx < len(pending)
                            else math.inf)
            if math.isinf(next_step) and math.isinf(next_arrival):
                break
            if next_arrival <= next_step:
                self.submit(pending[idx], now=next_arrival, on_token=on_token)
                idx += 1
                continue
            self.step()
            it += 1
            if it >= max_iterations:
                raise RuntimeError(
                    "serving loop exceeded max_iterations — likely livelock")
        return self

    # ------------------------------------------------------------- internal
    def _expire_deadlines(self, t: float) -> None:
        if not self._deadline_handles:
            return
        live = []
        for h in self._deadline_handles:
            if h.done():
                continue
            if h.deadline <= t:
                self.cancel(h, now=h.deadline)
            else:
                live.append(h)
        self._deadline_handles = live

    def _on_batch(self, event: BatchEvent, batch, result) -> None:
        if self._closed:
            return
        for rel_id in event.rel_ids:
            handle = self.handles.get(rel_id)
            if handle is not None:
                handle._deliver_new_tokens()
