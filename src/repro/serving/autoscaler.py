"""Queue-depth and latency-SLO autoscaler for the elastic ``Cluster``.

Ray-Serve-style control loop on the simulated clock: every
``eval_interval_s`` of simulated time it reads two signals from the fleet —
mean queue depth per admitting replica and the windowed p50 relQuery latency
— and scales between ``min_replicas`` and ``max_replicas``:

- scale UP (``cluster.add_replica``) when queue depth per replica exceeds
  ``scale_up_queue``, or the p50 breaches ``p50_slo_s`` (when configured);
- scale DOWN when queue depth per replica falls below ``scale_down_queue``
  and the SLO is healthy — by *gracefully draining* the least-loaded
  admitting replica (``cluster.drain_replica``): it stops admitting, its
  quiescent relQueries migrate via the snapshot codec, resident work
  finishes, then it retires.

One action per evaluation, separated by ``cooldown_s``, so a single burst
cannot thrash the fleet. Every action is recorded in ``decisions`` with the
signals that triggered it. The cluster ticks the autoscaler from ``submit``
and ``step``, so no separate driver loop is needed.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional


@dataclass
class AutoscaleConfig:
    min_replicas: int = 1
    max_replicas: int = 4
    scale_up_queue: float = 8.0    # outstanding requests per admitting replica
    scale_down_queue: float = 1.0
    p50_slo_s: Optional[float] = None   # None: queue-depth signal only
    latency_window_s: float = 120.0     # p50 lookback over finished relQueries
    eval_interval_s: float = 1.0
    cooldown_s: float = 10.0

    def validate(self) -> "AutoscaleConfig":
        if self.min_replicas < 1:
            raise ValueError("min_replicas must be >= 1")
        if self.max_replicas < self.min_replicas:
            raise ValueError("max_replicas must be >= min_replicas")
        if self.eval_interval_s <= 0:
            raise ValueError("eval_interval_s must be > 0")
        if self.scale_down_queue > self.scale_up_queue:
            raise ValueError("scale_down_queue must not exceed scale_up_queue")
        return self


class Autoscaler:
    def __init__(self, cluster, config: Optional[AutoscaleConfig] = None):
        self.cluster = cluster
        self.cfg = (config or AutoscaleConfig()).validate()
        self._last_eval = float("-inf")
        self._last_action = float("-inf")
        self.decisions: List[dict] = []

    # ----------------------------------------------------------------- signals
    def signals(self, now: float) -> dict:
        admitting = self.cluster.admitting_replicas()
        depth = sum(self.cluster.cores[i].load() for i in admitting)
        per_replica = depth / max(1, len(admitting))
        cutoff = now - self.cfg.latency_window_s
        lats = []
        for i, core in enumerate(self.cluster.cores):
            if self.cluster.replica_state[i] == "dead":
                continue   # frozen history; its finished work predates the window
            for rq in core.scheduler.finished_relqueries:
                if rq.cancel_time is None and rq.finish_time is not None \
                        and rq.finish_time >= cutoff:
                    lats.append(rq.finish_time - rq.arrival_time)
        lats.sort()
        p50 = lats[len(lats) // 2] if lats else None
        return {"admitting": len(admitting),
                "queue_per_replica": per_replica,
                "p50_latency_s": p50,
                "window_finished": len(lats)}

    # -------------------------------------------------------------------- tick
    def tick(self, now: float) -> Optional[dict]:
        """Evaluate and possibly act. Reentrancy-safe: the eval-interval
        stamp is taken first, so actions that re-enter ``cluster.submit``
        (drain migration) see an already-evaluated tick and return."""
        if now - self._last_eval < self.cfg.eval_interval_s:
            return None
        self._last_eval = now
        if now - self._last_action < self.cfg.cooldown_s:
            return None
        sig = self.signals(now)
        n = sig["admitting"]
        slo_breach = (self.cfg.p50_slo_s is not None
                      and sig["p50_latency_s"] is not None
                      and sig["p50_latency_s"] > self.cfg.p50_slo_s)
        if n < self.cfg.max_replicas and \
                (sig["queue_per_replica"] > self.cfg.scale_up_queue
                 or slo_breach):
            replica = self.cluster.add_replica(now)
            decision = {"time": now, "action": "scale_up", "replica": replica,
                        "reason": "p50_slo" if slo_breach else "queue_depth",
                        "signals": sig}
            self._last_action = now
            self.decisions.append(decision)
            return decision
        if n > self.cfg.min_replicas and not slo_breach and \
                sig["queue_per_replica"] < self.cfg.scale_down_queue:
            admitting = self.cluster.admitting_replicas()
            # drain the least-loaded admitting replica; ties prefer the
            # youngest so the original fleet stays intact longest
            victim = min(admitting,
                         key=lambda i: (self.cluster.cores[i].load(), -i))
            decision = {"time": now, "action": "scale_down",
                        "replica": victim, "reason": "queue_depth",
                        "signals": sig}
            self._last_action = now
            self.decisions.append(decision)
            self.cluster.drain_replica(victim, now)
            return decision
        return None
