"""Serving layer: the open-loop ``Frontend`` (submit / stream / cancel /
snapshot) over a relQuery-affine ``Router`` and a ``Cluster`` of steppable
``EngineCore`` replicas sharing one clock."""
from repro.serving.autoscaler import AutoscaleConfig, Autoscaler
from repro.serving.cluster import Cluster, ClusterReport
from repro.serving.factory import build_real_engine, build_simulated_cluster
from repro.serving.frontend import (Frontend, RelQueryCancelledError,
                                    RelQueryHandle, RelQueryStatus)
from repro.serving.router import (ROUTER_POLICIES, Router, route_relquery,
                                  template_fingerprint)

__all__ = ["AutoscaleConfig", "Autoscaler", "Cluster", "ClusterReport",
           "Frontend", "RelQueryCancelledError", "RelQueryHandle",
           "RelQueryStatus", "Router", "ROUTER_POLICIES", "build_real_engine",
           "build_simulated_cluster", "route_relquery",
           "template_fingerprint"]
