"""Multi-replica serving layer: a relQuery-affine ``Router`` in front of a
``Cluster`` of steppable ``EngineCore`` replicas sharing one clock."""
from repro.serving.cluster import Cluster, ClusterReport
from repro.serving.factory import build_simulated_cluster
from repro.serving.router import ROUTER_POLICIES, Router, route_relquery

__all__ = ["Cluster", "ClusterReport", "Router", "ROUTER_POLICIES",
           "build_simulated_cluster", "route_relquery"]
