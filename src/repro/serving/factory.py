"""One place to assemble serving stacks: ``build_simulated_cluster`` for the
simulated multi-replica clock (per replica a private PrefixCache, a scheduler
wired to it, and a SimulatedExecutor sharing the same cache) and
``build_real_engine`` for a single-host real-JAX engine on either KV backend
(dense slots or the block-paged pool) — the pairings every driver
(launch/serve, benchmarks, examples, tests) needs."""
from __future__ import annotations

from typing import Optional

from repro.core.latency_model import BatchLatencyModel, a100_opt13b
from repro.core.policies import SCHEDULERS
from repro.core.priority import BatchLimits, DPUConfig
from repro.engine.prefix_cache import PrefixCache
from repro.engine.simulator import SimulatedExecutor
from repro.serving.cluster import Cluster
from repro.serving.router import Router


def build_simulated_cluster(num_replicas: int, scheduler: str = "relserve",
                            router_policy: str = "affinity_spill",
                            latency_model: Optional[BatchLatencyModel] = None,
                            limits: Optional[BatchLimits] = None,
                            dpu_config: Optional[DPUConfig] = None,
                            seed: int = 0, block_size: int = 16,
                            router: Optional[Router] = None,
                            kv_admission: str = "conservative",
                            prefix_sharing: bool = False,
                            engine_loop: str = "serial",
                            kv_tiering: bool = False, host_kv_cap: int = 0,
                            swap_bandwidth_gbps: float = 32.0,
                            proactive_offload: bool = False,
                            idle_horizon_s: Optional[float] = None,
                            swap_prefetch: bool = False,
                            debug_invariants: bool = False,
                            snapshot_every: int = 0) -> Cluster:
    lm = latency_model or a100_opt13b()
    caches = {}

    def make_scheduler(i: int):
        caches[i] = PrefixCache(block_size=block_size)
        kw = dict(limits=limits or BatchLimits(), latency_model=lm,
                  prefix_cache=caches[i], kv_admission=kv_admission,
                  prefix_sharing=prefix_sharing)
        if kv_tiering:
            kw.update(kv_tiering=True, host_kv_cap=host_kv_cap,
                      swap_bandwidth_gbps=swap_bandwidth_gbps,
                      proactive_offload=proactive_offload,
                      idle_horizon_s=idle_horizon_s,
                      swap_prefetch=swap_prefetch)
        if scheduler.startswith("relserve"):
            kw["dpu_config"] = dpu_config or DPUConfig()
        return SCHEDULERS[scheduler](**kw)

    def make_executor(i: int):
        return SimulatedExecutor(lm, prefix_cache=caches[i], seed=seed + i,
                                 swap_bandwidth_gbps=swap_bandwidth_gbps)

    return Cluster(make_scheduler, make_executor, num_replicas,
                   router=router or Router(num_replicas, policy=router_policy),
                   engine_loop=engine_loop, debug_invariants=debug_invariants,
                   snapshot_every=snapshot_every)


def build_real_engine(arch: str = "qwen3-1.7b", scheduler: str = "relserve",
                      kv_backend: str = "dense", *,
                      limits: Optional[BatchLimits] = None,
                      latency_model: Optional[BatchLatencyModel] = None,
                      dpu_config: Optional[DPUConfig] = None,
                      kv_admission: str = "conservative",
                      prefix_sharing: bool = False,
                      max_slots: int = 32, max_len: int = 512,
                      block_size: int = 16, num_blocks: Optional[int] = None,
                      seed: int = 0, model=None, params=None,
                      engine_loop: str = "serial",
                      kv_tiering: bool = False, host_kv_cap: int = 0,
                      swap_bandwidth_gbps: float = 32.0,
                      proactive_offload: bool = False,
                      idle_horizon_s: Optional[float] = None,
                      swap_prefetch: bool = False,
                      debug_invariants: bool = False, **executor_kw):
    """A single-replica real-JAX serving engine on the chosen KV backend.

    ``kv_backend='dense'`` is the per-slot baseline; ``'paged'`` runs the
    block-paged executor (BlockManager pools + paged-attention decode), with
    physically shared prefix blocks whenever the scheduler runs with
    ``prefix_sharing=True``. Pass ``model``/``params`` to reuse compiled
    functions across engines (e.g. the dense-vs-paged equivalence pin).
    """
    import jax

    from repro.configs import get_smoke_config
    from repro.engine.engine import ServingEngine
    from repro.engine.executor import make_real_executor

    from repro.models.registry import build_model

    if model is None:
        model = build_model(get_smoke_config(arch))
    if params is None:
        params = model.init_params(jax.random.PRNGKey(seed))
    pc = PrefixCache(block_size=block_size)
    limits = limits or BatchLimits()
    if num_blocks is None and kv_backend == "paged":
        # The scheduler charges the cap in raw tokens while the pool hands
        # out whole blocks — size the pool to cover the cap plus one block
        # of per-sequence rounding waste for a full decode batch, and never
        # below the dense layout's physical capacity. (A workload of many
        # tiny resident sequences can still out-fragment any fixed pool; the
        # executor's OutOfBlocks escalation stays as the loud backstop.)
        dense_equiv = -(-max_slots * max_len // block_size)
        cap_blocks = -(-limits.cap // block_size) + limits.max_num_seqs
        num_blocks = max(dense_equiv, cap_blocks)
    kw = dict(limits=limits, prefix_cache=pc,
              kv_admission=kv_admission, prefix_sharing=prefix_sharing)
    if kv_tiering:
        kw.update(kv_tiering=True, host_kv_cap=host_kv_cap,
                  swap_bandwidth_gbps=swap_bandwidth_gbps,
                  proactive_offload=proactive_offload,
                  idle_horizon_s=idle_horizon_s,
                  swap_prefetch=swap_prefetch)
    if latency_model is not None:
        kw["latency_model"] = latency_model
    if scheduler.startswith("relserve"):
        kw["dpu_config"] = dpu_config or DPUConfig()
    sched = SCHEDULERS[scheduler](**kw)
    num_host_blocks = 0
    if kv_tiering and kv_backend == "paged":
        # whole-block rounding: each swapped sequence wastes < 1 block, so
        # cap-in-blocks plus one block per possible resident sequence covers
        # any population the scheduler's token-granular host cap admits
        num_host_blocks = -(-host_kv_cap // block_size) + limits.max_num_seqs
    ex = make_real_executor(kv_backend, model, params, max_slots=max_slots,
                            max_len=max_len, prefix_cache=pc,
                            num_blocks=num_blocks, block_size=block_size,
                            share_prefix_blocks=prefix_sharing,
                            num_host_blocks=num_host_blocks, **executor_kw)
    return ServingEngine(sched, ex, engine_loop=engine_loop,
                         debug_invariants=debug_invariants)
