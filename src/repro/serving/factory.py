"""One place to assemble a simulated multi-replica serving stack: per replica
a private PrefixCache, a scheduler wired to it, and a SimulatedExecutor
sharing the same cache — the pairing every driver (launch/serve, benchmarks,
examples, tests) needs."""
from __future__ import annotations

from typing import Optional

from repro.core.latency_model import BatchLatencyModel, a100_opt13b
from repro.core.policies import SCHEDULERS
from repro.core.priority import BatchLimits, DPUConfig
from repro.engine.prefix_cache import PrefixCache
from repro.engine.simulator import SimulatedExecutor
from repro.serving.cluster import Cluster
from repro.serving.router import Router


def build_simulated_cluster(num_replicas: int, scheduler: str = "relserve",
                            router_policy: str = "affinity_spill",
                            latency_model: Optional[BatchLatencyModel] = None,
                            limits: Optional[BatchLimits] = None,
                            dpu_config: Optional[DPUConfig] = None,
                            seed: int = 0, block_size: int = 16,
                            router: Optional[Router] = None,
                            kv_admission: str = "conservative",
                            prefix_sharing: bool = False) -> Cluster:
    lm = latency_model or a100_opt13b()
    caches = {}

    def make_scheduler(i: int):
        caches[i] = PrefixCache(block_size=block_size)
        kw = dict(limits=limits or BatchLimits(), latency_model=lm,
                  prefix_cache=caches[i], kv_admission=kv_admission,
                  prefix_sharing=prefix_sharing)
        if scheduler.startswith("relserve"):
            kw["dpu_config"] = dpu_config or DPUConfig()
        return SCHEDULERS[scheduler](**kw)

    def make_executor(i: int):
        return SimulatedExecutor(lm, prefix_cache=caches[i], seed=seed + i)

    return Cluster(make_scheduler, make_executor, num_replicas,
                   router=router or Router(num_replicas, policy=router_policy))
