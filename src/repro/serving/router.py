"""Front-end request router across data-parallel engine replicas.

relQuery-affine hashing keeps every request of a relQuery on one replica —
that is what keeps per-replica prefix caching effective (requests of one
relQuery share the template prefix) and what makes relQuery latency a
single-replica quantity. The affine policy optionally *spills over* to the
least-loaded replica when the home replica is hot: a relQuery's requests still
travel together (the spill decision is made once, at admission), only the home
assignment moves.

Policies:
- ``affinity``       — pure stable-hash placement, load-blind.
- ``affinity_spill`` — affine placement unless the home replica's load exceeds
  ``spill_factor`` x the least-loaded replica's (plus a small absolute slack);
  then the relQuery lands on the least-loaded replica. Default.
- ``least_loaded``   — ignore affinity, always pick the least-loaded replica.
- ``round_robin``    — classic baseline, load- and affinity-blind.
"""
from __future__ import annotations

import zlib
from typing import Optional, Sequence

from repro.core.relquery import RelQuery

ROUTER_POLICIES = ("affinity", "affinity_spill", "least_loaded", "round_robin")


def route_relquery(rel_id: str, num_replicas: int) -> int:
    """Stable relQuery-affine hash (deterministic across processes, unlike
    builtin ``hash`` which is seed-randomized)."""
    return zlib.crc32(rel_id.encode()) % max(1, num_replicas)


class Router:
    def __init__(self, num_replicas: int, policy: str = "affinity_spill",
                 spill_factor: float = 2.0, spill_slack: int = 8):
        if policy not in ROUTER_POLICIES:
            raise ValueError(f"unknown router policy {policy!r}; "
                             f"choose from {ROUTER_POLICIES}")
        self.num_replicas = num_replicas
        self.policy = policy
        self.spill_factor = spill_factor
        self.spill_slack = spill_slack
        self._rr = 0
        self.stats = {"routed": 0, "spilled": 0}

    def route(self, rq: RelQuery, loads: Optional[Sequence[int]] = None) -> int:
        """Pick the replica for ``rq``. ``loads`` is the per-replica
        outstanding-request count at admission time (required by the
        load-aware policies)."""
        self.stats["routed"] += 1
        if self.num_replicas <= 1:
            return 0
        if self.policy == "round_robin":
            r = self._rr
            self._rr = (self._rr + 1) % self.num_replicas
            return r
        home = route_relquery(rq.rel_id, self.num_replicas)
        if self.policy == "affinity" or loads is None:
            return home
        coldest = min(range(self.num_replicas), key=lambda i: (loads[i], i))
        if self.policy == "least_loaded":
            return coldest
        # affinity_spill: stay home unless home is disproportionately hot.
        if loads[home] > loads[coldest] * self.spill_factor + self.spill_slack:
            self.stats["spilled"] += 1
            return coldest
        return home
