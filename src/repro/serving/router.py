"""Front-end request router across data-parallel engine replicas.

relQuery-affine hashing keeps every request of a relQuery on one replica —
that is what keeps per-replica prefix caching effective (requests of one
relQuery share the template prefix) and what makes relQuery latency a
single-replica quantity. The affine policy optionally *spills over* to the
least-loaded replica when the home replica is hot: a relQuery's requests still
travel together (the spill decision is made once, at admission), only the home
assignment moves.

``prefix_affinity`` widens the affinity unit from one relQuery to one
*template*: relQueries rendered from the same task template share a long
prompt prefix, so sending them to the same replica turns cross-relQuery
prefix-cache hits from a coincidence into a policy. The template fingerprint
(template_id, or the first prompt block when untagged) maps to a sticky home
replica chosen on first sight — preferring a replica whose cache is already
warm for this prompt prefix when the backend supplies a warmth signal, else
the least-loaded replica — with the same hot-home spillover as
``affinity_spill`` (a spilled relQuery keeps its template's home assignment:
one hot burst must not thrash the template map).

Policies:
- ``affinity``        — pure stable-hash placement, load-blind.
- ``affinity_spill``  — affine placement unless the home replica's load
  exceeds ``spill_factor`` x the least-loaded replica's (plus a small absolute
  slack); then the relQuery lands on the least-loaded replica. Default.
- ``prefix_affinity`` — template-affine placement with warmth-aware first
  assignment and least-loaded spillover.
- ``least_loaded``    — ignore affinity, always pick the least-loaded replica.
- ``round_robin``     — classic baseline, load- and affinity-blind.
"""
from __future__ import annotations

import zlib
from typing import Dict, Optional, Sequence

from repro.core.relquery import RelQuery

ROUTER_POLICIES = ("affinity", "affinity_spill", "prefix_affinity",
                   "least_loaded", "round_robin")


def route_relquery(rel_id: str, num_replicas: int) -> int:
    """Stable relQuery-affine hash (deterministic across processes, unlike
    builtin ``hash`` which is seed-randomized)."""
    return zlib.crc32(rel_id.encode()) % max(1, num_replicas)


# canonical definition lives in core (the predictor keys on it too);
# re-exported here for the router's existing callers
from repro.core.predictor import template_fingerprint  # noqa: F401,E402


class Router:
    def __init__(self, num_replicas: int, policy: str = "affinity_spill",
                 spill_factor: float = 2.0, spill_slack: int = 8):
        if policy not in ROUTER_POLICIES:
            raise ValueError(f"unknown router policy {policy!r}; "
                             f"choose from {ROUTER_POLICIES}")
        self.num_replicas = num_replicas
        self.policy = policy
        self.spill_factor = spill_factor
        self.spill_slack = spill_slack
        self._rr = 0
        self._template_home: Dict[int, int] = {}   # fingerprint -> replica
        self.max_template_homes = 4096             # oldest dropped beyond this
        # ``template_homes`` is the LIVE map size (eviction and replica death
        # shrink it); ``template_homes_created`` counts first-sight
        # assignments cumulatively — the two diverge once the FIFO bound or
        # ``evict_replica`` fires.
        self.stats = {"routed": 0, "spilled": 0, "template_homes": 0,
                      "template_homes_created": 0, "warm_hits": 0,
                      "rehomed": 0}

    # ------------------------------------------------------------- elasticity
    def grow(self, num_replicas: int) -> None:
        """Widen the replica index space (the cluster added replicas)."""
        if num_replicas < self.num_replicas:
            raise ValueError(
                f"grow({num_replicas}) below current {self.num_replicas}; "
                f"shrinking routes through eligibility, not resizing")
        self.num_replicas = num_replicas

    def evict_replica(self, replica: int) -> int:
        """Forget template homes pinned to a dead/retired replica. Affected
        templates re-home on next sight (warmth/load-aware), exactly like a
        FIFO-evicted entry. Returns the number of homes dropped."""
        gone = [fp for fp, home in self._template_home.items()
                if home == replica]
        for fp in gone:
            del self._template_home[fp]
        self.stats["template_homes"] = len(self._template_home)
        return len(gone)

    # ---------------------------------------------------------------- routing
    def route(self, rq: RelQuery, loads: Optional[Sequence[int]] = None,
              warmth: Optional[Sequence[int]] = None,
              eligible: Optional[Sequence[int]] = None) -> int:
        """Pick the replica for ``rq``. ``loads`` is the per-replica
        outstanding-request count at admission time (required by the
        load-aware policies); ``warmth`` is an optional per-replica
        cached-prefix-token probe for ``rq``'s prompts (prefix_affinity);
        ``eligible`` restricts placement to the admitting replicas (draining
        and dead replicas drop out) — None means all are admitting."""
        self.stats["routed"] += 1
        elig = list(range(self.num_replicas)) if eligible is None \
            else sorted(eligible)
        if not elig:
            raise ValueError("route() needs at least one eligible replica")
        if len(elig) == 1:
            return elig[0]
        elig_set = set(elig)
        if self.policy == "round_robin":
            r = self._rr % self.num_replicas
            while r not in elig_set:
                r = (r + 1) % self.num_replicas
            self._rr = (r + 1) % self.num_replicas
            return r
        if self.policy == "prefix_affinity":
            home = self._template_home_for(rq, loads, warmth, elig)
        else:
            home = route_relquery(rq.rel_id, self.num_replicas)
            if home not in elig_set:
                # the affine home is not admitting: fall back to a stable
                # hash over the eligible set so placement stays deterministic
                home = elig[zlib.crc32(rq.rel_id.encode()) % len(elig)]
        if self.policy == "affinity" or loads is None:
            return home
        coldest = min(elig, key=lambda i: (loads[i], i))
        if self.policy == "least_loaded":
            return coldest
        # affinity_spill / prefix_affinity: stay home unless home is
        # disproportionately hot.
        if loads[home] > loads[coldest] * self.spill_factor + self.spill_slack:
            self.stats["spilled"] += 1
            return coldest
        return home

    def _template_home_for(self, rq: RelQuery, loads: Optional[Sequence[int]],
                           warmth: Optional[Sequence[int]],
                           elig: Sequence[int]) -> int:
        """Sticky template->replica assignment. First sight of a template
        picks the warmest replica (its cache already holds this prefix), else
        the least-loaded one, else the stable hash; later relQueries follow."""
        fp = template_fingerprint(rq)
        home = self._template_home.get(fp)
        elig_set = set(elig)
        if home is not None and home in elig_set:
            # sticky homes can go stale in a long-running service: if the
            # home's cache no longer holds this prefix but another replica's
            # does (e.g. past spillover traffic warmed it), follow the warmth
            if warmth is not None and warmth[home] == 0 \
                    and max(warmth[i] for i in elig) > 0:
                home = max(elig, key=lambda i: (warmth[i], -i))
                self._template_home[fp] = home
                self.stats["rehomed"] += 1
            return home
        if home is not None:
            # the sticky home stopped admitting (drain/crash): rehome below
            self.stats["rehomed"] += 1
        if warmth is not None and max(warmth[i] for i in elig) > 0:
            home = max(elig, key=lambda i: (warmth[i], -i))
            self.stats["warm_hits"] += 1
        elif loads is not None:
            home = min(elig, key=lambda i: (loads[i], i))
        else:
            home = elig[fp % len(elig)]
        if fp not in self._template_home:
            self.stats["template_homes_created"] += 1
        self._template_home[fp] = home
        while len(self._template_home) > self.max_template_homes:
            # FIFO bound (insertion-ordered dict): an evicted template simply
            # re-homes on next sight — the map must not grow without bound
            self._template_home.pop(next(iter(self._template_home)))
        self.stats["template_homes"] = len(self._template_home)
        return home
