"""``Cluster``: N data-parallel ``EngineCore`` replicas on one simulated clock.

A discrete-event loop interleaves two event kinds in global-time order:
arrivals (routed to a replica the moment they occur, using the replicas'
queue depths at that moment plus an in-flight-batch indicator — load state
is one-batch granular because a tick retires its batch atomically) and
per-replica batch completions (each replica executes its batches serially;
replicas run in parallel with each other).
This is the simulated-clock analogue of N engine processes behind a front-end
router, and it reuses the exact single-replica scheduler/executor stack —
the scheduling decisions per replica are identical to what ``ServingEngine``
would make for that replica's sub-trace.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence

from repro.core.relquery import RelQuery
from repro.engine.engine import EngineCore, ServiceReport, merge_reports
from repro.serving.router import Router


@dataclass
class ClusterReport:
    merged: ServiceReport
    per_replica: List[ServiceReport]
    assignments: dict = field(default_factory=dict)   # rel_id -> replica
    router_stats: dict = field(default_factory=dict)

    @property
    def num_replicas(self) -> int:
        return len(self.per_replica)


class Cluster:
    """Drives ``num_replicas`` independent scheduler+executor stacks. The
    factories are called once per replica — ``make_scheduler(i)`` strictly
    before ``make_executor(i)`` (factories may share per-replica state such
    as a prefix cache) — so replicas never share mutable state."""

    def __init__(self, make_scheduler: Callable[[int], object],
                 make_executor: Callable[[int], object],
                 num_replicas: int, router: Optional[Router] = None):
        if num_replicas < 1:
            raise ValueError("num_replicas must be >= 1")
        self.cores = []
        for i in range(num_replicas):
            sched = make_scheduler(i)
            executor = make_executor(i)
            self.cores.append(EngineCore(sched, executor, replica_id=i))
        self.router = router or Router(num_replicas)
        if self.router.num_replicas != num_replicas:
            raise ValueError("router sized for a different replica count")
        self.assignments: dict = {}

    # ------------------------------------------------------------------
    def run_trace(self, trace: Sequence[RelQuery],
                  max_iterations: int = 2_000_000) -> ClusterReport:
        pending = sorted(trace, key=lambda r: r.arrival_time)
        clocks = [0.0] * len(self.cores)   # replica-local frontier
        idx = 0
        it = 0
        while True:
            # next batch start: the earliest replica frontier with work queued
            busy = [i for i, c in enumerate(self.cores) if c.has_work()]
            next_step = min((clocks[i] for i in busy), default=math.inf)
            next_arrival = pending[idx].arrival_time if idx < len(pending) else math.inf
            if math.isinf(next_step) and math.isinf(next_arrival):
                break
            if next_arrival <= next_step:
                rq = pending[idx]
                idx += 1
                # Queue depth plus an in-flight indicator: a tick retires its
                # batch at the batch's *start* ordering, so a replica whose
                # frontier is past this arrival was still busy at it — without
                # the indicator, load-aware routing reads post-completion
                # state and dumps work on a replica that is hours from free.
                loads = [c.load() + (1 if clocks[i] > rq.arrival_time else 0)
                         for i, c in enumerate(self.cores)]
                replica = self.router.route(rq, loads)
                self.assignments[rq.rel_id] = replica
                core = self.cores[replica]
                if not core.has_work():   # replica idled until this arrival
                    clocks[replica] = max(clocks[replica], rq.arrival_time)
                core.admit(rq, rq.arrival_time)
                continue
            i = min(busy, key=lambda j: clocks[j])
            event = self.cores[i].tick(clocks[i])   # raises on true deadlock
            if event is not None:
                clocks[i] = event.end
            it += 1
            if it >= max_iterations:
                raise RuntimeError("cluster exceeded max_iterations — likely livelock")
        reports = [core.report(clocks[i]) for i, core in enumerate(self.cores)]
        return ClusterReport(merged=merge_reports(reports), per_replica=reports,
                             assignments=dict(self.assignments),
                             router_stats=dict(self.router.stats))
