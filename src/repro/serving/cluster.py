"""``Cluster``: elastic data-parallel ``EngineCore`` replicas on one simulated
clock, with crash-recovery.

The cluster is an *open-loop* backend: ``submit(rq, now)`` routes a relQuery
to an admitting replica the moment it arrives (using the replicas' queue
depths at that moment plus an in-flight-batch indicator — load state is
one-batch granular because a tick retires its batch atomically) and ``step()``
advances the earliest busy replica by one batch (each replica executes its
batches serially; replicas run in parallel with each other). ``repro.serving.
Frontend`` drives these two calls for interactive submit/stream/cancel
serving; ``run_trace`` is the closed-loop compatibility shim that replays a
prebuilt arrival trace through the same loop.

Elasticity (Ray Serve mold, on the simulated clock so every scenario is
deterministic):

- ``add_replica(now)`` spawns a fresh scheduler+executor stack from the
  construction-time factories and widens the router.
- ``drain_replica(i, now)`` stops admitting on ``i``, migrates its quiescent
  (no resident KV) relQueries to surviving replicas via the snapshot codec,
  lets resident work finish, then retires the replica and freezes its report.
- ``crash_replica(i, now)`` kills ``i`` outright: its KV and post-snapshot
  progress are gone. In-flight relQueries fail over to surviving replicas —
  rewound to the last periodic snapshot (``snapshot_every``) when one exists,
  from scratch otherwise. The deterministic executor regenerates the lost
  tokens bit-identically and the Frontend's per-request high-water marks
  suppress re-emission, so final client streams match a crash-free run.
- ``metrics_snapshot(now)`` is the live observability surface (per-replica
  queue depth, KV device/host occupancy, preemptions, swaps, prefix-hit
  ratio, router spills) consumed by benchmarks and ``serve.py
  --metrics-log``; an attached ``Autoscaler`` reads the same signals.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.relquery import RelQuery, Request
from repro.distributed import fault_tolerance as ft
from repro.engine.engine import (BatchEvent, EngineCore, ServiceReport,
                                 merge_reports)
from repro.serving.router import Router

REPLICA_UP = "up"
REPLICA_DRAINING = "draining"
REPLICA_DEAD = "dead"


@dataclass
class ClusterReport:
    merged: ServiceReport
    per_replica: List[ServiceReport]
    assignments: dict = field(default_factory=dict)   # rel_id -> replica
    router_stats: dict = field(default_factory=dict)
    replica_states: List[str] = field(default_factory=list)
    scale_events: List[dict] = field(default_factory=list)
    crash_events: List[dict] = field(default_factory=list)

    @property
    def num_replicas(self) -> int:
        return len(self.per_replica)


class Cluster:
    """Drives an elastic fleet of independent scheduler+executor stacks. The
    factories are kept for the fleet's lifetime and called once per replica —
    ``make_scheduler(i)`` strictly before ``make_executor(i)`` (factories may
    share per-replica state such as a prefix cache) — so replicas never share
    mutable state, and ``add_replica`` can mint identical fresh stacks."""

    def __init__(self, make_scheduler: Callable[[int], object],
                 make_executor: Callable[[int], object],
                 num_replicas: int, router: Optional[Router] = None,
                 engine_loop: str = "serial", debug_invariants: bool = False,
                 snapshot_every: int = 0):
        if num_replicas < 1:
            raise ValueError("num_replicas must be >= 1")
        if snapshot_every < 0:
            raise ValueError("snapshot_every must be >= 0")
        self._make_scheduler = make_scheduler
        self._make_executor = make_executor
        self._engine_loop = engine_loop
        self._debug_invariants = debug_invariants
        self.snapshot_every = snapshot_every
        self.cores: List[EngineCore] = []
        self.clocks: List[float] = []           # replica-local frontier
        self.replica_state: List[str] = []
        self._ticks: List[int] = []             # per-replica batches retired
        self._replica_snaps: Dict[int, dict] = {}   # last periodic snapshot
        self._frozen_reports: Dict[int, ServiceReport] = {}
        # late-core observers (the Frontend registers its on_batch listener
        # installer here so replicas added after construction stream too)
        self.core_added_hooks: List[Callable[[EngineCore], None]] = []
        self.scale_events: List[dict] = []
        self.crash_events: List[dict] = []
        self.autoscaler = None
        for _ in range(num_replicas):
            self._spawn(0.0)
        self.router = router or Router(num_replicas)
        if self.router.num_replicas != num_replicas:
            raise ValueError("router sized for a different replica count")
        self.assignments: dict = {}

    # ------------------------------------------------------------- elasticity
    def _spawn(self, clock: float) -> int:
        i = len(self.cores)
        sched = self._make_scheduler(i)
        executor = self._make_executor(i)
        core = EngineCore(sched, executor, replica_id=i,
                          engine_loop=self._engine_loop,
                          debug_invariants=self._debug_invariants)
        self.cores.append(core)
        self.clocks.append(clock)
        self.replica_state.append(REPLICA_UP)
        self._ticks.append(0)
        for hook in self.core_added_hooks:
            hook(core)
        return i

    def admitting_replicas(self) -> List[int]:
        return [i for i, s in enumerate(self.replica_state) if s == REPLICA_UP]

    def add_replica(self, now: float) -> int:
        """Scale up: spawn a fresh replica whose clock starts at ``now``."""
        i = self._spawn(now)
        self.router.grow(len(self.cores))
        self.scale_events.append({"time": now, "action": "add", "replica": i})
        return i

    def drain_replica(self, i: int, now: float) -> dict:
        """Graceful scale-down: stop admitting on ``i``, migrate its
        quiescent relQueries (waiting/preempted, no resident KV — nothing to
        lose) to surviving replicas through the snapshot codec, and let
        resident work finish. The replica retires lazily from ``step()`` the
        moment it runs dry."""
        if self.replica_state[i] != REPLICA_UP:
            raise ValueError(f"replica {i} is {self.replica_state[i]}, "
                             f"not up")
        if len(self.admitting_replicas()) <= 1:
            raise ValueError("cannot drain the last admitting replica")
        self.replica_state[i] = REPLICA_DRAINING
        core = self.cores[i]
        core._flush_plan()   # materialize any speculative window first
        sched = core.scheduler
        movable: List[RelQuery] = []
        for rq in list(sched.relqueries.values()):
            if rq.finish_time is not None or rq.cancel_time is not None:
                continue
            if all(r.is_terminal() or
                   (r.state.value in ("waiting", "preempted")
                    and not r.prefilled_tokens) for r in rq.requests):
                movable.append(rq)
        migrated = 0
        for rq in movable:
            snap_rq = ft.snapshot_relquery(sched, rq)
            sched.remove_relquery(rq.rel_id)
            ft.rewind_relquery_to_snapshot(rq, snap_rq)
            self.submit(rq, now)
            migrated += 1
        event = {"time": now, "action": "drain", "replica": i,
                 "migrated": migrated}
        self.scale_events.append(event)
        if not core.has_work():
            self._retire(i, now)
        return event

    def _retire(self, i: int, now: float) -> None:
        self._frozen_reports[i] = self.cores[i].report(self.clocks[i])
        self.replica_state[i] = REPLICA_DEAD
        self.router.evict_replica(i)
        self.scale_events.append(
            {"time": now, "action": "retire", "replica": i})

    # ---------------------------------------------------------- fault injection
    def snapshot_replica(self, i: int,
                         delivered: Optional[Dict[str, int]] = None) -> dict:
        """Checkpoint replica ``i``'s full scheduler state (crash-recovery
        anchor). Periodic snapshots run from ``step()`` every
        ``snapshot_every`` batches."""
        core = self.cores[i]
        core._flush_plan()
        snap = ft.snapshot_scheduler(core.scheduler, delivered=delivered)
        self._replica_snaps[i] = snap
        return snap

    def crash_replica(self, i: int, now: float) -> dict:
        """Deterministic replica-crash injection at simulated time ``now``:
        replica ``i``'s device/host KV and all post-snapshot progress are
        lost. Unfinished relQueries fail over to surviving replicas — rewound
        to the last periodic snapshot when one exists, restarted from scratch
        otherwise — and the router forgets template homes pinned to ``i``.
        Work the replica had already finished is durable (its report freezes
        with the crash). Returns the crash event record."""
        if self.replica_state[i] == REPLICA_DEAD:
            raise ValueError(f"replica {i} is already dead")
        survivors = [j for j in self.admitting_replicas() if j != i]
        if not survivors:
            raise ValueError("cannot crash the last admitting replica")
        core = self.cores[i]
        core._flush_plan()
        sched = core.scheduler
        snap = self._replica_snaps.pop(i, None)
        snap_rqs = {q["rel_id"]: q for q in snap["relqueries"]} if snap else {}
        victims = [rq for rq in sched.relqueries.values()
                   if rq.finish_time is None and rq.cancel_time is None]
        # the crashed replica takes its unfinished work with it: detach the
        # victims before freezing its report, or merge_reports would let the
        # frozen (stale) entries shadow the surviving replicas' live ones
        for rq in victims:
            del sched.relqueries[rq.rel_id]
        self._frozen_reports[i] = core.report(self.clocks[i])
        self.replica_state[i] = REPLICA_DEAD
        self.router.evict_replica(i)
        kept = lost = from_snap = 0
        for rq in sorted(victims, key=lambda q: (q.arrival_time, q.rel_id)):
            q = snap_rqs.get(rq.rel_id)
            if q is not None:
                kept += ft.rewind_relquery_to_snapshot(rq, q)
                from_snap += 1
            else:
                lost += ft.reset_relquery_for_recovery(rq)
            self.submit(rq, now)
        event = {"time": now, "replica": i, "victims": len(victims),
                 "from_snapshot": from_snap, "tokens_preserved": kept,
                 "tokens_lost": lost}
        self.crash_events.append(event)
        return event

    # ------------------------------------------------------------- autoscaling
    def attach_autoscaler(self, autoscaler) -> "Cluster":
        """Install an ``Autoscaler`` (ticked from ``submit`` and ``step``)."""
        self.autoscaler = autoscaler
        return self

    # ------------------------------------------------------------- open loop
    def submit(self, rq: RelQuery, now: float) -> int:
        """Route ``rq`` at service time ``now`` and admit it to an admitting
        replica. Returns the replica index. Queue depth plus an in-flight
        indicator: a tick retires its batch at the batch's *start* ordering,
        so a replica whose frontier is past ``now`` was still busy at it —
        without the indicator, load-aware routing reads post-completion
        state and dumps work on a replica that is hours from free."""
        if self.autoscaler is not None:
            self.autoscaler.tick(now)
        admitting = self.admitting_replicas()
        if not admitting:
            raise RuntimeError("no admitting replicas (all draining or dead)")
        loads = [c.load() + (1 if self.clocks[i] > now else 0)
                 if self.replica_state[i] != REPLICA_DEAD else 0
                 for i, c in enumerate(self.cores)]
        warmth = self._cache_warmth(rq) \
            if self.router.policy == "prefix_affinity" else None
        replica = self.router.route(rq, loads, warmth=warmth,
                                    eligible=admitting)
        self.assignments[rq.rel_id] = replica
        core = self.cores[replica]
        if not core.has_work():   # replica idled until this arrival
            self.clocks[replica] = max(self.clocks[replica], now)
        core.admit(rq, now)
        return replica

    def _cache_warmth(self, rq: RelQuery) -> Optional[List[int]]:
        """Per-replica cached-token probe for ``rq``'s template prefix: how
        much of the first request's prompt each replica's prefix cache
        already holds. Side-effect free (``peek_cached``) — the probe must
        not perturb LRU order or hit statistics."""
        if not rq.requests:
            return None
        tokens = rq.requests[0].tokens
        warmth = []
        for core in self.cores:
            pc = getattr(core.scheduler, "prefix_cache", None)
            peek = getattr(pc, "peek_cached", None)
            warmth.append(peek(tokens) if peek is not None else 0)
        return warmth

    def step(self) -> Optional[BatchEvent]:
        """Tick the earliest busy live replica (one batch). None when all
        idle; raises ``EngineDeadlockError`` on a truly stuck replica."""
        for i, state in enumerate(self.replica_state):
            if state == REPLICA_DRAINING and not self.cores[i].has_work():
                self._retire(i, self.clocks[i])
        busy = [i for i, c in enumerate(self.cores)
                if self.replica_state[i] != REPLICA_DEAD and c.has_work()]
        if not busy:
            return None
        i = min(busy, key=lambda j: self.clocks[j])
        event = self.cores[i].tick(self.clocks[i])
        if event is not None:
            self.clocks[i] = event.end
            self._ticks[i] += 1
            if self.snapshot_every \
                    and self._ticks[i] % self.snapshot_every == 0 \
                    and self.replica_state[i] == REPLICA_UP:
                self.snapshot_replica(i)
            if self.autoscaler is not None:
                self.autoscaler.tick(event.end)
        return event

    def has_work(self) -> bool:
        return any(c.has_work() for i, c in enumerate(self.cores)
                   if self.replica_state[i] != REPLICA_DEAD)

    def frontier(self) -> Optional[float]:
        """Start time of the next batch across the fleet; None when idle."""
        busy = [self.clocks[i] for i, c in enumerate(self.cores)
                if self.replica_state[i] != REPLICA_DEAD and c.has_work()]
        return min(busy) if busy else None

    def end_time(self) -> float:
        live = [self.clocks[i] for i in range(len(self.cores))
                if self.replica_state[i] != REPLICA_DEAD]
        return max(live) if live else max(self.clocks)

    def cancel_relquery(self, rel_id: str, now: float) -> List[Request]:
        """Cancel on whichever replica the relQuery was routed to."""
        replica = self.assignments.get(rel_id)
        if replica is None or self.replica_state[replica] == REPLICA_DEAD:
            return []
        return self.cores[replica].cancel_relquery(rel_id, now)

    # ----------------------------------------------------------- observability
    def metrics_snapshot(self, now: Optional[float] = None) -> dict:
        """One live metrics sample across the fleet — the stream
        ``serve.py --metrics-log`` writes and the autoscaler/benchmarks read.
        Pure observation: no scheduler state is touched."""
        replicas = []
        for i, core in enumerate(self.cores):
            state = self.replica_state[i]
            if state == REPLICA_DEAD:
                replicas.append({"replica": i, "state": state})
                continue
            s = core.scheduler
            pc = getattr(s, "prefix_cache", None)
            entry = {
                "replica": i,
                "state": state,
                "clock": self.clocks[i],
                "queue_depth": s.queue_depth(),
                "running": len(s._running),
                "swapped": len(s._swapped),
                "kv_tokens_in_use": s.tokens_in_use,
                "kv_partial_prefill_tokens": s.partial_prefill_tokens,
                "kv_committed_tokens": s.committed_tokens,
                "kv_host_tokens_in_use": getattr(s, "host_tokens_in_use", 0),
                "preemptions": getattr(s, "preemptions", 0),
                "swap_outs": getattr(s, "swap_outs", 0),
                "swap_ins": getattr(s, "swap_ins", 0),
            }
            if pc is not None and hasattr(pc, "hit_ratio"):
                entry["prefix_hit_ratio"] = pc.hit_ratio
            replicas.append(entry)
        return {
            "time": self.end_time() if now is None else now,
            "replicas": replicas,
            "num_replicas": len(self.cores),
            "admitting": len(self.admitting_replicas()),
            "router": dict(self.router.stats),
            "assignments": len(self.assignments),
            "scale_events": len(self.scale_events),
            "crash_events": len(self.crash_events),
        }

    def reports(self) -> List[ServiceReport]:
        # core.report flushes any pipelined speculative window first, so a
        # mid-flight snapshot never observes projected (placeholder) state;
        # dead replicas contribute the report frozen at crash/retire time
        return [self._frozen_reports[i]
                if self.replica_state[i] == REPLICA_DEAD
                else core.report(self.clocks[i])
                for i, core in enumerate(self.cores)]

    def report(self) -> ClusterReport:
        reports = self.reports()
        return ClusterReport(merged=merge_reports(reports),
                             per_replica=reports,
                             assignments=dict(self.assignments),
                             router_stats=dict(self.router.stats),
                             replica_states=list(self.replica_state),
                             scale_events=list(self.scale_events),
                             crash_events=list(self.crash_events))

    # ------------------------------------------------------------------
    def run_trace(self, trace: Sequence[RelQuery],
                  max_iterations: int = 2_000_000) -> ClusterReport:
        """Replay a full arrival trace across the fleet.

        .. deprecated:: closed-loop compatibility shim. Drive the open-loop
           ``repro.serving.Frontend`` over this cluster instead; this method
           is now a thin trace-replay driver over it and produces the
           identical merged ``ClusterReport``.
        """
        from repro.serving.frontend import Frontend

        fe = Frontend(self)
        try:
            fe.replay(trace, max_iterations=max_iterations)
        finally:
            fe.close()
        return self.report()
