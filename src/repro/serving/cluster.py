"""``Cluster``: N data-parallel ``EngineCore`` replicas on one simulated clock.

The cluster is an *open-loop* backend: ``submit(rq, now)`` routes a relQuery
to a replica the moment it arrives (using the replicas' queue depths at that
moment plus an in-flight-batch indicator — load state is one-batch granular
because a tick retires its batch atomically) and ``step()`` advances the
earliest busy replica by one batch (each replica executes its batches
serially; replicas run in parallel with each other). ``repro.serving.
Frontend`` drives these two calls for interactive submit/stream/cancel
serving; ``run_trace`` is the closed-loop compatibility shim that replays a
prebuilt arrival trace through the same loop.

This is the simulated-clock analogue of N engine processes behind a front-end
router, and it reuses the exact single-replica scheduler/executor stack —
the scheduling decisions per replica are identical to what ``ServingEngine``
would make for that replica's sub-trace.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence

from repro.core.relquery import RelQuery, Request
from repro.engine.engine import (BatchEvent, EngineCore, ServiceReport,
                                 merge_reports)
from repro.serving.router import Router


@dataclass
class ClusterReport:
    merged: ServiceReport
    per_replica: List[ServiceReport]
    assignments: dict = field(default_factory=dict)   # rel_id -> replica
    router_stats: dict = field(default_factory=dict)

    @property
    def num_replicas(self) -> int:
        return len(self.per_replica)


class Cluster:
    """Drives ``num_replicas`` independent scheduler+executor stacks. The
    factories are called once per replica — ``make_scheduler(i)`` strictly
    before ``make_executor(i)`` (factories may share per-replica state such
    as a prefix cache) — so replicas never share mutable state."""

    def __init__(self, make_scheduler: Callable[[int], object],
                 make_executor: Callable[[int], object],
                 num_replicas: int, router: Optional[Router] = None,
                 engine_loop: str = "serial", debug_invariants: bool = False):
        if num_replicas < 1:
            raise ValueError("num_replicas must be >= 1")
        self.cores = []
        for i in range(num_replicas):
            sched = make_scheduler(i)
            executor = make_executor(i)
            self.cores.append(EngineCore(sched, executor, replica_id=i,
                                         engine_loop=engine_loop,
                                         debug_invariants=debug_invariants))
        self.router = router or Router(num_replicas)
        if self.router.num_replicas != num_replicas:
            raise ValueError("router sized for a different replica count")
        self.assignments: dict = {}
        self.clocks: List[float] = [0.0] * num_replicas  # replica-local frontier

    # ------------------------------------------------------------- open loop
    def submit(self, rq: RelQuery, now: float) -> int:
        """Route ``rq`` at service time ``now`` and admit it to its replica.
        Returns the replica index. Queue depth plus an in-flight indicator:
        a tick retires its batch at the batch's *start* ordering, so a
        replica whose frontier is past ``now`` was still busy at it —
        without the indicator, load-aware routing reads post-completion
        state and dumps work on a replica that is hours from free."""
        loads = [c.load() + (1 if self.clocks[i] > now else 0)
                 for i, c in enumerate(self.cores)]
        warmth = self._cache_warmth(rq) \
            if self.router.policy == "prefix_affinity" else None
        replica = self.router.route(rq, loads, warmth=warmth)
        self.assignments[rq.rel_id] = replica
        core = self.cores[replica]
        if not core.has_work():   # replica idled until this arrival
            self.clocks[replica] = max(self.clocks[replica], now)
        core.admit(rq, now)
        return replica

    def _cache_warmth(self, rq: RelQuery) -> Optional[List[int]]:
        """Per-replica cached-token probe for ``rq``'s template prefix: how
        much of the first request's prompt each replica's prefix cache
        already holds. Side-effect free (``peek_cached``) — the probe must
        not perturb LRU order or hit statistics."""
        if not rq.requests:
            return None
        tokens = rq.requests[0].tokens
        warmth = []
        for core in self.cores:
            pc = getattr(core.scheduler, "prefix_cache", None)
            peek = getattr(pc, "peek_cached", None)
            warmth.append(peek(tokens) if peek is not None else 0)
        return warmth

    def step(self) -> Optional[BatchEvent]:
        """Tick the earliest busy replica (one batch). None when all idle;
        raises ``EngineDeadlockError`` on a truly stuck replica."""
        busy = [i for i, c in enumerate(self.cores) if c.has_work()]
        if not busy:
            return None
        i = min(busy, key=lambda j: self.clocks[j])
        event = self.cores[i].tick(self.clocks[i])
        if event is not None:
            self.clocks[i] = event.end
        return event

    def has_work(self) -> bool:
        return any(c.has_work() for c in self.cores)

    def frontier(self) -> Optional[float]:
        """Start time of the next batch across the fleet; None when idle."""
        busy = [self.clocks[i] for i, c in enumerate(self.cores) if c.has_work()]
        return min(busy) if busy else None

    def end_time(self) -> float:
        return max(self.clocks)

    def cancel_relquery(self, rel_id: str, now: float) -> List[Request]:
        """Cancel on whichever replica the relQuery was routed to."""
        replica = self.assignments.get(rel_id)
        if replica is None:
            return []
        return self.cores[replica].cancel_relquery(rel_id, now)

    def reports(self) -> List[ServiceReport]:
        # core.report flushes any pipelined speculative window first, so a
        # mid-flight snapshot never observes projected (placeholder) state
        return [core.report(self.clocks[i]) for i, core in enumerate(self.cores)]

    def report(self) -> ClusterReport:
        reports = self.reports()
        return ClusterReport(merged=merge_reports(reports), per_replica=reports,
                             assignments=dict(self.assignments),
                             router_stats=dict(self.router.stats))

    # ------------------------------------------------------------------
    def run_trace(self, trace: Sequence[RelQuery],
                  max_iterations: int = 2_000_000) -> ClusterReport:
        """Replay a full arrival trace across the fleet.

        .. deprecated:: closed-loop compatibility shim. Drive the open-loop
           ``repro.serving.Frontend`` over this cluster instead; this method
           is now a thin trace-replay driver over it and produces the
           identical merged ``ClusterReport``.
        """
        from repro.serving.frontend import Frontend

        fe = Frontend(self)
        try:
            fe.replay(trace, max_iterations=max_iterations)
        finally:
            fe.close()
        return self.report()
