"""RWKV6 chunked-WKV kernel (TPU Pallas).

One grid step processes one (batch, head) pair's chunk of ``c`` tokens:
cumulative per-channel log-decays, the strictly-lower-triangular decay-weighted
intra-chunk attention matrix A (all exponents <= 0 — numerically safe), the
inter-chunk state contribution, and the state update. The [c, c] products run
on the MXU; the decay reweighting is VPU elementwise work on [c, c, K] tiles
held in VMEM (c=64, K=64 -> 1 MB f32, well within budget).

Layouts: r/k/v/logw [B, c, H, K]; u [H, K]; state [B, H, K, V] (f32).
Outputs: o [B, c, H, V], new_state [B, H, K, V].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s_ref, o_ref, s_out_ref):
    c = r_ref.shape[1]
    f32 = jnp.float32
    r = r_ref[0, :, 0].astype(f32)          # [c, K]
    k = k_ref[0, :, 0].astype(f32)
    v = v_ref[0, :, 0].astype(f32)
    logw = w_ref[0, :, 0].astype(f32)
    u = u_ref[0].astype(f32)                # [K]
    state = s_ref[0, 0].astype(f32)         # [K, V]

    ldi = jnp.cumsum(logw, axis=0)          # inclusive decay log-sums [c, K]
    lde = ldi - logw                        # exclusive

    # inter-chunk: state contribution
    rd = r * jnp.exp(lde)
    o = jax.lax.dot_general(rd, state, (((1,), (0,)), ((), ())))   # [c, V]

    # intra-chunk: A[t, j] = sum_k r[t,k] k[j,k] exp(lde[t,k] - ldi[j,k]), j < t
    diff = lde[:, None, :] - ldi[None, :, :]                        # [c, c, K]
    tri = (jax.lax.broadcasted_iota(jnp.int32, (c, c), 0)
           > jax.lax.broadcasted_iota(jnp.int32, (c, c), 1))
    wdec = jnp.where(tri[:, :, None], jnp.exp(jnp.minimum(diff, 0.0)), 0.0)
    A = jnp.sum(r[:, None, :] * k[None, :, :] * wdec, axis=-1)      # [c, c]
    diag = jnp.sum(r * k * u[None, :], axis=-1)                     # [c]
    A = A + jnp.diag(diag)
    o = o + jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())))

    # state update: S' = diag(d_total) S + (k * exp(ldi[-1] - ldi))^T v
    d_total = jnp.exp(ldi[-1])                                      # [K]
    k_scaled = k * jnp.exp(ldi[-1][None, :] - ldi)
    s_new = state * d_total[:, None] + jax.lax.dot_general(
        k_scaled, v, (((0,), (0,)), ((), ())))

    o_ref[0, :, 0] = o.astype(o_ref.dtype)
    s_out_ref[0, 0] = s_new.astype(s_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def rwkv6_chunk(r, k, v, logw, u, state, *, interpret: bool = True):
    B, c, H, K = r.shape
    V = state.shape[-1]
    grid = (B, H)
    io_spec = pl.BlockSpec((1, c, 1, K), lambda b, h: (b, 0, h, 0))
    out, s_new = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            io_spec, io_spec,
            pl.BlockSpec((1, c, 1, V), lambda b, h: (b, 0, h, 0)),
            io_spec,
            pl.BlockSpec((1, K), lambda b, h: (h, 0)),
            pl.BlockSpec((1, 1, K, V), lambda b, h: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, c, 1, V), lambda b, h: (b, 0, h, 0)),
            pl.BlockSpec((1, 1, K, V), lambda b, h: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, c, H, V), r.dtype),
            jax.ShapeDtypeStruct((B, H, K, V), jnp.float32),
        ],
        interpret=interpret,
    )(r, k, v, logw, u, state)
    return out, s_new
