"""Paged-attention decode kernel (TPU Pallas).

One query token per sequence attends a paged KV cache. TPU adaptation of
vLLM's CUDA kernel: the GPU's shared-memory staging becomes explicit HBM→VMEM
BlockSpec tiling; the block table is scalar-prefetched (SMEM) and drives the
page index_map, so each grid step DMAs exactly one [page_size, head_dim] K/V
tile per kv head — MXU-aligned when head_dim is a multiple of 128 and
page_size a multiple of 8.

Layouts (matching the engine's packed-GQA scheme):
  q            [B, KV, Qp, hd]     one token per sequence
  k/v_pages    [P, page, KV, hd]   paged KV pool
  block_tables [B, max_pages]      page ids per sequence (pad with 0)
  context_lens [B]                 valid tokens per sequence
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(bt_ref, cl_ref,           # scalar-prefetch refs
            q_ref, k_ref, v_ref,       # VMEM tiles
            o_ref,
            acc_ref, m_ref, l_ref,     # VMEM scratch
            *, page_size: int, num_pages: int, num_q_tokens: int,
            q_per_token: int):
    b = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    ctx = cl_ref[b]
    page_start = i * page_size

    @pl.when(page_start < ctx)
    def _step():
        hd = q_ref.shape[-1]
        scale = 1.0 / math.sqrt(hd)
        q = q_ref[0, 0].astype(jnp.float32) * scale       # [Qt*Qp, hd]
        k = k_ref[0, :, 0].astype(jnp.float32)            # [page, hd]
        v = v_ref[0, :, 0].astype(jnp.float32)
        rows = q.shape[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))   # [rows, page]
        tok = page_start + jax.lax.broadcasted_iota(jnp.int32, (rows, page_size), 1)
        # causal chunk mask: q row r belongs to query token r // Qp, whose
        # absolute position is ctx - Qt + r // Qp (the chunk's Qt tokens end
        # the context). Qt == 1 degenerates to the classic tok < ctx mask.
        row = jax.lax.broadcasted_iota(jnp.int32, (rows, page_size), 0)
        qpos = ctx - num_q_tokens + row // q_per_token
        s = jnp.where(tok <= qpos, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(i == num_pages - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "num_q_tokens"))
def paged_attention(q, k_pages, v_pages, block_tables, context_lens,
                    *, interpret: bool = True, num_q_tokens: int = 1):
    """See module docstring for layouts. interpret=True validates on CPU.

    ``num_q_tokens`` > 1 runs a *chunk* of query tokens per sequence against
    the paged cache (speculative verify / chunked-prefill continuation): the
    q row axis is then [Qt * Qp] with query token t at absolute position
    ``context_lens[b] - Qt + t``, causally masked inside the kernel.
    """
    B, KV, rows, hd = q.shape
    if rows % num_q_tokens:
        raise ValueError(f"q rows {rows} not divisible by num_q_tokens"
                         f" {num_q_tokens}")
    Qp = rows
    page_size = k_pages.shape[1]
    max_pages = block_tables.shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, Qp, hd), lambda b, h, i, bt, cl: (b, h, 0, 0)),
            pl.BlockSpec((1, page_size, 1, hd),
                         lambda b, h, i, bt, cl: (bt[b, i], 0, h, 0)),
            pl.BlockSpec((1, page_size, 1, hd),
                         lambda b, h, i, bt, cl: (bt[b, i], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, Qp, hd), lambda b, h, i, bt, cl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Qp, hd), jnp.float32),
            pltpu.VMEM((Qp, 1), jnp.float32),
            pltpu.VMEM((Qp, 1), jnp.float32),
        ],
    )
    kernel = functools.partial(_kernel, page_size=page_size, num_pages=max_pages,
                               num_q_tokens=num_q_tokens,
                               q_per_token=rows // num_q_tokens)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(block_tables, context_lens, q, k_pages, v_pages)
