"""Pure-jnp oracles for every Pallas kernel. Tests sweep shapes/dtypes and
assert_allclose kernel (interpret=True) against these."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def paged_attention_ref(q, k_pages, v_pages, block_tables, context_lens,
                        *, num_q_tokens: int = 1):
    """q: [B, KV, Qt*Qp, hd]; k/v_pages: [num_pages, page, KV, hd];
    block_tables: [B, max_pages]; context_lens: [B] -> out [B, KV, Qt*Qp, hd].

    ``num_q_tokens`` (Qt) > 1: a chunk of query tokens per sequence, token t
    at absolute position ``context_lens[b] - Qt + t`` (causally masked) —
    mirrors the Pallas kernel's chunk mode."""
    B, KV, rows, hd = q.shape
    page = k_pages.shape[1]
    max_pages = block_tables.shape[1]
    scale = 1.0 / math.sqrt(hd)

    k = k_pages[block_tables]          # [B, max_pages, page, KV, hd]
    v = v_pages[block_tables]
    k = k.reshape(B, max_pages * page, KV, hd)
    v = v.reshape(B, max_pages * page, KV, hd)
    s = jnp.einsum("bgqh,btgh->bgqt", q.astype(jnp.float32) * scale,
                   k.astype(jnp.float32))
    idx = jnp.arange(max_pages * page)
    # per-row causal bound: key position must not exceed the row's query
    # token position (== ctx - 1 for every row when Qt == 1)
    qtok = jnp.repeat(jnp.arange(num_q_tokens), rows // num_q_tokens)  # [rows]
    qpos = context_lens[:, None] - num_q_tokens + qtok[None, :]        # [B, rows]
    valid = idx[None, None, :] <= qpos[:, :, None]                     # [B, rows, T]
    s = jnp.where(valid[:, None, :, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgqt,btgh->bgqh", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def flash_prefill_ref(q, k, v, *, causal=True, q_offset=0, window=0):
    """q: [B, G, S, R, hd] (R = q rows per kv slot); k/v: [B, G, T, hd].
    q row (s, r) attends keys t <= s + q_offset (and within window)."""
    B, G, S, R, hd = q.shape
    T = k.shape[2]
    scale = 1.0 / math.sqrt(hd)
    s_ = jnp.einsum("bgsrh,bgth->bgsrt", q.astype(jnp.float32) * scale,
                    k.astype(jnp.float32))
    qpos = q_offset + jnp.arange(S)
    kpos = jnp.arange(T)
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    s_ = jnp.where(mask[None, None, :, None, :], s_, -1e30)
    p = jax.nn.softmax(s_, axis=-1)
    o = jnp.einsum("bgsrt,bgth->bgsrh", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def rwkv6_chunk_ref(r, k, v, logw, u, state):
    """Naive sequential recurrence — the gold oracle for the chunked kernel.
    r/k/v/logw: [B, c, H, K]; u: [H, K]; state: [B, H, K, V]."""
    f32 = jnp.float32
    r, k, v, logw = (x.astype(f32) for x in (r, k, v, logw))
    state = state.astype(f32)
    c = r.shape[1]
    outs = []
    for t in range(c):
        kv = k[:, t][..., :, None] * v[:, t][..., None, :]       # [B, H, K, V]
        o = jnp.einsum("bhk,bhkv->bhv", r[:, t], state + u[None, :, :, None] * kv)
        outs.append(o)
        state = state * jnp.exp(logw[:, t])[..., None] + kv
    return jnp.stack(outs, axis=1), state
