"""Public jit'd entry points for the Pallas kernels.

``interpret`` defaults to True off-TPU (kernel bodies execute in Python for
validation); on a TPU backend the compiled Mosaic path is used. The model
graphs call the pure-XLA reference path by default (``use_pallas`` switch) so
CPU dry-run cost analysis reflects fused XLA ops — see DESIGN.md §3.
"""
from __future__ import annotations

import jax

from repro.kernels.flash_prefill import flash_prefill
from repro.kernels.paged_attention import paged_attention
from repro.kernels.rwkv6_chunk import rwkv6_chunk
from repro.kernels import ref


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def default_interpret() -> bool:
    return not on_tpu()


__all__ = [
    "paged_attention", "flash_prefill", "rwkv6_chunk", "ref",
    "on_tpu", "default_interpret",
]
