"""Flash-attention prefill kernel (TPU Pallas) with prefix-cache offset and
sliding-window support.

The GQA-packed layout folds the Qp q-rows of each kv slot into the q tile's
row dimension, so the MXU sees [q_block*Qp, hd] x [hd, kv_block] matmuls.
Causality works on the *sequence* index (row // Qp) shifted by ``q_offset`` —
this is what lets a prefix-cached prefill attend the cached tokens without
recomputing them (paper Fig. 7's utok linearity).

Layouts:
  q [B, G, S, R, hd]  (G = kv slots, R = q rows per slot)
  k [B, G, T, hd], v [B, G, T, hd]; T >= q_offset + S
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
            *, q_block: int, kv_block: int, rows: int, num_kv: int,
            q_offset: int, causal: bool, window: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    hd = q_ref.shape[-1]
    scale = 1.0 / math.sqrt(hd)
    q = q_ref[0].astype(jnp.float32) * scale                 # [q_block*R, hd]
    k = k_ref[0].astype(jnp.float32)                         # [kv_block, hd]
    v = v_ref[0].astype(jnp.float32)
    n_rows = q.shape[0]

    # absolute positions: q row r belongs to sequence index (qi*qb + r//R)
    row = jax.lax.broadcasted_iota(jnp.int32, (n_rows, kv_block), 0)
    qpos = q_offset + qi * q_block + row // rows
    kpos = kj * kv_block + jax.lax.broadcasted_iota(jnp.int32, (n_rows, kv_block), 1)
    mask = jnp.ones((n_rows, kv_block), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [rows, kv_block]
    s = jnp.where(mask, s, NEG_INF)
    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())))
    m_ref[...] = m_new

    @pl.when(kj == num_kv - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_offset",
                                             "q_block", "kv_block", "interpret"))
def flash_prefill(q, k, v, *, causal: bool = True, window: int = 0,
                  q_offset: int = 0, q_block: int = 128, kv_block: int = 128,
                  interpret: bool = True):
    B, G, S, R, hd = q.shape
    T = k.shape[2]
    q_block = min(q_block, S)
    kv_block = min(kv_block, T)
    assert S % q_block == 0 and T % kv_block == 0
    nq, nk = S // q_block, T // kv_block
    q2 = q.reshape(B, G, S * R, hd)

    grid = (B * G, nq, nk)
    kernel = functools.partial(
        _kernel, q_block=q_block, kv_block=kv_block, rows=R, num_kv=nk,
        q_offset=q_offset, causal=causal, window=window)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q_block * R, hd), lambda bg, i, j: (bg, i, 0)),
            pl.BlockSpec((1, kv_block, hd), lambda bg, i, j: (bg, j, 0)),
            pl.BlockSpec((1, kv_block, hd), lambda bg, i, j: (bg, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block * R, hd), lambda bg, i, j: (bg, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * G, S * R, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block * R, hd), jnp.float32),
            pltpu.VMEM((q_block * R, 1), jnp.float32),
            pltpu.VMEM((q_block * R, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q2.reshape(B * G, S * R, hd), k.reshape(B * G, T, hd),
      v.reshape(B * G, T, hd))
    return out.reshape(B, G, S, R, hd)
