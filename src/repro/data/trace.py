"""Serving-trace construction (paper §5.1): sample relQueries over datasets,
Poisson arrivals at a given rate, request counts uniform in [1, 100].
"""
from __future__ import annotations

import random
import zlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

from repro.core.relquery import RelQuery, Request, make_relquery
from repro.data.datasets import Dataset, make_dataset
from repro.engine.tokenizer import HashTokenizer


@dataclass
class TraceConfig:
    num_relqueries: int = 100
    rate: float = 1.0                  # relQueries per second (Poisson)
    min_requests: int = 1
    max_requests: int = 100
    seed: int = 0
    output_len_jitter: float = 0.35    # EOS terminates before OL sometimes
    # Clamp on the per-template OL(R), applied at construction. Traces are
    # immutable once built (they may be shared between runs/replicas), so
    # drivers that need short outputs — e.g. real-JAX smoke mode keeping CPU
    # decoding affordable — set this instead of mutating built relQueries.
    output_token_cap: Optional[int] = None
    # Restrict template sampling to the dataset's first N templates — the
    # shared-template regime (many relQueries rendered from few templates)
    # that prefix-sharing-aware scheduling and routing target. None keeps the
    # full template set and the historical trace byte-identical.
    num_templates: Optional[int] = None
    # Fraction of each relQuery's rows replaced by *exact* copies of earlier
    # rows in the same window — the duplicate-heavy regime the planner's
    # dedup pass targets. A duplicate is request-identical: same rendered
    # prompt AND the same sampled sim_output_len (copied from its source), so
    # answering the leader once reproduces every duplicate's stream exactly.
    # Drawn from a derived RNG stream: at 0.0 nothing is drawn and the trace
    # is byte-identical to historical traces.
    dup_row_fraction: float = 0.0


def poisson_arrivals(n: int, rate: float, rng: random.Random) -> List[float]:
    t, out = 0.0, []
    for _ in range(n):
        t += rng.expovariate(rate)
        out.append(t)
    return out


def build_trace(dataset: Dataset, cfg: TraceConfig,
                tokenizer: Optional[HashTokenizer] = None) -> List[RelQuery]:
    tokenizer = tokenizer or HashTokenizer()
    rng = random.Random(cfg.seed)
    arrivals = poisson_arrivals(cfg.num_relqueries, cfg.rate, rng)
    templates = dataset.templates if cfg.num_templates is None else \
        dataset.templates[:max(1, cfg.num_templates)]
    trace: List[RelQuery] = []
    for qi, arr in enumerate(arrivals):
        tpl = rng.choice(templates)
        n_req = rng.randint(cfg.min_requests, cfg.max_requests)
        offset = rng.randrange(0, max(1, len(dataset.table) - n_req))
        rows = dataset.table.rows[offset:offset + n_req]
        # duplicate-heavy synthesis: replace a fraction of the window with
        # copies of earlier rows (derived RNG — the main stream is untouched,
        # keeping 0.0 byte-identical to historical traces)
        dup_src: Dict[int, int] = {}
        if cfg.dup_row_fraction > 0 and len(rows) > 1:
            rows = list(rows)
            dup_rng = random.Random(
                zlib.crc32(f"dup:{cfg.seed}:{qi}".encode()))
            n_dup = int(round(cfg.dup_row_fraction * len(rows)))
            for _ in range(n_dup):
                dst = dup_rng.randrange(1, len(rows))
                src = dup_rng.randrange(0, dst)
                rows[dst] = rows[src]
                dup_src[dst] = src
        prompts = [tokenizer.encode(tpl.render(row)) for row in rows]
        ol = tpl.max_output_tokens
        if cfg.output_token_cap is not None:
            ol = max(1, min(ol, cfg.output_token_cap))
        rq = make_relquery(f"q{qi}", prompts, arr, ol,
                           template_id=tpl.template_id, eos_token=tokenizer.eos)
        # simulated actual output lengths (EOS can fire before the limit)
        for r in rq.requests:
            lo = max(1, int(ol * (1 - cfg.output_len_jitter)))
            r.sim_output_len = rng.randint(lo, ol)
        # duplicates are request-identical: copy the source row's sampled
        # length too (ascending dst order propagates through dup chains)
        for dst, src in sorted(dup_src.items()):
            rq.requests[dst].sim_output_len = rq.requests[src].sim_output_len
        trace.append(rq)
    return trace


def quick_trace(dataset_name: str = "rotten", num_relqueries: int = 20,
                rate: float = 1.0, seed: int = 0, num_rows: int = 2000,
                max_requests: int = 40) -> List[RelQuery]:
    ds = make_dataset(dataset_name, num_rows=num_rows, seed=seed)
    cfg = TraceConfig(num_relqueries=num_relqueries, rate=rate, seed=seed,
                      max_requests=max_requests)
    return build_trace(ds, cfg)
