"""The five relQuery task types (paper Table 5) with per-dataset adaptations.

``render(template, row)`` substitutes ``{attr}`` placeholders with row values —
Definition 2.1's ζ[s_i].
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, List, Sequence

from repro.data.tables import Table

# output-length limits per query type (paper §5.1)
OUTPUT_LIMITS = {
    "filter": 5,
    "classify": 10,
    "rating": 5,
    "summarize": 50,
    "open": 100,
}


@dataclass(frozen=True)
class RelQueryTemplate:
    template_id: str
    qtype: str                  # filter | classify | rating | summarize | open
    text: str                   # contains {attr} placeholders

    @property
    def max_output_tokens(self) -> int:
        return OUTPUT_LIMITS[self.qtype]

    @property
    def attributes(self) -> List[str]:
        return re.findall(r"\{(\w+)\}", self.text)

    def render(self, row: Dict[str, str]) -> str:
        out = self.text
        for attr in self.attributes:
            if attr not in row:
                # A silent empty substitution here poisons everything above:
                # dedup keys collide across genuinely different rows and the
                # planner's column projection can drop a column it believed
                # unused. Fail loudly instead.
                raise KeyError(
                    f"template {self.template_id!r}: row has no attribute "
                    f"{attr!r} (row columns: {sorted(row)})")
            out = out.replace("{" + attr + "}", row[attr])
        return out


def default_templates(dataset: str, item_attr: str, review_attr: str) -> List[RelQueryTemplate]:
    """Five templates per dataset ≈ the paper's 4 datasets x 5 types = 20."""
    mk = lambda qt, text: RelQueryTemplate(f"{dataset}/{qt}", qt, text)
    return [
        mk("filter", "Decide whether this item is suitable for children based on the "
                     f"description {{{item_attr}}} . Answer yes or no only ."),
        mk("classify", "Categorize the sentiment of the review "
                       f"{{{review_attr}}} as Negative , Positive , or Neutral ."),
        mk("rating", "Predict the user's rating from 1 to 5 based on the item "
                     f"{{{item_attr}}} and the comment {{{review_attr}}} . "
                     "Output only the digit and nothing else ."),
        mk("summarize", f"Summarize the user's review {{{review_attr}}} on the item "
                        f"{{{item_attr}}} within 20 words ."),
        mk("open", "Who are the most likely audiences for this item given its "
                   f"description {{{item_attr}}} and a sample review {{{review_attr}}} ? "
                   "Explain briefly ."),
    ]
