"""Relational table abstraction (Definition 2.1): T = {C, S}."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence


@dataclass
class Table:
    name: str
    columns: List[str]                  # schema C
    rows: List[Dict[str, str]]          # rows S

    def __len__(self) -> int:
        return len(self.rows)

    def select(self, n: int, offset: int = 0) -> "Table":
        return Table(self.name, self.columns, self.rows[offset:offset + n])

    def column(self, name: str) -> List[str]:
        return [r[name] for r in self.rows]
