"""Synthetic dataset generators matched to the paper's Table 4 statistics.

The originals (Amazon reviews, Rotten Tomatoes, RateBeer, PDMX) are not
available offline, so we generate tables whose rendered-prompt token-length
distributions match the published averages, with realistic *value overlap*
(shared item descriptions across rows — several reviews of the same product)
so prefix-cache hit ratios land in the paper's ~38% regime (Fig. 4).
"""
from __future__ import annotations

import random
import zlib
from dataclasses import dataclass
from typing import Dict, List, Tuple

from repro.data.tables import Table
from repro.data.templates import RelQueryTemplate, default_templates

# (avg prompt tokens, avg output tokens) per paper Table 4
DATASET_STATS: Dict[str, Tuple[int, int]] = {
    "amazon": (234, 18),
    "rotten": (215, 21),
    "beer": (174, 19),
    "pdmx": (158, 23),
}

_WORDS = [f"w{i:03d}" for i in range(800)]


def _sentence(rng: random.Random, n: int) -> str:
    return " ".join(rng.choice(_WORDS) for _ in range(n))


@dataclass
class Dataset:
    name: str
    table: Table
    templates: List[RelQueryTemplate]
    avg_output_tokens: int
    item_attr: str
    review_attr: str


def make_dataset(name: str, num_rows: int = 10_000, seed: int = 0,
                 items_per_catalog: int = 64) -> Dataset:
    """Rows reference a small catalog of shared item descriptions (value
    overlap) and carry unique review text (the uncached part)."""
    if name not in DATASET_STATS:
        raise KeyError(f"unknown dataset {name!r}; known: {list(DATASET_STATS)}")
    avg_in, avg_out = DATASET_STATS[name]
    rng = random.Random(seed ^ zlib.crc32(name.encode()))  # stable across processes
    # template overhead is ~25 words; split the rest between item (shared)
    # and review (unique) text, biased so shared prefixes are meaningful
    item_words = max(8, int(avg_in * 0.42))
    review_words = max(8, avg_in - item_words - 25)
    catalog = [_sentence(rng, max(4, int(rng.gauss(item_words, item_words * 0.25))))
               for _ in range(items_per_catalog)]
    rows = []
    for i in range(num_rows):
        rows.append({
            "item": rng.choice(catalog),
            "review": _sentence(rng, max(4, int(rng.gauss(review_words,
                                                          review_words * 0.3)))),
            "row_id": str(i),
        })
    table = Table(name, ["item", "review", "row_id"], rows)
    return Dataset(name, table, default_templates(name, "item", "review"),
                   avg_out, "item", "review")


ALL_DATASETS = tuple(DATASET_STATS)
