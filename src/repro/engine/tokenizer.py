"""Deterministic hash tokenizer: whitespace word-piece with stable ids.

Identical text → identical token ids, so template prefixes shared across a
relQuery's requests produce genuinely shared token-block prefixes — exactly
what the prefix cache and the DPU's utok estimate need to be exercised for
real. (No learned merges; this is a serving-system reproduction, not an NLP
one.)
"""
from __future__ import annotations

import hashlib
from typing import List, Sequence


class HashTokenizer:
    def __init__(self, vocab_size: int = 50_000, bos: int = 1, eos: int = 0):
        self.vocab_size = vocab_size
        self.bos = bos
        self.eos = eos

    def _tok(self, word: str) -> int:
        h = int.from_bytes(hashlib.blake2s(word.encode(), digest_size=4).digest(), "little")
        return 2 + h % (self.vocab_size - 2)

    def encode(self, text: str, add_bos: bool = True) -> List[int]:
        toks = [self._tok(w) for w in text.split()]
        return ([self.bos] + toks) if add_bos else toks

    def decode(self, ids: Sequence[int]) -> str:
        return " ".join(f"<{i}>" for i in ids)
