"""Real JAX executors: token-by-token execution of scheduler-issued batches on
an actual model (smoke-scale on CPU; the same code path drives a TPU slice).

Two KV backends behind one engine-facing contract (``dispatch`` / ``wait`` —
with ``execute`` as the serial composition — plus ``release_request`` /
``validate_relquery`` / ``prestage`` / ``fitted_model``):

``RealExecutor`` — the dense baseline. ``max_slots`` decode cache slots of
``max_len`` tokens each (the model's dense/ring KV layout); prefill assigns
slots one request at a time with bucketed padding, decode runs one
``decode_step`` over all active slots. Kept bit-identical as the reference
the paged backend is pinned against.

``PagedRealExecutor`` — block-paged KV owned by ``BlockManager``: a single
``[num_blocks, block_size, heads, dim]`` K/V pool per layer, per-request
block tables, batched multi-request prefill (shape-bucketed on batch and
length to bound recompilation, optionally through the Pallas
``flash_prefill`` kernel) and decode through the Pallas ``paged_attention``
kernel — falling back to ``kernels/ref.py`` on CPU so CI exercises the same
path. Prefix-sharing chains map to physically shared (ref-counted) blocks
with copy-on-write on divergence; preemption releases real blocks instead of
whole slots, so the scheduler's token ledger and device residency agree.

Both are the calibration source for the linear batch-cost model (paper
Fig. 7): ``fitted_model()`` fits α/β from measured (tokens, duration) /
(reqs, duration) samples on this host.
"""
from __future__ import annotations

import time as _time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import latency_model as lm_mod
from repro.core.batch import Batch
from repro.core.relquery import RelQuery, Request
from repro.core.scheduler import BatchResult
from repro.engine.kv_cache import BlockManager, OutOfBlocks
from repro.engine.prefix_cache import PrefixCache, block_hashes


class RequestCapacityError(ValueError):
    """A request can never fit this executor's per-sequence KV capacity —
    raised at admission (``EngineCore.admit``) instead of overflowing the
    slot buffer / block table mid-flight."""


@dataclass
class InFlight:
    """A dispatched-but-not-consumed batch: the device logits (JAX async
    futures until someone materializes them) plus the host bookkeeping
    ``wait`` needs to turn them into a ``BatchResult``.

    Splitting ``execute`` into ``dispatch`` (issue compiled calls, host-side
    KV bookkeeping) and ``wait`` (block on logits, sample, finish detection)
    lets the engine run the *next* scheduling decision while this batch is
    still on the device — ``jax.block_until_ready``/host transfer happens in
    ``wait``'s ``argmax`` materialization, not at dispatch."""
    batch: Batch
    # dense: [(req, logits)] per completing prefill; paged: [(group, logits)]
    prefill_pending: List
    decode_pending: Optional[object]     # decode-phase logits, or None
    decode_reqs: List[Request]
    decode_rows: List[int]               # dense: logits row per decode req
    utok: int                            # measured uncached prefill tokens
    prefill_issue_s: float               # host issue time, compile excluded
    decode_issue_s: float
    # produced-token count per req_id *as of dispatch* (this batch's token
    # included). The pipelined engine projects placeholder tokens onto
    # ``output_tokens`` while the batch is in flight, so ``wait`` must not
    # re-derive progress from live request state.
    produced: Dict[str, int] = field(default_factory=dict)


def _bucket(n: int, buckets=(16, 32, 64, 128, 256, 512, 1024, 2048, 4096)) -> int:
    for b in buckets:
        if n <= b:
            return b
    return ((n + 4095) // 4096) * 4096


def _pow2_bucket(n: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return b


class _ExecutorBase:
    """Shared mechanics of the real executors: sampling, finish detection,
    admission-time capacity validation and cost-model calibration."""

    def __init__(self, model, params, *, max_len: int,
                 prefix_cache: Optional[PrefixCache] = None,
                 greedy: bool = True):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.prefix_cache = prefix_cache
        self.greedy = greedy
        self.prefill_samples: List[Tuple[int, float]] = []
        self.decode_samples: List[Tuple[int, float]] = []
        # compile seconds spent pre-staging shape buckets during another
        # batch's device compute (never charged to any batch duration)
        self.prestage_compile_s = 0.0

    # ------------------------------------------------------------- admission
    def validate_relquery(self, rq: RelQuery) -> None:
        """Reject (at admission) any request whose worst-case prompt+output
        footprint can never fit a sequence's KV capacity — previously such a
        request silently overflowed the dense slot buffer mid-decode."""
        for r in rq.requests:
            need = r.num_prompt_tokens + r.max_output_tokens
            if need > self.max_len:
                raise RequestCapacityError(
                    f"request {r.req_id} of relQuery {rq.rel_id} needs up to "
                    f"{need} KV tokens (prompt {r.num_prompt_tokens} + "
                    f"max_output {r.max_output_tokens}) but this executor's "
                    f"per-sequence capacity is max_len={self.max_len}; "
                    f"shorten the prompt, lower max_output_tokens, or build "
                    f"the executor with a larger max_len")

    # ------------------------------------------------------------- shared bits
    def _aot(self, fn, *args) -> Tuple[object, float]:
        """Ahead-of-time compile ``fn`` for ``args``; returns (executable,
        compile_seconds). Callers subtract the compile time from their
        measured phase duration: throughput samples and the fitted cost model
        must see steady-state execution, not first-shape XLA compilation
        (the shape-bucketed paged backend compiles several decode variants
        over a run — charging those to decode latency would skew both the
        clock and Fig. 7's α/β fit)."""
        t0 = _time.perf_counter()
        exe = fn.lower(*args).compile()
        return exe, _time.perf_counter() - t0

    def _sample(self, logits) -> np.ndarray:
        return np.asarray(jnp.argmax(logits, axis=-1))

    def _is_finish_token(self, r: Request, tok: int, produced: int) -> bool:
        if r.eos_token is not None and tok == r.eos_token:
            return True
        return produced >= r.max_output_tokens

    def _account_prefill(self, r: Request, seq: Sequence[int]) -> int:
        """Prefix-cache stats identical across backends (count then insert,
        in batch order): only the prompt enters the cache — generated tokens
        are never prefix-cached (the estimator/PEM invariant)."""
        if self.prefix_cache is None:
            return len(seq)
        cached = self.prefix_cache.count_cached(seq)
        self.prefix_cache.insert(r.tokens)
        return len(seq) - cached

    # ------------------------------------------------------------- calibration
    def fitted_model(self):
        return lm_mod.fit(self.prefill_samples, self.decode_samples)


@dataclass
class Slot:
    req: Request
    position: int          # next decode position (== tokens written so far)


class RealExecutor(_ExecutorBase):
    """Dense per-slot KV backend (the bit-identical baseline)."""

    def __init__(self, model, params, *, max_slots: int = 32, max_len: int = 512,
                 prefix_cache: Optional[PrefixCache] = None, greedy: bool = True):
        super().__init__(model, params, max_len=max_len,
                         prefix_cache=prefix_cache, greedy=greedy)
        self.max_slots = max_slots
        self.cache = model.init_cache(max_slots, max_len)
        self.slots: List[Optional[Slot]] = [None] * max_slots
        self._slot_of: Dict[str, int] = {}
        self._prefill_fn = {}
        self._decode_fn = None
        self._decode_jit = jax.jit(model.decode_step, donate_argnums=(1,))
        self._compile_s = 0.0     # compile time to subtract from this batch
        # host KV tier: req_id -> (request, slot position, stashed cache
        # slice). Slices start as device arrays with an async device->host
        # copy issued at swap-out; the next wait() materializes them to
        # numpy, so the transfer overlaps the in-flight batch's compute.
        self._host_stash: Dict[str, Tuple[Request, int, object]] = {}
        self._pending_host: List[str] = []
        # swap-in prefetch: req_id -> device-resident copy of its stash,
        # staged ahead of the commit so the slot write pays no host->device
        # transfer (the stash itself stays authoritative until commit)
        self._prestaged: Dict[str, object] = {}

    # ------------------------------------------------------------------ slots
    def _alloc_slot(self, req: Request) -> int:
        for i, s in enumerate(self.slots):
            if s is None:
                self.slots[i] = Slot(req, 0)
                self._slot_of[req.req_id] = i
                return i
        raise RuntimeError("out of decode slots — scheduler exceeded max_num_seqs")

    def _free_slot(self, req_id: str) -> None:
        i = self._slot_of.pop(req_id, None)
        if i is not None:
            self.slots[i] = None

    def release_request(self, req_id: str) -> None:
        """Free executor-side state held for a request (its decode slot
        and/or host-tier stash). Called by the engine on cancellation/
        preemption; unknown req_ids are a no-op."""
        self._free_slot(req_id)
        self._host_stash.pop(req_id, None)
        self._prestaged.pop(req_id, None)

    # --------------------------------------------------------------- swapping
    def _slot_axis(self, arr) -> Optional[int]:
        """First axis carrying the per-slot dimension (same convention as
        ``_write_slot_cache``'s placement search); None for scalar-like cache
        entries shared by all slots."""
        for ax in range(arr.ndim):
            if arr.shape[ax] == self.max_slots:
                return ax
        return None

    def swap_out(self, req_id: str, tokens: int) -> float:
        """Stash ``req_id``'s dense KV slot on the host and free the slot.
        The device->host copy is issued async here and completed by the next
        ``wait()`` — it rides under the dispatched batch's compute, so the
        returned extra-seconds charge is 0.0. Unknown req_ids (already
        released, e.g. cancelled between the swap decision and its
        application) are a no-op."""
        i = self._slot_of.get(req_id)
        if i is None:
            return 0.0
        slot = self.slots[i]

        def take(leaf):
            ax = self._slot_axis(leaf)
            if ax is None:
                return "skip"   # string sentinel keeps the pytree structure
            idx = [slice(None)] * leaf.ndim
            idx[ax] = slice(i, i + 1)
            piece = leaf[tuple(idx)]
            copy = getattr(piece, "copy_to_host_async", None)
            if copy is not None:
                copy()
            return piece

        stash = jax.tree.map(take, self.cache)
        self._host_stash[req_id] = (slot.req, slot.position, stash)
        self._pending_host.append(req_id)
        self._free_slot(req_id)
        return 0.0

    def prefetch_swap_in(self, req_id: str, tokens: int) -> float:
        """Stage a stashed request's KV back onto the device ahead of its
        swap-in commit: the ``device_put`` is issued here, riding under the
        in-flight batch's compute, so the commit's slot write consumes an
        already-resident array instead of paying the host->device copy at
        dispatch. Unknown/already-staged req_ids are a no-op."""
        entry = self._host_stash.get(req_id)
        if entry is None or req_id in self._prestaged:
            return 0.0
        _, _, stash = entry
        self._prestaged[req_id] = jax.tree.map(
            lambda x: x if isinstance(x, str) else jax.device_put(x), stash)
        return 0.0

    def cancel_swap_prefetch(self, req_id: str, tokens: int) -> float:
        """Drop a staged prefetch whose request was cancelled before the
        swap-in commit (the authoritative host stash is freed by
        ``release_request``). Idempotent."""
        self._prestaged.pop(req_id, None)
        return 0.0

    def swap_in(self, req_id: str, tokens: int) -> float:
        """Restore a stashed request into a fresh slot (host->device write).
        The request resumes decoding at its stashed position — no re-prefill.
        A prefetched request's staged device copy is consumed instead of the
        host stash, skipping the transfer."""
        entry = self._host_stash.pop(req_id, None)
        if entry is None:
            return 0.0
        req, position, stash = entry
        staged = self._prestaged.pop(req_id, None)
        if staged is not None:
            stash = staged
        i = self._alloc_slot(req)

        def put(dst, src):
            if isinstance(src, str):
                return dst
            ax = self._slot_axis(dst)
            idx = [slice(None)] * dst.ndim
            idx[ax] = slice(i, i + 1)
            return dst.at[tuple(idx)].set(jnp.asarray(src).astype(dst.dtype))

        self.cache = jax.tree.map(put, self.cache, stash)
        self.slots[i].position = position
        return 0.0

    def _materialize_host_stash(self) -> None:
        """Finish pending device->host stash transfers (called from ``wait``,
        after the batch's own blocking transfer — by then the async copies
        have landed and ``np.asarray`` is a cheap view materialization)."""
        for req_id in self._pending_host:
            entry = self._host_stash.get(req_id)
            if entry is None:
                continue    # released (cancel) before materialization
            req, position, stash = entry
            stash = jax.tree.map(
                lambda x: x if isinstance(x, str) else np.asarray(x), stash)
            self._host_stash[req_id] = (req, position, stash)
        self._pending_host = []

    # ------------------------------------------------------------------ prefill
    def _prefill_issue(self, req: Request) -> Tuple[object, int]:
        """Issue a request's prefill and write its KV into a slot; returns
        (device logits, utok) without sampling — the logits stay a device
        future until ``wait`` materializes them. For a preempted request's
        restart the pass recomputes prompt + preserved generation
        (recompute-style preemption recovery)."""
        seq = req.prefill_token_ids()
        n = len(seq)
        utok = self._account_prefill(req, seq)
        # pad-masked prefill (recurrent state frozen on pads); never pad past
        # the slot length — admission guarantees n <= max_len
        bucket = min(_bucket(n), self.max_len)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :n] = seq
        args = (self.params, jnp.asarray(toks), jnp.asarray([n], jnp.int32))
        if bucket not in self._prefill_fn:
            fn = jax.jit(lambda p, t, sl: self.model.prefill(
                p, t, seq_lens=sl, max_len=self.max_len))
            self._prefill_fn[bucket], dt = self._aot(fn, *args)
            self._compile_s += dt
        logits, kv = self._prefill_fn[bucket](*args)
        slot = self._alloc_slot(req)
        self._write_slot_cache(slot, kv)
        self.slots[slot].position = n
        return logits, utok

    def prestage(self, batch: Batch) -> None:
        """Pre-compile the prefill shape buckets ``batch`` will need, with
        dummy-shaped arguments — called by the pipelined engine while the
        *previous* batch runs on the device, so a first-shape XLA compile
        never lands on the critical path. Decode/scatter functions are not
        pre-staged (they close over live cache shapes already compiled)."""
        for r in batch.prefill_requests:
            if not batch.completes_prompt(r):
                continue
            n = len(r.prefill_token_ids())
            bucket = min(_bucket(n), self.max_len)
            if bucket in self._prefill_fn:
                continue
            toks = np.zeros((1, bucket), np.int32)
            args = (self.params, jnp.asarray(toks),
                    jnp.asarray([n], jnp.int32))
            fn = jax.jit(lambda p, t, sl: self.model.prefill(
                p, t, seq_lens=sl, max_len=self.max_len))
            self._prefill_fn[bucket], dt = self._aot(fn, *args)
            self.prestage_compile_s += dt

    def _write_slot_cache(self, slot: int, kv) -> None:
        """Copy a single-sequence prefill cache into slot ``slot``."""
        def write(dst, src):
            if dst.ndim == src.ndim and dst.shape == src.shape:
                return src  # scalar-like entries (not per-slot)
            # batch dim location differs per model family; find the axis where
            # dst has max_slots and src has 1
            for ax in range(dst.ndim):
                if dst.shape[ax] == self.max_slots and src.shape[ax] == 1:
                    idx = [slice(None)] * dst.ndim
                    idx[ax] = slice(slot, slot + 1)
                    pad = [(0, d - s) if a != ax else (0, 0)
                           for a, (d, s) in enumerate(zip(dst.shape, src.shape))]
                    if any(p != (0, 0) for p in pad):
                        src = jnp.pad(src, pad)
                    return dst.at[tuple(idx)].set(src.astype(dst.dtype))
            raise ValueError(f"cannot place prefill cache {src.shape} into {dst.shape}")
        self.cache = jax.tree.map(write, self.cache, kv)

    # ------------------------------------------------------------------ decode
    def _decode_issue(self, reqs: List[Request]) -> object:
        tokens = np.zeros((self.max_slots,), np.int32)
        positions = np.zeros((self.max_slots,), np.int32)
        # decode_step scatters every row's K/V at positions[i] — rows must
        # never default to (token 0, position 0), which silently corrupted
        # position 0 of any occupied slot outside the scheduled batch (e.g. a
        # request prefilled earlier in the same mixed batch). Point occupied
        # off-batch rows at their own next position with their own last token:
        # for attention caches the write is idempotent (the slot's real
        # decode rewrites the same values) and the row's logits are discarded
        # below. Recurrent families (hymba's SSM/conv state) still advance
        # off-batch rows — a pre-existing limitation of whole-batch
        # decode_step that needs a per-row freeze mask to fix; the scheduler
        # only leaves a slot out of a decode batch in the same tick that
        # prefilled it, so attention archs are exact.
        for i, s in enumerate(self.slots):
            if s is not None:
                tokens[i] = s.req.output_tokens[-1] if s.req.output_tokens else 0
                positions[i] = s.position
        for r in reqs:
            i = self._slot_of[r.req_id]
            tokens[i] = r.output_tokens[-1] if r.output_tokens else 0
            positions[i] = self.slots[i].position
        args = (self.params, self.cache, jnp.asarray(tokens),
                jnp.asarray(positions))
        if self._decode_fn is None:
            self._decode_fn, dt = self._aot(self._decode_jit, *args)
            self._compile_s += dt
        logits, self.cache = self._decode_fn(*args)
        for r in reqs:
            self.slots[self._slot_of[r.req_id]].position += 1
        return logits

    # ------------------------------------------------------------------ engine API
    def dispatch(self, batch: Batch, now: float) -> InFlight:
        """Issue one unified batch on the device without blocking: prefill
        passes write their KV and the decode step advances the slot
        positions, but no logits are materialized on the host. Prefill and
        decode issue times are kept separate so ``wait`` can complete the
        phase-separated samples ``fitted_model()`` calibration expects."""
        self._compile_s = 0.0
        t0 = _time.perf_counter()
        pending = []
        total_utok = 0
        for r in batch.prefill_requests:
            if not batch.completes_prompt(r):
                continue  # chunk not finishing the prompt: accounted only
            logits, utok = self._prefill_issue(r)
            total_utok += utok
            pending.append((r, logits))
        prefill_issue = max(0.0, _time.perf_counter() - t0 - self._compile_s)
        reqs = [r for r in batch.decode_requests if r.req_id in self._slot_of]
        decode_logits, rows, decode_issue = None, [], 0.0
        if reqs:
            self._compile_s = 0.0
            t1 = _time.perf_counter()
            decode_logits = self._decode_issue(reqs)
            # capture logits rows now: a prefill request finishing in wait()
            # frees its own slot only, so these stay valid either way
            rows = [self._slot_of[r.req_id] for r in reqs]
            decode_issue = max(0.0,
                               _time.perf_counter() - t1 - self._compile_s)
        produced = {r.req_id: len(r.output_tokens) + 1
                    for r in (*(p[0] for p in pending), *reqs)}
        return InFlight(batch=batch, prefill_pending=pending,
                        decode_pending=decode_logits, decode_reqs=reqs,
                        decode_rows=rows, utok=total_utok,
                        prefill_issue_s=prefill_issue,
                        decode_issue_s=decode_issue, produced=produced)

    def wait(self, inflight: InFlight) -> Tuple[float, BatchResult]:
        """Materialize a dispatched batch: sample every pending logits row
        (the blocking host transfer), detect finishes and free their slots.
        Returns the same (duration, BatchResult) contract as ``execute`` —
        durations cover issue + wait, compile time excluded."""
        outputs: Dict[str, Tuple[int, bool]] = {}
        prefill_dur = inflight.prefill_issue_s
        if inflight.prefill_pending:
            t0 = _time.perf_counter()
            for r, logits in inflight.prefill_pending:
                tok = int(self._sample(logits)[0])
                # a restarted (preempted) request already produced its
                # preserved tokens; this prefill emits the (len + 1)-th
                finished = self._is_finish_token(r, tok,
                                                 inflight.produced[r.req_id])
                outputs[r.req_id] = (tok, finished)
                if finished:
                    self._free_slot(r.req_id)
            prefill_dur += _time.perf_counter() - t0
            self.prefill_samples.append((inflight.utok, prefill_dur))
        decode_dur = inflight.decode_issue_s
        if inflight.decode_pending is not None:
            t1 = _time.perf_counter()
            out = self._sample(inflight.decode_pending)
            for r, row in zip(inflight.decode_reqs, inflight.decode_rows):
                tok = int(out[row])
                # ``produced`` was counted at dispatch, when output_tokens
                # held only *landed* iterations — matching the simulated
                # executor's count even if a speculative placeholder has
                # been projected onto the request since.
                finished = self._is_finish_token(r, tok,
                                                 inflight.produced[r.req_id])
                outputs[r.req_id] = (tok, finished)
                if finished:
                    self._free_slot(r.req_id)
            decode_dur += _time.perf_counter() - t1
            self.decode_samples.append((len(inflight.decode_reqs), decode_dur))
        self._materialize_host_stash()
        return prefill_dur + decode_dur, BatchResult(outputs)

    def execute(self, batch: Batch, now: float) -> Tuple[float, BatchResult]:
        """Serial composition of the split contract — the serial engine loop
        and older callers see the exact pre-split behavior."""
        return self.wait(self.dispatch(batch, now))


class PagedRealExecutor(_ExecutorBase):
    """Block-paged KV backend: ``BlockManager``-owned pools, per-request
    block tables, batched bucketed prefill and paged-attention decode.

    The last pool block (id ``num_blocks``) is a scratch page: pad rows and
    pad table entries route there, so fixed-shape scatters never touch live
    blocks. KV demand agrees with the scheduler's token ledger: a request
    resident from prefill completion to finish/preempt/cancel, shared prefix
    chains (``share_prefix_blocks=True``, paired with the scheduler's
    ``prefix_sharing``) held once and ref-counted, copy-on-write if a write
    ever lands in a block a sibling still references.
    """

    def __init__(self, model, params, *, num_blocks: int = 1024,
                 block_size: int = 16, max_len: int = 512,
                 prefix_cache: Optional[PrefixCache] = None,
                 greedy: bool = True, attn_impl: Optional[str] = None,
                 prefill_attn: Optional[str] = None,
                 share_prefix_blocks: bool = False,
                 num_host_blocks: int = 0):
        if not getattr(model, "supports_paged", lambda: False)():
            raise NotImplementedError(
                f"model {model.cfg.name!r} does not support the paged KV "
                f"backend (full-attention transformer families only); use "
                f"kv_backend='dense'")
        on_cpu = jax.default_backend() == "cpu"
        if prefill_attn is None:
            prefill_attn = "block" if on_cpu else "flash"
        if prefill_attn == "flash":
            model = model.with_prefill_attn("flash")
        super().__init__(model, params, max_len=max_len,
                         prefix_cache=prefix_cache, greedy=greedy)
        # Pallas on a real accelerator, pure-jnp reference on CPU (CI's
        # fallback); 'pallas-interpret' forces the kernel through the
        # interpreter for parity debugging.
        self.attn_impl = attn_impl or ("ref" if on_cpu else "pallas")
        self.block_size = block_size
        self.num_blocks = num_blocks
        self.scratch_block = num_blocks          # pools hold one extra page
        self.max_blocks_per_seq = -(-max_len // block_size)
        self.share_prefix_blocks = share_prefix_blocks
        self.num_host_blocks = num_host_blocks
        self.bm = BlockManager(num_blocks, block_size=block_size,
                               num_host_blocks=num_host_blocks)
        self.pools = model.init_paged_pools(num_blocks + 1, block_size)
        self._active: Dict[str, Request] = {}
        # host KV tier: req_id -> (request, {"k": blocks, "v": blocks}) with
        # blocks gathered along the pool's block axis in table order. Device
        # arrays with an async device->host copy at swap-out, numpy after the
        # next wait() materializes them (transfer overlapped with compute).
        self._host_stash: Dict[str, Tuple[Request, Dict[str, object]]] = {}
        self._pending_host: List[str] = []
        # swap-in prefetch: req_id -> staged copy plan; the blocks were
        # written at prefetch time, so the commit is pure accounting
        self._staged_swap_in: Dict[str, List[Tuple[int, int]]] = {}
        self._prefill_fn: Dict[Tuple[int, int], object] = {}
        self._scatter_fn: Dict[Tuple[int, int], object] = {}
        self._decode_fn: Dict[Tuple[int, int], object] = {}
        self._copy_fn = None
        self.cow_copies = 0
        self.shared_block_hits = 0    # physically shared prefix blocks reused
        self._compile_s = 0.0     # compile time to subtract from this batch

    # ------------------------------------------------------------- admission
    def validate_relquery(self, rq: RelQuery) -> None:
        """Beyond the per-sequence ``max_len`` bound, a request must also fit
        the *pool*: a footprint needing more blocks than the pool holds could
        never prefill no matter what else is evicted."""
        super().validate_relquery(rq)
        for r in rq.requests:
            need = r.num_prompt_tokens + r.max_output_tokens
            blocks = self.bm.blocks_needed(need)
            if blocks > self.num_blocks:
                raise RequestCapacityError(
                    f"request {r.req_id} of relQuery {rq.rel_id} needs "
                    f"{blocks} KV blocks (footprint {need} tokens / "
                    f"block_size {self.block_size}) but the paged pool holds "
                    f"only num_blocks={self.num_blocks}; grow the pool or "
                    f"shrink the request")

    # ------------------------------------------------------------- bookkeeping
    def release_request(self, req_id: str) -> None:
        """Free the request's blocks (cancellation/preemption): real paged
        reclamation — siblings still referencing shared prefix blocks keep
        them alive; only the last reference returns a block to the free list.
        Frees whichever tier(s) hold the request — a swapped request's host
        blocks and stash go too."""
        known = self._active.pop(req_id, None) is not None
        known = (self._host_stash.pop(req_id, None) is not None) or known
        self._staged_swap_in.pop(req_id, None)
        if known:
            self.bm.free(req_id)   # staged prefetch blocks go back too

    # --------------------------------------------------------------- swapping
    def swap_out(self, req_id: str, tokens: int) -> float:
        """Move ``req_id``'s blocks to the host tier per the BlockManager's
        copy plan. Every block is gathered (shared prefix blocks included —
        the host image is self-contained) before the manager drops the device
        references, so a block a sibling still references stays resident and
        is never freed here. The device->host copy is issued async and
        completed by the next ``wait()``; returns 0.0 (overlapped)."""
        r = self._active.pop(req_id, None)
        if r is None:
            return 0.0
        plan = self.bm.swap_out(req_id)        # [(device_bid, host_bid)]
        dev = jnp.asarray([d for d, _ in plan], jnp.int32)
        data: Dict[str, object] = {}
        for name in ("k", "v"):
            piece = jnp.take(self.pools[name], dev, axis=2)
            copy = getattr(piece, "copy_to_host_async", None)
            if copy is not None:
                copy()
            data[name] = piece
        self._host_stash[req_id] = (r, data)
        self._pending_host.append(req_id)
        return 0.0

    def prefetch_swap_in(self, req_id: str, tokens: int) -> float:
        """Stage a swapped request's host image into freshly-allocated device
        blocks ahead of the swap-in commit — the pool writes happen here,
        riding under the in-flight batch's compute, so the commit is pure
        accounting. No-op when the request is unknown, already staged, or
        the pool lacks free blocks (the commit falls back to the synchronous
        path)."""
        entry = self._host_stash.get(req_id)
        if entry is None or req_id in self._staged_swap_in:
            return 0.0
        plan = self.bm.prefetch_swap_in(req_id)
        if plan is None:
            return 0.0
        _, data = entry
        dst = jnp.asarray([d for _, d in plan], jnp.int32)
        for name in ("k", "v"):
            src = jnp.asarray(data[name]).astype(self.pools[name].dtype)
            self.pools[name] = self.pools[name].at[:, :, dst].set(src)
        self._staged_swap_in[req_id] = plan
        return 0.0

    def cancel_swap_prefetch(self, req_id: str, tokens: int) -> float:
        """Return a staged prefetch's device blocks (the request was
        cancelled before commit). The pool bytes written at staging are
        simply orphaned — freed blocks are always rewritten before reuse.
        Idempotent."""
        if self._staged_swap_in.pop(req_id, None) is not None:
            self.bm.cancel_prefetch(req_id)
        return 0.0

    def swap_in(self, req_id: str, tokens: int) -> float:
        """Restore a swapped request into fresh private device blocks (its
        shared-prefix identity was dropped at swap-out) and resume decode at
        its stashed context length — no re-prefill. A prefetched request's
        blocks were already allocated and written at staging, so its commit
        skips the copy entirely."""
        entry = self._host_stash.pop(req_id, None)
        if entry is None:
            return 0.0
        r, data = entry
        if self._staged_swap_in.pop(req_id, None) is not None:
            self.bm.commit_prefetch(req_id)
            self._active[req_id] = r
            return 0.0
        plan = self.bm.swap_in(req_id)         # [(host_bid, device_bid)]
        dst = jnp.asarray([d for _, d in plan], jnp.int32)
        for name in ("k", "v"):
            src = jnp.asarray(data[name]).astype(self.pools[name].dtype)
            self.pools[name] = self.pools[name].at[:, :, dst].set(src)
        self._active[req_id] = r
        return 0.0

    def _materialize_host_stash(self) -> None:
        """Finish pending device->host stash transfers (from ``wait``, after
        the batch's own blocking transfer — the async copies have landed)."""
        for req_id in self._pending_host:
            entry = self._host_stash.get(req_id)
            if entry is None:
                continue    # released (cancel) before materialization
            r, data = entry
            self._host_stash[req_id] = (
                r, {n: np.asarray(a) for n, a in data.items()})
        self._pending_host = []

    def kv_tokens_resident(self) -> int:
        """Per-sequence resident tokens: shared prefix blocks count once per
        referencing sequence — i.e. the scheduler's *raw* optimistic charge
        (`tokens_in_use`) before the `SharedPrefixLedger` discount. Physical
        pool occupancy is lower by exactly that discount when sharing is on."""
        return self.bm.tokens_in_use()

    def _prompt_keys(self, r: Request) -> Tuple[int, ...]:
        return tuple(block_hashes(r.tokens, self.block_size))

    def _prefill_group_key(self, r: Request) -> int:
        """Block-aligned length bucket a request prefills under (the same
        per-request bucket the dense baseline pads to — keeping per-row
        numerics identical across backends, bf16 included)."""
        L = min(_bucket(len(r.prefill_token_ids())), self.max_len)
        return -(-L // self.block_size) * self.block_size

    def prestage(self, batch: Batch) -> None:
        """Pre-compile the (batch, length) prefill buckets ``batch`` will
        group into, with dummy-shaped arguments — run by the pipelined engine
        under the previous batch's device compute. Scatter/decode functions
        are not pre-staged: their argument shapes depend on live pool/cache
        values only available at dispatch."""
        groups: Dict[int, int] = {}
        for r in batch.prefill_requests:
            if batch.completes_prompt(r):
                L = self._prefill_group_key(r)
                groups[L] = groups.get(L, 0) + 1
        for L, n in sorted(groups.items()):
            key = (_pow2_bucket(n), L)
            if key in self._prefill_fn:
                continue
            B = key[0]
            toks = np.zeros((B, L), np.int32)
            args = (self.params, jnp.asarray(toks),
                    jnp.asarray(np.ones((B,), np.int32)))
            fn = jax.jit(lambda p, t, sl, L=L: self.model.prefill(
                p, t, seq_lens=sl, max_len=L))
            self._prefill_fn[key], dt = self._aot(fn, *args)
            self.prestage_compile_s += dt

    # ------------------------------------------------------------- prefill
    def _prefill_issue_batch(self, reqs: List[Request]) -> Tuple[List, int]:
        """Batched multi-request prefill, shape-bucketed on (batch, length):
        each group runs as one model call followed by one scatter into the
        pools. Returns ([(group requests, device logits)], utok) — sampling
        deferred to ``wait``."""
        seqs = {r.req_id: r.prefill_token_ids() for r in reqs}
        utok = 0
        for r in reqs:                      # accounting in dense batch order
            utok += self._account_prefill(r, seqs[r.req_id])
        bs = self.block_size
        groups: Dict[int, List[Request]] = {}
        for r in reqs:
            groups.setdefault(self._prefill_group_key(r), []).append(r)
        pending: List = []
        for L in sorted(groups):
            grp = groups[L]
            B = _pow2_bucket(len(grp))
            nblk = L // bs
            toks = np.zeros((B, L), np.int32)
            seq_lens = np.ones((B,), np.int32)
            tables = np.full((B, nblk), self.scratch_block, np.int32)
            for i, r in enumerate(grp):
                seq = seqs[r.req_id]
                n = len(seq)
                toks[i, :n] = seq
                seq_lens[i] = n
                keys = self._prompt_keys(r) if self.share_prefix_blocks else ()
                try:
                    alloc = self.bm.allocate(r.req_id, n, prefix_keys=keys)
                    self.shared_block_hits += alloc.shared_prefix_blocks
                except OutOfBlocks as e:
                    raise RuntimeError(
                        f"paged KV pool exhausted during prefill of "
                        f"{r.req_id}: {e} — the scheduler's cap admitted more "
                        f"resident tokens than num_blocks*block_size covers"
                    ) from e
                if keys:
                    self.bm.register_prefix(r.req_id, keys)
                self._active[r.req_id] = r
                row = self.bm.padded_block_table(r.req_id, nblk,
                                                 self.scratch_block)
                # a follower must never rewrite pages its leader already
                # owns: the leader may be mid-decode attending them, and on
                # kernel backends the recomputed bytes are not bit-identical
                # — shared leading pages are written exactly once (by the
                # leader), so route the follower's scatter there to scratch
                for j in range(alloc.shared_prefix_blocks):
                    row[j] = self.scratch_block
                tables[i] = row
            key = (B, L)
            args = (self.params, jnp.asarray(toks), jnp.asarray(seq_lens))
            if key not in self._prefill_fn:
                fn = jax.jit(lambda p, t, sl, L=L: self.model.prefill(
                    p, t, seq_lens=sl, max_len=L))
                self._prefill_fn[key], dt = self._aot(fn, *args)
                self._compile_s += dt
            logits, caches = self._prefill_fn[key](*args)
            sargs = (self.pools, caches, jnp.asarray(tables))
            if key not in self._scatter_fn:
                fn = jax.jit(self.model.scatter_prefill_pools,
                             donate_argnums=(0,))
                self._scatter_fn[key], dt = self._aot(fn, *sargs)
                self._compile_s += dt
            self.pools = self._scatter_fn[key](*sargs)
            pending.append((grp, logits))
        return pending, utok

    # ------------------------------------------------------------- decode
    def _copy_block(self, src: int, dst: int) -> None:
        """Device-side CoW: clone page ``src`` into ``dst`` across all layers
        before the diverging write."""
        args = (self.pools, jnp.asarray(src, jnp.int32),
                jnp.asarray(dst, jnp.int32))
        if self._copy_fn is None:
            def copy(pools, s, d):
                pools = dict(pools)
                for name in ("k", "v"):
                    pools[name] = jax.lax.dynamic_update_index_in_dim(
                        pools[name],
                        jax.lax.dynamic_index_in_dim(pools[name], s, axis=2,
                                                     keepdims=False),
                        d, axis=2)
                return pools
            self._copy_fn, dt = self._aot(jax.jit(copy, donate_argnums=(0,)),
                                          *args)
            self._compile_s += dt
        self.pools = self._copy_fn(*args)
        self.cow_copies += 1

    def _decode_issue(self, reqs: List[Request]) -> object:
        bs = self.block_size
        positions = []
        for r in reqs:
            pos = self.bm.context_len(r.req_id)
            positions.append(pos)
            try:
                _, cow = self.bm.append_token_cow(r.req_id)
            except OutOfBlocks as e:
                raise RuntimeError(
                    f"paged KV pool exhausted during decode of {r.req_id}: "
                    f"{e}") from e
            if cow is not None:
                self._copy_block(*cow)
        width = max(len(self.bm.block_table(r.req_id)) for r in reqs)
        NB = min(_pow2_bucket(width), self.max_blocks_per_seq)
        NB = max(NB, width)
        B = _pow2_bucket(len(reqs))
        tokens = np.zeros((B,), np.int32)
        pos_arr = np.zeros((B,), np.int32)
        ctx = np.ones((B,), np.int32)
        tables = np.full((B, NB), self.scratch_block, np.int32)
        for i, (r, pos) in enumerate(zip(reqs, positions)):
            tokens[i] = r.output_tokens[-1] if r.output_tokens else 0
            pos_arr[i] = pos
            ctx[i] = pos + 1
            tables[i] = self.bm.padded_block_table(r.req_id, NB,
                                                   self.scratch_block)
        key = (B, NB)
        args = (self.params, self.pools, jnp.asarray(tokens),
                jnp.asarray(pos_arr), jnp.asarray(tables), jnp.asarray(ctx))
        if key not in self._decode_fn:
            fn = jax.jit(
                lambda p, pools, t, po, bt, cl: self.model.decode_step_paged(
                    p, pools, t, po, bt, cl, attn_impl=self.attn_impl),
                donate_argnums=(1,))
            self._decode_fn[key], dt = self._aot(fn, *args)
            self._compile_s += dt
        logits, self.pools = self._decode_fn[key](*args)
        return logits

    # ------------------------------------------------------------- engine API
    def dispatch(self, batch: Batch, now: float) -> InFlight:
        """Issue one unified batch: block allocation, prefill + pool scatter
        and the paged decode step all run host-side/async; logits stay on the
        device until ``wait``. Block frees of requests finishing in this
        batch happen in ``wait`` — at a near-exhausted pool this defers a
        handful of frees by one phase, which can surface ``OutOfBlocks``
        slightly earlier than the fused loop did (the scheduler's cap keeps
        real configurations away from that boundary)."""
        prefill_reqs = [r for r in batch.prefill_requests
                        if batch.completes_prompt(r)]
        pending: List = []
        utok = 0
        prefill_issue = 0.0
        if prefill_reqs:
            self._compile_s = 0.0
            t0 = _time.perf_counter()
            pending, utok = self._prefill_issue_batch(prefill_reqs)
            prefill_issue = max(0.0,
                                _time.perf_counter() - t0 - self._compile_s)
        reqs = [r for r in batch.decode_requests if r.req_id in self._active]
        decode_logits, decode_issue = None, 0.0
        if reqs:
            self._compile_s = 0.0
            t1 = _time.perf_counter()
            decode_logits = self._decode_issue(reqs)
            decode_issue = max(0.0,
                               _time.perf_counter() - t1 - self._compile_s)
        produced = {r.req_id: len(r.output_tokens) + 1
                    for r in (*(r for grp, _ in pending for r in grp), *reqs)}
        return InFlight(batch=batch, prefill_pending=pending,
                        decode_pending=decode_logits, decode_reqs=reqs,
                        decode_rows=[], utok=utok,
                        prefill_issue_s=prefill_issue,
                        decode_issue_s=decode_issue, produced=produced)

    def wait(self, inflight: InFlight) -> Tuple[float, BatchResult]:
        """Same phase-separated timing contract as the dense executor:
        sample each prefill group then the decode step, free the blocks of
        anything that finished."""
        outputs: Dict[str, Tuple[int, bool]] = {}
        prefill_dur = inflight.prefill_issue_s
        if inflight.prefill_pending:
            t0 = _time.perf_counter()
            for grp, logits in inflight.prefill_pending:
                out_tokens = self._sample(logits)
                for i, r in enumerate(grp):
                    tok = int(out_tokens[i])
                    finished = self._is_finish_token(r, tok,
                                                     inflight.produced[r.req_id])
                    outputs[r.req_id] = (tok, finished)
                    if finished:
                        self.release_request(r.req_id)
            prefill_dur += _time.perf_counter() - t0
            self.prefill_samples.append((inflight.utok, prefill_dur))
        decode_dur = inflight.decode_issue_s
        if inflight.decode_pending is not None:
            t1 = _time.perf_counter()
            out = self._sample(inflight.decode_pending)
            for i, r in enumerate(inflight.decode_reqs):
                tok = int(out[i])
                finished = self._is_finish_token(r, tok,
                                                 inflight.produced[r.req_id])
                outputs[r.req_id] = (tok, finished)
                if finished:
                    self.release_request(r.req_id)
            decode_dur += _time.perf_counter() - t1
            self.decode_samples.append((len(inflight.decode_reqs), decode_dur))
        self._materialize_host_stash()
        return prefill_dur + decode_dur, BatchResult(outputs)

    def execute(self, batch: Batch, now: float) -> Tuple[float, BatchResult]:
        """Serial composition of the split contract."""
        return self.wait(self.dispatch(batch, now))


KV_BACKENDS = ("dense", "paged")


def make_real_executor(kv_backend: str, model, params, *, max_slots: int = 32,
                       max_len: int = 512,
                       prefix_cache: Optional[PrefixCache] = None,
                       num_blocks: Optional[int] = None, block_size: int = 16,
                       share_prefix_blocks: bool = False,
                       num_host_blocks: int = 0, **kw):
    """Build a real executor by backend name. ``num_blocks`` defaults to the
    dense layout's physical capacity (max_slots × max_len worth of tokens) so
    switching backends never shrinks device KV. ``num_host_blocks`` sizes the
    paged backend's host swap tier (the dense backend's host stash is
    per-slot and needs no sizing)."""
    if kv_backend == "dense":
        return RealExecutor(model, params, max_slots=max_slots,
                            max_len=max_len, prefix_cache=prefix_cache, **kw)
    if kv_backend == "paged":
        if num_blocks is None:
            num_blocks = -(-max_slots * max_len // block_size)
        return PagedRealExecutor(model, params, num_blocks=num_blocks,
                                 block_size=block_size, max_len=max_len,
                                 prefix_cache=prefix_cache,
                                 share_prefix_blocks=share_prefix_blocks,
                                 num_host_blocks=num_host_blocks, **kw)
    raise ValueError(f"unknown kv_backend {kv_backend!r}; expected one of "
                     f"{KV_BACKENDS}")
