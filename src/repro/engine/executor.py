"""Real JAX executor: token-by-token execution of scheduler-issued batches on
an actual model (smoke-scale on CPU; the same code path drives a TPU slice).

Slot-based continuous batching: the executor owns ``max_slots`` decode cache
slots (the model's dense/ring KV layout); prefill assigns slots, decode runs
one ``decode_step`` over all active slots (a strict superset of the scheduled
batch is never needed — RelServe decodes the whole running queue). Prefill
batches execute per-request with bucketed padding to bound recompilation.

Also the calibration source for the linear batch-cost model (paper Fig. 7):
``calibrate()`` measures (tokens, duration) / (reqs, duration) samples and fits
α/β on this host.
"""
from __future__ import annotations

import time as _time
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import latency_model as lm_mod
from repro.core.batch import Batch
from repro.core.relquery import Request
from repro.core.scheduler import BatchResult
from repro.engine.prefix_cache import PrefixCache


def _bucket(n: int, buckets=(16, 32, 64, 128, 256, 512, 1024, 2048, 4096)) -> int:
    for b in buckets:
        if n <= b:
            return b
    return ((n + 4095) // 4096) * 4096


@dataclass
class Slot:
    req: Request
    position: int          # next decode position (== tokens written so far)


class RealExecutor:
    def __init__(self, model, params, *, max_slots: int = 32, max_len: int = 512,
                 prefix_cache: Optional[PrefixCache] = None, greedy: bool = True):
        self.model = model
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.prefix_cache = prefix_cache
        self.greedy = greedy
        self.cache = model.init_cache(max_slots, max_len)
        self.slots: List[Optional[Slot]] = [None] * max_slots
        self._slot_of: Dict[str, int] = {}
        self._prefill_fn = {}
        self._decode_fn = jax.jit(model.decode_step, donate_argnums=(1,))
        self.prefill_samples: List[Tuple[int, float]] = []
        self.decode_samples: List[Tuple[int, float]] = []

    # ------------------------------------------------------------------ slots
    def _alloc_slot(self, req: Request) -> int:
        for i, s in enumerate(self.slots):
            if s is None:
                self.slots[i] = Slot(req, 0)
                self._slot_of[req.req_id] = i
                return i
        raise RuntimeError("out of decode slots — scheduler exceeded max_num_seqs")

    def _free_slot(self, req_id: str) -> None:
        i = self._slot_of.pop(req_id, None)
        if i is not None:
            self.slots[i] = None

    def release_request(self, req_id: str) -> None:
        """Free executor-side state held for a request (its decode slot).
        Called by the engine on cancellation; unknown req_ids are a no-op."""
        self._free_slot(req_id)

    # ------------------------------------------------------------------ prefill
    def _prefill_one(self, req: Request) -> Tuple[int, int]:
        """Prefill a request, write its KV into a slot; returns (token, utok).
        For a preempted request's restart the pass recomputes prompt +
        preserved generation (recompute-style preemption recovery)."""
        seq = req.prefill_token_ids()
        n = len(seq)
        if self.prefix_cache is not None:
            cached = self.prefix_cache.count_cached(seq)
            # only the prompt enters the cache — generated tokens are never
            # prefix-cached (the estimator/PEM invariant)
            self.prefix_cache.insert(req.tokens)
        else:
            cached = 0
        utok = n - cached
        bucket = _bucket(n)  # pad-masked prefill: recurrent state frozen on pads
        if bucket not in self._prefill_fn:
            self._prefill_fn[bucket] = jax.jit(
                lambda p, t, sl: self.model.prefill(p, t, seq_lens=sl,
                                                    max_len=self.max_len))
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :n] = seq
        logits, kv = self._prefill_fn[bucket](
            self.params, jnp.asarray(toks), jnp.asarray([n], jnp.int32))
        slot = self._alloc_slot(req)
        self._write_slot_cache(slot, kv)
        self.slots[slot].position = n
        token = self._sample(logits)[0]
        return int(token), utok

    def _write_slot_cache(self, slot: int, kv) -> None:
        """Copy a single-sequence prefill cache into slot ``slot``."""
        def write(dst, src):
            if dst.ndim == src.ndim and dst.shape == src.shape:
                return src  # scalar-like entries (not per-slot)
            # batch dim location differs per model family; find the axis where
            # dst has max_slots and src has 1
            for ax in range(dst.ndim):
                if dst.shape[ax] == self.max_slots and src.shape[ax] == 1:
                    idx = [slice(None)] * dst.ndim
                    idx[ax] = slice(slot, slot + 1)
                    pad = [(0, d - s) if a != ax else (0, 0)
                           for a, (d, s) in enumerate(zip(dst.shape, src.shape))]
                    if any(p != (0, 0) for p in pad):
                        src = jnp.pad(src, pad)
                    return dst.at[tuple(idx)].set(src.astype(dst.dtype))
            raise ValueError(f"cannot place prefill cache {src.shape} into {dst.shape}")
        self.cache = jax.tree.map(write, self.cache, kv)

    # ------------------------------------------------------------------ decode
    def _decode_all(self, reqs: List[Request]) -> Dict[str, int]:
        tokens = np.zeros((self.max_slots,), np.int32)
        positions = np.zeros((self.max_slots,), np.int32)
        for r in reqs:
            i = self._slot_of[r.req_id]
            tokens[i] = r.output_tokens[-1] if r.output_tokens else 0
            positions[i] = self.slots[i].position
        logits, self.cache = self._decode_fn(
            self.params, self.cache, jnp.asarray(tokens), jnp.asarray(positions))
        out = self._sample(logits)
        result = {}
        for r in reqs:
            i = self._slot_of[r.req_id]
            self.slots[i].position += 1
            result[r.req_id] = int(out[i])
        return result

    def _sample(self, logits) -> np.ndarray:
        return np.asarray(jnp.argmax(logits, axis=-1))

    # ------------------------------------------------------------------ engine API
    def execute(self, batch: Batch, now: float) -> Tuple[float, BatchResult]:
        """Run one unified batch. Prefill and decode phases are timed
        *separately* — a mixed batch contributes a prefill-only sample and a
        decode-only sample, so ``fitted_model()`` calibration never sees
        combined wall times."""
        outputs: Dict[str, Tuple[int, bool]] = {}
        prefill_dur = decode_dur = 0.0
        prefilled_any = False
        t0 = _time.perf_counter()
        total_utok = 0
        for r in batch.prefill_requests:
            if not batch.completes_prompt(r):
                continue  # chunk not finishing the prompt: accounted only
            tok, utok = self._prefill_one(r)
            total_utok += utok
            prefilled_any = True
            # a restarted (preempted) request already produced its preserved
            # tokens; this prefill emits the (len + 1)-th
            finished = self._is_finish_token(r, tok, len(r.output_tokens) + 1)
            outputs[r.req_id] = (tok, finished)
            if finished:
                self._free_slot(r.req_id)
        prefill_dur = _time.perf_counter() - t0
        if prefilled_any:
            self.prefill_samples.append((total_utok, prefill_dur))
        reqs = [r for r in batch.decode_requests if r.req_id in self._slot_of]
        if reqs:
            t1 = _time.perf_counter()
            toks = self._decode_all(reqs)
            decode_dur = _time.perf_counter() - t1
            self.decode_samples.append((len(reqs), decode_dur))
            for r in reqs:
                tok = toks[r.req_id]
                # r.output_tokens holds the tokens of *previous* iterations
                # (complete_batch appends after execute), so this token is the
                # (len + 1)-th produced — matching the simulated executor's
                # count; the old "+ 2" finished every request one token early.
                finished = self._is_finish_token(r, tok, len(r.output_tokens) + 1)
                outputs[r.req_id] = (tok, finished)
                if finished:
                    self._free_slot(r.req_id)
        return prefill_dur + decode_dur, BatchResult(outputs)

    def _is_finish_token(self, r: Request, tok: int, produced: int) -> bool:
        if r.eos_token is not None and tok == r.eos_token:
            return True
        return produced >= r.max_output_tokens

    # ------------------------------------------------------------------ calibration
    def fitted_model(self):
        return lm_mod.fit(self.prefill_samples, self.decode_samples)
