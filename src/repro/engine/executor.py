"""Real JAX executors: token-by-token execution of scheduler-issued batches on
an actual model (smoke-scale on CPU; the same code path drives a TPU slice).

Two KV backends behind one engine-facing contract (``execute`` /
``release_request`` / ``validate_relquery`` / ``fitted_model``):

``RealExecutor`` — the dense baseline. ``max_slots`` decode cache slots of
``max_len`` tokens each (the model's dense/ring KV layout); prefill assigns
slots one request at a time with bucketed padding, decode runs one
``decode_step`` over all active slots. Kept bit-identical as the reference
the paged backend is pinned against.

``PagedRealExecutor`` — block-paged KV owned by ``BlockManager``: a single
``[num_blocks, block_size, heads, dim]`` K/V pool per layer, per-request
block tables, batched multi-request prefill (shape-bucketed on batch and
length to bound recompilation, optionally through the Pallas
``flash_prefill`` kernel) and decode through the Pallas ``paged_attention``
kernel — falling back to ``kernels/ref.py`` on CPU so CI exercises the same
path. Prefix-sharing chains map to physically shared (ref-counted) blocks
with copy-on-write on divergence; preemption releases real blocks instead of
whole slots, so the scheduler's token ledger and device residency agree.

Both are the calibration source for the linear batch-cost model (paper
Fig. 7): ``fitted_model()`` fits α/β from measured (tokens, duration) /
(reqs, duration) samples on this host.
"""
from __future__ import annotations

import time as _time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import latency_model as lm_mod
from repro.core.batch import Batch
from repro.core.relquery import RelQuery, Request
from repro.core.scheduler import BatchResult
from repro.engine.kv_cache import BlockManager, OutOfBlocks
from repro.engine.prefix_cache import PrefixCache, block_hashes


class RequestCapacityError(ValueError):
    """A request can never fit this executor's per-sequence KV capacity —
    raised at admission (``EngineCore.admit``) instead of overflowing the
    slot buffer / block table mid-flight."""


def _bucket(n: int, buckets=(16, 32, 64, 128, 256, 512, 1024, 2048, 4096)) -> int:
    for b in buckets:
        if n <= b:
            return b
    return ((n + 4095) // 4096) * 4096


def _pow2_bucket(n: int) -> int:
    b = 1
    while b < n:
        b *= 2
    return b


class _ExecutorBase:
    """Shared mechanics of the real executors: sampling, finish detection,
    admission-time capacity validation and cost-model calibration."""

    def __init__(self, model, params, *, max_len: int,
                 prefix_cache: Optional[PrefixCache] = None,
                 greedy: bool = True):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.prefix_cache = prefix_cache
        self.greedy = greedy
        self.prefill_samples: List[Tuple[int, float]] = []
        self.decode_samples: List[Tuple[int, float]] = []

    # ------------------------------------------------------------- admission
    def validate_relquery(self, rq: RelQuery) -> None:
        """Reject (at admission) any request whose worst-case prompt+output
        footprint can never fit a sequence's KV capacity — previously such a
        request silently overflowed the dense slot buffer mid-decode."""
        for r in rq.requests:
            need = r.num_prompt_tokens + r.max_output_tokens
            if need > self.max_len:
                raise RequestCapacityError(
                    f"request {r.req_id} of relQuery {rq.rel_id} needs up to "
                    f"{need} KV tokens (prompt {r.num_prompt_tokens} + "
                    f"max_output {r.max_output_tokens}) but this executor's "
                    f"per-sequence capacity is max_len={self.max_len}; "
                    f"shorten the prompt, lower max_output_tokens, or build "
                    f"the executor with a larger max_len")

    # ------------------------------------------------------------- shared bits
    def _aot(self, fn, *args) -> Tuple[object, float]:
        """Ahead-of-time compile ``fn`` for ``args``; returns (executable,
        compile_seconds). Callers subtract the compile time from their
        measured phase duration: throughput samples and the fitted cost model
        must see steady-state execution, not first-shape XLA compilation
        (the shape-bucketed paged backend compiles several decode variants
        over a run — charging those to decode latency would skew both the
        clock and Fig. 7's α/β fit)."""
        t0 = _time.perf_counter()
        exe = fn.lower(*args).compile()
        return exe, _time.perf_counter() - t0

    def _sample(self, logits) -> np.ndarray:
        return np.asarray(jnp.argmax(logits, axis=-1))

    def _is_finish_token(self, r: Request, tok: int, produced: int) -> bool:
        if r.eos_token is not None and tok == r.eos_token:
            return True
        return produced >= r.max_output_tokens

    def _account_prefill(self, r: Request, seq: Sequence[int]) -> int:
        """Prefix-cache stats identical across backends (count then insert,
        in batch order): only the prompt enters the cache — generated tokens
        are never prefix-cached (the estimator/PEM invariant)."""
        if self.prefix_cache is None:
            return len(seq)
        cached = self.prefix_cache.count_cached(seq)
        self.prefix_cache.insert(r.tokens)
        return len(seq) - cached

    # ------------------------------------------------------------- calibration
    def fitted_model(self):
        return lm_mod.fit(self.prefill_samples, self.decode_samples)


@dataclass
class Slot:
    req: Request
    position: int          # next decode position (== tokens written so far)


class RealExecutor(_ExecutorBase):
    """Dense per-slot KV backend (the bit-identical baseline)."""

    def __init__(self, model, params, *, max_slots: int = 32, max_len: int = 512,
                 prefix_cache: Optional[PrefixCache] = None, greedy: bool = True):
        super().__init__(model, params, max_len=max_len,
                         prefix_cache=prefix_cache, greedy=greedy)
        self.max_slots = max_slots
        self.cache = model.init_cache(max_slots, max_len)
        self.slots: List[Optional[Slot]] = [None] * max_slots
        self._slot_of: Dict[str, int] = {}
        self._prefill_fn = {}
        self._decode_fn = None
        self._decode_jit = jax.jit(model.decode_step, donate_argnums=(1,))
        self._compile_s = 0.0     # compile time to subtract from this batch

    # ------------------------------------------------------------------ slots
    def _alloc_slot(self, req: Request) -> int:
        for i, s in enumerate(self.slots):
            if s is None:
                self.slots[i] = Slot(req, 0)
                self._slot_of[req.req_id] = i
                return i
        raise RuntimeError("out of decode slots — scheduler exceeded max_num_seqs")

    def _free_slot(self, req_id: str) -> None:
        i = self._slot_of.pop(req_id, None)
        if i is not None:
            self.slots[i] = None

    def release_request(self, req_id: str) -> None:
        """Free executor-side state held for a request (its decode slot).
        Called by the engine on cancellation/preemption; unknown req_ids are
        a no-op."""
        self._free_slot(req_id)

    # ------------------------------------------------------------------ prefill
    def _prefill_one(self, req: Request) -> Tuple[int, int]:
        """Prefill a request, write its KV into a slot; returns (token, utok).
        For a preempted request's restart the pass recomputes prompt +
        preserved generation (recompute-style preemption recovery)."""
        seq = req.prefill_token_ids()
        n = len(seq)
        utok = self._account_prefill(req, seq)
        # pad-masked prefill (recurrent state frozen on pads); never pad past
        # the slot length — admission guarantees n <= max_len
        bucket = min(_bucket(n), self.max_len)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :n] = seq
        args = (self.params, jnp.asarray(toks), jnp.asarray([n], jnp.int32))
        if bucket not in self._prefill_fn:
            fn = jax.jit(lambda p, t, sl: self.model.prefill(
                p, t, seq_lens=sl, max_len=self.max_len))
            self._prefill_fn[bucket], dt = self._aot(fn, *args)
            self._compile_s += dt
        logits, kv = self._prefill_fn[bucket](*args)
        slot = self._alloc_slot(req)
        self._write_slot_cache(slot, kv)
        self.slots[slot].position = n
        token = self._sample(logits)[0]
        return int(token), utok

    def _write_slot_cache(self, slot: int, kv) -> None:
        """Copy a single-sequence prefill cache into slot ``slot``."""
        def write(dst, src):
            if dst.ndim == src.ndim and dst.shape == src.shape:
                return src  # scalar-like entries (not per-slot)
            # batch dim location differs per model family; find the axis where
            # dst has max_slots and src has 1
            for ax in range(dst.ndim):
                if dst.shape[ax] == self.max_slots and src.shape[ax] == 1:
                    idx = [slice(None)] * dst.ndim
                    idx[ax] = slice(slot, slot + 1)
                    pad = [(0, d - s) if a != ax else (0, 0)
                           for a, (d, s) in enumerate(zip(dst.shape, src.shape))]
                    if any(p != (0, 0) for p in pad):
                        src = jnp.pad(src, pad)
                    return dst.at[tuple(idx)].set(src.astype(dst.dtype))
            raise ValueError(f"cannot place prefill cache {src.shape} into {dst.shape}")
        self.cache = jax.tree.map(write, self.cache, kv)

    # ------------------------------------------------------------------ decode
    def _decode_all(self, reqs: List[Request]) -> Dict[str, int]:
        tokens = np.zeros((self.max_slots,), np.int32)
        positions = np.zeros((self.max_slots,), np.int32)
        # decode_step scatters every row's K/V at positions[i] — rows must
        # never default to (token 0, position 0), which silently corrupted
        # position 0 of any occupied slot outside the scheduled batch (e.g. a
        # request prefilled earlier in the same mixed batch). Point occupied
        # off-batch rows at their own next position with their own last token:
        # for attention caches the write is idempotent (the slot's real
        # decode rewrites the same values) and the row's logits are discarded
        # below. Recurrent families (hymba's SSM/conv state) still advance
        # off-batch rows — a pre-existing limitation of whole-batch
        # decode_step that needs a per-row freeze mask to fix; the scheduler
        # only leaves a slot out of a decode batch in the same tick that
        # prefilled it, so attention archs are exact.
        for i, s in enumerate(self.slots):
            if s is not None:
                tokens[i] = s.req.output_tokens[-1] if s.req.output_tokens else 0
                positions[i] = s.position
        for r in reqs:
            i = self._slot_of[r.req_id]
            tokens[i] = r.output_tokens[-1] if r.output_tokens else 0
            positions[i] = self.slots[i].position
        args = (self.params, self.cache, jnp.asarray(tokens),
                jnp.asarray(positions))
        if self._decode_fn is None:
            self._decode_fn, dt = self._aot(self._decode_jit, *args)
            self._compile_s += dt
        logits, self.cache = self._decode_fn(*args)
        out = self._sample(logits)
        result = {}
        for r in reqs:
            i = self._slot_of[r.req_id]
            self.slots[i].position += 1
            result[r.req_id] = int(out[i])
        return result

    # ------------------------------------------------------------------ engine API
    def execute(self, batch: Batch, now: float) -> Tuple[float, BatchResult]:
        """Run one unified batch. Prefill and decode phases are timed
        *separately* — a mixed batch contributes a prefill-only sample and a
        decode-only sample, so ``fitted_model()`` calibration never sees
        combined wall times."""
        outputs: Dict[str, Tuple[int, bool]] = {}
        prefill_dur = decode_dur = 0.0
        prefilled_any = False
        self._compile_s = 0.0
        t0 = _time.perf_counter()
        total_utok = 0
        for r in batch.prefill_requests:
            if not batch.completes_prompt(r):
                continue  # chunk not finishing the prompt: accounted only
            tok, utok = self._prefill_one(r)
            total_utok += utok
            prefilled_any = True
            # a restarted (preempted) request already produced its preserved
            # tokens; this prefill emits the (len + 1)-th
            finished = self._is_finish_token(r, tok, len(r.output_tokens) + 1)
            outputs[r.req_id] = (tok, finished)
            if finished:
                self._free_slot(r.req_id)
        prefill_dur = max(0.0, _time.perf_counter() - t0 - self._compile_s)
        if prefilled_any:
            self.prefill_samples.append((total_utok, prefill_dur))
        reqs = [r for r in batch.decode_requests if r.req_id in self._slot_of]
        if reqs:
            self._compile_s = 0.0
            t1 = _time.perf_counter()
            toks = self._decode_all(reqs)
            decode_dur = max(0.0, _time.perf_counter() - t1 - self._compile_s)
            self.decode_samples.append((len(reqs), decode_dur))
            for r in reqs:
                tok = toks[r.req_id]
                # r.output_tokens holds the tokens of *previous* iterations
                # (complete_batch appends after execute), so this token is the
                # (len + 1)-th produced — matching the simulated executor's
                # count; the old "+ 2" finished every request one token early.
                finished = self._is_finish_token(r, tok, len(r.output_tokens) + 1)
                outputs[r.req_id] = (tok, finished)
                if finished:
                    self._free_slot(r.req_id)
        return prefill_dur + decode_dur, BatchResult(outputs)


class PagedRealExecutor(_ExecutorBase):
    """Block-paged KV backend: ``BlockManager``-owned pools, per-request
    block tables, batched bucketed prefill and paged-attention decode.

    The last pool block (id ``num_blocks``) is a scratch page: pad rows and
    pad table entries route there, so fixed-shape scatters never touch live
    blocks. KV demand agrees with the scheduler's token ledger: a request
    resident from prefill completion to finish/preempt/cancel, shared prefix
    chains (``share_prefix_blocks=True``, paired with the scheduler's
    ``prefix_sharing``) held once and ref-counted, copy-on-write if a write
    ever lands in a block a sibling still references.
    """

    def __init__(self, model, params, *, num_blocks: int = 1024,
                 block_size: int = 16, max_len: int = 512,
                 prefix_cache: Optional[PrefixCache] = None,
                 greedy: bool = True, attn_impl: Optional[str] = None,
                 prefill_attn: Optional[str] = None,
                 share_prefix_blocks: bool = False):
        if not getattr(model, "supports_paged", lambda: False)():
            raise NotImplementedError(
                f"model {model.cfg.name!r} does not support the paged KV "
                f"backend (full-attention transformer families only); use "
                f"kv_backend='dense'")
        on_cpu = jax.default_backend() == "cpu"
        if prefill_attn is None:
            prefill_attn = "block" if on_cpu else "flash"
        if prefill_attn == "flash":
            model = model.with_prefill_attn("flash")
        super().__init__(model, params, max_len=max_len,
                         prefix_cache=prefix_cache, greedy=greedy)
        # Pallas on a real accelerator, pure-jnp reference on CPU (CI's
        # fallback); 'pallas-interpret' forces the kernel through the
        # interpreter for parity debugging.
        self.attn_impl = attn_impl or ("ref" if on_cpu else "pallas")
        self.block_size = block_size
        self.num_blocks = num_blocks
        self.scratch_block = num_blocks          # pools hold one extra page
        self.max_blocks_per_seq = -(-max_len // block_size)
        self.share_prefix_blocks = share_prefix_blocks
        self.bm = BlockManager(num_blocks, block_size=block_size)
        self.pools = model.init_paged_pools(num_blocks + 1, block_size)
        self._active: Dict[str, Request] = {}
        self._prefill_fn: Dict[Tuple[int, int], object] = {}
        self._scatter_fn: Dict[Tuple[int, int], object] = {}
        self._decode_fn: Dict[Tuple[int, int], object] = {}
        self._copy_fn = None
        self.cow_copies = 0
        self.shared_block_hits = 0    # physically shared prefix blocks reused
        self._compile_s = 0.0     # compile time to subtract from this batch

    # ------------------------------------------------------------- admission
    def validate_relquery(self, rq: RelQuery) -> None:
        """Beyond the per-sequence ``max_len`` bound, a request must also fit
        the *pool*: a footprint needing more blocks than the pool holds could
        never prefill no matter what else is evicted."""
        super().validate_relquery(rq)
        for r in rq.requests:
            need = r.num_prompt_tokens + r.max_output_tokens
            blocks = self.bm.blocks_needed(need)
            if blocks > self.num_blocks:
                raise RequestCapacityError(
                    f"request {r.req_id} of relQuery {rq.rel_id} needs "
                    f"{blocks} KV blocks (footprint {need} tokens / "
                    f"block_size {self.block_size}) but the paged pool holds "
                    f"only num_blocks={self.num_blocks}; grow the pool or "
                    f"shrink the request")

    # ------------------------------------------------------------- bookkeeping
    def release_request(self, req_id: str) -> None:
        """Free the request's blocks (cancellation/preemption): real paged
        reclamation — siblings still referencing shared prefix blocks keep
        them alive; only the last reference returns a block to the free list."""
        if self._active.pop(req_id, None) is not None:
            self.bm.free(req_id)

    def kv_tokens_resident(self) -> int:
        """Per-sequence resident tokens: shared prefix blocks count once per
        referencing sequence — i.e. the scheduler's *raw* optimistic charge
        (`tokens_in_use`) before the `SharedPrefixLedger` discount. Physical
        pool occupancy is lower by exactly that discount when sharing is on."""
        return self.bm.tokens_in_use()

    def _prompt_keys(self, r: Request) -> Tuple[int, ...]:
        return tuple(block_hashes(r.tokens, self.block_size))

    # ------------------------------------------------------------- prefill
    def _prefill_batch(self, reqs: List[Request]) -> Tuple[Dict[str, int], int]:
        """Batched multi-request prefill, shape-bucketed on (batch, length):
        requests are grouped by their *per-request* length bucket (the same
        bucket the dense baseline pads each one to — keeping per-row numerics
        identical across backends, bf16 included) and each group runs as one
        model call followed by one scatter into the pools."""
        seqs = {r.req_id: r.prefill_token_ids() for r in reqs}
        utok = 0
        for r in reqs:                      # accounting in dense batch order
            utok += self._account_prefill(r, seqs[r.req_id])
        bs = self.block_size
        groups: Dict[int, List[Request]] = {}
        for r in reqs:
            L = min(_bucket(len(seqs[r.req_id])), self.max_len)
            L = -(-L // bs) * bs            # block-aligned bucket
            groups.setdefault(L, []).append(r)
        out: Dict[str, int] = {}
        for L in sorted(groups):
            grp = groups[L]
            B = _pow2_bucket(len(grp))
            nblk = L // bs
            toks = np.zeros((B, L), np.int32)
            seq_lens = np.ones((B,), np.int32)
            tables = np.full((B, nblk), self.scratch_block, np.int32)
            for i, r in enumerate(grp):
                seq = seqs[r.req_id]
                n = len(seq)
                toks[i, :n] = seq
                seq_lens[i] = n
                keys = self._prompt_keys(r) if self.share_prefix_blocks else ()
                try:
                    alloc = self.bm.allocate(r.req_id, n, prefix_keys=keys)
                    self.shared_block_hits += alloc.shared_prefix_blocks
                except OutOfBlocks as e:
                    raise RuntimeError(
                        f"paged KV pool exhausted during prefill of "
                        f"{r.req_id}: {e} — the scheduler's cap admitted more "
                        f"resident tokens than num_blocks*block_size covers"
                    ) from e
                if keys:
                    self.bm.register_prefix(r.req_id, keys)
                self._active[r.req_id] = r
                row = self.bm.padded_block_table(r.req_id, nblk,
                                                 self.scratch_block)
                # a follower must never rewrite pages its leader already
                # owns: the leader may be mid-decode attending them, and on
                # kernel backends the recomputed bytes are not bit-identical
                # — shared leading pages are written exactly once (by the
                # leader), so route the follower's scatter there to scratch
                for j in range(alloc.shared_prefix_blocks):
                    row[j] = self.scratch_block
                tables[i] = row
            key = (B, L)
            args = (self.params, jnp.asarray(toks), jnp.asarray(seq_lens))
            if key not in self._prefill_fn:
                fn = jax.jit(lambda p, t, sl, L=L: self.model.prefill(
                    p, t, seq_lens=sl, max_len=L))
                self._prefill_fn[key], dt = self._aot(fn, *args)
                self._compile_s += dt
            logits, caches = self._prefill_fn[key](*args)
            sargs = (self.pools, caches, jnp.asarray(tables))
            if key not in self._scatter_fn:
                fn = jax.jit(self.model.scatter_prefill_pools,
                             donate_argnums=(0,))
                self._scatter_fn[key], dt = self._aot(fn, *sargs)
                self._compile_s += dt
            self.pools = self._scatter_fn[key](*sargs)
            out_tokens = self._sample(logits)
            for i, r in enumerate(grp):
                out[r.req_id] = int(out_tokens[i])
        return out, utok

    # ------------------------------------------------------------- decode
    def _copy_block(self, src: int, dst: int) -> None:
        """Device-side CoW: clone page ``src`` into ``dst`` across all layers
        before the diverging write."""
        args = (self.pools, jnp.asarray(src, jnp.int32),
                jnp.asarray(dst, jnp.int32))
        if self._copy_fn is None:
            def copy(pools, s, d):
                pools = dict(pools)
                for name in ("k", "v"):
                    pools[name] = jax.lax.dynamic_update_index_in_dim(
                        pools[name],
                        jax.lax.dynamic_index_in_dim(pools[name], s, axis=2,
                                                     keepdims=False),
                        d, axis=2)
                return pools
            self._copy_fn, dt = self._aot(jax.jit(copy, donate_argnums=(0,)),
                                          *args)
            self._compile_s += dt
        self.pools = self._copy_fn(*args)
        self.cow_copies += 1

    def _decode_batch(self, reqs: List[Request]) -> Dict[str, int]:
        bs = self.block_size
        positions = []
        for r in reqs:
            pos = self.bm.context_len(r.req_id)
            positions.append(pos)
            try:
                _, cow = self.bm.append_token_cow(r.req_id)
            except OutOfBlocks as e:
                raise RuntimeError(
                    f"paged KV pool exhausted during decode of {r.req_id}: "
                    f"{e}") from e
            if cow is not None:
                self._copy_block(*cow)
        width = max(len(self.bm.block_table(r.req_id)) for r in reqs)
        NB = min(_pow2_bucket(width), self.max_blocks_per_seq)
        NB = max(NB, width)
        B = _pow2_bucket(len(reqs))
        tokens = np.zeros((B,), np.int32)
        pos_arr = np.zeros((B,), np.int32)
        ctx = np.ones((B,), np.int32)
        tables = np.full((B, NB), self.scratch_block, np.int32)
        for i, (r, pos) in enumerate(zip(reqs, positions)):
            tokens[i] = r.output_tokens[-1] if r.output_tokens else 0
            pos_arr[i] = pos
            ctx[i] = pos + 1
            tables[i] = self.bm.padded_block_table(r.req_id, NB,
                                                   self.scratch_block)
        key = (B, NB)
        args = (self.params, self.pools, jnp.asarray(tokens),
                jnp.asarray(pos_arr), jnp.asarray(tables), jnp.asarray(ctx))
        if key not in self._decode_fn:
            fn = jax.jit(
                lambda p, pools, t, po, bt, cl: self.model.decode_step_paged(
                    p, pools, t, po, bt, cl, attn_impl=self.attn_impl),
                donate_argnums=(1,))
            self._decode_fn[key], dt = self._aot(fn, *args)
            self._compile_s += dt
        logits, self.pools = self._decode_fn[key](*args)
        out = self._sample(logits)
        return {r.req_id: int(out[i]) for i, r in enumerate(reqs)}

    # ------------------------------------------------------------- engine API
    def execute(self, batch: Batch, now: float) -> Tuple[float, BatchResult]:
        """Same phase-separated timing contract as the dense executor."""
        outputs: Dict[str, Tuple[int, bool]] = {}
        prefill_dur = decode_dur = 0.0
        prefill_reqs = [r for r in batch.prefill_requests
                        if batch.completes_prompt(r)]
        if prefill_reqs:
            self._compile_s = 0.0
            t0 = _time.perf_counter()
            toks, utok = self._prefill_batch(prefill_reqs)
            prefill_dur = max(0.0,
                              _time.perf_counter() - t0 - self._compile_s)
            self.prefill_samples.append((utok, prefill_dur))
            for r in prefill_reqs:
                tok = toks[r.req_id]
                finished = self._is_finish_token(r, tok,
                                                 len(r.output_tokens) + 1)
                outputs[r.req_id] = (tok, finished)
                if finished:
                    self.release_request(r.req_id)
        reqs = [r for r in batch.decode_requests if r.req_id in self._active]
        if reqs:
            self._compile_s = 0.0
            t1 = _time.perf_counter()
            toks = self._decode_batch(reqs)
            decode_dur = max(0.0, _time.perf_counter() - t1 - self._compile_s)
            self.decode_samples.append((len(reqs), decode_dur))
            for r in reqs:
                tok = toks[r.req_id]
                finished = self._is_finish_token(r, tok,
                                                 len(r.output_tokens) + 1)
                outputs[r.req_id] = (tok, finished)
                if finished:
                    self.release_request(r.req_id)
        return prefill_dur + decode_dur, BatchResult(outputs)


KV_BACKENDS = ("dense", "paged")


def make_real_executor(kv_backend: str, model, params, *, max_slots: int = 32,
                       max_len: int = 512,
                       prefix_cache: Optional[PrefixCache] = None,
                       num_blocks: Optional[int] = None, block_size: int = 16,
                       share_prefix_blocks: bool = False, **kw):
    """Build a real executor by backend name. ``num_blocks`` defaults to the
    dense layout's physical capacity (max_slots × max_len worth of tokens) so
    switching backends never shrinks device KV."""
    if kv_backend == "dense":
        return RealExecutor(model, params, max_slots=max_slots,
                            max_len=max_len, prefix_cache=prefix_cache, **kw)
    if kv_backend == "paged":
        if num_blocks is None:
            num_blocks = -(-max_slots * max_len // block_size)
        return PagedRealExecutor(model, params, num_blocks=num_blocks,
                                 block_size=block_size, max_len=max_len,
                                 prefix_cache=prefix_cache,
                                 share_prefix_blocks=share_prefix_blocks, **kw)
    raise ValueError(f"unknown kv_backend {kv_backend!r}; expected one of "
                     f"{KV_BACKENDS}")
