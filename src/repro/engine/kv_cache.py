"""Paged KV block manager: block-granular accounting of device KV memory with
per-sequence block tables, shared (ref-counted) prefix blocks, and watermark
admission. The Pallas paged-attention kernel consumes exactly this layout
(block_tables [B, max_blocks], context_lens [B]).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple


class OutOfBlocks(Exception):
    pass


@dataclass
class SeqAllocation:
    block_ids: List[int]
    num_tokens: int
    shared_prefix_blocks: int = 0


@dataclass
class HostAllocation:
    """A sequence's KV parked on the host tier: one host block per device
    block it occupied at swap-out time (including then-shared prefix blocks —
    the host copy is always self-contained so swap-in never depends on a
    sibling still being resident)."""
    block_ids: List[int]
    num_tokens: int


class BlockManager:
    def __init__(self, num_blocks: int, block_size: int = 16,
                 watermark: float = 0.01, num_host_blocks: int = 0):
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.num_host_blocks = num_host_blocks
        self.watermark_blocks = max(1, int(num_blocks * watermark))
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._ref: Dict[int, int] = {}
        self._seqs: Dict[str, SeqAllocation] = {}
        # prefix-block sharing: hash key -> block id
        self._prefix_blocks: Dict[int, int] = {}
        self._block_keys: Dict[int, int] = {}
        # host tier: swapped-out sequences hold host blocks (never shared)
        self._host_free: List[int] = list(range(num_host_blocks - 1, -1, -1))
        self._host_seqs: Dict[str, HostAllocation] = {}
        # swap-in prefetch staging: seq_id -> fresh device blocks already
        # holding (a copy of) the host image, awaiting commit or cancel. The
        # host allocation stays authoritative until commit.
        self._staged: Dict[str, List[int]] = {}

    # ---------------------------------------------------------------- queries
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    def blocks_needed(self, num_tokens: int) -> int:
        return (num_tokens + self.block_size - 1) // self.block_size

    def can_allocate(self, num_tokens: int, cached_blocks: int = 0) -> bool:
        need = max(0, self.blocks_needed(num_tokens) - cached_blocks)
        return self.free_blocks - need >= self.watermark_blocks

    def block_table(self, seq_id: str) -> List[int]:
        return list(self._seqs[seq_id].block_ids)

    def context_len(self, seq_id: str) -> int:
        return self._seqs[seq_id].num_tokens

    def tokens_in_use(self) -> int:
        return sum(a.num_tokens for a in self._seqs.values())

    @property
    def host_free_blocks(self) -> int:
        return len(self._host_free)

    def host_tokens_in_use(self) -> int:
        return sum(a.num_tokens for a in self._host_seqs.values())

    def is_swapped(self, seq_id: str) -> bool:
        return seq_id in self._host_seqs

    def host_block_table(self, seq_id: str) -> List[int]:
        return list(self._host_seqs[seq_id].block_ids)

    # ---------------------------------------------------------------- alloc
    def allocate(self, seq_id: str, num_tokens: int,
                 prefix_keys: Sequence[int] = ()) -> SeqAllocation:
        """Allocate blocks for a prefilled sequence; reuse shared prefix blocks
        when their keys are resident."""
        if seq_id in self._seqs:
            raise KeyError(f"sequence {seq_id} already allocated")
        shared: List[int] = []
        for key in prefix_keys:
            bid = self._prefix_blocks.get(key)
            if bid is None:
                break
            shared.append(bid)
        need = self.blocks_needed(num_tokens) - len(shared)
        if need > len(self._free):
            raise OutOfBlocks(f"need {need} blocks, have {len(self._free)}")
        fresh = [self._free.pop() for _ in range(max(0, need))]
        for bid in shared + fresh:
            self._ref[bid] = self._ref.get(bid, 0) + 1
        alloc = SeqAllocation(block_ids=shared + fresh, num_tokens=num_tokens,
                              shared_prefix_blocks=len(shared))
        self._seqs[seq_id] = alloc
        return alloc

    def register_prefix(self, seq_id: str, keys: Sequence[int]) -> None:
        """Publish the first len(keys) blocks of a sequence as shared prefix
        blocks (called after prefill writes them)."""
        alloc = self._seqs[seq_id]
        for i, key in enumerate(keys):
            if i >= len(alloc.block_ids):
                break
            bid = alloc.block_ids[i]
            if key not in self._prefix_blocks:
                self._prefix_blocks[key] = bid
                self._block_keys[bid] = key

    def fork(self, parent_id: str, child_id: str) -> SeqAllocation:
        """Copy-on-write fork: ``child_id`` shares *every* block of
        ``parent_id`` (ref-counted, zero copies). The first append that lands
        in a still-shared partial block triggers CoW (``append_token_cow``)."""
        if child_id in self._seqs:
            raise KeyError(f"sequence {child_id} already allocated")
        parent = self._seqs[parent_id]
        for bid in parent.block_ids:
            self._ref[bid] += 1
        alloc = SeqAllocation(block_ids=list(parent.block_ids),
                              num_tokens=parent.num_tokens,
                              shared_prefix_blocks=len(parent.block_ids))
        self._seqs[child_id] = alloc
        return alloc

    def append_token_cow(self, seq_id: str
                         ) -> Tuple[Optional[int], Optional[Tuple[int, int]]]:
        """Account one decoded token with copy-on-write semantics. Returns
        ``(new_block_id | None, copy | None)`` where ``copy = (src, dst)``
        instructs the device pool to clone block ``src`` into ``dst`` before
        the write: the token would have landed in a block another sequence
        still references (a CoW-forked tail), so the writer gets a private
        copy and the sibling keeps the original bytes."""
        alloc = self._seqs[seq_id]
        write_idx = alloc.num_tokens        # token index this append writes
        blk_pos = write_idx // self.block_size
        if blk_pos >= len(alloc.block_ids):     # boundary: fresh private block
            if not self._free:
                raise OutOfBlocks("decode append")
            bid = self._free.pop()
            self._ref[bid] = 1
            alloc.block_ids.append(bid)
            alloc.num_tokens += 1
            return bid, None
        bid = alloc.block_ids[blk_pos]
        if self._ref[bid] > 1:                  # shared partial tail: CoW
            if not self._free:
                raise OutOfBlocks("cow append")
            dst = self._free.pop()
            self._ref[bid] -= 1
            self._ref[dst] = 1
            alloc.block_ids[blk_pos] = dst
            alloc.num_tokens += 1
            return dst, (bid, dst)
        alloc.num_tokens += 1
        return None, None

    def append_token(self, seq_id: str) -> Optional[int]:
        """Account one decoded token; returns a newly allocated block id if a
        block boundary was crossed (or a CoW copy was taken)."""
        bid, _ = self.append_token_cow(seq_id)
        return bid

    def padded_block_table(self, seq_id: str, width: int,
                           pad_id: int) -> List[int]:
        """``seq_id``'s block table padded (or validated) to ``width`` entries
        — the fixed-shape row the paged-attention kernels consume. ``pad_id``
        should be a scratch block no live sequence owns."""
        table = self._seqs[seq_id].block_ids
        if len(table) > width:
            raise ValueError(f"sequence {seq_id} spans {len(table)} blocks"
                             f" > table width {width}")
        return list(table) + [pad_id] * (width - len(table))

    def free(self, seq_id: str) -> None:
        alloc = self._seqs.pop(seq_id, None)
        if alloc is not None:
            for bid in alloc.block_ids:
                self._ref[bid] -= 1
                if self._ref[bid] == 0:
                    del self._ref[bid]
                    key = self._block_keys.pop(bid, None)
                    if key is not None:
                        self._prefix_blocks.pop(key, None)
                    self._free.append(bid)
        self.cancel_prefetch(seq_id)
        host = self._host_seqs.pop(seq_id, None)
        if host is not None:
            self._host_free.extend(host.block_ids)

    # ---------------------------------------------------------------- swapping
    def can_swap_out(self, seq_id: str) -> bool:
        alloc = self._seqs.get(seq_id)
        return (alloc is not None
                and len(alloc.block_ids) <= len(self._host_free))

    def swap_out(self, seq_id: str) -> List[Tuple[int, int]]:
        """Park ``seq_id``'s KV on the host tier. Returns the copy plan
        ``[(device_bid, host_bid), ...]`` in table order — the executor copies
        *every* block (shared prefix blocks included, so the host image is
        self-contained), then this accounting drops one device reference per
        block: blocks siblings still reference stay resident on device and are
        never returned to the free list here."""
        alloc = self._seqs.pop(seq_id)
        need = len(alloc.block_ids)
        if need > len(self._host_free):
            self._seqs[seq_id] = alloc
            raise OutOfBlocks(
                f"swap_out {seq_id}: need {need} host blocks, "
                f"have {len(self._host_free)}")
        plan: List[Tuple[int, int]] = []
        host_ids: List[int] = []
        for bid in alloc.block_ids:
            hid = self._host_free.pop()
            host_ids.append(hid)
            plan.append((bid, hid))
            self._ref[bid] -= 1
            if self._ref[bid] == 0:
                del self._ref[bid]
                key = self._block_keys.pop(bid, None)
                if key is not None:
                    self._prefix_blocks.pop(key, None)
                self._free.append(bid)
        self._host_seqs[seq_id] = HostAllocation(
            block_ids=host_ids, num_tokens=alloc.num_tokens)
        return plan

    def can_swap_in(self, seq_id: str) -> bool:
        if seq_id in self._staged:
            return True    # its device blocks are already allocated
        host = self._host_seqs.get(seq_id)
        return host is not None and len(host.block_ids) <= len(self._free)

    # ------------------------------------------------------- swap-in prefetch
    def prefetch_swap_in(self, seq_id: str) -> Optional[List[Tuple[int, int]]]:
        """Stage a swapped sequence's host image into fresh device blocks
        ahead of the swap-in commit. Returns the copy plan
        ``[(host_bid, device_bid), ...]``, or None when the sequence is not on
        the host tier, is already staged, or the pool lacks free blocks (the
        commit then takes the synchronous ``swap_in`` path). The host
        allocation stays authoritative until ``commit_prefetch`` — a cancel
        just returns the fresh blocks."""
        host = self._host_seqs.get(seq_id)
        if host is None or seq_id in self._staged:
            return None
        need = len(host.block_ids)
        if need > len(self._free):
            return None
        fresh = [self._free.pop() for _ in range(need)]
        for bid in fresh:
            self._ref[bid] = 1
        self._staged[seq_id] = fresh
        return list(zip(host.block_ids, fresh))

    def commit_prefetch(self, seq_id: str) -> None:
        """Finish a staged swap-in: the staged blocks become the sequence's
        device allocation and its host blocks are returned to the host free
        list."""
        fresh = self._staged.pop(seq_id)
        host = self._host_seqs.pop(seq_id)
        self._host_free.extend(host.block_ids)
        self._seqs[seq_id] = SeqAllocation(
            block_ids=fresh, num_tokens=host.num_tokens,
            shared_prefix_blocks=0)

    def cancel_prefetch(self, seq_id: str) -> None:
        """Abort a staged swap-in (the request was cancelled between prefetch
        and commit): the staged device blocks return to the free list; the
        host image is untouched — ``free`` reclaims it separately.
        Idempotent."""
        fresh = self._staged.pop(seq_id, None)
        if fresh is None:
            return
        for bid in fresh:
            del self._ref[bid]
            self._free.append(bid)

    def swap_in(self, seq_id: str) -> List[Tuple[int, int]]:
        """Bring a swapped sequence back to device. Returns the copy plan
        ``[(host_bid, device_bid), ...]``. The sequence gets fresh private
        blocks (its former shared-prefix identity was dropped at swap-out —
        resumption never aliases a sibling's pages). A staged sequence
        commits its prefetched blocks instead (the plan's copies already
        happened, but re-copying is harmless)."""
        if seq_id in self._staged:
            plan = list(zip(self._host_seqs[seq_id].block_ids,
                            self._staged[seq_id]))
            self.commit_prefetch(seq_id)
            return plan
        host = self._host_seqs.pop(seq_id)
        need = len(host.block_ids)
        if need > len(self._free):
            self._host_seqs[seq_id] = host
            raise OutOfBlocks(
                f"swap_in {seq_id}: need {need} blocks, "
                f"have {len(self._free)}")
        plan: List[Tuple[int, int]] = []
        fresh: List[int] = []
        for hid in host.block_ids:
            bid = self._free.pop()
            self._ref[bid] = 1
            fresh.append(bid)
            plan.append((hid, bid))
        self._host_free.extend(host.block_ids)
        self._seqs[seq_id] = SeqAllocation(
            block_ids=fresh, num_tokens=host.num_tokens,
            shared_prefix_blocks=0)
        return plan

    # ---------------------------------------------------------------- invariants
    def check_invariants(self) -> None:
        in_use = set()
        for alloc in self._seqs.values():
            in_use.update(alloc.block_ids)
        # staged prefetch blocks are device-resident (not free, not yet a
        # sequence allocation) and only ever staged for host-tier sequences
        assert set(self._staged) <= set(self._host_seqs), \
            "prefetch staged for a sequence not on the host tier"
        for blocks in self._staged.values():
            assert not (in_use & set(blocks)), "staged block also allocated"
            in_use.update(blocks)
        free = set(self._free)
        assert not (in_use & free), "block both free and in use"
        assert all(self._ref.get(b, 0) > 0 for b in in_use)
        # exact conservation: every block is either free or referenced by at
        # least one sequence — shared prefix blocks appear once in ``in_use``
        # no matter how many sequences reference them
        assert len(free) + len(in_use) == self.num_blocks, \
            f"{len(free)} free + {len(in_use)} in use != {self.num_blocks}"
        assert len(self._free) == len(free), "duplicate id in free list"
        # host-tier conservation: host blocks are never shared, so the sum of
        # per-sequence host tables plus the host free list is exact
        host_used = [b for a in self._host_seqs.values() for b in a.block_ids]
        host_free = set(self._host_free)
        assert len(set(host_used)) == len(host_used), \
            "host block owned by two sequences"
        assert not (set(host_used) & host_free), "host block free and in use"
        assert len(host_free) + len(host_used) == self.num_host_blocks, \
            (f"{len(host_free)} host free + {len(host_used)} host in use "
             f"!= {self.num_host_blocks}")
        assert len(self._host_free) == len(host_free), \
            "duplicate id in host free list"


class SharedPrefixLedger:
    """Token-granular admission twin of ``BlockManager``'s ref-counted shared
    prefix blocks: schedulers charge the KV cap in *tokens*, so this ledger
    tracks, per block key, how many live requests' charges include that block
    — and exposes ``discount``, the tokens counted more than once. Admission
    subtracts the discount from raw per-request charges, making shared prefix
    blocks count once against ``limits.cap`` exactly as they occupy device
    memory once in the paged ``BlockManager``.

    Because keys are chained hashes, a key's holders all share the entire
    prefix up to that block, and reference counts are non-increasing along any
    request's chain — so the still-shared blocks after any release form a
    leading run and the discount never goes negative.
    """

    def __init__(self, block_size: int):
        self.block_size = block_size
        self._ref: Dict[int, int] = {}
        self.discount = 0          # tokens charged more than once (Σ (ref-1)·bs)

    def __len__(self) -> int:
        return len(self._ref)

    def contains(self, key: int) -> bool:
        return self._ref.get(key, 0) > 0

    def shared_tokens(self, keys: Sequence[int]) -> int:
        """Tokens of the leading blocks of ``keys`` already charged by a live
        request — what admitting this chain would add to the discount."""
        n = 0
        for k in keys:
            if self._ref.get(k, 0) > 0:
                n += self.block_size
            else:
                break
        return n

    def acquire(self, keys: Sequence[int]) -> int:
        """Register a charged request's block chain; returns the tokens newly
        discounted (its prefix overlap with already-charged requests)."""
        saved = self.shared_tokens(keys)
        for k in keys:
            self._ref[k] = self._ref.get(k, 0) + 1
        self.discount += saved
        return saved

    def release(self, keys: Sequence[int]) -> None:
        """Drop one charge of ``keys``. Blocks still referenced by siblings
        stay discounted — their tokens remain charged through the survivors'
        raw footprints, so nothing shared is double-freed."""
        for k in keys:
            n = self._ref.get(k, 0) - 1
            if n > 0:
                self._ref[k] = n
                self.discount -= self.block_size
            else:
                self._ref.pop(k, None)

    def check_invariants(self) -> None:
        assert self.discount == sum(
            max(0, n - 1) for n in self._ref.values()) * self.block_size
        assert all(n > 0 for n in self._ref.values())
