"""Paged KV block manager: block-granular accounting of device KV memory with
per-sequence block tables, shared (ref-counted) prefix blocks, and watermark
admission. The Pallas paged-attention kernel consumes exactly this layout
(block_tables [B, max_blocks], context_lens [B]).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set


class OutOfBlocks(Exception):
    pass


@dataclass
class SeqAllocation:
    block_ids: List[int]
    num_tokens: int
    shared_prefix_blocks: int = 0


class BlockManager:
    def __init__(self, num_blocks: int, block_size: int = 16,
                 watermark: float = 0.01):
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.watermark_blocks = max(1, int(num_blocks * watermark))
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._ref: Dict[int, int] = {}
        self._seqs: Dict[str, SeqAllocation] = {}
        # prefix-block sharing: hash key -> block id
        self._prefix_blocks: Dict[int, int] = {}
        self._block_keys: Dict[int, int] = {}

    # ---------------------------------------------------------------- queries
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    def blocks_needed(self, num_tokens: int) -> int:
        return (num_tokens + self.block_size - 1) // self.block_size

    def can_allocate(self, num_tokens: int, cached_blocks: int = 0) -> bool:
        need = max(0, self.blocks_needed(num_tokens) - cached_blocks)
        return self.free_blocks - need >= self.watermark_blocks

    def block_table(self, seq_id: str) -> List[int]:
        return list(self._seqs[seq_id].block_ids)

    def context_len(self, seq_id: str) -> int:
        return self._seqs[seq_id].num_tokens

    def tokens_in_use(self) -> int:
        return sum(a.num_tokens for a in self._seqs.values())

    # ---------------------------------------------------------------- alloc
    def allocate(self, seq_id: str, num_tokens: int,
                 prefix_keys: Sequence[int] = ()) -> SeqAllocation:
        """Allocate blocks for a prefilled sequence; reuse shared prefix blocks
        when their keys are resident."""
        if seq_id in self._seqs:
            raise KeyError(f"sequence {seq_id} already allocated")
        shared: List[int] = []
        for key in prefix_keys:
            bid = self._prefix_blocks.get(key)
            if bid is None:
                break
            shared.append(bid)
        need = self.blocks_needed(num_tokens) - len(shared)
        if need > len(self._free):
            raise OutOfBlocks(f"need {need} blocks, have {len(self._free)}")
        fresh = [self._free.pop() for _ in range(max(0, need))]
        for bid in shared + fresh:
            self._ref[bid] = self._ref.get(bid, 0) + 1
        alloc = SeqAllocation(block_ids=shared + fresh, num_tokens=num_tokens,
                              shared_prefix_blocks=len(shared))
        self._seqs[seq_id] = alloc
        return alloc

    def register_prefix(self, seq_id: str, keys: Sequence[int]) -> None:
        """Publish the first len(keys) blocks of a sequence as shared prefix
        blocks (called after prefill writes them)."""
        alloc = self._seqs[seq_id]
        for i, key in enumerate(keys):
            if i >= len(alloc.block_ids):
                break
            bid = alloc.block_ids[i]
            if key not in self._prefix_blocks:
                self._prefix_blocks[key] = bid
                self._block_keys[bid] = key

    def append_token(self, seq_id: str) -> Optional[int]:
        """Account one decoded token; returns a newly allocated block id if a
        block boundary was crossed."""
        alloc = self._seqs[seq_id]
        alloc.num_tokens += 1
        if (alloc.num_tokens - 1) // self.block_size >= len(alloc.block_ids):
            if not self._free:
                raise OutOfBlocks("decode append")
            bid = self._free.pop()
            self._ref[bid] = 1
            alloc.block_ids.append(bid)
            return bid
        return None

    def free(self, seq_id: str) -> None:
        alloc = self._seqs.pop(seq_id)
        for bid in alloc.block_ids:
            self._ref[bid] -= 1
            if self._ref[bid] == 0:
                del self._ref[bid]
                key = self._block_keys.pop(bid, None)
                if key is not None:
                    self._prefix_blocks.pop(key, None)
                self._free.append(bid)

    # ---------------------------------------------------------------- invariants
    def check_invariants(self) -> None:
        in_use = set()
        for alloc in self._seqs.values():
            in_use.update(alloc.block_ids)
        free = set(self._free)
        assert not (in_use & free), "block both free and in use"
        assert all(self._ref.get(b, 0) > 0 for b in in_use)
        total_tracked = len(free | in_use)
        assert total_tracked <= self.num_blocks
