"""Simulated-clock executor: executes scheduler-issued ``Batch``es against the
calibrated linear cost model (paper Fig. 7) and a *real* prefix cache, so the
scheduling decisions — the paper's subject — are identical to what the real
engine would issue, while batch durations come from the A100/OPT-13B-regime
constants (or any fitted model). Used by the paper-scale benchmarks.

One code path handles all batch kinds: the prefill side of a batch is a set of
(request, chunk) pairs — a pure prefill batch is simply the chunk covering the
whole remaining prompt — and the decode side decodes one token per request.
"""
from __future__ import annotations

import random
import zlib
from typing import Dict, Optional, Tuple

from repro.core.batch import Batch
from repro.core.latency_model import BatchLatencyModel
from repro.core.relquery import Request
from repro.core.scheduler import BatchResult
from repro.engine.prefix_cache import PrefixCache


def sim_output_len(r: Request) -> int:
    """Actual (EOS-terminated) output length for simulation; defaults to OL."""
    return getattr(r, "sim_output_len", None) or r.max_output_tokens


def _content_key(r: Request) -> int:
    """Stable per-request stream seed derived from the *prompt content*, not
    the request identity: two requests with equal prompts emit identical
    streams, which is what makes the planner's exact-duplicate dedup
    answer-preserving (the leader's stream is bit-identical to what each
    duplicate would have produced alone). Memoized on the request."""
    key = getattr(r, "_sim_content_key", None)
    if key is None:
        key = zlib.crc32(",".join(map(str, r.tokens)).encode())
        r._sim_content_key = key
    return key


def sim_token(r: Request, produced: int) -> int:
    """The deterministic simulated token value for ``r``'s ``produced``-th
    output token (1-based). Single source of truth — tests pin streams
    against this exact formula."""
    return (zlib.crc32(f"{_content_key(r)}:{produced}".encode()) & 0x7FFF) + 2


def expected_stream(r: Request) -> list:
    """The full output stream the simulated executor will produce for ``r``
    (EOS replaces the final token when the request carries one)."""
    target = min(sim_output_len(r), r.max_output_tokens)
    toks = [sim_token(r, i) for i in range(1, target + 1)]
    if toks and r.eos_token is not None:
        toks[-1] = r.eos_token
    return toks


class SimulatedExecutor:
    # finish rule is the deterministic sim_output_len clamp — the pipelined
    # engine's finish prediction mirrors it exactly (speculation always hits)
    uses_sim_output_len = True

    def __init__(self, latency_model: BatchLatencyModel,
                 prefix_cache: Optional[PrefixCache] = None, seed: int = 0,
                 straggler_prob: float = 0.0, straggler_slowdown: float = 10.0,
                 hedge_threshold: Optional[float] = None,
                 swap_bandwidth_gbps: float = 32.0,
                 kv_bytes_per_token: int = 819_200):
        self.lm = latency_model
        self.prefix_cache = prefix_cache
        self._rng = random.Random(seed)
        self.total_prefill_tokens = 0
        self.total_uncached_tokens = 0
        self.total_decode_tokens = 0
        # host-tier swap model: moving a request's KV across the PCIe link
        # costs tokens * kv_bytes_per_token / bandwidth seconds, charged to
        # the tick that performs the swap (deterministic — no RNG)
        self.swap_bandwidth_bytes = swap_bandwidth_gbps * 1e9
        self.kv_bytes_per_token = kv_bytes_per_token
        self.swap_busy_s = 0.0          # seconds the channel actually moved bytes
        self.swap_bytes_total = 0.0     # invariant: busy_s * bandwidth == bytes
        # shared-bandwidth budget: one device<->host channel, FIFO. Absolute
        # sim time the channel frees up (prefetch copies queued in earlier
        # ticks keep it busy across tick boundaries), and the per-tick charge
        # ledger (seconds of swap stall this tick's ops billed the engine).
        self._channel_free_at = 0.0
        self._tick_now: Optional[float] = None
        self._tick_charged_s = 0.0
        # req_id -> absolute time its prefetched host->device copy completes
        self._prefetch_done: Dict[str, float] = {}
        self.prefetch_issues = 0
        self.prefetch_hits = 0          # commits whose copy had fully landed
        self.prefetch_cancels = 0
        # straggler-mitigation model: with straggler_prob a batch takes
        # slowdown x nominal; with hedging, a duplicate dispatch to a healthy
        # DP replica bounds the wait at threshold x nominal + nominal.
        self.straggler_prob = straggler_prob
        self.straggler_slowdown = straggler_slowdown
        self.hedge_threshold = hedge_threshold
        self.stragglers_seen = 0
        self.hedges_fired = 0

    def _apply_straggler(self, duration: float) -> float:
        if self.straggler_prob <= 0 or self._rng.random() >= self.straggler_prob:
            return duration
        self.stragglers_seen += 1
        slow = duration * self.straggler_slowdown
        if self.hedge_threshold is not None:
            self.hedges_fired += 1
            return min(slow, duration * self.hedge_threshold + duration)
        return slow

    # ------------------------------------------------------------------
    # KV-tiering swap hooks (engine-drained): the simulated device has no
    # buffers to copy, so a swap is pure modeled transfer time, priced by a
    # shared-bandwidth queue — concurrent ops serialize on one channel, so a
    # tick's k-th swap queues behind the first k-1 and any still-running
    # prefetch copy. With the channel free at tick start this degenerates to
    # the per-op full-bandwidth price (each op charged exactly bytes/budget),
    # bit-identical to the pre-budget model.
    def _horizon(self) -> float:
        """When this tick's already-billed swap stall ends — the point a new
        op's wait is measured from (the engine serializes billed charges)."""
        return (self._tick_now or 0.0) + self._tick_charged_s

    def begin_swap_tick(self, now: float) -> None:
        """Engine hook: called before a tick's swap ops are mirrored. Resets
        the per-tick charge ledger; the channel-free clock persists across
        ticks (a prefetch issued last tick may still occupy the link)."""
        if now != self._tick_now:
            self._tick_now = now
            self._tick_charged_s = 0.0

    def _charge(self, nbytes: float) -> float:
        """Queue a synchronous (engine-blocking) transfer on the channel and
        return the stall it bills this tick: wait-for-channel + transfer.
        Never less than the raw transfer time, never negative."""
        dur = nbytes / self.swap_bandwidth_bytes
        horizon = self._horizon()
        end = max(horizon, self._channel_free_at) + dur
        self._channel_free_at = end
        charge = end - horizon
        self._tick_charged_s += charge
        self.swap_busy_s += dur
        self.swap_bytes_total += nbytes
        return charge

    def swap_out(self, req_id: str, tokens: int) -> float:
        return self._charge(tokens * self.kv_bytes_per_token)

    def swap_in(self, req_id: str, tokens: int) -> float:
        done = self._prefetch_done.pop(req_id, None)
        if done is None:
            return self._charge(tokens * self.kv_bytes_per_token)
        # prefetched commit: the copy was queued (and its bytes accounted)
        # when issued; the commit only bills whatever tail of it hasn't
        # landed yet. A fully-landed copy is a zero-stall resume.
        charge = max(0.0, done - self._horizon())
        if charge == 0.0:
            self.prefetch_hits += 1
        self._tick_charged_s += charge
        return charge

    def prefetch_swap_in(self, req_id: str, tokens: int) -> float:
        """Issue a request's host->device copy ahead of its swap-in commit.
        The copy queues on the shared channel and rides under compute — the
        issuing tick is billed nothing; the commit bills only the un-landed
        tail (usually zero by the time it fires)."""
        if req_id in self._prefetch_done:
            return 0.0
        nbytes = tokens * self.kv_bytes_per_token
        dur = nbytes / self.swap_bandwidth_bytes
        start = max(self._horizon(), self._channel_free_at)
        self._channel_free_at = start + dur
        self._prefetch_done[req_id] = start + dur
        self.prefetch_issues += 1
        self.swap_busy_s += dur
        self.swap_bytes_total += nbytes
        return 0.0

    def cancel_swap_prefetch(self, req_id: str, tokens: int) -> float:
        """Abort a staged prefetch (request cancelled before commit). The
        un-copied remainder is refunded to the channel — bytes that never
        moved must not count as moved — when the copy is still the channel's
        tail; a copy another op already queued behind is sunk cost."""
        done = self._prefetch_done.pop(req_id, None)
        if done is None:
            return 0.0
        self.prefetch_cancels += 1
        dur = tokens * self.kv_bytes_per_token / self.swap_bandwidth_bytes
        if self._channel_free_at == done:
            new_free = max(min(self._horizon(), done), done - dur)
            refund = done - new_free
            self._channel_free_at = new_free
            self.swap_busy_s -= refund
            self.swap_bytes_total -= refund * self.swap_bandwidth_bytes
        return 0.0

    def swap_ledger(self) -> Dict[str, float]:
        """Audit view of the bandwidth budget — tests assert conservation
        (busy seconds x budget == bytes moved; both non-negative)."""
        return {
            "busy_s": self.swap_busy_s,
            "bytes": self.swap_bytes_total,
            "tick_charged_s": self._tick_charged_s,
            "channel_free_at": self._channel_free_at,
            "prefetch_issues": self.prefetch_issues,
            "prefetch_hits": self.prefetch_hits,
            "prefetch_cancels": self.prefetch_cancels,
        }

    # ------------------------------------------------------------------
    def _true_utok(self, r: Request, chunk: int) -> int:
        """Uncached tokens of the ``chunk`` next prompt tokens of ``r`` —
        prefix-cache savings apply to the front of the prompt (for a preempted
        request's restart, the prompt + preserved generation). Only the first
        chunk of a prefill pass probes with stats: one stats-bearing lookup
        per pass keeps hits+misses equal to the prompt tokens actually looked
        up, instead of inflating once per chunk."""
        seq = r.prefill_token_ids()
        if self.prefix_cache is None:
            n_cached = 0
        elif r.prefilled_tokens == 0:
            n_cached = self.prefix_cache.count_cached(seq)
        else:
            n_cached = self.prefix_cache.peek_cached(seq)
        done = r.prefilled_tokens
        return max(0, min(done + chunk, r.prefill_target_tokens)
                   - max(done, n_cached))

    def _token_for(self, r: Request) -> Tuple[int, bool]:
        produced = len(r.output_tokens) + 1
        target = min(sim_output_len(r), r.max_output_tokens)
        finished = produced >= target
        token = sim_token(r, produced)
        if finished and r.eos_token is not None:
            token = r.eos_token
        return token, finished

    # ------------------------------------------------------------------
    def execute(self, batch: Batch, now: float) -> Tuple[float, BatchResult]:
        outputs: Dict[str, Tuple[int, bool]] = {}
        utok = 0
        for r in batch.prefill_requests:
            chunk = batch.chunk_of(r)
            utok += self._true_utok(r, chunk)
            self.total_prefill_tokens += chunk
            if batch.completes_prompt(r):
                if self.prefix_cache is not None:
                    # only the *prompt* enters the prefix cache: generated
                    # tokens are never prefix-cached, the invariant the utok
                    # estimator and PEM's re-prefill pricing rely on
                    self.prefix_cache.insert(r.tokens)
                outputs[r.req_id] = self._token_for(r)
        for r in batch.decode_requests:
            outputs[r.req_id] = self._token_for(r)
        self.total_uncached_tokens += utok
        self.total_decode_tokens += len(batch.decode_requests)
        dur = self._apply_straggler(batch.cost(self.lm, true_uncached=utok))
        return dur, BatchResult(outputs, uncached_tokens=utok if
                                batch.prefill_requests else None)

    # ------------------------------------------------------------------
    # Split dispatch/wait contract (pipelined engine loop): the simulated
    # clock has no device to overlap with, so ``dispatch`` computes the whole
    # batch synchronously and ``wait`` just hands the result back. Durations
    # are model-computed either way, so pipelined simulated runs stay
    # bit-identical to serial ones while still exercising the engine's
    # speculate/reconcile machinery.
    def dispatch(self, batch: Batch, now: float) -> Tuple[float, BatchResult]:
        return self.execute(batch, now)

    def wait(self, inflight: Tuple[float, BatchResult]) -> Tuple[float, BatchResult]:
        return inflight
