"""Serving engine (Fig. 6), split into a steppable per-replica core.

``EngineCore`` owns one scheduler + one executor and exposes
``admit(rq, now)`` / ``tick(now) -> BatchEvent | None`` — the *caller* owns the
clock, which is what lets ``repro.serving.Cluster`` drive N replicas on one
simulated timeline (and what a real async serving loop would do with
wall-clock time). ``ServingEngine`` is the single-replica convenience wrapper
that replays a whole arrival trace.

Works with either the simulated-clock executor (paper-scale traces) or the
real JAX executor (smoke-scale models). One tick = one scheduled batch.

Two engine loops share the tick interface (``engine_loop=`` selects one):

- ``serial`` — schedule, execute, complete: the device idles while Python
  picks the next batch.
- ``pipelined`` — the executor contract is split into ``dispatch``/``wait``;
  after dispatching batch N the engine *speculates*: it checkpoints the
  scheduler, applies N's predicted completion to the ledgers, schedules batch
  N+1 against the projection and pre-stages its prefill shape buckets, all
  while N runs on device. When ``wait`` lands, a matching prediction commits
  (placeholder tokens/timestamps patched with real values) and N+1 dispatches
  immediately next tick; a mismatch — or any admit/cancel/report between
  ticks — rolls the scheduler back and replays the real completion, so every
  externally observable state (token streams, simulated-clock reports, ledger
  invariants) is bit-identical to the serial loop.
"""
from __future__ import annotations

import time as _time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.batch import Batch
from repro.core.relquery import RelQuery, Request
from repro.core.scheduler import BatchResult, SchedulerBase

ENGINE_LOOPS = ("serial", "pipelined")

# Speculation placeholders: the projected completion of an in-flight batch
# appends _SPEC_TOKEN for every predicted output and stamps _SPEC_END as the
# batch end time; both are patched with real values at commit and can never
# leak (any read between ticks flushes the window first).
_SPEC_TOKEN = -1
_SPEC_END = float("-inf")


@dataclass
class BatchEvent:
    kind: str
    start: float
    end: float
    num_requests: int
    uncached_tokens: int
    rel_ids: Tuple[str, ...]
    replica: int = 0


class EngineDeadlockError(RuntimeError):
    """The scheduler still has work but can never make progress (e.g. a
    request that does not fit under the KV cap with nothing left running)."""

    def __init__(self, tokens_in_use: int, cap: int, stuck_rel_ids: Sequence[str],
                 replica: int = 0):
        self.tokens_in_use = tokens_in_use
        self.cap = cap
        self.stuck_rel_ids = list(stuck_rel_ids)
        self.replica = replica
        super().__init__(
            f"engine deadlock on replica {replica}: scheduler has work but no "
            f"batch is schedulable (tokens_in_use={tokens_in_use}, "
            f"cap={cap}, stuck relQueries={self.stuck_rel_ids})")


@dataclass
class ServiceReport:
    latencies: Dict[str, float]
    waiting: Dict[str, float]
    core: Dict[str, float]
    tail: Dict[str, float]
    events: List[BatchEvent]
    end_to_end: float
    dpu_time: float = 0.0
    aba_time: float = 0.0
    prefix_hit_ratio: float = 0.0
    prefix_lookup_tokens: int = 0   # hits + misses behind prefix_hit_ratio
    schedule_time: float = 0.0
    # scheduling-overhead split: first-try scheduling vs deadlock-retry
    # rounds, plus the wall-clock the pipelined loop hid behind device compute
    # (checkpoint + projection + speculative schedule + prestage)
    schedule_retry_time: float = 0.0
    overlap_hidden_time: float = 0.0
    schedule_retries: int = 0
    cancelled_rel_ids: List[str] = field(default_factory=list)
    # KV-pressure subsystem: preempt/restart cycles under optimistic admission
    preemptions: int = 0
    preempted_tokens: int = 0
    missing_decode_outputs: int = 0
    # prefix-sharing subsystem: cumulative cap tokens the shared-block
    # admission ledger discounted (0 with prefix sharing off)
    shared_kv_tokens: int = 0
    # planner subsystem: logical rows answered by dedup fan-out instead of
    # execution, and planner wall-clock (stamped by PlanExecutor.snapshot)
    deduped_requests: int = 0
    plan_time: float = 0.0
    # KV-tiering subsystem: device<->host swap traffic and the cost model's
    # per-victim reclaim decisions (all zero with tiering off)
    swap_outs: int = 0
    swap_ins: int = 0
    swapped_out_tokens: int = 0
    swapped_in_tokens: int = 0
    swap_bytes_moved: int = 0
    reclaim_swap_decisions: int = 0
    reclaim_recompute_decisions: int = 0
    # proactive-tiering subsystem: idle-tail offloads ahead of pressure,
    # prefetched swap-ins (and how many committed with the copy fully
    # landed), and prefetches aborted by cancellation
    proactive_offloads: int = 0
    swap_prefetches: int = 0
    prefetch_hits: int = 0
    prefetch_cancelled: int = 0

    @property
    def avg_latency(self) -> float:
        return float(np.mean(list(self.latencies.values()))) if self.latencies else 0.0

    @property
    def max_latency(self) -> float:
        return float(np.max(list(self.latencies.values()))) if self.latencies else 0.0

    def percentile(self, p: float) -> float:
        return float(np.percentile(list(self.latencies.values()), p)) if self.latencies else 0.0

    def phase_means(self) -> Tuple[float, float, float]:
        def m(d):
            vals = [v for v in d.values() if v is not None]
            return float(np.mean(vals)) if vals else 0.0
        return m(self.waiting), m(self.core), m(self.tail)


def merge_reports(reports: Sequence[ServiceReport]) -> ServiceReport:
    """Fleet view: union the per-replica relQuery metrics, global end-to-end."""
    merged = ServiceReport(latencies={}, waiting={}, core={}, tail={},
                           events=[], end_to_end=0.0)
    hit_tokens = 0.0
    for rep in reports:
        merged.latencies.update(rep.latencies)
        merged.waiting.update(rep.waiting)
        merged.core.update(rep.core)
        merged.tail.update(rep.tail)
        merged.events.extend(rep.events)
        merged.end_to_end = max(merged.end_to_end, rep.end_to_end)
        merged.dpu_time += rep.dpu_time
        merged.aba_time += rep.aba_time
        merged.schedule_time += rep.schedule_time
        merged.schedule_retry_time += rep.schedule_retry_time
        merged.overlap_hidden_time += rep.overlap_hidden_time
        merged.schedule_retries += rep.schedule_retries
        # hit ratio is a per-token quantity: weight by lookup volume
        merged.prefix_lookup_tokens += rep.prefix_lookup_tokens
        hit_tokens += rep.prefix_hit_ratio * rep.prefix_lookup_tokens
        merged.cancelled_rel_ids.extend(rep.cancelled_rel_ids)
        merged.preemptions += rep.preemptions
        merged.preempted_tokens += rep.preempted_tokens
        merged.missing_decode_outputs += rep.missing_decode_outputs
        merged.shared_kv_tokens += rep.shared_kv_tokens
        merged.deduped_requests += rep.deduped_requests
        merged.plan_time += rep.plan_time
        merged.swap_outs += rep.swap_outs
        merged.swap_ins += rep.swap_ins
        merged.swapped_out_tokens += rep.swapped_out_tokens
        merged.swapped_in_tokens += rep.swapped_in_tokens
        merged.swap_bytes_moved += rep.swap_bytes_moved
        merged.reclaim_swap_decisions += rep.reclaim_swap_decisions
        merged.reclaim_recompute_decisions += rep.reclaim_recompute_decisions
        merged.proactive_offloads += rep.proactive_offloads
        merged.swap_prefetches += rep.swap_prefetches
        merged.prefetch_hits += rep.prefetch_hits
        merged.prefetch_cancelled += rep.prefetch_cancelled
    merged.events.sort(key=lambda e: (e.start, e.replica))
    merged.cancelled_rel_ids.sort()
    merged.prefix_hit_ratio = (hit_tokens / merged.prefix_lookup_tokens
                               if merged.prefix_lookup_tokens else 0.0)
    return merged


class EngineCore:
    """One serving replica: scheduler + executor behind a step interface."""

    def __init__(self, scheduler: SchedulerBase, executor, replica_id: int = 0,
                 record_events: bool = True, engine_loop: str = "serial",
                 debug_invariants: bool = False):
        if engine_loop not in ENGINE_LOOPS:
            raise ValueError(f"engine_loop must be one of {ENGINE_LOOPS} "
                             f"(got {engine_loop!r})")
        if engine_loop == "pipelined" and not hasattr(executor, "dispatch"):
            raise ValueError("engine_loop='pipelined' requires an executor "
                             "with the split dispatch/wait contract")
        self.scheduler = scheduler
        self.executor = executor
        self.replica_id = replica_id
        self.record_events = record_events
        self.engine_loop = engine_loop
        # per-tick ledger/block-pool consistency checks (off by default —
        # O(resident blocks) per tick; benchmarks turn it on under --smoke)
        self.debug_invariants = debug_invariants
        # finish-prediction rule for the speculative window: the simulated
        # executor terminates at the trace's sim_output_len; real executors
        # run to max_output_tokens unless a sampled EOS lands (unpredictable
        # — that path simply costs a rollback)
        self._predict_sim_len = bool(getattr(executor,
                                             "uses_sim_output_len", False))
        self.events: List[BatchEvent] = []
        self.schedule_time = 0.0
        self.schedule_retry_time = 0.0
        self.overlap_hidden_time = 0.0
        self.schedule_retries = 0
        self.iterations = 0
        # pipelined-loop speculative window (one batch deep): the pre-planned
        # next batch, the pre-projection checkpoint, the in-flight batch it
        # projected, and that batch's real (result, start, end) for flush
        self._plan: Optional[Batch] = None
        self._plan_cp: Optional[dict] = None
        self._plan_batch: Optional[Batch] = None
        self._plan_real: Optional[Tuple[BatchResult, float, float]] = None
        # Batch-completion listener (event, batch, result) — the open-loop
        # Frontend subscribes here to stream tokens and observe completions.
        self.on_batch: Optional[
            Callable[[BatchEvent, Batch, BatchResult], None]] = None

    # ------------------------------------------------------------------ steps
    def admit(self, rq: RelQuery, now: float) -> None:
        """Admit a relQuery. Executors exposing ``validate_relquery`` (the
        real backends) get to reject requests that can never fit their
        per-sequence KV capacity *before* the scheduler sees them — a
        too-long request used to overflow the dense slot buffer silently
        mid-decode instead of failing here with a clear error."""
        self._flush_plan()   # the pre-planned batch ignored this arrival
        validate = getattr(self.executor, "validate_relquery", None)
        if validate is not None:
            validate(rq)
        self.scheduler.add_relquery(rq, now)

    def has_work(self) -> bool:
        return self.scheduler.has_work()

    def load(self) -> int:
        """Outstanding requests (waiting + running) — the router's load signal."""
        return self.scheduler.queue_depth()

    def tick(self, now: float) -> Optional[BatchEvent]:
        """Schedule + execute one batch at clock ``now``. Returns ``None`` when
        the replica is idle (nothing admitted and unfinished). Under optimistic
        KV admission a stalled scheduler is first asked to preempt
        lowest-priority running relQueries and retry; ``EngineDeadlockError``
        is reserved for work that can never be scheduled no matter what is
        evicted (a single request that does not fit under the cap)."""
        if self.engine_loop == "pipelined":
            return self._tick_pipelined(now)
        return self._tick_serial(now)

    def _tick_serial(self, now: float) -> Optional[BatchEvent]:
        batch = self._acquire_batch(now)
        if batch is None:
            return None
        swap_s = self._apply_swaps(now)
        duration, result = self.executor.execute(batch, now)
        start, end = now, now + duration + swap_s
        self.scheduler.complete_batch(batch, result, start, end)
        return self._finish_tick(batch, result, start, end)

    def _tick_pipelined(self, now: float) -> Optional[BatchEvent]:
        """Dispatch → speculate → wait → reconcile. The speculative window is
        exactly one batch deep: while the dispatched batch runs on device, its
        completion is projected onto the scheduler and the *next* batch is
        planned against the projection (the plan is consumed — or flushed — at
        the next tick). Every ledger mutation of the window sits behind a
        checkpoint, so reconcile on a misprediction is an exact rewind plus a
        replay with the device's real result."""
        if self._plan_cp is not None:
            # The previous window predicted correctly: its plan is the batch
            # to run, the window commits permanently, and executor slots of
            # any requests the speculative schedule preempted are freed now —
            # the same release-before-next-dispatch order as the serial loop.
            batch = self._take_plan()
            self._release_preempted()
            if batch is None:
                return None   # speculated idle (queue drained by that batch)
        else:
            batch = self._acquire_batch(now)
            if batch is None:
                return None
        # swaps the schedule decided on (speculative ones included — a
        # committed plan's journal survived, a flushed plan's was rolled
        # back) land on the device before the batch that relies on them
        swap_s = self._apply_swaps(now)
        inflight = self.executor.dispatch(batch, now)
        spec = self._speculate(batch, now)
        duration, result = self.executor.wait(inflight)
        start, end = now, now + duration + swap_s
        if spec is not None and self._prediction_matches(spec["predicted"],
                                                         result):
            self._commit_speculation(spec, batch, result, start, end)
        else:
            if spec is not None:
                self.scheduler.rollback(spec["cp"])
            self.scheduler.complete_batch(batch, result, start, end)
        return self._finish_tick(batch, result, start, end)

    def _acquire_batch(self, now: float) -> Optional[Batch]:
        """Schedule with the deadlock-escape retry loop (non-speculative)."""
        batch, deadlocked = self._retry_schedule(now)
        if deadlocked:
            # Nothing left to evict — admitting more work, advancing the
            # clock or reclaiming KV cannot help.
            raise EngineDeadlockError(self.scheduler.tokens_in_use,
                                      self.scheduler.limits.cap,
                                      self.scheduler.stuck_rel_ids(),
                                      self.replica_id)
        return batch

    def _retry_schedule(self, now: float) -> Tuple[Optional[Batch], bool]:
        """Schedule; while nothing is schedulable but work remains, preempt a
        *round* of victims and retry. Returns (batch, deadlocked)."""
        batch = self._schedule(now)
        while batch is None and self.scheduler.has_work():
            if not self.scheduler.preempt_for_progress(now):
                return None, True
            self.schedule_retries += 1
            batch = self._schedule(now, retry=True)
        return batch, False

    def _apply_swaps(self, now: float = 0.0) -> float:
        """Mirror the scheduler's swap decisions onto the executor *before*
        the next dispatch: a swap-out must free device KV before the batch
        that was admitted into that headroom runs, a swap-in must restore it
        before the request decodes, and a prefetch stages the copy early so
        the later swap-in commit finds it landed (prefetch_cancel undoes a
        staging whose request was cancelled first). Returns the seconds of
        swap transfer the executor charges to this tick (0.0 for real
        executors, which overlap the copies with dispatch/wait; the simulated
        executor prices a shared-bandwidth channel)."""
        ops = self.scheduler.drain_swap_ops()
        if not ops:
            return 0.0
        begin = getattr(self.executor, "begin_swap_tick", None)
        if begin is not None:
            begin(now)
        hooks = {
            "out": getattr(self.executor, "swap_out", None),
            "in": getattr(self.executor, "swap_in", None),
            "prefetch": getattr(self.executor, "prefetch_swap_in", None),
            "prefetch_cancel": getattr(self.executor,
                                       "cancel_swap_prefetch", None),
        }
        swap_s = 0.0
        for kind, req_id, tokens in ops:
            hook = hooks[kind]
            if hook is not None:
                swap_s += hook(req_id, tokens)
        return swap_s

    def _check_invariants(self) -> None:
        """Per-tick consistency sweep (``debug_invariants``): scheduler token
        ledgers stay non-negative and within cap-accounting bounds, the
        shared-prefix ledger's discount matches its refcounts, and any real
        block pool conserves device+host blocks exactly."""
        s = self.scheduler
        assert s.tokens_in_use >= 0, f"tokens_in_use={s.tokens_in_use}"
        assert s.committed_tokens >= 0, f"committed_tokens={s.committed_tokens}"
        assert s.partial_prefill_tokens >= 0
        if hasattr(s, "audit_ledgers"):
            # every incremental ledger must equal its queue-derived value —
            # the same derivation restore_scheduler rebuilds from
            s.audit_ledgers(repair=False)
        host = getattr(s, "host_tokens_in_use", 0)
        assert host >= 0, f"host_tokens_in_use={host}"
        cap = getattr(s, "host_kv_cap", 0)
        if getattr(s, "kv_tiering", False):
            assert host <= cap, f"host tier over cap: {host} > {cap}"
        ledger = getattr(s, "_shared_ledger", None)
        if ledger is not None:
            ledger.check_invariants()
        bm = getattr(self.executor, "bm", None)
        if bm is not None:
            bm.check_invariants()

    def _finish_tick(self, batch: Batch, result: BatchResult, start: float,
                     end: float) -> BatchEvent:
        if self.debug_invariants:
            self._check_invariants()
        self.iterations += 1
        event = BatchEvent(batch.kind, start, end, batch.num_requests,
                           batch.uncached_tokens, batch.rel_ids(),
                           self.replica_id)
        if self.record_events:
            self.events.append(event)
        if self.on_batch is not None:
            self.on_batch(event, batch, result)
        return event

    def _schedule(self, now: float, retry: bool = False) -> Optional[Batch]:
        """One timed scheduler call, then free executor slots of any requests
        the scheduler preempted while choosing (headroom or retry preemption
        both funnel through ``drain_preempt_releases``)."""
        t0 = _time.perf_counter()
        batch = self.scheduler.schedule(now)
        dt = _time.perf_counter() - t0
        if retry:
            self.schedule_retry_time += dt
        else:
            self.schedule_time += dt
        self._release_preempted()
        return batch

    # ------------------------------------------------------- speculative window
    def _can_speculate(self) -> bool:
        """Speculative scheduling runs at the in-flight batch's *start* time.
        No policy's batch choice reads the clock — except the DPU starvation
        promotion (Eq. 13), which compares waiting time against ``now`` — so
        speculation is decision-identical exactly when starvation prevention
        is off."""
        dpu = getattr(self.scheduler, "dpu", None)
        return dpu is None or dpu.cfg.starvation_threshold is None

    def _predict_result(self, batch: Batch) -> BatchResult:
        """Predicted completion of ``batch``: which requests emit a token and
        whether they finish. Token *values* are placeholders — nothing reads
        them before commit patches in the real ones. Finish prediction mirrors
        the simulated executor's length rule exactly (bit-identical simulated
        runs); real executors additionally finish on sampled EOS, which simply
        lands in the mismatch → rollback path."""
        outputs: Dict[str, Tuple[int, bool]] = {}
        for r in batch.prefill_requests:
            if batch.completes_prompt(r):
                outputs[r.req_id] = (_SPEC_TOKEN, self._predict_finished(r))
        for r in batch.decode_requests:
            outputs[r.req_id] = (_SPEC_TOKEN, self._predict_finished(r))
        return BatchResult(outputs)

    def _predict_finished(self, r: Request) -> bool:
        produced = len(r.output_tokens) + 1
        target = r.max_output_tokens
        if self._predict_sim_len:
            sim = getattr(r, "sim_output_len", None) or target
            target = min(sim, target)
        return produced >= target

    @staticmethod
    def _prediction_matches(predicted: BatchResult, real: BatchResult) -> bool:
        if predicted.outputs.keys() != real.outputs.keys():
            return False
        return all(predicted.outputs[k][1] == real.outputs[k][1]
                   for k in real.outputs)

    def _speculate(self, batch: Batch, now: float) -> Optional[dict]:
        """While ``batch`` runs on device: checkpoint, project its predicted
        completion onto the ledgers, schedule the next batch against the
        projection (with the same deadlock-retry loop, except a genuine
        deadlock rolls back and defers to the next real tick instead of
        raising), and pre-stage the plan's prefill shape buckets. Executor
        slot releases for speculatively preempted victims are deferred until
        the plan is actually dispatched — device state is not rewindable.
        Returns the window dict, or None when speculation is off/unsafe."""
        if not self._can_speculate():
            return None
        sched = self.scheduler
        t_start = _time.perf_counter()
        cp = sched.checkpoint(batch)
        predicted = self._predict_result(batch)
        sched.complete_batch(batch, predicted, now, _SPEC_END)
        patches = [(r, len(r.output_tokens) - 1)
                   for r in (*batch.prefill_requests, *batch.decode_requests)
                   if r.req_id in predicted.outputs]
        t0 = _time.perf_counter()
        plan = sched.schedule(now)
        sched_s = _time.perf_counter() - t0
        retry_s, retries = 0.0, 0
        while plan is None and sched.has_work():
            t0 = _time.perf_counter()
            if not sched.preempt_for_progress(now):
                sched.rollback(cp)
                return None   # genuine deadlock: surface it un-speculated
            retries += 1
            plan = sched.schedule(now)
            retry_s += _time.perf_counter() - t0
        prestage = getattr(self.executor, "prestage", None)
        if plan is not None and prestage is not None:
            prestage(plan)
        return {"cp": cp, "predicted": predicted, "patches": patches,
                "plan": plan, "sched_s": sched_s, "retry_s": retry_s,
                "retries": retries,
                "spec_s": _time.perf_counter() - t_start}

    def _commit_speculation(self, spec: dict, batch: Batch,
                            result: BatchResult, start: float,
                            end: float) -> None:
        """The device agreed with the projection: patch placeholder tokens and
        timestamps with the real values and adopt the planned next batch. The
        checkpoint (and its op journal) stays open until the plan is consumed
        or flushed — an admit/cancel/snapshot between ticks still needs the
        exact rewind."""
        for r, idx in spec["patches"]:
            r.output_tokens[idx] = result.outputs[r.req_id][0]
        rqs = {}
        for r in (*batch.prefill_requests, *batch.decode_requests):
            if r.finish_time == _SPEC_END:
                r.finish_time = end
            rqs[r.rel_id] = self.scheduler.relqueries[r.rel_id]
        for rq in rqs.values():
            if rq.last_prefill_end == _SPEC_END:
                rq.last_prefill_end = end
            if rq.finish_time == _SPEC_END:
                rq.finish_time = end
        self.schedule_time += spec["sched_s"]
        self.schedule_retry_time += spec["retry_s"]
        self.schedule_retries += spec["retries"]
        self.overlap_hidden_time += spec["spec_s"]
        self._plan = spec["plan"]
        self._plan_cp = spec["cp"]
        self._plan_batch = batch
        self._plan_real = (result, start, end)

    def _take_plan(self) -> Optional[Batch]:
        """Consume the pre-planned batch, committing the previous window for
        good (the journal closes; no rewind past this point)."""
        plan = self._plan
        self.scheduler.discard_checkpoint()
        self._drop_plan_state()
        return plan

    def _flush_plan(self) -> None:
        """Un-speculate: rewind to the pre-projection checkpoint and replay
        the in-flight batch's *real* completion, leaving exactly the state
        the serial loop would have between ticks. Called before any
        between-tick interaction the plan could not have seen — admit,
        cancel, report/snapshot."""
        if self._plan_cp is None:
            return
        result, start, end = self._plan_real
        batch = self._plan_batch
        self.scheduler.rollback(self._plan_cp)
        self._drop_plan_state()
        self.scheduler.complete_batch(batch, result, start, end)

    def _drop_plan_state(self) -> None:
        self._plan = None
        self._plan_cp = None
        self._plan_batch = None
        self._plan_real = None

    def _release_preempted(self) -> None:
        release = getattr(self.executor, "release_request", None)
        for req_id in self.scheduler.drain_preempt_releases():
            if release is not None:
                release(req_id)

    def cancel_relquery(self, rel_id: str, now: float) -> List[Request]:
        """Cancel a relQuery between ticks: evict its queued/running requests
        from the scheduler (reclaiming ``tokens_in_use``/``committed_tokens``)
        and release any executor-side state (decode slots) they hold. Returns
        the evicted requests; [] if the relQuery is unknown or terminal."""
        self._flush_plan()   # the pre-planned batch may contain the victim
        cancelled = self.scheduler.cancel_relquery(rel_id, now)
        release = getattr(self.executor, "release_request", None)
        if release is not None:
            for r in cancelled:
                release(r.req_id)
        return cancelled

    # ------------------------------------------------------------------ report
    def report(self, end_time: float) -> ServiceReport:
        """Service metrics as of ``end_time``. Safe to call mid-flight (the
        Frontend's ``snapshot()``): unfinished relQueries simply have no
        latency entry yet. Cancelled relQueries are excluded from every
        latency statistic and listed in ``cancelled_rel_ids``."""
        self._flush_plan()   # mid-flight views must not see speculative state
        all_rqs = list(self.scheduler.relqueries.values())
        cancelled = [rq.rel_id for rq in all_rqs if rq.cancelled]
        rqs = [rq for rq in all_rqs if not rq.cancelled]
        lat = {rq.rel_id: rq.latency() for rq in rqs if rq.latency() is not None}
        waiting = {rq.rel_id: rq.waiting_time() for rq in rqs}
        core = {rq.rel_id: rq.core_running_time() for rq in rqs}
        tail = {rq.rel_id: rq.tail_running_time() for rq in rqs}
        pc = getattr(self.scheduler, "prefix_cache", None)
        return ServiceReport(
            latencies=lat, waiting=waiting, core=core, tail=tail,
            events=self.events, end_to_end=end_time,
            dpu_time=getattr(self.scheduler, "dpu_time", 0.0),
            aba_time=getattr(self.scheduler, "aba_time", 0.0),
            prefix_hit_ratio=pc.hit_ratio if pc is not None else 0.0,
            prefix_lookup_tokens=(getattr(pc, "hits", 0) + getattr(pc, "misses", 0)
                                  if pc is not None else 0),
            schedule_time=self.schedule_time,
            schedule_retry_time=self.schedule_retry_time,
            overlap_hidden_time=self.overlap_hidden_time,
            schedule_retries=self.schedule_retries,
            cancelled_rel_ids=cancelled,
            preemptions=getattr(self.scheduler, "preemptions", 0),
            preempted_tokens=getattr(self.scheduler, "preempted_tokens", 0),
            missing_decode_outputs=getattr(self.scheduler,
                                           "missing_decode_outputs", 0),
            shared_kv_tokens=getattr(self.scheduler, "shared_tokens_saved", 0),
            swap_outs=getattr(self.scheduler, "swap_outs", 0),
            swap_ins=getattr(self.scheduler, "swap_ins", 0),
            swapped_out_tokens=getattr(self.scheduler, "swapped_out_tokens", 0),
            swapped_in_tokens=getattr(self.scheduler, "swapped_in_tokens", 0),
            swap_bytes_moved=getattr(self.scheduler, "swap_bytes_moved", 0),
            reclaim_swap_decisions=getattr(self.scheduler,
                                           "reclaim_swap_decisions", 0),
            reclaim_recompute_decisions=getattr(self.scheduler,
                                                "reclaim_recompute_decisions",
                                                0),
            proactive_offloads=getattr(self.scheduler,
                                       "proactive_offloads", 0),
            swap_prefetches=getattr(self.scheduler, "swap_prefetches", 0),
            prefetch_hits=getattr(self.executor, "prefetch_hits", 0),
            prefetch_cancelled=getattr(self.scheduler,
                                       "prefetch_cancelled", 0),
        )


class ServingEngine:
    """Single-replica trace driver built on ``EngineCore``."""

    def __init__(self, scheduler: SchedulerBase, executor,
                 engine_loop: str = "serial", debug_invariants: bool = False):
        self.core = EngineCore(scheduler, executor, engine_loop=engine_loop,
                               debug_invariants=debug_invariants)

    @property
    def scheduler(self) -> SchedulerBase:
        return self.core.scheduler

    @property
    def executor(self):
        return self.core.executor

    @property
    def events(self) -> List[BatchEvent]:
        return self.core.events

    @property
    def schedule_time(self) -> float:
        return self.core.schedule_time

    def run_trace(self, trace: Sequence[RelQuery], max_iterations: int = 2_000_000,
                  record_events: bool = True) -> ServiceReport:
        """Replay a full arrival trace on the simulated clock.

        .. deprecated:: closed-loop compatibility shim. The open-loop
           ``repro.serving.Frontend`` (submit / stream / cancel / snapshot) is
           the serving API; this method is now a thin trace-replay driver over
           it and produces the identical ``ServiceReport``.
        """
        from repro.serving.frontend import Frontend

        self.core.record_events = record_events
        fe = Frontend(self.core)
        try:
            fe.replay(trace, max_iterations=max_iterations)
        finally:
            fe.close()
        return self.core.report(fe.clock)
