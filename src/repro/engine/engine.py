"""Serving engine: arrival handling + scheduler + executor loop (Fig. 6).

Works with either the simulated-clock executor (paper-scale traces) or the
real JAX executor (smoke-scale models). One iteration = one scheduled batch.
"""
from __future__ import annotations

import time as _time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.relquery import RelQuery
from repro.core.scheduler import SchedulerBase, ScheduledBatch


@dataclass
class BatchEvent:
    kind: str
    start: float
    end: float
    num_requests: int
    uncached_tokens: int
    rel_ids: Tuple[str, ...]


@dataclass
class ServiceReport:
    latencies: Dict[str, float]
    waiting: Dict[str, float]
    core: Dict[str, float]
    tail: Dict[str, float]
    events: List[BatchEvent]
    end_to_end: float
    dpu_time: float = 0.0
    aba_time: float = 0.0
    prefix_hit_ratio: float = 0.0
    schedule_time: float = 0.0

    @property
    def avg_latency(self) -> float:
        return float(np.mean(list(self.latencies.values()))) if self.latencies else 0.0

    @property
    def max_latency(self) -> float:
        return float(np.max(list(self.latencies.values()))) if self.latencies else 0.0

    def percentile(self, p: float) -> float:
        return float(np.percentile(list(self.latencies.values()), p)) if self.latencies else 0.0

    def phase_means(self) -> Tuple[float, float, float]:
        def m(d):
            vals = [v for v in d.values() if v is not None]
            return float(np.mean(vals)) if vals else 0.0
        return m(self.waiting), m(self.core), m(self.tail)


class ServingEngine:
    def __init__(self, scheduler: SchedulerBase, executor):
        self.scheduler = scheduler
        self.executor = executor
        self.events: List[BatchEvent] = []
        self.schedule_time = 0.0

    def run_trace(self, trace: Sequence[RelQuery], max_iterations: int = 2_000_000,
                  record_events: bool = True) -> ServiceReport:
        """Run a full arrival trace on the simulated clock."""
        pending = sorted(trace, key=lambda r: r.arrival_time)
        now = 0.0
        it = 0
        idx = 0
        while idx < len(pending) or self.scheduler.has_work():
            # admit arrivals up to the current clock
            while idx < len(pending) and pending[idx].arrival_time <= now:
                self.scheduler.add_relquery(pending[idx], now)
                idx += 1
            t0 = _time.perf_counter()
            batch = self.scheduler.schedule(now)
            self.schedule_time += _time.perf_counter() - t0
            if batch is None:
                if idx < len(pending):
                    now = max(now, pending[idx].arrival_time)
                    continue
                break
            duration, result = self.executor.execute(batch, now)
            start, end = now, now + duration
            self.scheduler.complete_batch(batch, result, start, end)
            now = end
            if record_events:
                rel_ids = tuple({r.rel_id for r in batch.requests}
                                | {r.rel_id for r in batch.decode_requests})
                self.events.append(BatchEvent(batch.kind, start, end,
                                              batch.num_requests,
                                              batch.uncached_tokens, rel_ids))
            it += 1
            if it >= max_iterations:
                raise RuntimeError("engine exceeded max_iterations — likely livelock")
        return self._report(now)

    def _report(self, end_time: float) -> ServiceReport:
        rqs = list(self.scheduler.relqueries.values())
        lat = {rq.rel_id: rq.latency() for rq in rqs if rq.latency() is not None}
        waiting = {rq.rel_id: rq.waiting_time() for rq in rqs}
        core = {rq.rel_id: rq.core_running_time() for rq in rqs}
        tail = {rq.rel_id: rq.tail_running_time() for rq in rqs}
        pc = getattr(self.scheduler, "prefix_cache", None)
        return ServiceReport(
            latencies=lat, waiting=waiting, core=core, tail=tail,
            events=self.events, end_to_end=end_time,
            dpu_time=getattr(self.scheduler, "dpu_time", 0.0),
            aba_time=getattr(self.scheduler, "aba_time", 0.0),
            prefix_hit_ratio=pc.hit_ratio if pc is not None else 0.0,
            schedule_time=self.schedule_time,
        )
