"""Serving engine (Fig. 6), split into a steppable per-replica core.

``EngineCore`` owns one scheduler + one executor and exposes
``admit(rq, now)`` / ``tick(now) -> BatchEvent | None`` — the *caller* owns the
clock, which is what lets ``repro.serving.Cluster`` drive N replicas on one
simulated timeline (and what a real async serving loop would do with
wall-clock time). ``ServingEngine`` is the single-replica convenience wrapper
that replays a whole arrival trace.

Works with either the simulated-clock executor (paper-scale traces) or the
real JAX executor (smoke-scale models). One tick = one scheduled batch.
"""
from __future__ import annotations

import time as _time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.batch import Batch
from repro.core.relquery import RelQuery, Request
from repro.core.scheduler import BatchResult, SchedulerBase


@dataclass
class BatchEvent:
    kind: str
    start: float
    end: float
    num_requests: int
    uncached_tokens: int
    rel_ids: Tuple[str, ...]
    replica: int = 0


class EngineDeadlockError(RuntimeError):
    """The scheduler still has work but can never make progress (e.g. a
    request that does not fit under the KV cap with nothing left running)."""

    def __init__(self, tokens_in_use: int, cap: int, stuck_rel_ids: Sequence[str],
                 replica: int = 0):
        self.tokens_in_use = tokens_in_use
        self.cap = cap
        self.stuck_rel_ids = list(stuck_rel_ids)
        self.replica = replica
        super().__init__(
            f"engine deadlock on replica {replica}: scheduler has work but no "
            f"batch is schedulable (tokens_in_use={tokens_in_use}, "
            f"cap={cap}, stuck relQueries={self.stuck_rel_ids})")


@dataclass
class ServiceReport:
    latencies: Dict[str, float]
    waiting: Dict[str, float]
    core: Dict[str, float]
    tail: Dict[str, float]
    events: List[BatchEvent]
    end_to_end: float
    dpu_time: float = 0.0
    aba_time: float = 0.0
    prefix_hit_ratio: float = 0.0
    prefix_lookup_tokens: int = 0   # hits + misses behind prefix_hit_ratio
    schedule_time: float = 0.0
    cancelled_rel_ids: List[str] = field(default_factory=list)
    # KV-pressure subsystem: preempt/restart cycles under optimistic admission
    preemptions: int = 0
    preempted_tokens: int = 0
    missing_decode_outputs: int = 0
    # prefix-sharing subsystem: cumulative cap tokens the shared-block
    # admission ledger discounted (0 with prefix sharing off)
    shared_kv_tokens: int = 0

    @property
    def avg_latency(self) -> float:
        return float(np.mean(list(self.latencies.values()))) if self.latencies else 0.0

    @property
    def max_latency(self) -> float:
        return float(np.max(list(self.latencies.values()))) if self.latencies else 0.0

    def percentile(self, p: float) -> float:
        return float(np.percentile(list(self.latencies.values()), p)) if self.latencies else 0.0

    def phase_means(self) -> Tuple[float, float, float]:
        def m(d):
            vals = [v for v in d.values() if v is not None]
            return float(np.mean(vals)) if vals else 0.0
        return m(self.waiting), m(self.core), m(self.tail)


def merge_reports(reports: Sequence[ServiceReport]) -> ServiceReport:
    """Fleet view: union the per-replica relQuery metrics, global end-to-end."""
    merged = ServiceReport(latencies={}, waiting={}, core={}, tail={},
                           events=[], end_to_end=0.0)
    hit_tokens = 0.0
    for rep in reports:
        merged.latencies.update(rep.latencies)
        merged.waiting.update(rep.waiting)
        merged.core.update(rep.core)
        merged.tail.update(rep.tail)
        merged.events.extend(rep.events)
        merged.end_to_end = max(merged.end_to_end, rep.end_to_end)
        merged.dpu_time += rep.dpu_time
        merged.aba_time += rep.aba_time
        merged.schedule_time += rep.schedule_time
        # hit ratio is a per-token quantity: weight by lookup volume
        merged.prefix_lookup_tokens += rep.prefix_lookup_tokens
        hit_tokens += rep.prefix_hit_ratio * rep.prefix_lookup_tokens
        merged.cancelled_rel_ids.extend(rep.cancelled_rel_ids)
        merged.preemptions += rep.preemptions
        merged.preempted_tokens += rep.preempted_tokens
        merged.missing_decode_outputs += rep.missing_decode_outputs
        merged.shared_kv_tokens += rep.shared_kv_tokens
    merged.events.sort(key=lambda e: (e.start, e.replica))
    merged.cancelled_rel_ids.sort()
    merged.prefix_hit_ratio = (hit_tokens / merged.prefix_lookup_tokens
                               if merged.prefix_lookup_tokens else 0.0)
    return merged


class EngineCore:
    """One serving replica: scheduler + executor behind a step interface."""

    def __init__(self, scheduler: SchedulerBase, executor, replica_id: int = 0,
                 record_events: bool = True):
        self.scheduler = scheduler
        self.executor = executor
        self.replica_id = replica_id
        self.record_events = record_events
        self.events: List[BatchEvent] = []
        self.schedule_time = 0.0
        self.iterations = 0
        # Batch-completion listener (event, batch, result) — the open-loop
        # Frontend subscribes here to stream tokens and observe completions.
        self.on_batch: Optional[
            Callable[[BatchEvent, Batch, BatchResult], None]] = None

    # ------------------------------------------------------------------ steps
    def admit(self, rq: RelQuery, now: float) -> None:
        """Admit a relQuery. Executors exposing ``validate_relquery`` (the
        real backends) get to reject requests that can never fit their
        per-sequence KV capacity *before* the scheduler sees them — a
        too-long request used to overflow the dense slot buffer silently
        mid-decode instead of failing here with a clear error."""
        validate = getattr(self.executor, "validate_relquery", None)
        if validate is not None:
            validate(rq)
        self.scheduler.add_relquery(rq, now)

    def has_work(self) -> bool:
        return self.scheduler.has_work()

    def load(self) -> int:
        """Outstanding requests (waiting + running) — the router's load signal."""
        return self.scheduler.queue_depth()

    def tick(self, now: float) -> Optional[BatchEvent]:
        """Schedule + execute one batch at clock ``now``. Returns ``None`` when
        the replica is idle (nothing admitted and unfinished). Under optimistic
        KV admission a stalled scheduler is first asked to preempt the
        lowest-priority running relQuery and retry; ``EngineDeadlockError`` is
        reserved for work that can never be scheduled no matter what is
        evicted (a single request that does not fit under the cap)."""
        batch = self._schedule(now)
        while batch is None and self.scheduler.has_work():
            if not self.scheduler.preempt_for_progress(now):
                # Nothing left to evict — admitting more work, advancing the
                # clock or reclaiming KV cannot help.
                raise EngineDeadlockError(self.scheduler.tokens_in_use,
                                          self.scheduler.limits.cap,
                                          self.scheduler.stuck_rel_ids(),
                                          self.replica_id)
            batch = self._schedule(now)
        if batch is None:
            return None
        duration, result = self.executor.execute(batch, now)
        start, end = now, now + duration
        self.scheduler.complete_batch(batch, result, start, end)
        self.iterations += 1
        event = BatchEvent(batch.kind, start, end, batch.num_requests,
                           batch.uncached_tokens, batch.rel_ids(),
                           self.replica_id)
        if self.record_events:
            self.events.append(event)
        if self.on_batch is not None:
            self.on_batch(event, batch, result)
        return event

    def _schedule(self, now: float) -> Optional[Batch]:
        """One timed scheduler call, then free executor slots of any requests
        the scheduler preempted while choosing (headroom or retry preemption
        both funnel through ``drain_preempt_releases``)."""
        t0 = _time.perf_counter()
        batch = self.scheduler.schedule(now)
        self.schedule_time += _time.perf_counter() - t0
        self._release_preempted()
        return batch

    def _release_preempted(self) -> None:
        release = getattr(self.executor, "release_request", None)
        for req_id in self.scheduler.drain_preempt_releases():
            if release is not None:
                release(req_id)

    def cancel_relquery(self, rel_id: str, now: float) -> List[Request]:
        """Cancel a relQuery between ticks: evict its queued/running requests
        from the scheduler (reclaiming ``tokens_in_use``/``committed_tokens``)
        and release any executor-side state (decode slots) they hold. Returns
        the evicted requests; [] if the relQuery is unknown or terminal."""
        cancelled = self.scheduler.cancel_relquery(rel_id, now)
        release = getattr(self.executor, "release_request", None)
        if release is not None:
            for r in cancelled:
                release(r.req_id)
        return cancelled

    # ------------------------------------------------------------------ report
    def report(self, end_time: float) -> ServiceReport:
        """Service metrics as of ``end_time``. Safe to call mid-flight (the
        Frontend's ``snapshot()``): unfinished relQueries simply have no
        latency entry yet. Cancelled relQueries are excluded from every
        latency statistic and listed in ``cancelled_rel_ids``."""
        all_rqs = list(self.scheduler.relqueries.values())
        cancelled = [rq.rel_id for rq in all_rqs if rq.cancelled]
        rqs = [rq for rq in all_rqs if not rq.cancelled]
        lat = {rq.rel_id: rq.latency() for rq in rqs if rq.latency() is not None}
        waiting = {rq.rel_id: rq.waiting_time() for rq in rqs}
        core = {rq.rel_id: rq.core_running_time() for rq in rqs}
        tail = {rq.rel_id: rq.tail_running_time() for rq in rqs}
        pc = getattr(self.scheduler, "prefix_cache", None)
        return ServiceReport(
            latencies=lat, waiting=waiting, core=core, tail=tail,
            events=self.events, end_to_end=end_time,
            dpu_time=getattr(self.scheduler, "dpu_time", 0.0),
            aba_time=getattr(self.scheduler, "aba_time", 0.0),
            prefix_hit_ratio=pc.hit_ratio if pc is not None else 0.0,
            prefix_lookup_tokens=(getattr(pc, "hits", 0) + getattr(pc, "misses", 0)
                                  if pc is not None else 0),
            schedule_time=self.schedule_time,
            cancelled_rel_ids=cancelled,
            preemptions=getattr(self.scheduler, "preemptions", 0),
            preempted_tokens=getattr(self.scheduler, "preempted_tokens", 0),
            missing_decode_outputs=getattr(self.scheduler,
                                           "missing_decode_outputs", 0),
            shared_kv_tokens=getattr(self.scheduler, "shared_tokens_saved", 0),
        )


class ServingEngine:
    """Single-replica trace driver built on ``EngineCore``."""

    def __init__(self, scheduler: SchedulerBase, executor):
        self.core = EngineCore(scheduler, executor)

    @property
    def scheduler(self) -> SchedulerBase:
        return self.core.scheduler

    @property
    def executor(self):
        return self.core.executor

    @property
    def events(self) -> List[BatchEvent]:
        return self.core.events

    @property
    def schedule_time(self) -> float:
        return self.core.schedule_time

    def run_trace(self, trace: Sequence[RelQuery], max_iterations: int = 2_000_000,
                  record_events: bool = True) -> ServiceReport:
        """Replay a full arrival trace on the simulated clock.

        .. deprecated:: closed-loop compatibility shim. The open-loop
           ``repro.serving.Frontend`` (submit / stream / cancel / snapshot) is
           the serving API; this method is now a thin trace-replay driver over
           it and produces the identical ``ServiceReport``.
        """
        from repro.serving.frontend import Frontend

        self.core.record_events = record_events
        fe = Frontend(self.core)
        try:
            fe.replay(trace, max_iterations=max_iterations)
        finally:
            fe.close()
        return self.core.report(fe.clock)
