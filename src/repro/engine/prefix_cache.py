"""Hash-block prefix cache (vLLM-style) with LRU eviction.

Token sequences are split into fixed-size blocks; each block's key chains the
previous block's hash so a hit means the *entire* prefix up to that block is
cached. ``count_cached`` is the DPU's utok oracle; the real executor can attach
per-block KV tensors for genuine compute reuse.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple


def block_hashes(tokens: Sequence[int], block_size: int) -> List[int]:
    """Chained hashes of all *full* blocks of ``tokens``."""
    out = []
    h = 0
    for i in range(len(tokens) // block_size):
        blk = tuple(tokens[i * block_size:(i + 1) * block_size])
        h = hash((h, blk))
        out.append(h)
    return out


@dataclass
class CachedBlock:
    key: int
    ref_count: int = 0
    payload: Any = None      # optional per-layer KV tensors (real executor)


class PrefixCache:
    def __init__(self, block_size: int = 16, capacity_blocks: int = 65536):
        self.block_size = block_size
        self.capacity_blocks = capacity_blocks
        self._blocks: "OrderedDict[int, CachedBlock]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._blocks)

    # ---------------------------------------------------------------- lookup
    def match_blocks(self, tokens: Sequence[int]) -> List[int]:
        """Keys of the longest cached block-prefix (touches LRU)."""
        matched = []
        for key in block_hashes(tokens, self.block_size):
            if key in self._blocks:
                self._blocks.move_to_end(key)
                matched.append(key)
            else:
                break
        return matched

    def count_cached(self, tokens: Sequence[int]) -> int:
        """Cached-token count for a prompt (DPU's Eq. 11 oracle)."""
        n = len(self.match_blocks(tokens)) * self.block_size
        self.hits += n
        self.misses += max(0, len(tokens) - n)
        return n

    def peek_cached(self, tokens: Sequence[int]) -> int:
        """count_cached without stats/LRU side effects (scheduling probes)."""
        n = 0
        h = 0
        for i in range(len(tokens) // self.block_size):
            blk = tuple(tokens[i * self.block_size:(i + 1) * self.block_size])
            h = hash((h, blk))
            if h in self._blocks:
                n += self.block_size
            else:
                break
        return n

    def get_payloads(self, tokens: Sequence[int]) -> List[Any]:
        return [self._blocks[k].payload for k in self.match_blocks(tokens)]

    # ---------------------------------------------------------------- insert
    def insert(self, tokens: Sequence[int], payloads: Optional[List[Any]] = None) -> None:
        keys = block_hashes(tokens, self.block_size)
        for i, key in enumerate(keys):
            if key in self._blocks:
                self._blocks.move_to_end(key)
                continue
            self._blocks[key] = CachedBlock(
                key, payload=payloads[i] if payloads and i < len(payloads) else None)
            self._evict_to_capacity()

    def _evict_to_capacity(self) -> None:
        while len(self._blocks) > self.capacity_blocks:
            self._blocks.popitem(last=False)
            self.evictions += 1

    @property
    def hit_ratio(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0
