"""Hash-block prefix cache (vLLM-style) with ref-count-aware LRU eviction.

Token sequences are split into fixed-size blocks; each block's key chains the
previous block's hash so a hit means the *entire* prefix up to that block is
cached. ``count_cached`` is the DPU's utok oracle; the real executor can attach
per-block KV tensors for genuine compute reuse.

Keys are 64-bit chained crc32 pairs, not Python ``hash``: the builtin is
salted per process (PYTHONHASHSEED), and block keys flow into scheduling
order, the shared-KV admission ledger and the router — every one of which
must be reproducible across interpreter invocations. A single 32-bit crc
would make birthday collisions likely at the default 65536-block capacity
(false hits corrupt utok estimates, admission discounts and — in the real
executor — reused KV payloads); the pair keeps collisions at ~2^-64 while
staying deterministic everywhere zlib is.
"""
from __future__ import annotations

import zlib
from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Dict, Iterator, List, Optional, Sequence


def iter_block_hashes(tokens: Sequence[int], block_size: int) -> Iterator[int]:
    """Lazily yield 64-bit chained keys of all *full* blocks of ``tokens``
    (two independently-chained crc32 halves). Two sequences share the key of
    block i iff their first (i+1) blocks are token-identical — key equality
    certifies the whole prefix."""
    h = 0
    for i in range(len(tokens) // block_size):
        blk = tokens[i * block_size:(i + 1) * block_size]
        data = b",".join(b"%d" % t for t in blk)
        lo = zlib.crc32(data, h & 0xFFFFFFFF)
        hi = zlib.crc32(data + b"|", h >> 32)
        h = (hi << 32) | lo
        yield h


def block_hashes(tokens: Sequence[int], block_size: int) -> List[int]:
    """Chained hashes of all *full* blocks of ``tokens``."""
    return list(iter_block_hashes(tokens, block_size))


@dataclass
class CachedBlock:
    key: int
    ref_count: int = 0
    payload: Any = None      # optional per-layer KV tensors (real executor)


class PrefixCache:
    def __init__(self, block_size: int = 16, capacity_blocks: int = 65536):
        self.block_size = block_size
        self.capacity_blocks = capacity_blocks
        self._blocks: "OrderedDict[int, CachedBlock]" = OrderedDict()
        # pins for blocks that may not be resident yet: the scheduler acquires
        # a request's prompt keys at KV-charge time, which can precede the
        # executor's insert (the prefill that writes the blocks)
        self._pins: Dict[int, int] = {}
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._blocks)

    # ---------------------------------------------------------------- lookup
    def match_blocks(self, tokens: Sequence[int]) -> List[int]:
        """Keys of the longest cached block-prefix (touches LRU)."""
        matched = []
        for key in iter_block_hashes(tokens, self.block_size):
            if key in self._blocks:
                self._blocks.move_to_end(key)
                matched.append(key)
            else:
                break
        return matched

    def count_cached(self, tokens: Sequence[int]) -> int:
        """Cached-token count for a prompt (DPU's Eq. 11 oracle)."""
        n = len(self.match_blocks(tokens)) * self.block_size
        self.hits += n
        self.misses += max(0, len(tokens) - n)
        return n

    def peek_cached(self, tokens: Sequence[int]) -> int:
        """count_cached without stats/LRU side effects (scheduling probes)."""
        n = 0
        for h in iter_block_hashes(tokens, self.block_size):
            if h in self._blocks:
                n += self.block_size
            else:
                break
        return n

    def has_block(self, key: int) -> bool:
        """Residency probe by key — no stats, no LRU touch."""
        return key in self._blocks

    def get_payloads(self, tokens: Sequence[int]) -> List[Any]:
        return [self._blocks[k].payload for k in self.match_blocks(tokens)]

    # ---------------------------------------------------------------- pinning
    def ref_count(self, key: int) -> int:
        block = self._blocks.get(key)
        return (block.ref_count if block is not None else 0) + \
            self._pins.get(key, 0)

    def acquire_blocks(self, keys: Sequence[int]) -> None:
        """Pin ``keys`` against LRU eviction while a request's KV depends on
        them. Keys not (yet) resident are remembered: the pin attaches when
        the executor inserts the block."""
        for key in keys:
            block = self._blocks.get(key)
            if block is not None:
                block.ref_count += 1
            else:
                self._pins[key] = self._pins.get(key, 0) + 1

    def release_blocks(self, keys: Sequence[int]) -> None:
        """Undo one ``acquire_blocks``; unknown keys are a no-op."""
        for key in keys:
            block = self._blocks.get(key)
            if block is not None and block.ref_count > 0:
                block.ref_count -= 1
            elif key in self._pins:
                self._pins[key] -= 1
                if self._pins[key] <= 0:
                    del self._pins[key]

    # ---------------------------------------------------------------- insert
    def insert(self, tokens: Sequence[int], payloads: Optional[List[Any]] = None) -> None:
        for i, key in enumerate(iter_block_hashes(tokens, self.block_size)):
            if key in self._blocks:
                self._blocks.move_to_end(key)
                continue
            self._blocks[key] = CachedBlock(
                key, ref_count=self._pins.pop(key, 0),
                payload=payloads[i] if payloads and i < len(payloads) else None)
            self._evict_to_capacity()

    def _evict_to_capacity(self) -> None:
        """Evict oldest *unreferenced* blocks down to capacity. Referenced
        blocks back live KV (a scheduled request's shared prefix) and are
        never dropped — when everything over capacity is pinned, the cache
        temporarily exceeds ``capacity_blocks`` instead. The walk starts at
        the LRU end and stops as soon as the excess is covered, so the
        steady-state insert cost is O(evictions + pinned blocks skipped),
        not O(cache size)."""
        excess = len(self._blocks) - self.capacity_blocks
        if excess <= 0:
            return
        victims = []
        for key, block in self._blocks.items():   # oldest first
            if block.ref_count == 0:
                victims.append(key)
                if len(victims) >= excess:
                    break
        for key in victims:
            del self._blocks[key]
            self.evictions += 1

    @property
    def hit_ratio(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0
