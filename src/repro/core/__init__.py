"""Scheduling core: the unified Batch type, latency model, DPU priorities,
the Adaptive Batch Arranger and the schedulers that tie them together."""
from repro.core.batch import Batch
from repro.core.latency_model import BatchLatencyModel, a100_opt13b
from repro.core.relquery import RelQuery, Request, RequestState

__all__ = ["Batch", "BatchLatencyModel", "a100_opt13b",
           "RelQuery", "Request", "RequestState"]
