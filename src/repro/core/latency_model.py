"""Linear batch-duration predictors (paper §4.2, Eq. 9 / Fig. 7).

``L_prefill(p) = α_p · utok(p) + β_p`` — only *uncached* tokens cost compute
(the paper's Fig. 7 shows this is what restores linearity under prefix caching).
``L_decode(d) = α_d · req(d) + β_d``.

Constants are fit offline: ``fit()`` least-squares over profiled (x, duration)
samples. ``a100_opt13b()`` ships constants matching the paper's OPT-13B/A100
regime (used by the simulated-clock executor); ``calibrate_on_host()`` fits
against the real JAX executor on this machine.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Iterable, List, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class BatchLatencyModel:
    alpha_p: float   # s per uncached prefill token
    beta_p: float    # s per prefill batch
    alpha_d: float   # s per request in the decode batch
    beta_d: float    # s per decode batch

    def prefill_time(self, uncached_tokens: int) -> float:
        return self.alpha_p * uncached_tokens + self.beta_p

    def decode_time(self, num_requests: int) -> float:
        return self.alpha_d * num_requests + self.beta_d

    def mixed_time(self, uncached_tokens: int, num_decode_requests: int) -> float:
        """Sarathi-style chunked-prefill batch: one pass over both."""
        return (self.alpha_p * uncached_tokens + self.alpha_d * num_decode_requests
                + max(self.beta_p, self.beta_d))

    def scaled(self, factor: float) -> "BatchLatencyModel":
        return BatchLatencyModel(self.alpha_p * factor, self.beta_p * factor,
                                 self.alpha_d * factor, self.beta_d * factor)


def a100_opt13b() -> BatchLatencyModel:
    """Paper regime (Fig. 7: prefill ~0.1-0.4s up to ~2k tokens; decode
    ~0.03-0.1s up to ~256 requests)."""
    return BatchLatencyModel(alpha_p=0.8e-4, beta_p=0.03, alpha_d=1.0e-4, beta_d=0.025)


def fit(prefill_samples: Sequence[Tuple[int, float]],
        decode_samples: Sequence[Tuple[int, float]]) -> BatchLatencyModel:
    """Least-squares fit of (x, seconds) samples for each phase."""
    def linfit(samples):
        xs = np.asarray([s[0] for s in samples], np.float64)
        ys = np.asarray([s[1] for s in samples], np.float64)
        if len(xs) < 2 or np.allclose(xs, xs[0]):
            return 0.0, float(ys.mean()) if len(ys) else 0.0
        A = np.stack([xs, np.ones_like(xs)], axis=1)
        (a, b), *_ = np.linalg.lstsq(A, ys, rcond=None)
        return float(max(a, 0.0)), float(max(b, 0.0))

    ap, bp = linfit(prefill_samples)
    ad, bd = linfit(decode_samples)
    return BatchLatencyModel(ap, bp, ad, bd)


def r_squared(samples: Sequence[Tuple[int, float]], a: float, b: float) -> float:
    xs = np.asarray([s[0] for s in samples], np.float64)
    ys = np.asarray([s[1] for s in samples], np.float64)
    pred = a * xs + b
    ss_res = float(np.sum((ys - pred) ** 2))
    ss_tot = float(np.sum((ys - ys.mean()) ** 2))
    return 1.0 - ss_res / max(ss_tot, 1e-12)
