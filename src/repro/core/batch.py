"""The one batch abstraction shared by schedulers, the arranger and executors.

A ``Batch`` is simultaneously an ABA *candidate* (something the Adaptive Batch
Arranger can price with ``cost()``/its Δ-latency projection) and a *scheduled*
unit of work (something an executor runs and ``complete_batch`` retires).
Before this type existed the repo carried a ``CandidateBatch``/
``ScheduledBatch`` duality and the RelServe scheduler structurally could not
emit the chunked/mixed batches the executors already understood; unifying the
type makes chunked-prefill arrangement a first-class ABA case.

Kinds:
- ``prefill``: prefill ``prefill_requests`` fully (their whole remaining
  prompt); ``uncached_tokens`` is the *estimated* uncached-token compute.
- ``decode``: one decode step over ``decode_requests``.
- ``mixed``: Sarathi-style chunked prefill — decode ``decode_requests`` one
  token while ``prefill_chunks[req_id]`` prompt tokens of each request in
  ``prefill_requests`` are prefilled in the same pass.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple, TYPE_CHECKING

from repro.core.latency_model import BatchLatencyModel
from repro.core.relquery import RelQuery, Request

if TYPE_CHECKING:  # pragma: no cover - typing only, avoids a circular import
    from repro.core.arranger import ArrangerDecision

BATCH_KINDS = ("prefill", "decode", "mixed")


@dataclass
class Batch:
    kind: str                                               # one of BATCH_KINDS
    prefill_requests: List[Request] = field(default_factory=list)
    decode_requests: List[Request] = field(default_factory=list)
    prefill_chunks: Dict[str, int] = field(default_factory=dict)  # req_id -> len
    uncached_tokens: int = 0           # estimated utok of the prefill side
    # estimated prefill tokens this batch saves through *intra-batch* prefix
    # reuse (warm-then-follow: followers priced at the post-leader hit rate);
    # already subtracted from uncached_tokens, carried for instrumentation
    shared_prefix_tokens: int = 0
    relquery: Optional[RelQuery] = None  # single-relQuery prefill candidates
    decision: Optional["ArrangerDecision"] = None

    def __post_init__(self):
        if self.kind not in BATCH_KINDS:
            raise ValueError(f"unknown batch kind {self.kind!r}")

    # ------------------------------------------------------------------ views
    @property
    def requests(self) -> List[Request]:
        """Legacy view: the batch's primary request list (prefill targets, or
        the decode requests for a pure-decode batch)."""
        return self.decode_requests if self.kind == "decode" else self.prefill_requests

    @property
    def num_requests(self) -> int:
        return len(self.prefill_requests) + len(self.decode_requests)

    def all_requests(self) -> List[Request]:
        return self.prefill_requests + self.decode_requests

    def rel_ids(self) -> Tuple[str, ...]:
        # sorted: str-set iteration order is hash-salted, and event logs must
        # be reproducible across processes
        return tuple(sorted({r.rel_id for r in self.all_requests()}))

    def is_empty(self) -> bool:
        return not self.prefill_requests and not self.decode_requests

    def chunk_of(self, r: Request) -> int:
        """Prompt tokens this batch prefills for ``r``: the scheduled chunk, or
        the whole remaining prompt (prompt + preserved generation for a
        preempted request's restart) for non-chunked prefill."""
        default = r.prefill_target_tokens - r.prefilled_tokens
        return self.prefill_chunks.get(r.req_id, default)

    def completes_prompt(self, r: Request) -> bool:
        return r.prefilled_tokens + self.chunk_of(r) >= r.prefill_target_tokens

    def min_priority(self, prio_of) -> float:
        return min(prio_of(r) for r in self.all_requests())

    def min_prefill_priority(self, prio_of) -> float:
        reqs = self.prefill_requests or self.decode_requests
        return min(prio_of(r) for r in reqs)

    # ------------------------------------------------------------------ cost
    def cost(self, lm: BatchLatencyModel,
             true_uncached: Optional[int] = None) -> float:
        """Predicted duration under the linear batch-cost model (Eq. 9).
        ``true_uncached`` lets an executor substitute the measured uncached
        token count for the scheduler's estimate."""
        utok = self.uncached_tokens if true_uncached is None else true_uncached
        if self.kind == "prefill":
            return lm.prefill_time(utok)
        if self.kind == "decode":
            return lm.decode_time(len(self.decode_requests))
        return lm.mixed_time(utok, len(self.decode_requests))

    # ------------------------------------------------------------------ makers
    @classmethod
    def prefill(cls, requests: List[Request], uncached_tokens: int = 0,
                relquery: Optional[RelQuery] = None,
                shared_prefix_tokens: int = 0) -> "Batch":
        return cls("prefill", prefill_requests=list(requests),
                   uncached_tokens=uncached_tokens, relquery=relquery,
                   shared_prefix_tokens=shared_prefix_tokens)

    @classmethod
    def decode(cls, requests: List[Request]) -> "Batch":
        return cls("decode", decode_requests=list(requests))

    @classmethod
    def mixed(cls, prefill_requests: List[Request], decode_requests: List[Request],
              chunks: Dict[str, int], uncached_tokens: int = 0,
              shared_prefix_tokens: int = 0) -> "Batch":
        return cls("mixed", prefill_requests=list(prefill_requests),
                   decode_requests=list(decode_requests),
                   prefill_chunks=dict(chunks), uncached_tokens=uncached_tokens,
                   shared_prefix_tokens=shared_prefix_tokens)


# --------------------------------------------------------------------------
# Back-compat aliases (pre-unification API). New code should construct Batch
# directly; these keep the old constructor signatures working for callers
# that update their import to this module (the old homes in core.scheduler /
# core.arranger no longer export the names).
# --------------------------------------------------------------------------
def CandidateBatch(requests: List[Request], uncached_tokens: int = 0,
                   relquery: Optional[RelQuery] = None) -> Batch:
    """Legacy constructor: an arranger candidate (was a distinct dataclass)."""
    return Batch.prefill(requests, uncached_tokens, relquery)


def ScheduledBatch(kind: str, requests: List[Request], uncached_tokens: int = 0,
                   decode_requests: Optional[List[Request]] = None,
                   prefill_chunks: Optional[Dict[str, int]] = None,
                   decision: Optional["ArrangerDecision"] = None) -> Batch:
    """Legacy constructor: a scheduler-issued batch (was a distinct dataclass)."""
    if kind == "decode":
        b = Batch.decode(requests)
    else:
        b = Batch(kind, prefill_requests=list(requests),
                  decode_requests=list(decode_requests or []),
                  prefill_chunks=dict(prefill_chunks or {}),
                  uncached_tokens=uncached_tokens)
    b.decision = decision
    return b
