"""Dynamic Priority Updater (paper §4.2).

PEM simulates the remaining execution of a relQuery: Batch Decomposition
(Algorithm 1) splits R_t into prefill/decode batches under the engine limits,
then the linear predictors price each batch (Eq. 10). Fast estimation:
``utok*`` replaces exact prefix-cache matching with a sampled miss ratio
(Eq. 11); priorities are reused across iterations while a relQuery sits wholly
in the waiting queue (Eq. 12). Starvation prevention forces priority 0 once
``unit_waiting_time`` exceeds a threshold (Eq. 13).
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Protocol, Sequence, Tuple

from repro.core.latency_model import BatchLatencyModel
from repro.core.relquery import RelQuery, Request, RequestState


class PrefixCacheView(Protocol):
    """What the DPU needs from the engine's prefix cache."""

    def count_cached(self, tokens: Sequence[int]) -> int: ...


@dataclass(frozen=True)
class BatchLimits:
    max_num_batched_tokens: int = 2048   # mnbt: prefill batch token cap
    max_num_seqs: int = 256              # mns: decode batch request cap
    cap: int = 16384                     # KV-resident token cap on the device


@dataclass
class SimBatch:
    """A batch in PEM's simulated decomposition."""
    kind: str                 # 'prefill' | 'decode'
    utok: int = 0             # uncached tokens (prefill)
    reqs: int = 0             # request count (decode)


def batch_decompose(utoks: Sequence[int], output_len: int, already_running: int,
                    limits: BatchLimits) -> List[SimBatch]:
    """Algorithm 1. ``utoks``: uncached token counts of *not-yet-prefilled*
    requests of R_t; ``already_running``: R_t requests already prefilled (they
    join decode batches with utok = 0)."""
    P: List[SimBatch] = []
    D: List[SimBatch] = []
    p_tok, p_reqs = 0, 0
    d_reqs = already_running
    accum = 0
    n = len(utoks)
    for i, u in enumerate(utoks):
        if u + accum > limits.cap or d_reqs + 1 > limits.max_num_seqs:
            # device full: flush pending prefill, decode everyone to completion
            if p_reqs:
                P.append(SimBatch("prefill", utok=p_tok))
            for _ in range(output_len):
                D.append(SimBatch("decode", reqs=d_reqs))
            p_tok, p_reqs, d_reqs, accum = 0, 0, 0, 0
        if u + p_tok > limits.max_num_batched_tokens and p_reqs:
            P.append(SimBatch("prefill", utok=p_tok))
            p_tok, p_reqs = 0, 0
        p_tok += u
        p_reqs += 1
        d_reqs += 1
        accum += u
    if p_reqs or d_reqs:
        if p_reqs:
            P.append(SimBatch("prefill", utok=p_tok))
        for _ in range(output_len):
            D.append(SimBatch("decode", reqs=d_reqs))
    return P + D


@dataclass
class DPUConfig:
    sample_size: int = 8                 # |R_t^s| for Eq. 11
    starvation_threshold: Optional[float] = None  # seconds per request (Eq. 13)
    resample_every: int = 16             # refresh miss ratio every N iterations
    seed: int = 0
    # Exact-probe mode: replace Eq. 11's sampled miss ratio with a full probe
    # of every pending request, *including* the intra-relQuery sharing that
    # warm-then-follow scheduling will realize (a leader's prompt warms the
    # cache for its followers). No RNG is consumed. Costs O(pending prompt
    # tokens) per resample — use when priorities must reflect realized
    # sharing, e.g. with prefix-sharing-aware scheduling enabled.
    exact_probe: bool = False
    # Incremental refresh: memoize the per-relQuery phase probe
    # (is_finished / all_waiting — each O(#requests)) against the scheduler's
    # ``RelQuery._phase_version`` counter, so a decode-heavy tick re-scores
    # only relQueries whose phase actually changed. Pure caching: priority
    # decisions are bit-identical with it on or off.
    incremental: bool = True


class DynamicPriorityUpdater:
    """Recomputes Prio(R_t) for every relQuery in the engine, each iteration."""

    def __init__(self, latency_model: BatchLatencyModel, limits: BatchLimits,
                 config: Optional[DPUConfig] = None):
        self.lm = latency_model
        self.limits = limits
        self.cfg = config or DPUConfig()
        # Optional ALISE-style output-length predictor (attached by the
        # scheduler): with history for a relQuery's template, PEM prices the
        # remaining decode phase at the predicted output length instead of
        # the OL(R) worst case. None = bit-identical to the unpredicted path.
        self.predictor = None
        self._rng = random.Random(self.cfg.seed)
        self._iteration = 0
        self._last_sampled: Dict[str, int] = {}
        # incremental-refresh memo: rel_id -> (phase_version, finished,
        # all_waiting) — valid while the scheduler hasn't bumped the version
        self._phase_memo: Dict[str, Tuple[int, bool, bool]] = {}
        # instrumentation
        self.stats = {"pem_calls": 0, "reuses": 0, "starvation_promotions": 0,
                      "sampled_requests": 0, "exact_probes": 0,
                      "phase_probes": 0, "phase_memo_hits": 0}

    def forget(self, rel_id: str) -> None:
        """Drop per-relQuery DPU state (used when a relQuery is cancelled)."""
        self._last_sampled.pop(rel_id, None)
        self._phase_memo.pop(rel_id, None)

    # ---------------------------------------------------------------- Eq. 11
    def _estimate_miss_ratio(self, rq: RelQuery, prefix_cache: Optional[PrefixCacheView]) -> float:
        if prefix_cache is None:
            return 1.0
        pending = rq.waiting_requests() + rq.preempted_requests()
        if not pending:
            return rq.cache_miss_ratio
        if self.cfg.exact_probe:
            return self._exact_miss_ratio(pending, prefix_cache)
        sample = pending if len(pending) <= self.cfg.sample_size else \
            self._rng.sample(pending, self.cfg.sample_size)
        tok = sum(r.num_prompt_tokens for r in sample)
        probe = getattr(prefix_cache, "peek_cached", prefix_cache.count_cached)
        cached = sum(probe(r.tokens) for r in sample)
        self.stats["sampled_requests"] += len(sample)
        return (tok - cached) / max(1, tok)

    def _exact_miss_ratio(self, pending: Sequence[Request],
                          prefix_cache: PrefixCacheView) -> float:
        """Full probe over every pending request, accumulating the warm set a
        warm-then-follow schedule will build: once any pending request has
        prefilled, its prompt blocks are hits for every later sibling — the
        realized sharing Eq. 11's sample-and-scale cannot see."""
        self.stats["exact_probes"] += 1
        self.stats["sampled_requests"] += len(pending)
        block_size = getattr(prefix_cache, "block_size", None)
        has_block = getattr(prefix_cache, "has_block", None)
        tok, cached = 0, 0
        if block_size is None or has_block is None:
            probe = getattr(prefix_cache, "peek_cached", prefix_cache.count_cached)
            for r in pending:
                tok += r.num_prompt_tokens
                cached += probe(r.tokens)
            return (tok - cached) / max(1, tok)
        from repro.engine.prefix_cache import iter_block_hashes
        warm: set = set()
        for r in pending:
            tok += r.num_prompt_tokens
            keys = list(iter_block_hashes(r.tokens, block_size))
            for k in keys:
                if k in warm or has_block(k):
                    cached += block_size
                else:
                    break
            warm.update(keys)
        return (tok - cached) / max(1, tok)

    # ---------------------------------------------------------------- PEM (Eq. 10)
    def pem(self, rq: RelQuery) -> float:
        self.stats["pem_calls"] += 1
        ratio = rq.cache_miss_ratio
        waiting = rq.waiting_requests()
        preempted = rq.preempted_requests()
        utoks = [max(1, round(r.num_prompt_tokens * ratio)) for r in waiting]
        # Preempted requests restart with a re-prefill of prompt + generation
        # so far; the generated suffix is never prefix-cached. Pricing this
        # keeps Prio(R) honest after the memory subsystem evicts R's KV.
        utoks += [max(1, round(r.num_prompt_tokens * ratio))
                  + r.preserved_output_tokens for r in preempted]
        running = rq.running_requests()
        # Swapped requests resume decoding without re-prefill once their KV
        # returns from the host tier: they price like running requests (no
        # prefill batches, full membership in the decode phase).
        swapped = rq.swapped_requests()
        inflight = running + preempted + swapped
        # remaining decode iterations: not-yet-prefilled requests need the full
        # OL; otherwise only the longest-remaining in-flight request matters
        if waiting or not inflight:
            rem_out = rq.max_output_tokens
            if self.predictor is not None:
                pred = self.predictor.predict(self.predictor.key_of(rq))
                if pred is not None:   # predicted decode work, not worst case
                    rem_out = max(1, min(rem_out, pred))
        else:
            rem_out = max(r.remaining_output for r in inflight)
        batches = batch_decompose(utoks, rem_out,
                                  len(running) + len(swapped), self.limits)
        total = 0.0
        for b in batches:
            if b.kind == "prefill":
                total += self.lm.prefill_time(b.utok)
            else:
                total += self.lm.decode_time(b.reqs)
        return total

    # ---------------------------------------------------------------- Eq. 8 / 12 / 13
    def update(self, relqueries: Sequence[RelQuery], now: float,
               prefix_cache: Optional[PrefixCacheView] = None) -> None:
        self._iteration += 1
        for rq in relqueries:
            if self.cfg.incremental:
                ver = rq._phase_version
                memo = self._phase_memo.get(rq.rel_id)
                if memo is not None and memo[0] == ver:
                    self.stats["phase_memo_hits"] += 1
                    finished, all_waiting_now = memo[1], memo[2]
                else:
                    self.stats["phase_probes"] += 1
                    finished = rq.is_finished()
                    all_waiting_now = False if finished else rq.all_waiting()
                    self._phase_memo[rq.rel_id] = (ver, finished,
                                                   all_waiting_now)
                if finished:
                    continue
            else:
                if rq.is_finished():
                    continue
                all_waiting_now = rq.all_waiting()
            if all_waiting_now and rq._was_all_waiting and rq.priority_fresh:
                self.stats["reuses"] += 1            # Eq. 12: reuse Prio(R_{t-1})
            else:
                last = self._last_sampled.get(rq.rel_id, -10**9)
                if self._iteration - last >= self.cfg.resample_every or not rq.priority_fresh:
                    rq.cache_miss_ratio = self._estimate_miss_ratio(rq, prefix_cache)
                    self._last_sampled[rq.rel_id] = self._iteration
                rq.priority = self.pem(rq)
                rq.priority_fresh = True
            rq._was_all_waiting = all_waiting_now
            if (self.cfg.starvation_threshold is not None
                    and rq.first_prefill_start is None
                    and rq.unit_waiting_time(now) > self.cfg.starvation_threshold):
                rq.priority = 0.0                    # Eq. 13
                self.stats["starvation_promotions"] += 1


class StaticPriorityEstimator:
    """Baseline (vLLM-SP): Eq. 6/7 literally — ``ReqPrio(r) = L¹(tok(r)) +
    L²(OL(r))`` summed over requests, fixed at arrival. Like the static-priority
    works the paper cites, L¹/L² are simple per-request linear functions: no
    prefix-cache term, no batching model, no execution-progress updates."""

    def __init__(self, latency_model: BatchLatencyModel, limits: BatchLimits,
                 nominal_decode_batch: int = 32):
        self.lm = latency_model
        self.limits = limits
        self._l2_slope = self.lm.alpha_d + self.lm.beta_d / nominal_decode_batch

    def assign(self, rq: RelQuery) -> None:
        total = 0.0
        for r in rq.requests:
            total += self.lm.alpha_p * r.num_prompt_tokens          # L¹(tok(r))
            total += self._l2_slope * r.max_output_tokens           # L²(OL(r))
        rq.priority = total
        rq.priority_fresh = True
