"""Baseline scheduling policies (paper §5.1 baselines + §5.3 ablations).

- ``VLLMScheduler``      — FCFS arrival order, strict prefill prioritization.
- ``SarathiScheduler``   — FCFS + chunked prefill mixed with decode.
- ``StaticPriorityScheduler`` (vLLM-SP) — Eq. 6/7 priority fixed at arrival,
  prefill prioritization; same code base as RelServe minus DPU/ABA.
- ``RelServePP`` / ``RelServeDP`` — RelServe with the transitional-case
  arrangement pinned to prefill-first / decode-first (Fig. 10 ablation).
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.core.priority import StaticPriorityEstimator
from repro.core.relquery import RelQuery, Request, RequestState
from repro.core.scheduler import (
    BatchResult, RelServeScheduler, ScheduledBatch, SchedulerBase,
)


class VLLMScheduler(SchedulerBase):
    """FCFS + prefill-first (vLLM default). Prefill batches may mix relQueries
    — vLLM has no relQuery awareness."""

    name = "vllm"

    def rq_sort_key(self, rq: RelQuery):
        return (rq.arrival_time, rq.rel_id)

    def schedule(self, now: float):
        p_cand = self.build_prefill_candidate(single_relquery=False)
        if p_cand is not None:
            return ScheduledBatch("prefill", p_cand.requests,
                                  uncached_tokens=p_cand.uncached_tokens)
        d_cand = self.build_decode_candidate()
        if d_cand is not None:
            return ScheduledBatch("decode", d_cand.requests)
        return None

    def estimated_utok(self, r: Request) -> int:
        # FCFS baselines still benefit from the engine prefix cache at
        # *execution* time; for batch construction they use full token counts
        # (vLLM packs prefill batches by prompt length).
        return r.num_prompt_tokens


class StaticPriorityScheduler(SchedulerBase):
    """vLLM-SP: static priority assigned once at arrival (Eq. 6/7)."""

    name = "vllm_sp"

    def __init__(self, limits=None, latency_model=None, prefix_cache=None):
        super().__init__(limits, latency_model, prefix_cache)
        self.estimator = StaticPriorityEstimator(self.lm, self.limits)

    def on_relquery_added(self, rq: RelQuery, now: float) -> None:
        self.estimator.assign(rq)

    def schedule(self, now: float):
        p_cand = self.build_prefill_candidate(single_relquery=True)
        if p_cand is not None:
            return ScheduledBatch("prefill", p_cand.requests,
                                  uncached_tokens=p_cand.uncached_tokens)
        d_cand = self.build_decode_candidate()
        if d_cand is not None:
            return ScheduledBatch("decode", d_cand.requests)
        return None


class SarathiScheduler(SchedulerBase):
    """FCFS + chunked prefill: every iteration runs one *mixed* batch — all
    running requests decode one token while a chunk of the head waiting
    request's prompt is prefilled, sharing the token budget."""

    name = "sarathi"

    def rq_sort_key(self, rq: RelQuery):
        return (rq.arrival_time, rq.rel_id)

    def schedule(self, now: float):
        decode_reqs = self.running_requests()[: self.limits.max_num_seqs]
        budget = max(0, self.limits.max_num_batched_tokens - len(decode_reqs))
        chunks: Dict[str, int] = {}
        prefill_reqs: List[Request] = []
        full_tok_sum = 0
        for rq in self.sorted_waiting_rqs():
            if budget <= 0:
                break
            for r in self._waiting_of[rq.rel_id]:
                if budget <= 0 or len(decode_reqs) + len(prefill_reqs) >= self.limits.max_num_seqs:
                    break
                remaining = r.num_prompt_tokens - r.prefilled_tokens
                needed = r.num_prompt_tokens + r.max_output_tokens
                if r.prefilled_tokens == 0 and \
                        self.tokens_in_use + full_tok_sum + needed > self.limits.cap:
                    budget = 0
                    break
                chunk = min(remaining, budget)
                chunks[r.req_id] = chunk
                prefill_reqs.append(r)
                budget -= chunk
                full_tok_sum += needed if r.prefilled_tokens == 0 else 0
        if not decode_reqs and not prefill_reqs:
            return None
        utok = sum(chunks.values())
        return ScheduledBatch("mixed", prefill_reqs, uncached_tokens=utok,
                              decode_requests=decode_reqs, prefill_chunks=chunks)

    def complete_batch(self, batch: ScheduledBatch, result: BatchResult,
                       start_ts: float, end_ts: float) -> None:
        super().complete_batch(batch, result, start_ts, end_ts)
        for r in batch.requests:
            chunk = batch.prefill_chunks.get(r.req_id, 0)
            r.prefilled_tokens += chunk
            if r.prefilled_tokens >= r.num_prompt_tokens and not r.prefilled:
                rq = self.relqueries[r.rel_id]
                self._finish_prefill(r, rq, result, end_ts)
                self._maybe_finish_relquery(rq, end_ts)


class RelServePP(RelServeScheduler):
    """Ablation: RelServe priorities, transitional case pinned to prefill."""
    name = "relserve_pp"
    arrangement = "prefill_first"


class RelServeDP(RelServeScheduler):
    """Ablation: RelServe priorities, transitional case pinned to decode."""
    name = "relserve_dp"
    arrangement = "decode_first"


SCHEDULERS = {
    "vllm": VLLMScheduler,
    "sarathi": SarathiScheduler,
    "vllm_sp": StaticPriorityScheduler,
    "relserve": RelServeScheduler,
    "relserve_pp": RelServePP,
    "relserve_dp": RelServeDP,
}


def build_scheduler(name: str, **kw) -> SchedulerBase:
    return SCHEDULERS[name](**kw)
