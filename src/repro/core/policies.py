"""Baseline scheduling policies (paper §5.1 baselines + §5.3 ablations).

- ``VLLMScheduler``      — FCFS arrival order, strict prefill prioritization.
- ``SarathiScheduler``   — FCFS + chunked prefill mixed with decode (one
  ``build_mixed_candidate`` batch per iteration).
- ``StaticPriorityScheduler`` (vLLM-SP) — Eq. 6/7 priority fixed at arrival,
  prefill prioritization; same code base as RelServe minus DPU/ABA.
- ``RelServePP`` / ``RelServeDP`` — RelServe with the transitional-case
  arrangement pinned to prefill-first / decode-first (Fig. 10 ablation).

All policies emit the unified ``repro.core.batch.Batch``.
"""
from __future__ import annotations

from typing import Optional

from repro.core.batch import Batch
from repro.core.priority import StaticPriorityEstimator
from repro.core.relquery import RelQuery, Request
from repro.core.scheduler import RelServeScheduler, SchedulerBase


class VLLMScheduler(SchedulerBase):
    """FCFS + prefill-first (vLLM default). Prefill batches may mix relQueries
    — vLLM has no relQuery awareness."""

    name = "vllm"

    def rq_sort_key(self, rq: RelQuery):
        return (rq.arrival_time, rq.rel_id)

    def choose_batch(self, now: float) -> Optional[Batch]:
        p_cand = self.build_prefill_candidate(single_relquery=False)
        if p_cand is not None:
            return p_cand
        return self.build_decode_candidate()

    def estimated_utok(self, r: Request) -> int:
        # FCFS baselines still benefit from the engine prefix cache at
        # *execution* time; for batch construction they use full token counts
        # (vLLM packs prefill batches by prompt length).
        return r.num_prompt_tokens


class StaticPriorityScheduler(SchedulerBase):
    """vLLM-SP: static priority assigned once at arrival (Eq. 6/7)."""

    name = "vllm_sp"

    def __init__(self, limits=None, latency_model=None, prefix_cache=None,
                 kv_admission: str = "conservative",
                 prefix_sharing: bool = False, **kw):
        super().__init__(limits, latency_model, prefix_cache, kv_admission,
                         prefix_sharing, **kw)
        self.estimator = StaticPriorityEstimator(self.lm, self.limits)

    def on_relquery_added(self, rq: RelQuery, now: float) -> None:
        self.estimator.assign(rq)

    def choose_batch(self, now: float) -> Optional[Batch]:
        p_cand = self.build_prefill_candidate(single_relquery=True)
        if p_cand is not None:
            return p_cand
        return self.build_decode_candidate()


class SarathiScheduler(SchedulerBase):
    """FCFS + chunked prefill: every iteration runs one *mixed* batch — all
    running requests decode one token while a chunk of the head waiting
    request's prompt is prefilled, sharing the token budget."""

    name = "sarathi"

    def rq_sort_key(self, rq: RelQuery):
        return (rq.arrival_time, rq.rel_id)

    def choose_batch(self, now: float) -> Optional[Batch]:
        return self.build_mixed_candidate(single_relquery=False)


class RelServePP(RelServeScheduler):
    """Ablation: RelServe priorities, transitional case pinned to prefill."""
    name = "relserve_pp"
    arrangement = "prefill_first"


class RelServeDP(RelServeScheduler):
    """Ablation: RelServe priorities, transitional case pinned to decode."""
    name = "relserve_dp"
    arrangement = "decode_first"


SCHEDULERS = {
    "vllm": VLLMScheduler,
    "sarathi": SarathiScheduler,
    "vllm_sp": StaticPriorityScheduler,
    "relserve": RelServeScheduler,
    "relserve_pp": RelServePP,
    "relserve_dp": RelServeDP,
}


def build_scheduler(name: str, **kw) -> SchedulerBase:
    return SCHEDULERS[name](**kw)
