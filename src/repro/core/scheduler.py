"""Iteration-level schedulers. ``SchedulerBase`` owns queue mechanics, KV-cap
accounting and latency-phase bookkeeping (Definition 2.2); ``RelServeScheduler``
adds the paper's DPU + ABA pipeline (Fig. 6 steps 2-3). Baselines live in
``repro.core.policies``.

Queues are maintained *incrementally* (per-relQuery waiting lists + a running
list) so one scheduling iteration costs O(#relQueries + batch size), not
O(total requests) — at paper scale (~5k requests, tens of thousands of
iterations) this is the difference between seconds and hours.

The engine contract:
  batch = scheduler.schedule(now)              # None -> idle
  ... engine executes batch ...
  scheduler.complete_batch(batch, results, start_ts, end_ts)
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.arranger import AdaptiveBatchArranger, ArrangerDecision, CandidateBatch
from repro.core.latency_model import BatchLatencyModel
from repro.core.priority import (
    BatchLimits, DPUConfig, DynamicPriorityUpdater, PrefixCacheView,
)
from repro.core.relquery import RelQuery, Request, RequestState


@dataclass
class ScheduledBatch:
    kind: str                        # 'prefill' | 'decode' | 'mixed'
    requests: List[Request]          # prefill targets (or decode requests)
    uncached_tokens: int = 0         # prefill compute (engine refines w/ real cache)
    decode_requests: List[Request] = field(default_factory=list)  # mixed batches
    prefill_chunks: Dict[str, int] = field(default_factory=dict)  # req_id -> chunk len
    decision: Optional[ArrangerDecision] = None

    @property
    def num_requests(self) -> int:
        return len(self.requests) + len(self.decode_requests)


@dataclass
class BatchResult:
    """Engine-reported outcome: req_id -> (new_token, finished)."""
    outputs: Dict[str, Tuple[int, bool]]
    uncached_tokens: Optional[int] = None   # engine-measured true utok


class SchedulerBase:
    def __init__(self, limits: Optional[BatchLimits] = None,
                 latency_model: Optional[BatchLatencyModel] = None,
                 prefix_cache: Optional[PrefixCacheView] = None):
        from repro.core.latency_model import a100_opt13b
        self.limits = limits or BatchLimits()
        self.lm = latency_model or a100_opt13b()
        self.prefix_cache = prefix_cache
        self.relqueries: Dict[str, RelQuery] = {}
        self.tokens_in_use = 0
        self.iteration = 0
        self.finished_relqueries: List[RelQuery] = []
        # incremental queues
        self._waiting_of: Dict[str, List[Request]] = {}
        self._running: List[Request] = []
        self._unfinished = 0

    # ------------------------------------------------------------- queue state
    def add_relquery(self, rq: RelQuery, now: float) -> None:
        self.relqueries[rq.rel_id] = rq
        self._waiting_of[rq.rel_id] = list(rq.requests)
        self._unfinished += 1
        self.on_relquery_added(rq, now)

    def on_relquery_added(self, rq: RelQuery, now: float) -> None:
        pass

    def active_relqueries(self) -> List[RelQuery]:
        return [rq for rq in self.relqueries.values() if not rq.is_finished()]

    def waiting_requests(self) -> List[Request]:
        out = []
        for rel_id in self._waiting_of:
            out.extend(self._waiting_of[rel_id])
        return out

    def running_requests(self) -> List[Request]:
        return list(self._running)

    def running_rqs(self) -> List[RelQuery]:
        seen, out = set(), []
        for r in self._running:
            if r.rel_id not in seen:
                seen.add(r.rel_id)
                out.append(self.relqueries[r.rel_id])
        return out

    def waiting_rqs(self) -> List[RelQuery]:
        running = {r.rel_id for r in self._running}
        return [self.relqueries[rel_id] for rel_id, lst in self._waiting_of.items()
                if lst and rel_id not in running]

    def has_work(self) -> bool:
        return self._unfinished > 0

    # ------------------------------------------------------------- candidates
    def rq_sort_key(self, rq: RelQuery):
        """Waiting-queue order: ascending priority, FCFS tie-break."""
        return (rq.priority, rq.arrival_time, rq.rel_id)

    def sorted_waiting_rqs(self) -> List[RelQuery]:
        rqs = [self.relqueries[rel_id] for rel_id, lst in self._waiting_of.items() if lst]
        rqs.sort(key=self.rq_sort_key)
        return rqs

    def build_decode_candidate(self) -> Optional[CandidateBatch]:
        if not self._running:
            return None
        return CandidateBatch(requests=self._running[: self.limits.max_num_seqs])

    def estimated_utok(self, r: Request) -> int:
        rq = self.relqueries[r.rel_id]
        return max(1, round(r.num_prompt_tokens * rq.cache_miss_ratio))

    def build_prefill_candidate(self, single_relquery: bool = True) -> Optional[CandidateBatch]:
        order = self.sorted_waiting_rqs()
        if not order:
            return None
        if single_relquery:
            order = order[:1]
        chosen: List[Request] = []
        utok_sum, full_tok_sum = 0, 0
        for rq in order:
            for r in self._waiting_of[rq.rel_id]:
                u = self.estimated_utok(r)
                if chosen and utok_sum + u > self.limits.max_num_batched_tokens:
                    break
                if len(chosen) + 1 > self.limits.max_num_seqs:
                    break
                needed = r.num_prompt_tokens + r.max_output_tokens
                if self.tokens_in_use + full_tok_sum + needed > self.limits.cap:
                    if chosen:
                        break
                    return None  # not even one request fits right now
                chosen.append(r)
                utok_sum += u
                full_tok_sum += needed
            else:
                continue
            break
        if not chosen:
            return None
        rel = self.relqueries[order[0].rel_id] if single_relquery else None
        return CandidateBatch(requests=chosen, uncached_tokens=utok_sum, relquery=rel)

    # ------------------------------------------------------------- lifecycle
    def schedule(self, now: float) -> Optional[ScheduledBatch]:
        raise NotImplementedError

    def complete_batch(self, batch: ScheduledBatch, result: BatchResult,
                       start_ts: float, end_ts: float) -> None:
        self.iteration += 1
        touched_rels = set()
        if batch.kind in ("prefill", "mixed"):
            for r in batch.requests:
                rq = self.relqueries[r.rel_id]
                if rq.first_prefill_start is None:
                    rq.first_prefill_start = start_ts
                if batch.kind == "mixed":
                    continue  # chunk bookkeeping handled by the policy
                self._finish_prefill(r, rq, result, end_ts)
                touched_rels.add(r.rel_id)
        decode_reqs = batch.requests if batch.kind == "decode" else batch.decode_requests
        if batch.kind in ("decode", "mixed"):
            for r in decode_reqs:
                tok, finished = result.outputs.get(r.req_id, (0, False))
                r.output_tokens.append(tok)
                self.tokens_in_use += 1
                if finished or r.remaining_output <= 0:
                    self._finish_request(r, end_ts)
                touched_rels.add(r.rel_id)
        for rel_id in touched_rels:
            self._maybe_finish_relquery(self.relqueries[rel_id], end_ts)

    def _finish_prefill(self, r: Request, rq: RelQuery, result: BatchResult,
                        end_ts: float) -> None:
        r.prefilled = True
        r.state = RequestState.RUNNING
        wl = self._waiting_of.get(r.rel_id)
        if wl is not None and r in wl:
            wl.remove(r)
            if not wl:
                del self._waiting_of[r.rel_id]
        self._running.append(r)
        self.tokens_in_use += r.num_prompt_tokens
        tok, finished = result.outputs.get(r.req_id, (0, False))
        r.output_tokens.append(tok)
        self.tokens_in_use += 1
        rq.last_prefill_end = end_ts   # monotone: last prefill wins
        if finished or r.remaining_output <= 0:
            self._finish_request(r, end_ts)

    def _finish_request(self, r: Request, end_ts: float) -> None:
        r.state = RequestState.FINISHED
        r.finish_time = end_ts
        if r in self._running:
            self._running.remove(r)
        self.tokens_in_use -= r.total_tokens

    def _maybe_finish_relquery(self, rq: RelQuery, end_ts: float) -> None:
        if rq.finish_time is None and rq.is_finished():
            rq.finish_time = end_ts
            self.finished_relqueries.append(rq)
            self._unfinished -= 1


class RelServeScheduler(SchedulerBase):
    """The paper's scheduler: DPU priority refresh + ABA batch choice."""

    name = "relserve"
    arrangement = "adaptive"   # 'adaptive' | 'prefill_first' | 'decode_first'

    def __init__(self, limits=None, latency_model=None, prefix_cache=None,
                 dpu_config: Optional[DPUConfig] = None):
        super().__init__(limits, latency_model, prefix_cache)
        self.dpu = DynamicPriorityUpdater(self.lm, self.limits, dpu_config)
        self.aba = AdaptiveBatchArranger(self.lm)
        # wall-clock overhead instrumentation (paper Table 6)
        self.dpu_time = 0.0
        self.aba_time = 0.0

    def _dpu_targets(self) -> List[RelQuery]:
        """relQueries whose priority may need a refresh this iteration: every
        relQuery with waiting or running requests."""
        ids = {r.rel_id for r in self._running}
        ids.update(rel_id for rel_id, lst in self._waiting_of.items() if lst)
        return [self.relqueries[i] for i in ids]

    def schedule(self, now: float) -> Optional[ScheduledBatch]:
        import time as _time
        t0 = _time.perf_counter()
        self.dpu.update(self._dpu_targets(), now, self.prefix_cache)
        self.dpu_time += _time.perf_counter() - t0

        d_cand = self.build_decode_candidate()
        p_cand = self.build_prefill_candidate(single_relquery=True)
        if d_cand is None and p_cand is None:
            return None

        t0 = _time.perf_counter()
        if self.arrangement == "adaptive":
            decision = self.aba.choose(p_cand, d_cand, self.running_rqs(),
                                       self.waiting_rqs(),
                                       lambda r: self.relqueries[r.rel_id].priority, now)
        elif self.arrangement == "prefill_first":
            decision = ArrangerDecision("prefill" if p_cand else "decode", "forced")
        else:  # decode_first
            decision = ArrangerDecision("decode" if d_cand else "prefill", "forced")
        self.aba_time += _time.perf_counter() - t0

        if decision.kind == "prefill" and p_cand is not None:
            return ScheduledBatch("prefill", p_cand.requests,
                                  uncached_tokens=p_cand.uncached_tokens,
                                  decision=decision)
        if d_cand is None:
            return None
        return ScheduledBatch("decode", d_cand.requests, decision=decision)
