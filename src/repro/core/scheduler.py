"""Iteration-level schedulers. ``SchedulerBase`` owns queue mechanics, KV-cap
accounting and latency-phase bookkeeping (Definition 2.2); ``RelServeScheduler``
adds the paper's DPU + ABA pipeline (Fig. 6 steps 2-3). Baselines live in
``repro.core.policies``.

All schedulers produce (and executors consume) the unified ``repro.core.batch.
Batch`` type — candidate construction (`build_prefill_candidate`,
`build_decode_candidate`, `build_mixed_candidate`) and scheduled output are the
same objects, so the Adaptive Batch Arranger can evaluate chunked-mixed
batches as first-class candidates.

Queues are maintained *incrementally* (per-relQuery waiting lists + a running
list) so one scheduling iteration costs O(#relQueries + batch size), not
O(total requests) — at paper scale (~5k requests, tens of thousands of
iterations) this is the difference between seconds and hours.

The engine contract:
  batch = scheduler.schedule(now)              # None -> idle
  ... engine executes batch ...
  scheduler.complete_batch(batch, results, start_ts, end_ts)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.core.arranger import AdaptiveBatchArranger, ArrangerDecision
from repro.core.batch import Batch
from repro.core.latency_model import BatchLatencyModel
from repro.core.predictor import OutputLenPredictor
from repro.core.priority import (
    BatchLimits, DPUConfig, DynamicPriorityUpdater, PrefixCacheView,
)
from repro.core.relquery import RelQuery, Request, RequestState
from repro.engine.kv_cache import SharedPrefixLedger
from repro.engine.prefix_cache import block_hashes


@dataclass
class BatchResult:
    """Engine-reported outcome: req_id -> (new_token, finished)."""
    outputs: Dict[str, Tuple[int, bool]]
    uncached_tokens: Optional[int] = None   # engine-measured true utok


KV_ADMISSION_MODES = ("conservative", "optimistic", "predicted")

# KV bytes one token occupies (all layers, K+V). Default models OPT-13B
# fp16: 2 (K,V) * 40 layers * 5120 hidden * 2 bytes — matches the a100_opt13b
# latency model the cost-based reclaim weighs swap transfers against.
KV_BYTES_PER_TOKEN = 819_200


class SchedulerBase:
    def __init__(self, limits: Optional[BatchLimits] = None,
                 latency_model: Optional[BatchLatencyModel] = None,
                 prefix_cache: Optional[PrefixCacheView] = None,
                 kv_admission: str = "conservative",
                 prefix_sharing: bool = False,
                 kv_tiering: bool = False,
                 host_kv_cap: int = 0,
                 swap_bandwidth_gbps: float = 32.0,
                 kv_bytes_per_token: int = KV_BYTES_PER_TOKEN,
                 predictor: Optional[OutputLenPredictor] = None,
                 proactive_offload: bool = False,
                 idle_horizon_s: Optional[float] = None,
                 swap_prefetch: bool = False):
        from repro.core.latency_model import a100_opt13b
        if kv_admission not in KV_ADMISSION_MODES:
            raise ValueError(f"kv_admission must be one of {KV_ADMISSION_MODES}"
                             f" (got {kv_admission!r})")
        if kv_tiering and kv_admission == "conservative":
            raise ValueError("kv_tiering requires a preempting admission mode "
                             "(optimistic or predicted) — conservative "
                             "admission never evicts, so the host tier would "
                             "be dead weight")
        if kv_tiering and host_kv_cap <= 0:
            raise ValueError(f"kv_tiering requires host_kv_cap > 0 "
                             f"(got {host_kv_cap})")
        if kv_tiering and swap_bandwidth_gbps <= 0:
            raise ValueError(f"swap_bandwidth_gbps must be > 0 "
                             f"(got {swap_bandwidth_gbps})")
        if proactive_offload and not kv_tiering:
            raise ValueError("proactive_offload requires kv_tiering — without "
                             "a host tier there is nowhere to park idle-tail "
                             "KV")
        if swap_prefetch and not kv_tiering:
            raise ValueError("swap_prefetch requires kv_tiering — there are "
                             "no swap-ins to prefetch without a host tier")
        if idle_horizon_s is not None and not proactive_offload:
            raise ValueError("idle_horizon_s only applies with "
                             "proactive_offload on")
        if idle_horizon_s is not None and idle_horizon_s <= 0:
            raise ValueError(f"idle_horizon_s must be > 0 "
                             f"(got {idle_horizon_s})")
        self.limits = limits or BatchLimits()
        self.lm = latency_model or a100_opt13b()
        self.prefix_cache = prefix_cache
        self.kv_admission = kv_admission
        # --- tiered KV memory (device -> host -> recompute) ---
        self.kv_tiering = bool(kv_tiering)
        self.host_kv_cap = int(host_kv_cap)          # host-tier cap, tokens
        self.swap_bandwidth_bytes = float(swap_bandwidth_gbps) * 1e9
        self.kv_bytes_per_token = int(kv_bytes_per_token)
        self._swapped: List[Request] = []            # FCFS swap-in order
        self.host_tokens_in_use = 0
        self.swap_outs = 0
        self.swap_ins = 0
        self.swapped_out_tokens = 0
        self.swapped_in_tokens = 0
        self.swap_bytes_moved = 0
        self.reclaim_swap_decisions = 0
        self.reclaim_recompute_decisions = 0
        # swap ops the engine must mirror onto the executor before the next
        # dispatch: ("out" | "in" | "prefetch" | "prefetch_cancel", req_id,
        # tokens), in decision order
        self._swap_ops: List[Tuple[str, str, int]] = []
        # --- proactive tiering (FastServe-style offload + ALISE prefetch) ---
        self.proactive_offload = bool(proactive_offload)
        if self.proactive_offload and idle_horizon_s is None:
            # the horizon must sit well above a typical request's remaining
            # decode time: a victim below it is mid-flight work the batch
            # would have scheduled, and offloading it thrashes the swap
            # channel (measured: 2x avg-latency regression at 1s horizons on
            # the kv_pressure trace). 8s only catches genuine stragglers.
            idle_horizon_s = 8.0
        self.idle_horizon_s = idle_horizon_s
        self.swap_prefetch = bool(swap_prefetch)
        self.proactive_offloads = 0
        self.swap_prefetches = 0
        self.prefetch_cancelled = 0
        # proactively-offloaded victims: held on the host tier while admission
        # work is waiting, so offload->swap-in ping-pong can't oscillate
        self._proactive_out: Set[str] = set()
        # req_id -> tokens whose host->device copy was issued ahead of the
        # swap-in commit (the executor holds the staged blocks)
        self._prefetch_inflight: Dict[str, int] = {}
        # per-tick swap-channel state: requests resumed this tick are never
        # proactive victims in the same tick, and the queue ledger is the
        # contention term `_swap_cost_s` adds on top of the raw transfer
        self._resumed_this_tick: Set[str] = set()
        self._swap_tick_now: Optional[float] = None
        self._tick_swap_queue_s = 0.0
        # per-request charged footprint: under predicted admission the charge
        # is prediction-dependent, so releases must use the exact value that
        # was charged, not a recomputed one
        self._footprint_of: Dict[str, int] = {}
        # output-length prediction (predicted admission + DPU feed). Attached
        # only when asked for — a None predictor keeps every pre-existing
        # scheduling path untouched.
        if predictor is None and kv_admission == "predicted":
            predictor = OutputLenPredictor()
        self.predictor = predictor
        self._tmpl_key: Dict[str, int] = {}          # rel_id -> template key
        # Prefix-sharing-aware scheduling: warm-then-follow candidate pricing
        # plus shared-block KV admission (each shared prefix block charged
        # once against limits.cap). Off by default — every sharing-off code
        # path is bit-identical to the pre-sharing scheduler.
        self.prefix_sharing = bool(prefix_sharing)
        if self.prefix_sharing:
            block_size = getattr(prefix_cache, "block_size", None)
            if block_size is None:
                raise ValueError("prefix_sharing=True requires a block-based "
                                 "prefix cache (PrefixCache) on the scheduler")
            self._shared_ledger: Optional[SharedPrefixLedger] = \
                SharedPrefixLedger(block_size)
        else:
            self._shared_ledger = None
        self._prompt_keys: Dict[str, Tuple[int, ...]] = {}  # req_id -> chain
        self._kv_charged: Set[str] = set()            # req_ids in the ledger
        self.shared_tokens_saved = 0  # cumulative shared-block cap discount
        # memoized warm-then-follow orders, invalidated by any waiting-list
        # mutation (bump of _queue_version) — decode-heavy stretches rebuild
        # candidates every tick without touching the queues, and one tick
        # builds both the prefill and the mixed candidate from the same order
        self._queue_version = 0
        self._order_cache: Dict[str, Tuple[int, List[Request]]] = {}
        self.relqueries: Dict[str, RelQuery] = {}
        self.tokens_in_use = 0
        # Worst-case KV commitment: the full prompt+output footprint of every
        # request that has started prefilling (chunked or complete) and not
        # finished. Conservative admission checks use this, not tokens_in_use —
        # running requests grow into their footprint as they decode, so
        # admitting against current usage overcommits the cap. Optimistic
        # admission checks ``kv_demand()`` (current footprint) instead and
        # relies on priority-aware preemption when decode growth hits the cap.
        self.committed_tokens = 0
        # KV held by in-flight chunked prefills (chunks landed, prompt not yet
        # complete) — tokens_in_use only counts completed prefills, so the
        # optimistic demand measure needs this ledger on top.
        self.partial_prefill_tokens = 0
        self.iteration = 0
        self.finished_relqueries: List[RelQuery] = []
        # preemption instrumentation + executor-release handoff
        self.preemptions = 0
        self.preempted_tokens = 0          # KV tokens reclaimed by preemption
        self.missing_decode_outputs = 0    # decode reqs absent from BatchResult
        self._preempt_release: List[str] = []
        # Speculative-window journal (pipelined engine loop): while a
        # checkpoint is open, every shared-ledger acquire/release is logged so
        # ``rollback`` can replay the exact inverse ops. None = no open window.
        self._spec_log: Optional[List[Tuple[str, Tuple[int, ...]]]] = None
        # incremental queues
        self._waiting_of: Dict[str, List[Request]] = {}
        self._running: List[Request] = []
        self._unfinished = 0

    # ------------------------------------------------------------- queue state
    def add_relquery(self, rq: RelQuery, now: float) -> None:
        if any(r.state is not RequestState.WAITING or r.output_tokens
               or r.prefilled_tokens for r in rq.requests):
            # A relQuery with progress is a failover/drain re-admission from
            # another replica, not a fresh arrival — its generated tokens must
            # survive (preemption-style restart), not be double-queued.
            self.readmit_relquery(rq, now)
            return
        self.relqueries[rq.rel_id] = rq
        self._waiting_of[rq.rel_id] = list(rq.requests)
        self._queue_version += 1
        self._unfinished += 1
        self.on_relquery_added(rq, now)

    def readmit_relquery(self, rq: RelQuery, now: float) -> None:
        """Re-admit a relQuery recovered from another replica (crash failover
        or graceful drain). Non-terminal requests re-enter the waiting queue;
        any that already generated output restarts preemption-style — the
        next prefill pass recomputes prompt + preserved generation, and the
        preserved tokens are never re-emitted downstream. The relQuery brings
        no resident KV with it: whatever it held belonged to the replica that
        lost it, so no ledger is charged here."""
        if rq.rel_id in self.relqueries:
            raise ValueError(f"relQuery {rq.rel_id!r} is already admitted on "
                             f"this replica")
        waiting: List[Request] = []
        for r in rq.requests:
            if r.is_terminal():
                continue
            r.prefilled = False
            r.prefilled_tokens = 0
            r.finish_time = None
            if r.output_tokens:
                r.preserved_output_tokens = len(r.output_tokens)
                r.state = RequestState.PREEMPTED
            else:
                r.preserved_output_tokens = 0
                r.state = RequestState.WAITING
            waiting.append(r)
        rq.note_phase_change()
        self.relqueries[rq.rel_id] = rq
        if waiting:
            self._waiting_of[rq.rel_id] = waiting
            self._queue_version += 1
        if rq.finish_time is None and rq.cancel_time is None:
            self._unfinished += 1
        elif rq.finish_time is not None and rq.cancel_time is None:
            self.finished_relqueries.append(rq)
        self.on_relquery_added(rq, now)

    def remove_relquery(self, rel_id: str) -> Optional[RelQuery]:
        """Detach a live relQuery for migration to another replica (graceful
        drain). Only legal while it holds no replica-local KV: every
        non-terminal request WAITING or PREEMPTED with no landed chunks —
        resident work must finish (or be preempted) on this replica first.
        Unlike cancellation the relQuery stays live; the caller re-admits it
        elsewhere (``readmit_relquery``). Returns the detached relQuery, or
        None when unknown."""
        rq = self.relqueries.get(rel_id)
        if rq is None:
            return None
        for r in rq.requests:
            if r.is_terminal():
                continue
            if r.state not in (RequestState.WAITING, RequestState.PREEMPTED) \
                    or r.prefilled_tokens:
                raise ValueError(
                    f"cannot migrate relQuery {rel_id!r}: request "
                    f"{r.req_id} is {r.state.value} with resident KV")
        del self.relqueries[rel_id]
        self._waiting_of.pop(rel_id, None)
        self._order_cache.pop(rel_id, None)
        self._queue_version += 1
        self._tmpl_key.pop(rel_id, None)
        for r in rq.requests:
            self._prompt_keys.pop(r.req_id, None)
        if rq.finish_time is None and rq.cancel_time is None:
            self._unfinished -= 1
        self.on_relquery_removed(rq)
        return rq

    def on_relquery_added(self, rq: RelQuery, now: float) -> None:
        pass

    def on_relquery_removed(self, rq: RelQuery) -> None:
        pass

    def audit_ledgers(self, *, repair: bool = False) -> Dict[str, int]:
        """One audited source of truth for every token ledger, derived from
        the queues themselves: ``tokens_in_use`` is the resident KV of the
        running requests, ``partial_prefill_tokens`` the landed chunks of
        waiting requests, ``host_tokens_in_use`` the swapped population,
        ``committed_tokens`` the sum of charged footprints (the per-request
        charge is prediction-dependent, so the footprint map is the ledger of
        record, not a recomputation), and ``_unfinished`` the non-terminal
        relQuery count. ``repair=True`` assigns the derived values (the
        restore path); ``repair=False`` asserts the incremental ledgers match
        them exactly (the ``--debug-invariants`` per-tick audit)."""
        waiting = [r for lst in self._waiting_of.values() for r in lst]
        expected = {
            "tokens_in_use": sum(r.total_tokens for r in self._running),
            "partial_prefill_tokens": sum(r.prefilled_tokens for r in waiting),
            "host_tokens_in_use": sum(r.total_tokens for r in self._swapped),
            "committed_tokens": sum(self._footprint_of.values()),
            "_unfinished": sum(
                1 for rq in self.relqueries.values()
                if rq.finish_time is None and rq.cancel_time is None),
        }
        swapped_ids = {r.req_id for r in self._swapped}
        if repair:
            for key, value in expected.items():
                setattr(self, key, value)
            # proactive/prefetch tags are only meaningful for requests still
            # on the host tier — restore paths intersect them down
            self._proactive_out &= swapped_ids
            self._prefetch_inflight = {
                rid: tok for rid, tok in self._prefetch_inflight.items()
                if rid in swapped_ids}
            return expected
        for key, value in expected.items():
            got = getattr(self, key)
            assert got == value, (
                f"ledger drift: {key}={got} but queues imply {value}")
        assert self._proactive_out <= swapped_ids, (
            f"proactive-offload tags for non-swapped requests: "
            f"{sorted(self._proactive_out - swapped_ids)}")
        assert set(self._prefetch_inflight) <= swapped_ids, (
            f"prefetch staged for non-swapped requests: "
            f"{sorted(set(self._prefetch_inflight) - swapped_ids)}")
        owners = {r.req_id for r in self._running}
        owners |= {r.req_id for r in waiting if r.prefilled_tokens}
        charged = set(self._footprint_of)
        assert charged == owners, (
            f"footprint ledger drift: charged-but-not-resident="
            f"{sorted(charged - owners)}, resident-but-uncharged="
            f"{sorted(owners - charged)}")
        return expected

    def active_relqueries(self) -> List[RelQuery]:
        return [rq for rq in self.relqueries.values()
                if not rq.is_finished() and not rq.cancelled]

    def waiting_requests(self) -> List[Request]:
        out = []
        for rel_id in self._waiting_of:
            out.extend(self._waiting_of[rel_id])
        return out

    def running_requests(self) -> List[Request]:
        return list(self._running)

    def running_rqs(self) -> List[RelQuery]:
        seen, out = set(), []
        for r in self._running:
            if r.rel_id not in seen:
                seen.add(r.rel_id)
                out.append(self.relqueries[r.rel_id])
        return out

    def waiting_rqs(self) -> List[RelQuery]:
        running = {r.rel_id for r in self._running}
        return [self.relqueries[rel_id] for rel_id, lst in self._waiting_of.items()
                if lst and rel_id not in running]

    def has_work(self) -> bool:
        return self._unfinished > 0

    def swapped_requests(self) -> List[Request]:
        return list(self._swapped)

    def swapped_rqs(self) -> List[RelQuery]:
        seen, out = set(), []
        for r in self._swapped:
            if r.rel_id not in seen:
                seen.add(r.rel_id)
                out.append(self.relqueries[r.rel_id])
        return out

    def queue_depth(self) -> int:
        """Outstanding requests (waiting + running + swapped) without copying
        the queues — the router polls this on every arrival."""
        return (sum(len(lst) for lst in self._waiting_of.values())
                + len(self._running) + len(self._swapped))

    def stuck_rel_ids(self) -> List[str]:
        """relQueries with queued work (used in deadlock diagnostics)."""
        ids = {rel_id for rel_id, lst in self._waiting_of.items() if lst}
        ids.update(r.rel_id for r in self._running)
        ids.update(r.rel_id for r in self._swapped)
        return sorted(ids)

    # ------------------------------------------------------------- candidates
    def rq_sort_key(self, rq: RelQuery):
        """Waiting-queue order: ascending priority, FCFS tie-break."""
        return (rq.priority, rq.arrival_time, rq.rel_id)

    def sorted_waiting_rqs(self) -> List[RelQuery]:
        rqs = [self.relqueries[rel_id] for rel_id, lst in self._waiting_of.items() if lst]
        rqs.sort(key=self.rq_sort_key)
        return rqs

    def build_decode_candidate(self) -> Optional[Batch]:
        if not self._running:
            return None
        return Batch.decode(self._running[: self.limits.max_num_seqs])

    def estimated_utok(self, r: Request) -> int:
        """Estimated uncached tokens of the whole remaining prompt — the
        chunk estimate with the chunk covering everything left."""
        remaining = r.prefill_target_tokens - r.prefilled_tokens
        return max(1, self.estimated_chunk_utok(r, remaining))

    def estimated_chunk_utok(self, r: Request, chunk: int) -> int:
        """Estimated uncached tokens of the next ``chunk`` prompt tokens,
        mirroring the executor's chunked-prefill cache accounting with the
        sampled miss ratio in place of an exact prefix-cache probe. A
        preempted request's preserved generation is part of the target and
        never prefix-cached."""
        rq = self.relqueries[r.rel_id]
        n = r.num_prompt_tokens
        est_cached = n - max(1, round(n * rq.cache_miss_ratio))
        done = r.prefilled_tokens
        return max(0, min(done + chunk, r.prefill_target_tokens)
                   - max(done, est_cached))

    def _kv_footprint(self, r: Request) -> int:
        """KV a request is charged against the cap for. Conservative and
        optimistic admission charge the worst case (prompt+OL — the bound
        also covers preempted restarts: preserved tokens count toward OL).
        Predicted admission charges ``prompt + predicted_OL`` instead,
        clamped to at least what the request already holds plus one token
        (a request can't be charged below its resident KV) and never above
        the worst case. With no history the predictor abstains and the
        worst case applies."""
        worst = r.num_prompt_tokens + r.max_output_tokens
        if self.kv_admission != "predicted" or self.predictor is None:
            return worst
        pred = self.predictor.predict(self._template_key(r))
        if pred is None:
            return worst
        expected = r.num_prompt_tokens + max(pred, len(r.output_tokens) + 1)
        return min(worst, max(expected, r.total_tokens + 1))

    def _template_key(self, r: Request) -> int:
        key = self._tmpl_key.get(r.rel_id)
        if key is None:
            key = self.predictor.key_of(self.relqueries[r.rel_id])
            self._tmpl_key[r.rel_id] = key
        return key

    # ------------------------------------------------------------- prefix sharing
    def prompt_block_keys(self, r: Request) -> Tuple[int, ...]:
        """Chained block keys of ``r``'s prompt (cached — prompts are
        immutable). Only meaningful with prefix sharing enabled."""
        keys = self._prompt_keys.get(r.req_id)
        if keys is None:
            keys = tuple(block_hashes(r.tokens, self._shared_ledger.block_size))
            self._prompt_keys[r.req_id] = keys
        return keys

    def _sharing_order(self, rel_id: str,
                       reqs: Sequence[Request]) -> List[Request]:
        """Warm-then-follow order: lexicographic in the block-key chain, so
        requests sharing a prefix run adjacently — the first of each run is
        the leader that warms the cache for its followers. Preempted restarts
        keep their head-of-queue position; the sort is stable, so identical
        chains stay FCFS. Memoized until the next waiting-list mutation to
        preserve the module's O(#relQueries + batch size) iteration cost."""
        cached = self._order_cache.get(rel_id)
        if cached is not None and cached[0] == self._queue_version:
            return cached[1]
        ordered = sorted(reqs, key=lambda r: (
            r.state is not RequestState.PREEMPTED,
            self.prompt_block_keys(r)))
        self._order_cache[rel_id] = (self._queue_version, ordered)
        return ordered

    def _sharing_utok(self, r: Request, warm_keys: Set[int],
                      chunk: Optional[int] = None) -> Tuple[int, int]:
        """Exact-probe estimate for ``r``'s next ``chunk`` prompt tokens
        (default: all remaining), assuming ``warm_keys`` are resident by the
        time ``r`` executes — the post-leader hit rate of a follower in a
        warm-then-follow candidate. One chain walk returns both ``(uncached
        tokens, tokens saved vs a cache-only probe)`` — the saving is the
        intra-candidate reuse instrumentation, and walking twice for it
        would double the hot path's probe cost. Preserved generation
        (preempted restarts) is never prefix-cached."""
        cached = cold_cached = 0
        cold_alive = True       # the cache-only walk stops at the first
        block_size = self._shared_ledger.block_size   # warm-only block
        for k in self.prompt_block_keys(r):
            resident = self.prefix_cache.has_block(k)
            if resident or k in warm_keys:
                cached += block_size
                if resident and cold_alive:
                    cold_cached += block_size
                else:
                    cold_alive = False
            else:
                break
        done = r.prefilled_tokens
        target = r.prefill_target_tokens
        if chunk is None:
            chunk = target - done
        end = min(done + chunk, target)
        u = max(0, end - max(done, cached))
        u_cold = max(0, end - max(done, cold_cached))
        return u, u_cold - u

    def _shared_resident_tokens(self, r: Request,
                                pending_keys: Optional[Set[int]] = None) -> int:
        """Leading prompt tokens of ``r`` already charged against the cap by a
        live sibling (ledger) or by an earlier request of the candidate under
        construction (``pending_keys``) — admission may discount them."""
        if self._shared_ledger is None:
            return 0
        n = 0
        for k in self.prompt_block_keys(r):
            if self._shared_ledger.contains(k) or \
                    (pending_keys is not None and k in pending_keys):
                n += self._shared_ledger.block_size
            else:
                break
        return n

    def _kv_acquire(self, r: Request) -> None:
        """Register ``r``'s prompt chain in the shared-block ledger and pin
        the blocks against prefix-cache eviction. Timing is mode-dependent:
        conservative charges full footprints at the first chunk, so the chain
        is acquired there; optimistic charges only resident KV, so the chain
        is acquired at prompt *completion* — discounting a full chain while
        only partial chunks are resident would understate (even negate)
        ``kv_demand()`` and over-admit past the cap."""
        if self._shared_ledger is None or r.req_id in self._kv_charged:
            return
        keys = self.prompt_block_keys(r)
        self._kv_charged.add(r.req_id)
        self.shared_tokens_saved += self._shared_ledger.acquire(keys)
        self.prefix_cache.acquire_blocks(keys)
        if self._spec_log is not None:
            self._spec_log.append(("acquire", keys))

    def _kv_release(self, r: Request) -> None:
        """Drop ``r``'s charge from the shared-block ledger (finish, preempt
        or cancel). Blocks still referenced by siblings stay charged through
        the survivors — a victim never frees a sibling's shared prefix."""
        if self._shared_ledger is None or r.req_id not in self._kv_charged:
            return
        self._kv_charged.discard(r.req_id)
        keys = self.prompt_block_keys(r)
        self._shared_ledger.release(keys)
        self.prefix_cache.release_blocks(keys)
        if self._spec_log is not None:
            self._spec_log.append(("release", keys))

    # ------------------------------------------------------------- KV admission
    def kv_demand(self) -> int:
        """Tokens the admission check must assume resident. Conservative:
        worst-case commitment of every started request. Optimistic: the KV
        actually held right now (completed prefills + generation so far +
        landed chunks). Predicted: the larger of the predicted commitment and
        the resident KV — a request that outgrows its predicted footprint
        keeps counting in full, so under-predictions throttle admission
        instead of silently overcommitting (preemption is the safety valve
        past that). With prefix sharing the raw per-request charges are kept
        unchanged and the ledger's discount — tokens counted more than once
        because they live in shared blocks — is subtracted, so shared blocks
        count once against ``limits.cap`` in every mode. Swapped requests
        hold nothing on device and contribute to no term here — their KV is
        accounted in ``host_tokens_in_use``."""
        if self.kv_admission == "conservative":
            raw = self.committed_tokens
        elif self.kv_admission == "optimistic":
            raw = self.tokens_in_use + self.partial_prefill_tokens
        else:
            raw = max(self.committed_tokens,
                      self.tokens_in_use + self.partial_prefill_tokens)
        if self._shared_ledger is not None:
            return raw - self._shared_ledger.discount
        return raw

    def _resident_demand(self) -> int:
        """KV physically on the device right now (the optimistic measure,
        mode-independent) — what headroom preemption and swap-in gating must
        check against: committed-but-unwritten footprint can't overflow the
        device, resident KV can."""
        raw = self.tokens_in_use + self.partial_prefill_tokens
        if self._shared_ledger is not None:
            return raw - self._shared_ledger.discount
        return raw

    def _admission_need(self, r: Request,
                        pending_keys: Optional[Set[int]] = None) -> int:
        """Cap headroom required to schedule the rest of ``r``'s prefill.
        Conservative/predicted: the full (worst-case/predicted) footprint,
        charged once (already-started requests are pre-committed).
        Optimistic: only the KV this prefill pass will write, plus the decode
        token emitted on completion. Under prefix sharing both shrink by the
        prefix already charged by siblings — those blocks are resident once
        no matter how many requests share them. A request already charged
        (mid-chunk) gets no discount: its own chain is what the ledger holds,
        and its remaining chunks are raw."""
        shared = 0 if r.req_id in self._kv_charged else \
            self._shared_resident_tokens(r, pending_keys)
        if self.kv_admission != "optimistic":
            if r.prefilled_tokens:
                return 0
            return max(0, self._kv_footprint(r) - shared)
        uncharged = max(0, r.prefill_target_tokens
                        - max(r.prefilled_tokens, shared))
        return uncharged + 1

    def build_prefill_candidate(self, single_relquery: bool = True) -> Optional[Batch]:
        full_order = self.sorted_waiting_rqs()
        if not full_order:
            return None
        order = full_order[:1] if single_relquery else full_order
        sharing = self._shared_ledger is not None
        chosen: List[Request] = []
        utok_sum, full_tok_sum = 0, 0
        # warm-then-follow state: keys the candidate's leaders will have
        # inserted by the time a follower prefills, and the estimated tokens
        # that intra-candidate reuse saves (ABA instrumentation)
        warm_keys: Set[int] = set()
        pending_keys: Set[int] = set()
        shared_est = 0
        for rq in order:
            waiting = self._waiting_of[rq.rel_id]
            if sharing:
                waiting = self._sharing_order(rq.rel_id, waiting)
            for r in waiting:
                if sharing:
                    # exact probe, priced at the post-leader hit rate: the
                    # leader of each shared-prefix run pays its real misses,
                    # followers only their divergent suffix
                    u, saved = self._sharing_utok(r, warm_keys)
                    u = max(1, u)
                else:
                    u, saved = self.estimated_utok(r), 0
                if chosen and utok_sum + u > self.limits.max_num_batched_tokens:
                    break
                if len(chosen) + 1 > self.limits.max_num_seqs:
                    break
                needed = self._admission_need(r, pending_keys)
                if self.kv_demand() + full_tok_sum + needed > self.limits.cap:
                    break  # head-of-line: don't skip ahead of the cap-blocked rq
                chosen.append(r)
                utok_sum += u
                full_tok_sum += needed
                shared_est += saved
                if sharing:
                    keys = self.prompt_block_keys(r)
                    warm_keys.update(keys)
                    pending_keys.update(keys)
            else:
                continue
            break
        if chosen:
            rel = self.relqueries[chosen[0].rel_id] if single_relquery else None
            return Batch.prefill(chosen, uncached_tokens=utok_sum, relquery=rel,
                                 shared_prefix_tokens=shared_est)
        # Cap-blocked head of line. Fall back to requests whose KV is already
        # committed (partially chunked): under conservative admission finishing
        # them adds nothing to the commitment and is the only way the queue can
        # drain — without this, a committed request stranded behind a too-big
        # newcomer would turn into a spurious engine deadlock. Under optimistic
        # admission a mid-chunk request's *remaining* prefill is NOT yet
        # resident, so it still needs real cap headroom — requests that don't
        # fit are skipped (if none fit, the engine's preempt-and-retry reclaims
        # someone's partial chunks instead of overshooting the device cap).
        for rq in full_order:
            committed = [r for r in self._waiting_of[rq.rel_id] if r.prefilled_tokens]
            reqs, utok, need_sum = [], 0, 0
            for r in committed:   # same budget discipline as the main path
                u = self.estimated_utok(r)
                if reqs and (utok + u > self.limits.max_num_batched_tokens
                             or len(reqs) >= self.limits.max_num_seqs):
                    break
                if self.kv_admission == "optimistic":
                    need = self._admission_need(r)
                    if self.kv_demand() + need_sum + need > self.limits.cap:
                        continue   # its remaining chunks don't fit right now
                    need_sum += need
                reqs.append(r)
                utok += u
            if reqs:
                return Batch.prefill(reqs, uncached_tokens=utok,
                                     relquery=rq if single_relquery else None)
        return None

    def build_mixed_candidate(self, single_relquery: bool = False) -> Optional[Batch]:
        """Chunked-prefill candidate (Sarathi-style): all running requests
        decode one token while prompt chunks of the head waiting request(s)
        share the leftover token budget. Chunks consume raw prompt tokens
        from the budget (the pass computes over them either way); the
        candidate's ``uncached_tokens`` is the *estimated uncached* share, so
        ABA prices it on the same cache-discounted scale as pure prefill.
        Starting a chunk commits the request's whole prompt+output KV
        footprint against the cap (tracked in ``committed_tokens``)."""
        decode_reqs = self.running_requests()[: self.limits.max_num_seqs]
        budget = max(0, self.limits.max_num_batched_tokens - len(decode_reqs))
        sharing = self._shared_ledger is not None
        chunks: Dict[str, int] = {}
        prefill_reqs: List[Request] = []
        utok_sum, full_tok_sum, shared_est = 0, 0, 0
        warm_keys: Set[int] = set()
        pending_keys: Set[int] = set()
        order = self.sorted_waiting_rqs()
        if single_relquery:
            order = order[:1]
        for rq in order:
            if budget <= 0:
                break
            waiting = self._waiting_of[rq.rel_id]
            if sharing:
                waiting = self._sharing_order(rq.rel_id, waiting)
            for r in waiting:
                if budget <= 0 or \
                        len(decode_reqs) + len(prefill_reqs) >= self.limits.max_num_seqs:
                    break
                remaining = r.prefill_target_tokens - r.prefilled_tokens
                if self.kv_admission != "optimistic":
                    needed = self._admission_need(r, pending_keys)
                    if self.kv_demand() + full_tok_sum + needed > self.limits.cap:
                        budget = 0
                        break
                    chunk = min(remaining, budget)
                else:
                    # optimistic: the chunk itself is the commitment; shrink it
                    # to the cap headroom left after this pass's decode growth
                    free = self.limits.cap - self.kv_demand() \
                        - len(decode_reqs) - full_tok_sum
                    chunk = min(remaining, budget, max(0, free))
                    if chunk == remaining and chunk + 1 > free:
                        chunk -= 1   # completing the prompt emits a decode token
                    if chunk <= 0:
                        budget = 0
                        break
                    needed = chunk + (1 if chunk == remaining else 0)
                chunks[r.req_id] = chunk
                prefill_reqs.append(r)
                budget -= chunk
                if sharing:
                    u, saved = self._sharing_utok(r, warm_keys, chunk)
                    shared_est += saved
                    keys = self.prompt_block_keys(r)
                    completes = r.prefilled_tokens + chunk >= \
                        r.prefill_target_tokens
                    # the executor inserts a prompt into the prefix cache only
                    # when it *completes*: a partial chunk warms nothing yet
                    if completes:
                        warm_keys.update(keys)
                    # ledger membership mirrors _kv_acquire timing: first
                    # chunk (conservative/predicted) vs prompt completion
                    # (optimistic)
                    if completes or self.kv_admission != "optimistic":
                        pending_keys.update(keys)
                else:
                    u = self.estimated_chunk_utok(r, chunk)
                utok_sum += u
                full_tok_sum += needed
        if not decode_reqs and not prefill_reqs:
            return None
        return Batch.mixed(prefill_reqs, decode_reqs, chunks,
                           uncached_tokens=utok_sum,
                           shared_prefix_tokens=shared_est)

    # ------------------------------------------------------------- cancellation
    def cancel_relquery(self, rel_id: str, now: float) -> List[Request]:
        """Evict every waiting and running request of ``rel_id`` and reclaim
        its KV commitment. The relQuery becomes terminal (``cancel_time`` set)
        and is excluded from latency reporting; already-finished requests keep
        their outputs. Returns the evicted requests (for executor cleanup —
        they may hold decode slots). Idempotent: a finished or already
        cancelled relQuery returns []."""
        rq = self.relqueries.get(rel_id)
        if rq is None or rq.finish_time is not None or rq.cancel_time is not None:
            return []
        cancelled = list(self._waiting_of.pop(rel_id, []))
        self._queue_version += 1
        self._order_cache.pop(rel_id, None)
        mine = [r for r in self._running if r.rel_id == rel_id]
        if mine:
            self._running = [r for r in self._running if r.rel_id != rel_id]
            cancelled.extend(mine)
        mine_swapped = [r for r in self._swapped if r.rel_id == rel_id]
        if mine_swapped:
            self._swapped = [r for r in self._swapped if r.rel_id != rel_id]
            cancelled.extend(mine_swapped)
        for r in cancelled:
            # RUNNING requests hold prompt + generated tokens in the KV cache;
            # requests mid-chunk hold their landed chunks; SWAPPED requests
            # hold host-tier KV only (their committed charge was dropped at
            # swap-out, and the executor frees their host stash on release).
            # Any charged request releases the exact footprint it was charged
            # (mirrors complete_batch / _finish_request accounting). PREEMPTED
            # requests hold nothing — their KV was reclaimed at preemption.
            if r.state == RequestState.RUNNING:
                self.tokens_in_use -= r.total_tokens
            elif r.state == RequestState.SWAPPED:
                self.host_tokens_in_use -= r.total_tokens
            elif r.prefilled_tokens > 0:
                self.partial_prefill_tokens -= r.prefilled_tokens
            fp = self._footprint_of.pop(r.req_id, None)
            if fp is not None:
                self.committed_tokens -= fp
            self._kv_release(r)
            self._prompt_keys.pop(r.req_id, None)
            r.state = RequestState.CANCELLED
            r.finish_time = now
        pending_prefetch = {op[1] for op in self._swap_ops
                            if op[0] == "prefetch"}
        if self._swap_ops:
            # drop not-yet-drained swap ops for the cancelled requests: the
            # engine releases their executor state directly, so mirroring a
            # stale op would copy KV for a request that no longer exists
            gone = {r.req_id for r in cancelled}
            self._swap_ops = [op for op in self._swap_ops
                              if op[1] not in gone]
        # cancel-while-prefetching: a staged swap-in for a cancelled request
        # must release its device staging and refund this tick's bandwidth
        # ledger — the copy never happens, so the channel time it reserved is
        # given back. Prefetch ops still queued locally were purged above;
        # ops already drained to the executor need an explicit cancel op so
        # the staged device blocks are freed.
        for r in cancelled:
            self._proactive_out.discard(r.req_id)
            staged = self._prefetch_inflight.pop(r.req_id, None)
            if staged is None:
                continue
            self.prefetch_cancelled += 1
            self._tick_swap_queue_s = max(
                0.0, self._tick_swap_queue_s - self._xfer_s(staged))
            if r.req_id not in pending_prefetch:
                self._swap_ops.append(("prefetch_cancel", r.req_id, staged))
        rq.note_phase_change()
        rq.cancel_time = now
        self._unfinished -= 1
        self.on_relquery_cancelled(rq, now)
        return cancelled

    def on_relquery_cancelled(self, rq: RelQuery, now: float) -> None:
        pass

    # ------------------------------------------------------------- preemption
    def preempt_request(self, r: Request, now: float) -> None:
        """Reclaim ``r``'s KV under memory pressure. A RUNNING victim moves to
        ``PREEMPTED`` at the front of its relQuery's waiting list and restarts
        recompute-style (re-prefill of prompt + generation so far, generated
        tokens preserved); a mid-chunk victim just loses its landed chunks.
        The engine drains ``drain_preempt_releases`` to free executor slots."""
        rq = self.relqueries[r.rel_id]
        if r.state == RequestState.RUNNING:
            self.tokens_in_use -= r.total_tokens
            self.preempted_tokens += r.total_tokens
            self._running.remove(r)
            r.preserved_output_tokens = len(r.output_tokens)
            r.prefilled = False
            r.state = RequestState.PREEMPTED
            rq.note_phase_change()
            self._waiting_of.setdefault(r.rel_id, []).insert(0, r)
            self._queue_version += 1
        elif r.prefilled_tokens > 0:
            self.partial_prefill_tokens -= r.prefilled_tokens
            self.preempted_tokens += r.prefilled_tokens
        else:
            return                      # nothing on the device: no-op
        self.committed_tokens -= self._footprint_of.pop(
            r.req_id, self._kv_footprint(r))
        # the victim's ledger charge is dropped, but blocks its siblings still
        # reference stay discounted — preemption never frees shared KV twice
        self._kv_release(r)
        r.prefilled_tokens = 0
        self.preemptions += 1
        rq.preemptions += 1
        self._preempt_release.append(r.req_id)

    def drain_preempt_releases(self) -> List[str]:
        """req_ids preempted since the last drain — the engine frees their
        executor-side decode slots."""
        out, self._preempt_release = self._preempt_release, []
        return out

    # ------------------------------------------------------------- KV tiering
    def _xfer_s(self, tokens: int) -> float:
        """One-way transfer time of ``tokens`` of KV over the host link at
        the full budget — the unit the per-tick queue ledger accumulates."""
        return tokens * self.kv_bytes_per_token / self.swap_bandwidth_bytes

    def _swap_cost_s(self, tokens: int) -> float:
        """Modeled wall time to move ``tokens`` of KV device->host AND back
        (a swap is only worth taking if the round trip beats re-prefill).
        Swaps already decided this tick share the ``swap_bandwidth_gbps``
        budget, so the round trip queues behind them — under a swap storm
        the contention term pushes the break-even toward recompute. A tick's
        first swap sees an empty queue and prices exactly as the
        pre-contention model did."""
        return self._tick_swap_queue_s + 2.0 * self._xfer_s(tokens)

    def _should_swap(self, r: Request) -> bool:
        """Per-victim reclaim decision: swap beats recompute when moving the
        victim's KV over the host link (both ways) costs less than
        re-prefilling ``prompt + generation so far`` at the measured prefill
        rate — and the host tier has room. Mid-chunk victims always
        recompute: their partial chunks are not a resumable sequence."""
        if not self.kv_tiering or r.state != RequestState.RUNNING:
            return False
        tokens = r.total_tokens
        if self.host_tokens_in_use + tokens > self.host_kv_cap:
            return False
        recompute_s = self.lm.prefill_time(
            r.num_prompt_tokens + len(r.output_tokens))
        return self._swap_cost_s(tokens) < recompute_s

    def _reclaim(self, r: Request, now: float) -> None:
        """Reclaim a victim's device KV: swap to the host tier when the cost
        model favors it, recompute-preempt otherwise."""
        if self._should_swap(r):
            self.reclaim_swap_decisions += 1
            self.swap_out_request(r, now)
        else:
            if self.kv_tiering and r.state == RequestState.RUNNING:
                self.reclaim_recompute_decisions += 1
            self.preempt_request(r, now)

    def swap_out_request(self, r: Request, now: float) -> None:
        """Park a RUNNING victim's KV on the host tier. Unlike recompute
        preemption the request keeps its prefill progress and outputs: it
        resumes decoding (state SWAPPED -> RUNNING) once its blocks are
        swapped back — no re-prefill pass. The engine mirrors the move onto
        the executor via ``drain_swap_ops``."""
        rq = self.relqueries[r.rel_id]
        assert r.state == RequestState.RUNNING, r.state
        tokens = r.total_tokens
        self.tokens_in_use -= tokens
        self.committed_tokens -= self._footprint_of.pop(
            r.req_id, self._kv_footprint(r))
        self._running.remove(r)
        self._kv_release(r)
        r.state = RequestState.SWAPPED
        rq.note_phase_change()
        self._swapped.append(r)
        self.host_tokens_in_use += tokens
        self.swap_outs += 1
        self.swapped_out_tokens += tokens
        self.swap_bytes_moved += tokens * self.kv_bytes_per_token
        self._tick_swap_queue_s += self._xfer_s(tokens)
        self._swap_ops.append(("out", r.req_id, tokens))

    def _swap_in_request(self, r: Request, now: float) -> None:
        rq = self.relqueries[r.rel_id]
        assert r.state == RequestState.SWAPPED, r.state
        tokens = r.total_tokens
        self._swapped.remove(r)
        self.host_tokens_in_use -= tokens
        r.state = RequestState.RUNNING
        rq.note_phase_change()
        self._running.append(r)
        self.tokens_in_use += tokens
        fp = self._kv_footprint(r)
        self._footprint_of[r.req_id] = fp
        self.committed_tokens += fp
        self._kv_acquire(r)
        self.swap_ins += 1
        self.swapped_in_tokens += tokens
        self.swap_bytes_moved += tokens * self.kv_bytes_per_token
        self._proactive_out.discard(r.req_id)
        self._resumed_this_tick.add(r.req_id)
        if self._prefetch_inflight.pop(r.req_id, None) is None:
            # un-prefetched resume: the copy happens now and occupies the
            # shared channel this tick (a prefetched one already paid when
            # the copy was issued)
            self._tick_swap_queue_s += self._xfer_s(tokens)
        self._swap_ops.append(("in", r.req_id, tokens))

    def _swap_in_blocked(self, r: Request) -> bool:
        """A swapped request the resume scan must pass over: its relQuery is
        parked (the KV was offloaded *because* nobody will decode it), or it
        is a proactive victim and admission work is still waiting — resuming
        it would undo the offload and ping-pong against the next tick's
        pressure. Proactive victims resume once the waiting queue drains."""
        if self.relqueries[r.rel_id].parked:
            return True
        return (r.req_id in self._proactive_out
                and any(self._waiting_of.values()))

    def _pick_swap_in_candidate(self) -> Optional[Request]:
        """Next resume candidate: the first swapped request not blocked.
        With nothing blocked this is the FCFS head — identical to the
        pre-proactive scheduler."""
        for r in self._swapped:
            if not self._swap_in_blocked(r):
                return r
        return None

    def _maybe_swap_in(self, now: float) -> None:
        """Bring swapped requests back to device, FCFS (skipping blocked
        entries — parked relQueries and held proactive victims), while the
        *resident* measure plus one decode step fits under the cap. Progress
        guarantee: with nothing running and nothing waiting, the candidate
        swaps in as long as it alone fits the cap — a replica whose whole
        population is on the host tier must not idle forever. With prefetch
        enabled, the next candidate's host->device copy is issued now so a
        later commit finds the blocks already staged."""
        while self._swapped:
            r = self._pick_swap_in_candidate()
            if r is None:
                break
            tokens = r.total_tokens
            growth = min(len(self._running) + 1, self.limits.max_num_seqs)
            fits = (len(self._running) < self.limits.max_num_seqs
                    and self._resident_demand() + tokens + growth
                    <= self.limits.cap)
            force = (not self._running
                     and not any(self._waiting_of.values())
                     and self._resident_demand() + tokens <= self.limits.cap)
            if not (fits or force):
                break
            self._swap_in_request(r, now)
        if self.swap_prefetch:
            self._issue_swap_prefetch(now)

    def _issue_swap_prefetch(self, now: float) -> None:
        """Start the next resume candidate's host->device copy one tick
        early: the executor stages the blocks under this tick's compute, so
        when ``_maybe_swap_in`` commits the resume the copy has already been
        paid for. One candidate deep — prefetching further would speculate
        on a resume order that pressure may reshuffle. Timing-only: the
        resume decision itself is unchanged, so token streams are
        bit-identical prefetch-on vs off."""
        r = self._pick_swap_in_candidate()
        if r is None or r.req_id in self._prefetch_inflight:
            return
        tokens = r.total_tokens
        self._prefetch_inflight[r.req_id] = tokens
        self.swap_prefetches += 1
        self._tick_swap_queue_s += self._xfer_s(tokens)
        self._swap_ops.append(("prefetch", r.req_id, tokens))

    def _proactive_offload_tick(self, now: float) -> None:
        """FastServe-style proactive offload, run after resumes and *before*
        ``preempt_for_headroom``/``choose_batch`` — victims leave the running
        list before the batch is chosen, so a scheduled request is never
        evicted by construction. Three idle-tail victim classes:

        1. requests of parked relQueries (a derive stage blocked on upstream
           DAG results): their device KV is dead weight until unparked;
        2. overflow stragglers past the decode batch width: the decode
           candidate can never include them this tick;
        3. under pre-pressure (the head-of-line admission need does not fit
           the cap), the running request with the largest predicted remaining
           work, while that estimate exceeds the idle horizon.

        Victims are tagged in ``_proactive_out`` so ``_maybe_swap_in`` holds
        them on the host tier while admission work is waiting; requests
        resumed this tick are never re-offloaded in the same tick."""
        def can_offload(r: Request) -> bool:
            return (r.state == RequestState.RUNNING
                    and r.req_id not in self._resumed_this_tick
                    and self.host_tokens_in_use + r.total_tokens
                    <= self.host_kv_cap)

        def offload(r: Request) -> None:
            self.proactive_offloads += 1
            self._proactive_out.add(r.req_id)
            self.swap_out_request(r, now)

        for r in [r for r in self._running
                  if self.relqueries[r.rel_id].parked]:
            if can_offload(r):
                offload(r)
        width = min(self.limits.max_num_seqs,
                    self.limits.max_num_batched_tokens)
        for r in list(self._running[width:]):
            if can_offload(r):
                offload(r)
        if self.idle_horizon_s is None:
            return
        while True:
            need = self._progress_need()
            if need <= 0 or self.kv_demand() + need <= self.limits.cap:
                break       # no pre-pressure: nothing to make headroom for
            best: Optional[Request] = None
            best_s = self.idle_horizon_s
            for r in self._running:
                if not can_offload(r):
                    continue
                rem_s = self._predicted_remaining_s(r)
                if rem_s > best_s:
                    best, best_s = r, rem_s
            if best is None:
                break
            offload(best)

    def _predicted_remaining_s(self, r: Request) -> float:
        """Expected remaining decode wall time of ``r`` — the idle-horizon
        yardstick. Predictor-driven when history exists, worst-case
        ``remaining_output`` otherwise."""
        rem: Optional[int] = None
        if self.predictor is not None:
            rem = self.predictor.predicted_remaining(
                self._template_key(r), len(r.output_tokens))
        if rem is None:
            rem = r.remaining_output
        return rem * self.lm.decode_time(1)

    def drain_swap_ops(self) -> List[Tuple[str, str, int]]:
        """Swap decisions since the last drain, in order — the engine mirrors
        each onto the executor (device<->host copies) before dispatching the
        next batch."""
        out, self._swap_ops = self._swap_ops, []
        return out

    def _pick_preemption_victim(self) -> Optional[Request]:
        """Lowest-priority victim per the DPU: the running relQuery with the
        *highest* priority value (ascending priority == most urgent first, the
        same order ``rq_sort_key`` gives the waiting queue — FCFS baselines
        therefore preempt the latest arrival). Within the victim relQuery,
        the most recently started request yields first (least wasted work)."""
        rqs = self.running_rqs()
        if not rqs:
            return None
        victim_rq = max(rqs, key=self.rq_sort_key)
        for r in reversed(self._running):
            if r.rel_id == victim_rq.rel_id:
                return r
        return None

    def preempt_for_headroom(self, now: float) -> None:
        """Pressure valve for the preempting admission modes, run before
        every batch choice: while the next decode step over the running queue
        would exceed the cap, reclaim victims (swap or recompute, per the
        cost model) until it fits (or nothing is left running). The trigger
        is the *resident* measure — identical to ``kv_demand()`` under
        optimistic admission; under predicted admission the committed term is
        prediction headroom, not device bytes, so it must not trip the
        valve."""
        while self._running:
            growth = min(len(self._running), self.limits.max_num_seqs)
            if self._resident_demand() + growth <= self.limits.cap:
                break
            victim = self._pick_preemption_victim()
            if victim is None:
                break
            self._reclaim(victim, now)

    def preempt_for_progress(self, now: float) -> List[Request]:
        """Engine-deadlock escape hatch: when no batch is schedulable but work
        remains, reclaim low-priority KV and let the engine retry — running
        requests first, else mid-chunk requests' landed chunks (two half-loaded
        prompts can wedge against the cap with nothing running). Victims are
        picked in a *batch* per retry round: keep preempting until the
        head-of-line request's admission need fits under the cap, so one
        engine retry (one full re-sort of the waiting queue) suffices instead
        of one re-sort per victim. Returns the victims ([] when nothing can be
        preempted — conservative mode, or no KV left to reclaim: a genuine
        deadlock)."""
        if self.kv_admission == "conservative":
            return []
        victims: List[Request] = []
        while self.kv_demand() + self._progress_need() > self.limits.cap:
            victim = self._pick_preemption_victim() or self._pick_chunk_victim()
            if victim is None:
                break
            self._reclaim(victim, now)
            victims.append(victim)
        if not victims:
            # Cap pressure wasn't the (measurable) blocker — fall back to the
            # single-victim escape so the engine's retry loop still terminates
            # by strictly shrinking resident KV each round.
            victim = self._pick_preemption_victim() or self._pick_chunk_victim()
            if victim is None:
                return []
            self._reclaim(victim, now)
            victims.append(victim)
        return victims

    def _progress_need(self) -> int:
        """Cap headroom the head-of-line waiting request needs — the target
        ``preempt_for_progress`` batches victims toward. Mirrors
        ``build_prefill_candidate``'s order: highest-urgency relQuery, its
        first request in sharing (or FCFS) order."""
        order = self.sorted_waiting_rqs()
        if not order:
            return 0
        rq = order[0]
        waiting = self._waiting_of[rq.rel_id]
        if self._shared_ledger is not None:
            waiting = self._sharing_order(rq.rel_id, waiting)
        return self._admission_need(waiting[0])

    def _pick_chunk_victim(self) -> Optional[Request]:
        """A mid-chunk waiting request holding partial KV, from the
        lowest-priority relQuery that has one. Preempting it strictly shrinks
        resident partial KV, so the engine's retry loop terminates."""
        best_rq = None
        for rel_id, lst in self._waiting_of.items():
            if any(r.prefilled_tokens for r in lst):
                rq = self.relqueries[rel_id]
                if best_rq is None or self.rq_sort_key(rq) > self.rq_sort_key(best_rq):
                    best_rq = rq
        if best_rq is None:
            return None
        mine = [r for r in self._waiting_of[best_rq.rel_id] if r.prefilled_tokens]
        return mine[-1]   # least queue-progress first: deterministic, minimal waste

    # ------------------------------------------------------------- lifecycle
    def schedule(self, now: float) -> Optional[Batch]:
        """Template: refresh priorities, resume swapped requests that fit
        again (tiering), proactively offload idle tails, relieve KV pressure
        (preempting admission modes), then let the policy pick this
        iteration's batch."""
        self.refresh_priorities(now)
        if self.kv_tiering:
            if now != self._swap_tick_now:
                # fresh tick: the swap channel drained, resumes age out
                self._swap_tick_now = now
                self._tick_swap_queue_s = 0.0
                self._resumed_this_tick = set()
            self._maybe_swap_in(now)
            if self.proactive_offload:
                self._proactive_offload_tick(now)
        if self.kv_admission != "conservative":
            self.preempt_for_headroom(now)
        return self.choose_batch(now)

    def refresh_priorities(self, now: float) -> None:
        """Hook: recompute relQuery priorities before victim/batch choice."""

    def choose_batch(self, now: float) -> Optional[Batch]:
        raise NotImplementedError

    def complete_batch(self, batch: Batch, result: BatchResult,
                       start_ts: float, end_ts: float) -> None:
        self.iteration += 1
        touched_rels = set()
        for r in batch.prefill_requests:
            rq = self.relqueries[r.rel_id]
            if rq.first_prefill_start is None:
                rq.first_prefill_start = start_ts
            before = r.prefilled_tokens
            if before == 0:   # first chunk (or whole prompt) lands
                fp = self._kv_footprint(r)
                self._footprint_of[r.req_id] = fp
                self.committed_tokens += fp
                if self.kv_admission != "optimistic":
                    self._kv_acquire(r)   # leaders registered before followers
            target = r.prefill_target_tokens
            r.prefilled_tokens = min(target, before + batch.chunk_of(r))
            self.partial_prefill_tokens += r.prefilled_tokens - before
            if r.prefilled_tokens >= target and not r.prefilled:
                self.partial_prefill_tokens -= r.prefilled_tokens
                self._finish_prefill(r, rq, result, end_ts)
                touched_rels.add(r.rel_id)
        for r in batch.decode_requests:
            if r.req_id not in result.outputs:
                # The executor produced nothing for this request (e.g. its
                # slot vanished mid-batch). Fabricating a token here would
                # corrupt the output stream *and* the KV ledger — count it
                # and let the request be rescheduled instead.
                self.missing_decode_outputs += 1
                continue
            tok, finished = result.outputs[r.req_id]
            r.output_tokens.append(tok)
            self.tokens_in_use += 1
            if finished or r.remaining_output <= 0:
                self._finish_request(r, end_ts)
            touched_rels.add(r.rel_id)
        for rel_id in touched_rels:
            self._maybe_finish_relquery(self.relqueries[rel_id], end_ts)

    def _finish_prefill(self, r: Request, rq: RelQuery, result: BatchResult,
                        end_ts: float) -> None:
        r.prefilled = True
        r.state = RequestState.RUNNING
        rq.note_phase_change()
        wl = self._waiting_of.get(r.rel_id)
        if wl is not None and r in wl:
            wl.remove(r)
            self._queue_version += 1
            if not wl:
                del self._waiting_of[r.rel_id]
                self._order_cache.pop(r.rel_id, None)
        self._running.append(r)
        self.tokens_in_use += r.prefill_target_tokens
        self._kv_acquire(r)   # optimistic: chain resident only from here
        rq.last_prefill_end = end_ts   # monotone: last prefill wins
        out = result.outputs.get(r.req_id)
        if out is None:
            # Same guard as the decode path: no fabricated token 0 — the
            # prefill landed, so the request decodes next iteration instead.
            self.missing_decode_outputs += 1
            return
        tok, finished = out
        r.output_tokens.append(tok)
        self.tokens_in_use += 1
        if finished or r.remaining_output <= 0:
            self._finish_request(r, end_ts)

    def _finish_request(self, r: Request, end_ts: float) -> None:
        r.state = RequestState.FINISHED
        r.finish_time = end_ts
        self.relqueries[r.rel_id].note_phase_change()
        if r in self._running:
            self._running.remove(r)
        self.tokens_in_use -= r.total_tokens
        self.committed_tokens -= self._footprint_of.pop(
            r.req_id, self._kv_footprint(r))
        self._kv_release(r)
        self._prompt_keys.pop(r.req_id, None)
        if self.predictor is not None:
            self.predictor.observe(self._template_key(r), len(r.output_tokens))

    def _maybe_finish_relquery(self, rq: RelQuery, end_ts: float) -> None:
        if rq.finish_time is None and rq.is_finished():
            rq.finish_time = end_ts
            self.finished_relqueries.append(rq)
            self._unfinished -= 1

    # ------------------------------------------------- speculative checkpoint
    # Pipelined engine loop support: while batch N runs on device, the engine
    # projects N's completion onto the ledger and schedules batch N+1 against
    # the projection. ``checkpoint`` snapshots everything that one projected
    # ``complete_batch`` plus one speculative ``schedule`` (priority refresh,
    # headroom/progress preemptions, queue pops) can touch; ``rollback``
    # restores it bit-exactly when the device result contradicts the
    # projection (or the window must flush for an admit/cancel/snapshot).
    # Shared-ledger and prefix-pin refcounts are journaled in ``_spec_log``
    # and inverted op-by-op — no prefix-cache inserts or evictions happen
    # inside a window, so acquire/release are exact inverses.

    def checkpoint(self, batch: Batch) -> dict:
        reqs: Dict[str, Request] = {}
        for r in batch.prefill_requests:
            reqs[r.req_id] = r
        for r in batch.decode_requests:
            reqs[r.req_id] = r
        for r in self._running:
            reqs[r.req_id] = r
        for r in self._swapped:             # a speculative swap-in target
            reqs[r.req_id] = r
        for lst in self._waiting_of.values():
            for r in lst:
                if r.prefilled_tokens:      # mid-chunk: a chunk-victim target
                    reqs[r.req_id] = r
        cp = {
            "scalars": (self.tokens_in_use, self.committed_tokens,
                        self.partial_prefill_tokens, self.iteration,
                        self._unfinished, self.preemptions,
                        self.preempted_tokens, self.missing_decode_outputs,
                        self.shared_tokens_saved, self._queue_version),
            "tiering": (list(self._swapped), list(self._swap_ops),
                        self.host_tokens_in_use, self.swap_outs,
                        self.swap_ins, self.swapped_out_tokens,
                        self.swapped_in_tokens, self.swap_bytes_moved,
                        self.reclaim_swap_decisions,
                        self.reclaim_recompute_decisions,
                        set(self._proactive_out),
                        dict(self._prefetch_inflight),
                        set(self._resumed_this_tick),
                        self._swap_tick_now, self._tick_swap_queue_s,
                        self.proactive_offloads, self.swap_prefetches,
                        self.prefetch_cancelled),
            "footprints": dict(self._footprint_of),
            "waiting_of": {k: list(v) for k, v in self._waiting_of.items()},
            "running": list(self._running),
            "order_cache": dict(self._order_cache),
            "preempt_release": list(self._preempt_release),
            "n_finished_rqs": len(self.finished_relqueries),
            "kv_charged": set(self._kv_charged),
            "prompt_keys": dict(self._prompt_keys),
            "reqs": [(r, r.state, r.prefilled, r.prefilled_tokens,
                      len(r.output_tokens), r.finish_time,
                      r.preserved_output_tokens) for r in reqs.values()],
            "rqs": [(rq, rq.priority, rq.priority_fresh, rq._was_all_waiting,
                     rq.cache_miss_ratio, rq.preemptions,
                     rq.first_prefill_start, rq.last_prefill_end,
                     rq.finish_time)
                    for rq in self.relqueries.values()
                    if rq.finish_time is None and rq.cancel_time is None],
            "extra": self._checkpoint_extra(),
        }
        self._spec_log = []
        if self.predictor is not None:
            self.predictor.checkpoint()
        return cp

    def rollback(self, cp: dict) -> None:
        for op, keys in reversed(self._spec_log or []):
            if op == "acquire":
                self._shared_ledger.release(keys)
                self.prefix_cache.release_blocks(keys)
            else:
                self._shared_ledger.acquire(keys)
                self.prefix_cache.acquire_blocks(keys)
        self._spec_log = None
        if self.predictor is not None:
            self.predictor.rollback()
        (self.tokens_in_use, self.committed_tokens, self.partial_prefill_tokens,
         self.iteration, self._unfinished, self.preemptions,
         self.preempted_tokens, self.missing_decode_outputs,
         self.shared_tokens_saved, self._queue_version) = cp["scalars"]
        (self._swapped, self._swap_ops, self.host_tokens_in_use,
         self.swap_outs, self.swap_ins, self.swapped_out_tokens,
         self.swapped_in_tokens, self.swap_bytes_moved,
         self.reclaim_swap_decisions,
         self.reclaim_recompute_decisions,
         self._proactive_out, self._prefetch_inflight,
         self._resumed_this_tick,
         self._swap_tick_now, self._tick_swap_queue_s,
         self.proactive_offloads, self.swap_prefetches,
         self.prefetch_cancelled) = cp["tiering"]
        self._footprint_of = cp["footprints"]
        self._waiting_of = cp["waiting_of"]
        self._running = cp["running"]
        self._order_cache = cp["order_cache"]
        self._preempt_release = cp["preempt_release"]
        del self.finished_relqueries[cp["n_finished_rqs"]:]
        self._kv_charged = cp["kv_charged"]
        self._prompt_keys = cp["prompt_keys"]
        for (r, state, prefilled, ptoks, n_out, ft, preserved) in cp["reqs"]:
            r.state = state
            r.prefilled = prefilled
            r.prefilled_tokens = ptoks
            del r.output_tokens[n_out:]
            r.finish_time = ft
            r.preserved_output_tokens = preserved
        for (rq, prio, fresh, waswait, miss, pre, fps, lpe, ft) in cp["rqs"]:
            rq.priority = prio
            rq.priority_fresh = fresh
            rq._was_all_waiting = waswait
            rq.cache_miss_ratio = miss
            rq.preemptions = pre
            rq.first_prefill_start = fps
            rq.last_prefill_end = lpe
            rq.finish_time = ft
            rq.note_phase_change()     # invalidate any DPU phase memo
        self._restore_extra(cp["extra"])

    def discard_checkpoint(self) -> None:
        """Commit the speculative window: keep its mutations, close the
        journal."""
        self._spec_log = None
        if self.predictor is not None:
            self.predictor.discard()

    def _checkpoint_extra(self):
        """Policy hook: snapshot subclass state a speculative window touches."""
        return None

    def _restore_extra(self, extra) -> None:
        pass


class RelServeScheduler(SchedulerBase):
    """The paper's scheduler: DPU priority refresh + ABA batch choice over
    prefill, decode *and* chunked-mixed candidates."""

    name = "relserve"
    arrangement = "adaptive"   # 'adaptive' | 'prefill_first' | 'decode_first'
    enable_mixed = True        # offer a chunked-mixed candidate to ABA

    def __init__(self, limits=None, latency_model=None, prefix_cache=None,
                 dpu_config: Optional[DPUConfig] = None,
                 kv_admission: str = "conservative",
                 prefix_sharing: bool = False, **kw):
        super().__init__(limits, latency_model, prefix_cache, kv_admission,
                         prefix_sharing, **kw)
        self.dpu = DynamicPriorityUpdater(self.lm, self.limits, dpu_config)
        # ALISE-style feed: with a predictor attached, the DPU's
        # remaining-work estimate uses predicted output lengths instead of
        # the OL(R) worst case (None keeps the estimate bit-identical)
        self.dpu.predictor = self.predictor
        self.aba = AdaptiveBatchArranger(self.lm)
        # wall-clock overhead instrumentation (paper Table 6)
        self.dpu_time = 0.0
        self.aba_time = 0.0

    def on_relquery_cancelled(self, rq: RelQuery, now: float) -> None:
        # The DPU keeps a per-relQuery resample clock; drop it so the entry
        # can't alias a future relQuery reusing the id.
        self.dpu.forget(rq.rel_id)

    def on_relquery_removed(self, rq: RelQuery) -> None:
        # Migration (graceful drain) detaches the relQuery the same way
        # cancellation does as far as DPU identity is concerned: the
        # receiving replica's DPU starts it fresh.
        self.dpu.forget(rq.rel_id)

    def _checkpoint_extra(self):
        # A speculative schedule consumes DPU RNG draws and mutates the
        # resample clocks / instrumentation; restore all of it on rollback so
        # the post-flush *real* schedule sees the exact serial RNG stream.
        return (self.dpu._rng.getstate(), self.dpu._iteration,
                dict(self.dpu._last_sampled), dict(self.dpu.stats),
                dict(self.dpu._phase_memo), dict(self.aba.stats),
                self.dpu_time, self.aba_time)

    def _restore_extra(self, extra) -> None:
        (rng_state, it, sampled, dstats, memo, astats,
         self.dpu_time, self.aba_time) = extra
        self.dpu._rng.setstate(rng_state)
        self.dpu._iteration = it
        self.dpu._last_sampled = sampled
        self.dpu.stats = dstats
        self.dpu._phase_memo = memo
        self.aba.stats = astats

    def _dpu_targets(self) -> List[RelQuery]:
        """relQueries whose priority may need a refresh this iteration: every
        relQuery with waiting or running requests. Deterministic order (the
        DPU's sampling RNG is consumed in iteration order — a set here would
        make runs irreproducible across processes)."""
        out = self.running_rqs()
        seen = {rq.rel_id for rq in out}
        for rq in self.swapped_rqs():
            if rq.rel_id not in seen:
                seen.add(rq.rel_id)
                out.append(rq)
        for rel_id, lst in self._waiting_of.items():
            if lst and rel_id not in seen:
                seen.add(rel_id)
                out.append(self.relqueries[rel_id])
        return out

    def refresh_priorities(self, now: float) -> None:
        import time as _time
        t0 = _time.perf_counter()
        self.dpu.update(self._dpu_targets(), now, self.prefix_cache)
        self.dpu_time += _time.perf_counter() - t0

    def choose_batch(self, now: float) -> Optional[Batch]:
        import time as _time
        d_cand = self.build_decode_candidate()
        p_cand = self.build_prefill_candidate(single_relquery=True)
        m_cand = None
        if self.enable_mixed and d_cand is not None and p_cand is not None:
            m_cand = self.build_mixed_candidate(single_relquery=True)
            if m_cand is not None and not m_cand.prefill_requests:
                m_cand = None  # nothing to chunk: identical to the decode cand
        candidates = [c for c in (p_cand, d_cand, m_cand) if c is not None]
        if not candidates:
            return None

        t0 = _time.perf_counter()
        if self.arrangement == "adaptive":
            decision = self.aba.choose(candidates, self.running_rqs(),
                                       self.waiting_rqs(),
                                       lambda r: self.relqueries[r.rel_id].priority,
                                       now)
        elif self.arrangement == "prefill_first":
            decision = ArrangerDecision("prefill" if p_cand else "decode", "forced")
        else:  # decode_first
            decision = ArrangerDecision("decode" if d_cand else "prefill", "forced")
        self.aba_time += _time.perf_counter() - t0

        chosen = {c.kind: c for c in candidates}.get(decision.kind)
        if chosen is None:  # forced arrangement pointing at a missing candidate
            chosen = candidates[0]
        chosen.decision = decision
        return chosen
