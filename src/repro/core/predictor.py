"""Per-template output-length prediction (ALISE-style, PAPERS.md).

RelServe's relational workloads run one template over many rows, so finished
requests of a template are a strong predictor for the output length of the
template's remaining rows. ``OutputLenPredictor`` keeps a bounded window of
observed output lengths per template fingerprint and predicts a configurable
quantile — deterministic (pure sorted-window lookup, no RNG, no clocks) so
serial and pipelined engine loops see identical predictions at identical
observation histories.

Two consumers:

* ``kv_admission="predicted"`` — the scheduler admits on
  ``prompt + predicted_OL`` instead of the ``prompt + max_output`` worst case
  (preemption stays on as the safety valve for under-predictions).
* The DPU's remaining-work estimate (Eq. 9's ``pem``) — a waiting relQuery's
  expected decode work shrinks from ``OL(R)`` to the predicted length.

The pipelined engine loop speculates scheduler state one batch ahead;
speculative ``_finish_request`` calls feed the predictor projected lengths,
so the predictor journals observations between ``checkpoint()`` and
``rollback()``/``discard()`` exactly like the scheduler's ledger spec-log.
"""
from __future__ import annotations

import zlib
from typing import Dict, List, Optional, Tuple

from repro.core.relquery import RelQuery


def template_fingerprint(rq: RelQuery, block_size: int = 16) -> int:
    """Stable identity of the shared prompt prefix of ``rq``'s requests: the
    template id when tagged, else the first prompt block of the first request
    (the rendered template head — what actually lands in the prefix cache).
    Used both for router prefix affinity and as the predictor's template key
    (deterministic across processes, unlike seed-randomized ``hash``)."""
    if rq.template_id:
        return zlib.crc32(rq.template_id.encode())
    if rq.requests:
        blk = rq.requests[0].tokens[:block_size]
        return zlib.crc32(b",".join(b"%d" % t for t in blk))
    return zlib.crc32(rq.rel_id.encode())


class OutputLenPredictor:
    """Running per-template quantile of observed output lengths.

    ``quantile=1.0`` predicts the window max (safest), ``0.5`` the median.
    The default 0.9 mirrors ALISE: rare long tails are absorbed by the
    preemption safety valve instead of inflating every admission.
    """

    def __init__(self, quantile: float = 0.9, window: int = 256):
        if not 0.0 < quantile <= 1.0:
            raise ValueError(f"quantile must be in (0, 1], got {quantile}")
        self.quantile = quantile
        self.window = window
        self._obs: Dict[int, List[int]] = {}
        self.observations = 0
        # open speculative journal: [(key, evicted_or_None), ...]
        self._journal: Optional[List[Tuple[int, Optional[int]]]] = None

    # ------------------------------------------------------------------ keys
    def key_of(self, rq: RelQuery) -> int:
        return template_fingerprint(rq)

    # ------------------------------------------------------------------ core
    def observe(self, key: int, output_len: int) -> None:
        lst = self._obs.setdefault(key, [])
        lst.append(int(output_len))
        evicted: Optional[int] = None
        if len(lst) > self.window:
            evicted = lst.pop(0)
        self.observations += 1
        if self._journal is not None:
            self._journal.append((key, evicted))

    def predict(self, key: int) -> Optional[int]:
        """Predicted output length for ``key``, or None with no history
        (callers fall back to the ``max_output`` worst case)."""
        lst = self._obs.get(key)
        if not lst:
            return None
        ordered = sorted(lst)
        idx = min(len(ordered) - 1,
                  max(0, int(self.quantile * len(ordered) + 0.999999) - 1))
        return ordered[idx]

    def predicted_remaining(self, key: int, produced: int) -> Optional[int]:
        """Output tokens a request of template ``key`` that has already
        produced ``produced`` tokens is still expected to emit — the
        remaining-work estimate proactive offload's idle horizon consumes.
        None with no history (callers fall back to ``remaining_output``).
        A request that outran its prediction clamps to 0: it is presumed
        near finish, so it is never an idle-tail victim on prediction
        grounds."""
        p = self.predict(key)
        if p is None:
            return None
        return max(0, p - produced)

    # ---------------------------------------------------- speculation support
    def checkpoint(self) -> None:
        self._journal = []

    def rollback(self) -> None:
        """Undo every observation since ``checkpoint()`` (newest first)."""
        journal = self._journal or []
        for key, evicted in reversed(journal):
            lst = self._obs[key]
            lst.pop()
            if evicted is not None:
                lst.insert(0, evicted)
            if not lst:
                del self._obs[key]
        self.observations -= len(journal)
        self._journal = None

    def discard(self) -> None:
        self._journal = None
