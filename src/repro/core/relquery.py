"""relQuery workload model (paper §2.1, Definitions 2.1 & 2.2).

A relQuery R = relQuery(T, ζ) instantiates one request per table row by
substituting row values into the task template ζ. All requests of R share one
latency: R completes when its last request completes.
"""
from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

_req_counter = itertools.count()


class RequestState(enum.Enum):
    WAITING = "waiting"
    RUNNING = "running"    # prefilled; decoding
    PREEMPTED = "preempted"  # KV reclaimed under pressure; awaiting re-prefill
    SWAPPED = "swapped"    # KV parked on the host tier; resumes w/o re-prefill
    FINISHED = "finished"
    CANCELLED = "cancelled"  # terminal: evicted by relQuery cancellation


@dataclass
class Request:
    """One LLM request r = ζ[s_i] (token ids already rendered)."""

    rel_id: str
    tokens: Tuple[int, ...]            # prompt token ids
    max_output_tokens: int             # OL(R)
    req_id: str = field(default_factory=lambda: f"r{next(_req_counter)}")
    eos_token: Optional[int] = None

    # --- runtime state (owned by the scheduler) ---
    state: RequestState = RequestState.WAITING
    output_tokens: List[int] = field(default_factory=list)
    prefilled: bool = False
    prefilled_tokens: int = 0          # chunked-prefill progress (Sarathi)
    finish_time: Optional[float] = None
    # Output tokens generated before the last preemption. A preempted request
    # restarts recompute-style: its next prefill pass re-loads the prompt plus
    # these preserved tokens (they are kept in ``output_tokens``), then decode
    # resumes from where it left off.
    preserved_output_tokens: int = 0

    @property
    def num_prompt_tokens(self) -> int:
        return len(self.tokens)

    @property
    def prefill_target_tokens(self) -> int:
        """Tokens the next prefill pass must load into KV: the prompt, plus —
        after a preemption — the generated tokens being recomputed."""
        return self.num_prompt_tokens + self.preserved_output_tokens

    def prefill_token_ids(self) -> Tuple[int, ...]:
        """The token sequence a prefill pass computes over (prompt, or prompt
        + preserved generation for a preempted request's restart)."""
        if not self.preserved_output_tokens:
            return tuple(self.tokens)
        return tuple(self.tokens) + \
            tuple(self.output_tokens[:self.preserved_output_tokens])

    @property
    def remaining_output(self) -> int:
        return max(0, self.max_output_tokens - len(self.output_tokens))

    @property
    def total_tokens(self) -> int:
        return self.num_prompt_tokens + len(self.output_tokens)

    def is_finished(self) -> bool:
        return self.state == RequestState.FINISHED

    def is_terminal(self) -> bool:
        """Finished or cancelled: this request will never be scheduled again."""
        return self.state in (RequestState.FINISHED, RequestState.CANCELLED)


@dataclass
class RelQuery:
    """A set of requests sharing one user-facing latency (Definition 2.1)."""

    rel_id: str
    requests: List[Request]
    arrival_time: float
    max_output_tokens: int             # OL(R): shared output-length limit
    template_id: str = ""

    # --- latency phase bookkeeping (Definition 2.2) ---
    first_prefill_start: Optional[float] = None
    last_prefill_end: Optional[float] = None
    finish_time: Optional[float] = None
    cancel_time: Optional[float] = None    # terminal: set once by cancellation

    # --- scheduling state ---
    priority: float = 0.0
    priority_fresh: bool = False       # was recomputed this iteration
    _was_all_waiting: bool = False     # Eq. 12 reuse predicate memo
    cache_miss_ratio: float = 1.0      # sampled utok*/tok estimate (Eq. 11)
    preemptions: int = 0               # times any request of R was preempted
    # Parked relQueries hold results another stage is waiting on (a derive
    # stage blocked on upstream DAG output, or a tool-call suspension): their
    # device KV is idle until whoever parked them unparks them. A tiering
    # scheduler with proactive offload treats their RUNNING requests as
    # first-class swap-out victims and will not swap them back in while
    # parked. Parking only affects KV placement — it does not cancel, finish,
    # or reorder the relQuery.
    parked: bool = False
    # Monotone counter bumped by the scheduler whenever any request of R
    # changes state (prefill finish, decode finish, preemption, cancel,
    # speculative rollback). The DPU's incremental refresh memoizes its
    # O(#requests) phase probe (``all_waiting``) against this version, so a
    # decode-heavy tick re-scores only relQueries whose phase actually moved.
    _phase_version: int = 0

    def __post_init__(self):
        for r in self.requests:
            r.rel_id = self.rel_id
            if r.max_output_tokens <= 0:
                r.max_output_tokens = self.max_output_tokens

    # ------------------------------------------------------------------
    def note_phase_change(self) -> None:
        """Invalidate memoized phase probes. Any code that flips a request's
        ``state`` (or finishes/cancels this relQuery) outside the scheduler's
        own transition methods must call this, or the DPU's incremental
        refresh will keep serving the stale phase."""
        self._phase_version += 1

    @property
    def num_requests(self) -> int:
        return len(self.requests)

    def active_requests(self) -> List[Request]:
        """R_t: requests not yet finished (or cancelled)."""
        return [r for r in self.requests if not r.is_terminal()]

    @property
    def cancelled(self) -> bool:
        return self.cancel_time is not None

    def waiting_requests(self) -> List[Request]:
        return [r for r in self.requests if r.state == RequestState.WAITING]

    def running_requests(self) -> List[Request]:
        return [r for r in self.requests if r.state == RequestState.RUNNING]

    def preempted_requests(self) -> List[Request]:
        return [r for r in self.requests if r.state == RequestState.PREEMPTED]

    def swapped_requests(self) -> List[Request]:
        return [r for r in self.requests if r.state == RequestState.SWAPPED]

    def is_finished(self) -> bool:
        return all(r.is_finished() for r in self.requests)

    def all_waiting(self) -> bool:
        return all(r.state == RequestState.WAITING for r in self.requests
                   if not r.is_finished()) and not self.is_finished()

    def remaining_workload_ratio(self) -> float:
        """Fraction of total token workload still to process (Fig. 3)."""
        total = sum(r.num_prompt_tokens + r.max_output_tokens for r in self.requests)
        done = sum((r.num_prompt_tokens if r.prefilled else 0) + len(r.output_tokens)
                   for r in self.requests)
        return 1.0 - done / max(1, total)

    # ------------------------------------------------------------------ metrics
    def latency(self) -> Optional[float]:
        if self.finish_time is None:
            return None
        return self.finish_time - self.arrival_time

    def waiting_time(self) -> Optional[float]:
        if self.first_prefill_start is None:
            return None
        return self.first_prefill_start - self.arrival_time

    def core_running_time(self) -> Optional[float]:
        if self.first_prefill_start is None or self.last_prefill_end is None:
            return None
        return self.last_prefill_end - self.first_prefill_start

    def tail_running_time(self) -> Optional[float]:
        if self.last_prefill_end is None or self.finish_time is None:
            return None
        return self.finish_time - self.last_prefill_end

    def unit_waiting_time(self, now: float) -> float:
        """Eq. 13 fairness metric: waiting time normalized by request count."""
        start = self.first_prefill_start if self.first_prefill_start is not None else now
        return max(0.0, start - self.arrival_time) / max(1, self.num_requests)


def make_relquery(rel_id: str, prompts: Sequence[Sequence[int]], arrival: float,
                  max_output_tokens: int, template_id: str = "",
                  eos_token: Optional[int] = None) -> RelQuery:
    reqs = [Request(rel_id=rel_id, tokens=tuple(p), max_output_tokens=max_output_tokens,
                    eos_token=eos_token) for p in prompts]
    return RelQuery(rel_id=rel_id, requests=reqs, arrival_time=arrival,
                    max_output_tokens=max_output_tokens, template_id=template_id)
