"""Adaptive Batch Arranger (paper §4.3, Eq. 14-17), generalized to a
multi-candidate choice over unified ``Batch`` objects.

The scheduler hands ABA a *list* of candidate batches for this iteration —
typically the candidate decode batch (all running requests), the candidate
prefill batch (head of the priority-ordered waiting queue), and a
chunked-mixed candidate (running requests decode while a prompt chunk of the
head waiting request prefills in the same pass). ABA picks one:

- m⁺ > m⁻  → *preemption*: a shorter relQuery is waiting; start it (prefill).
- m⁺ = m⁻  → *internal*: same relQuery on both sides; prefill first to
             maximize the eventual combined decode batch.
- m⁺ < m⁻  → *transitional*: the running relQuery finished its prefills; price
             the latency trade-off Δ = Δ⁺ + Δ⁻ for every prefill-side
             candidate (pure and chunked-mixed) and run the cheapest when its
             Δ < 0, else decode.

Chunked-mixed candidates extend Eq. 15/16: the running requests still decode
inside a mixed pass, so Δ⁺ only charges the *incremental* compute
``L_mixed(utok, d) − L_decode(d)`` per running relQuery, and only chunks that
complete their prompt contribute newcomers to future decode batches.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, List, Optional, Sequence

from repro.core.batch import Batch
from repro.core.latency_model import BatchLatencyModel
from repro.core.relquery import RelQuery


@dataclass
class ArrangerDecision:
    kind: str          # 'prefill' | 'decode' | 'mixed'
    case: str          # 'preempt' | 'internal' | 'transitional' | 'forced'
    delta: Optional[float] = None


class AdaptiveBatchArranger:
    def __init__(self, latency_model: BatchLatencyModel):
        self.lm = latency_model
        self.stats = {"preempt": 0, "internal": 0, "transitional_prefill": 0,
                      "transitional_mixed": 0, "transitional_decode": 0,
                      "forced": 0, "warm_follow": 0}

    def _done(self, decision: ArrangerDecision, by_kind) -> ArrangerDecision:
        """Count wins of warm-then-follow candidates: prefill-side batches
        whose ``uncached_tokens`` was discounted by intra-batch prefix reuse
        — the reuse ABA saw through ``Batch.cost``."""
        cand = by_kind.get(decision.kind)
        if cand is not None and cand.shared_prefix_tokens > 0:
            self.stats["warm_follow"] += 1
        return decision

    def choose(
        self,
        candidates: Iterable[Optional[Batch]],
        running_rqs: Sequence[RelQuery],      # R_t^+
        waiting_rqs: Sequence[RelQuery],      # R_t^-
        prio_of,                              # Request -> priority value
        now: float = 0.0,
    ) -> ArrangerDecision:
        by_kind = {}
        for c in candidates:
            if c is not None and not c.is_empty():
                by_kind[c.kind] = c
        if not by_kind:
            raise ValueError("no candidates — engine should idle instead")

        d_cand = by_kind.get("decode")
        prefill_side = [by_kind[k] for k in ("prefill", "mixed") if k in by_kind]
        if d_cand is None:
            self.stats["forced"] += 1
            return self._done(ArrangerDecision(prefill_side[0].kind, "forced"),
                              by_kind)
        if not prefill_side:
            self.stats["forced"] += 1
            return ArrangerDecision("decode", "forced")

        m_plus = d_cand.min_priority(prio_of)
        m_minus = min(c.min_prefill_priority(prio_of) for c in prefill_side)
        if m_plus >= m_minus:
            # preemption / internal: a relQuery at least as urgent as everything
            # running is waiting — start it with a full prefill when available.
            case = "preempt" if m_plus > m_minus else "internal"
            self.stats[case] += 1
            return self._done(ArrangerDecision(prefill_side[0].kind, case),
                              by_kind)

        # transitional: price every prefill-side candidate, take the cheapest.
        best, best_delta = None, None
        for c in prefill_side:
            delta = self.delta_latency(c, running_rqs, waiting_rqs)
            if best_delta is None or delta < best_delta:
                best, best_delta = c, delta
        if best_delta < 0:
            self.stats[f"transitional_{best.kind}"] += 1
            return self._done(
                ArrangerDecision(best.kind, "transitional", best_delta), by_kind)
        self.stats["transitional_decode"] += 1
        return ArrangerDecision("decode", "transitional", best_delta)

    # ------------------------------------------------------------- Eq. 15-17
    def delta_latency(self, cand: Batch, running_rqs: Sequence[RelQuery],
                      waiting_rqs: Sequence[RelQuery]) -> float:
        """Projected total-latency change of executing ``cand`` before the
        candidate decode batch. Handles pure-prefill and chunked-mixed."""
        lm = self.lm
        preqs = cand.prefill_requests
        ol_p = cand.relquery.max_output_tokens if cand.relquery else \
            max((r.max_output_tokens for r in preqs), default=0)
        completing = [r for r in preqs if cand.completes_prompt(r)]

        rem_out = {rq.rel_id: max((r.remaining_output for r in rq.running_requests()),
                                  default=0) for rq in running_rqs}
        if cand.kind == "mixed":
            # running requests decode inside the mixed pass: they only pay the
            # incremental chunk compute, and only completing chunks add
            # newcomers to their future decode batches.
            n_d = len(cand.decode_requests)
            stall = lm.mixed_time(cand.uncached_tokens, n_d) - lm.decode_time(n_d)
            joiners = len(completing)
        else:
            # Δ⁺ (Eq. 15): every running relQuery is delayed by the prefill
            # pass and by the larger decode batches it will share.
            stall = lm.prefill_time(cand.uncached_tokens)
            joiners = len(completing)
        delta_plus = stall * len(running_rqs)
        delta_plus += sum(lm.alpha_d * joiners * min(rem_out[rq.rel_id], ol_p)
                          for rq in running_rqs)

        # Δ⁻ (Eq. 16): waiting relQueries gain from combined decoding — every
        # decode iteration the newcomer shares with a running relQuery is one
        # batch overhead β_d the queue does not pay twice. For mixed batches
        # only the completing fraction of the chunked requests joins decode now.
        max_run_out = max([rem_out[rq.rel_id] for rq in running_rqs], default=0)
        share = 1.0 if cand.kind != "mixed" else \
            len(completing) / max(1, len(preqs))
        delta_minus = -len(waiting_rqs) * lm.beta_d * min(ol_p, max_run_out) * share
        return delta_plus + delta_minus
