"""Adaptive Batch Arranger (paper §4.3, Eq. 14-17).

Given the candidate decode batch (all running requests) and the candidate
prefill batch (head of the priority-ordered waiting queue, single relQuery),
ABA picks which to execute this iteration:

- m⁺ > m⁻  → *preemption*: a shorter relQuery is waiting; prefill it.
- m⁺ = m⁻  → *internal*: same relQuery on both sides; prefill first to
             maximize the eventual combined decode batch.
- m⁺ < m⁻  → *transitional*: the running relQuery finished its prefills; price
             the latency trade-off Δ = Δ⁺ + Δ⁻ and prefill only when Δ < 0.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

from repro.core.latency_model import BatchLatencyModel
from repro.core.relquery import RelQuery, Request


@dataclass
class CandidateBatch:
    requests: List[Request]
    uncached_tokens: int = 0      # prefill candidates: utok(p)
    relquery: Optional[RelQuery] = None

    @property
    def num_requests(self) -> int:
        return len(self.requests)

    def min_priority(self, prio_of) -> float:
        return min(prio_of(r) for r in self.requests)


@dataclass
class ArrangerDecision:
    kind: str          # 'prefill' | 'decode'
    case: str          # 'preempt' | 'internal' | 'transitional' | 'forced'
    delta: Optional[float] = None


class AdaptiveBatchArranger:
    def __init__(self, latency_model: BatchLatencyModel):
        self.lm = latency_model
        self.stats = {"preempt": 0, "internal": 0, "transitional_prefill": 0,
                      "transitional_decode": 0, "forced": 0}

    def choose(
        self,
        p_cand: Optional[CandidateBatch],
        d_cand: Optional[CandidateBatch],
        running_rqs: Sequence[RelQuery],      # R_t^+
        waiting_rqs: Sequence[RelQuery],      # R_t^-
        prio_of,                              # Request -> priority value
        now: float = 0.0,
    ) -> ArrangerDecision:
        if p_cand is None and d_cand is None:
            raise ValueError("both candidates empty — engine should idle instead")
        if d_cand is None or not d_cand.requests:
            self.stats["forced"] += 1
            return ArrangerDecision("prefill", "forced")
        if p_cand is None or not p_cand.requests:
            self.stats["forced"] += 1
            return ArrangerDecision("decode", "forced")

        m_plus = d_cand.min_priority(prio_of)
        m_minus = p_cand.min_priority(prio_of)
        if m_plus > m_minus:
            self.stats["preempt"] += 1
            return ArrangerDecision("prefill", "preempt")
        if m_plus == m_minus:
            self.stats["internal"] += 1
            return ArrangerDecision("prefill", "internal")

        delta = self.delta_latency(p_cand, running_rqs, waiting_rqs)
        if delta < 0:
            self.stats["transitional_prefill"] += 1
            return ArrangerDecision("prefill", "transitional", delta)
        self.stats["transitional_decode"] += 1
        return ArrangerDecision("decode", "transitional", delta)

    # ------------------------------------------------------------- Eq. 15-17
    def delta_latency(self, p_cand: CandidateBatch, running_rqs: Sequence[RelQuery],
                      waiting_rqs: Sequence[RelQuery]) -> float:
        """Projected total-latency change of executing p_cand before d_cand."""
        lm = self.lm
        ol_p = p_cand.relquery.max_output_tokens if p_cand.relquery else \
            max((r.max_output_tokens for r in p_cand.requests), default=0)

        # Δ⁺ (Eq. 15): every running relQuery is delayed by the prefill pass and
        # by the larger decode batches it will share with the newcomers.
        rem_out = {rq.rel_id: max((r.remaining_output for r in rq.running_requests()),
                                  default=0) for rq in running_rqs}
        delta_plus = lm.prefill_time(p_cand.uncached_tokens) * len(running_rqs)
        delta_plus += sum(
            lm.alpha_d * p_cand.num_requests * min(rem_out[rq.rel_id], ol_p)
            for rq in running_rqs)

        # Δ⁻ (Eq. 16): waiting relQueries gain from combined decoding — every
        # decode iteration the newcomer shares with a running relQuery is one
        # batch overhead β_d the queue does not pay twice.
        max_run_out = max([rem_out[rq.rel_id] for rq in running_rqs], default=0)
        delta_minus = -len(waiting_rqs) * lm.beta_d * min(ol_p, max_run_out)
        return delta_plus + delta_minus
