"""Serving driver: RelServe (or any baseline) over a relQuery trace.

Two modes:
  --simulate      paper-scale traces on the simulated clock (default constants
                  match the paper's OPT-13B/A100 regime); supports
                  --num-replicas N data-parallel engine replicas behind the
                  relQuery-affine router (repro.serving)
  (default)       real JAX execution of a smoke-scale model on this host
                  (single replica — one model fits this machine)

  PYTHONPATH=src python -m repro.launch.serve --simulate --scheduler relserve
  PYTHONPATH=src python -m repro.launch.serve --simulate --num-replicas 4
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --num-relqueries 4
"""
from __future__ import annotations

import argparse

from repro.core.latency_model import a100_opt13b
from repro.core.policies import SCHEDULERS
from repro.core.priority import BatchLimits, DPUConfig
from repro.data.datasets import ALL_DATASETS, make_dataset
from repro.data.trace import TraceConfig, build_trace
from repro.engine.engine import ServingEngine
from repro.engine.prefix_cache import PrefixCache
from repro.serving import ROUTER_POLICIES, build_simulated_cluster


def _print_report(tag: str, report) -> None:
    w, c, t = report.phase_means()
    print(f"[{tag}] relqueries={len(report.latencies)}  "
          f"avg {report.avg_latency:.2f}s  p50 {report.percentile(50):.2f}  "
          f"p99 {report.percentile(99):.2f}  max {report.max_latency:.2f}")
    print(f"[{tag}] phases: waiting {w:.2f}s  core {c:.2f}s  tail {t:.2f}s  |  "
          f"e2e {report.end_to_end:.1f}s  prefix-hit {report.prefix_hit_ratio:.2%}  "
          f"iterations {len(report.events)}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scheduler", default="relserve", choices=list(SCHEDULERS))
    ap.add_argument("--dataset", default="rotten", choices=list(ALL_DATASETS))
    ap.add_argument("--simulate", action="store_true")
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--num-relqueries", type=int, default=100)
    ap.add_argument("--rate", type=float, default=1.0)
    ap.add_argument("--max-requests", type=int, default=100)
    ap.add_argument("--num-replicas", type=int, default=1,
                    help="data-parallel engine replicas (simulate mode)")
    ap.add_argument("--router", default="affinity_spill",
                    choices=list(ROUTER_POLICIES))
    ap.add_argument("--starvation-threshold", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.num_replicas < 1:
        raise SystemExit("--num-replicas must be >= 1")
    lm = a100_opt13b()

    if args.simulate:
        ds = make_dataset(args.dataset, num_rows=10_000, seed=args.seed)
        trace = build_trace(ds, TraceConfig(num_relqueries=args.num_relqueries,
                                            rate=args.rate, seed=args.seed,
                                            max_requests=args.max_requests))
        dpu = DPUConfig(starvation_threshold=args.starvation_threshold)
        cluster = build_simulated_cluster(
            args.num_replicas, scheduler=args.scheduler, latency_model=lm,
            router_policy=args.router, dpu_config=dpu, seed=args.seed)
        result = cluster.run_trace(trace)
        print(f"scheduler={args.scheduler} replicas={args.num_replicas} "
              f"router={args.router}")
        for i, rep in enumerate(result.per_replica):
            _print_report(f"replica {i}", rep)
        _print_report("merged", result.merged)
        report = result.merged
        if args.num_replicas > 1:
            print(f"router: {result.router_stats['routed']} routed, "
                  f"{result.router_stats['spilled']} spilled")
    else:
        import jax

        from repro.configs import get_smoke_config
        from repro.engine.executor import RealExecutor
        from repro.engine.tokenizer import HashTokenizer
        from repro.models.registry import build_model

        if args.num_replicas != 1:
            raise SystemExit("real-JAX mode runs a single replica on this host; "
                             "use --simulate for --num-replicas > 1")
        pc = PrefixCache(block_size=16)
        kw = dict(limits=BatchLimits(), latency_model=lm, prefix_cache=pc)
        if args.scheduler.startswith("relserve"):
            kw["dpu_config"] = DPUConfig(
                starvation_threshold=args.starvation_threshold)
        sched = SCHEDULERS[args.scheduler](**kw)
        cfg = get_smoke_config(args.arch)
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(args.seed))
        tok = HashTokenizer(vocab_size=cfg.vocab_size - 2)
        ds = make_dataset(args.dataset, num_rows=1000, seed=args.seed)
        trace = build_trace(ds, TraceConfig(
            num_relqueries=min(args.num_relqueries, 8), rate=args.rate,
            seed=args.seed, max_requests=min(args.max_requests, 8)),
            tokenizer=tok)
        for rq in trace:     # keep CPU decoding affordable
            rq.max_output_tokens = min(rq.max_output_tokens, 8)
            for r in rq.requests:
                r.max_output_tokens = rq.max_output_tokens
        executor = RealExecutor(model, params, max_slots=64, max_len=1024,
                                prefix_cache=pc)
        engine = ServingEngine(sched, executor)
        report = engine.run_trace(trace)
        print(f"scheduler={args.scheduler}")
        _print_report("merged", report)

    print(f"overheads: DPU {report.dpu_time:.3f}s  ABA {report.aba_time:.3f}s  "
          f"schedule {report.schedule_time:.3f}s")


if __name__ == "__main__":
    main()
