"""Serving driver: RelServe (or any baseline) over a relQuery workload.

Two execution modes:
  --simulate      paper-scale traces on the simulated clock (default constants
                  match the paper's OPT-13B/A100 regime); supports
                  --num-replicas N data-parallel engine replicas behind the
                  relQuery-affine router (repro.serving)
  (default)       real JAX execution of a smoke-scale model on this host
                  (single replica — one model fits this machine)

and two drive modes:
  (default)       closed-loop trace replay through the Frontend shim
  --open-loop     scripted open-loop session on the Frontend: mid-flight
                  submission, token streaming, cancellation and a live
                  snapshot — the smoke test for the serving API

Closed-loop replay optionally routes through the workload planner
(``--plan off|dedup|reorder|full``): exact-duplicate rows are answered once
and fanned out, rows are reordered into prefix-maximizing order, and the
report gains logical-vs-physical accounting — with per-row outputs
bit-identical to the unplanned replay.

  PYTHONPATH=src python -m repro.launch.serve --simulate --scheduler relserve
  PYTHONPATH=src python -m repro.launch.serve --simulate --num-replicas 4
  PYTHONPATH=src python -m repro.launch.serve --simulate --open-loop
  PYTHONPATH=src python -m repro.launch.serve --simulate --plan full \
      --dup-row-fraction 0.5 --prefix-sharing on
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --num-relqueries 4
"""
from __future__ import annotations

import argparse

from repro.core.latency_model import a100_opt13b
from repro.core.policies import SCHEDULERS
from repro.core.priority import BatchLimits, DPUConfig
from repro.data.datasets import ALL_DATASETS, make_dataset
from repro.data.trace import TraceConfig, build_trace
from repro.planner import PLAN_MODES, PlanExecutor, Planner
from repro.serving import (ROUTER_POLICIES, AutoscaleConfig, Autoscaler,
                           Frontend, build_simulated_cluster)
from repro.serving.frontend import RelQueryStatus


def _print_report(tag: str, report) -> None:
    w, c, t = report.phase_means()
    print(f"[{tag}] relqueries={len(report.latencies)}  "
          f"avg {report.avg_latency:.2f}s  p50 {report.percentile(50):.2f}  "
          f"p99 {report.percentile(99):.2f}  max {report.max_latency:.2f}")
    print(f"[{tag}] phases: waiting {w:.2f}s  core {c:.2f}s  tail {t:.2f}s  |  "
          f"e2e {report.end_to_end:.1f}s  prefix-hit {report.prefix_hit_ratio:.2%}  "
          f"iterations {len(report.events)}")
    if report.preemptions:
        print(f"[{tag}] kv-pressure: {report.preemptions} preemptions  "
              f"{report.preempted_tokens} tokens reclaimed")
    if report.shared_kv_tokens:
        print(f"[{tag}] prefix-sharing: {report.shared_kv_tokens} KV cap "
              f"tokens counted once (shared blocks)")
    if report.deduped_requests or report.plan_time:
        print(f"[{tag}] planner: {report.deduped_requests} rows answered by "
              f"dedup fan-out  plan {report.plan_time * 1e3:.2f}ms")
    if report.swap_outs or report.swap_ins:
        print(f"[{tag}] kv-tiering: {report.swap_outs} swap-outs "
              f"({report.swapped_out_tokens} tok)  {report.swap_ins} swap-ins "
              f"({report.swapped_in_tokens} tok)  "
              f"{report.swap_bytes_moved / 1e9:.2f} GB moved  reclaim "
              f"{report.reclaim_swap_decisions} swap / "
              f"{report.reclaim_recompute_decisions} recompute")
    if report.proactive_offloads or report.swap_prefetches:
        print(f"[{tag}] proactive-tiering: {report.proactive_offloads} offloads  "
              f"{report.swap_prefetches} prefetches "
              f"({report.prefetch_hits} zero-stall hits, "
              f"{report.prefetch_cancelled} cancelled)")


def run_planned(frontend: Frontend, trace, mode: str, tokenizer=None):
    """Closed-loop replay through the workload planner: rewrite the trace
    (dedup / prefix-maximizing reorder per --plan), submit the physical
    relQueries through the Frontend, fan answers back out to every logical
    row. Per-row outputs are bit-identical to the unplanned replay."""
    planner = Planner(mode, tokenizer=tokenizer)
    executor = PlanExecutor(frontend, planner)
    planned = planner.plan_trace(trace)
    n_logical = sum(p.num_logical for p in planned)
    n_physical = sum(p.num_physical for p in planned)
    print(f"planner: mode={mode}  {n_logical} logical requests -> "
          f"{n_physical} physical ({n_logical - n_physical} deduped)")
    return executor.replay(planned)


def run_open_loop(frontend: Frontend, trace) -> "object":
    """Scripted open-loop session over ``frontend``: replay-style arrivals
    interleaved with engine steps, plus — mid-flight — a token-streaming
    subscription, one cancellation, one interactive late submission and a
    live snapshot. Returns the final merged ServiceReport; asserts the
    invariants CI relies on (KV fully reclaimed, cancellation terminal)."""
    pending = sorted(trace, key=lambda r: r.arrival_time)
    if len(pending) < 4:
        raise SystemExit("--open-loop needs --num-relqueries >= 4")
    late = pending[-1]            # held back, submitted interactively
    pending = pending[:-1]

    streamed = {"tokens": 0}

    def on_token(req_id: str, token: int) -> None:
        streamed["tokens"] += 1

    handles = []
    cancel_handle = None
    late_handle = None
    snapshot_taken = False
    idx = 0
    steps = 0
    while idx < len(pending) or frontend.has_work():
        nxt = frontend.next_step_time()
        if idx < len(pending) and (nxt is None or
                                   pending[idx].arrival_time <= nxt):
            rq = pending[idx]
            idx += 1
            handles.append(frontend.submit(
                rq, now=rq.arrival_time,
                on_token=on_token if len(handles) == 0 else None))
            continue
        frontend.step()
        steps += 1
        if steps >= 5 and cancel_handle is None and len(handles) >= 3:
            live = [h for h in handles[1:]   # keep the streaming handle alive
                    if h.status() in (RelQueryStatus.QUEUED,
                                      RelQueryStatus.RUNNING)]
            if live:
                cancel_handle = live[-1]
                cancel_handle.cancel()
                print(f"[open-loop] cancelled {cancel_handle.rel_id} "
                      f"mid-flight at t={frontend.now:.2f}s")
        if steps >= 8 and late_handle is None and cancel_handle is not None:
            late_handle = frontend.submit(late)   # arrives "now"
            handles.append(late_handle)
            print(f"[open-loop] late-submitted {late.rel_id} "
                  f"at t={late.arrival_time:.2f}s")
        if not snapshot_taken and late_handle is not None and steps >= 12:
            snapshot_taken = True
            snap = frontend.snapshot()
            print(f"[open-loop] mid-flight snapshot: "
                  f"{len(snap.latencies)} finished, "
                  f"{len(snap.cancelled_rel_ids)} cancelled, "
                  f"clock {snap.end_to_end:.2f}s")

    report = frontend.snapshot()
    done = sum(1 for h in handles if h.status() is RelQueryStatus.FINISHED)
    print(f"[open-loop] {done} finished / {len(report.cancelled_rel_ids)} "
          f"cancelled, {streamed['tokens']} tokens streamed on "
          f"{handles[0].rel_id}")
    # invariants the smoke lane pins — strict: if the workload drains before
    # the scripted cancel/late-submit/snapshot fire, the smoke exercised
    # nothing and must fail loudly, not pass vacuously.
    for core in frontend.cores:
        assert core.scheduler.tokens_in_use == 0, "KV tokens leaked"
        assert core.scheduler.committed_tokens == 0, "KV commitment leaked"
    assert cancel_handle is not None, \
        "smoke never cancelled — raise --num-relqueries/--rate"
    assert cancel_handle.status() is RelQueryStatus.CANCELLED
    assert cancel_handle.rel_id not in report.latencies
    assert streamed["tokens"] > 0, "no tokens streamed"
    assert late_handle is not None, "smoke never late-submitted"
    assert late_handle.status() is RelQueryStatus.FINISHED
    assert snapshot_taken, "smoke never took a mid-flight snapshot"
    print("OPEN-LOOP SMOKE OK")
    return report


def run_elastic_replay(frontend: Frontend, cluster, trace,
                       crash_at: "float | None" = None,
                       metrics_log: "str | None" = None,
                       metrics_interval: float = 5.0,
                       max_iterations: int = 2_000_000):
    """Closed-loop replay with the elastic controls live: deterministic
    replica-crash injection at ``--crash-at`` (the busiest admitting replica
    dies; its in-flight relQueries fail over to the survivors), autoscaler
    ticks (attached on the cluster), and periodic ``metrics_snapshot``
    samples written as JSONL to ``--metrics-log``."""
    import json
    import math
    import os

    pending = sorted(trace, key=lambda r: r.arrival_time)
    idx = 0
    it = 0
    crash_done = crash_at is None
    samples = []
    next_sample = 0.0
    while True:
        f = frontend.next_step_time()
        next_step = math.inf if f is None else f
        next_arrival = (pending[idx].arrival_time if idx < len(pending)
                        else math.inf)
        if not crash_done and min(next_step, next_arrival) >= crash_at:
            admitting = cluster.admitting_replicas()
            victim = max(admitting,
                         key=lambda i: (cluster.cores[i].load(), -i))
            event = cluster.crash_replica(victim, crash_at)
            print(f"[fault] crashed replica {victim} at t={crash_at:.2f}s: "
                  f"{event['victims']} relQueries failed over "
                  f"({event['from_snapshot']} from snapshot, "
                  f"{event['tokens_preserved']} tokens preserved, "
                  f"{event['tokens_lost']} lost -> recomputed)")
            crash_done = True
            continue
        if math.isinf(next_step) and math.isinf(next_arrival):
            break
        if next_arrival <= next_step:
            frontend.submit(pending[idx], now=next_arrival)
            idx += 1
        else:
            frontend.step()
            it += 1
            if it >= max_iterations:
                raise RuntimeError(
                    "elastic replay exceeded max_iterations — likely livelock")
        if metrics_log is not None and frontend.clock >= next_sample:
            samples.append(cluster.metrics_snapshot(frontend.clock))
            next_sample = frontend.clock + metrics_interval
    if not crash_done:
        print(f"[fault] warning: workload drained before --crash-at "
              f"{crash_at}s — no crash was injected")
    if metrics_log is not None:
        samples.append(cluster.metrics_snapshot(frontend.clock))
        parent = os.path.dirname(metrics_log)
        if parent:
            os.makedirs(parent, exist_ok=True)
        with open(metrics_log, "w") as fh:
            for s in samples:
                fh.write(json.dumps(s) + "\n")
        print(f"[metrics] wrote {len(samples)} samples to {metrics_log}")
    return cluster.report()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scheduler", default="relserve", choices=list(SCHEDULERS))
    ap.add_argument("--dataset", default="rotten", choices=list(ALL_DATASETS))
    ap.add_argument("--simulate", action="store_true")
    ap.add_argument("--open-loop", action="store_true",
                    help="scripted open-loop Frontend session (submit/stream/"
                         "cancel/snapshot) instead of closed-loop replay")
    ap.add_argument("--plan", default="off", choices=list(PLAN_MODES),
                    help="workload planner in front of the scheduler: 'dedup' "
                         "answers each exact-duplicate row once and fans the "
                         "stream out; 'reorder' sorts rows into prefix-"
                         "maximizing order; 'full' runs both. Per-row outputs "
                         "stay bit-identical to 'off'")
    ap.add_argument("--dup-row-fraction", type=float, default=0.0,
                    help="fraction of each relQuery's rows replaced by exact "
                         "copies of earlier rows (duplicate-heavy regime the "
                         "planner's dedup pass targets); 0.0 is byte-"
                         "identical to historical traces")
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--num-relqueries", type=int, default=100)
    ap.add_argument("--rate", type=float, default=1.0)
    ap.add_argument("--max-requests", type=int, default=100)
    ap.add_argument("--num-replicas", type=int, default=1,
                    help="data-parallel engine replicas (simulate mode)")
    ap.add_argument("--router", default="affinity_spill",
                    choices=list(ROUTER_POLICIES))
    ap.add_argument("--kv-backend", default="dense", choices=["dense", "paged"],
                    help="real-mode KV layout: 'dense' per-slot caches "
                         "(max_slots x max_len buffers) or 'paged' — a "
                         "BlockManager-owned block pool with per-request "
                         "block tables, batched bucketed prefill and "
                         "paged-attention decode (Pallas kernel on "
                         "accelerators, jnp reference on CPU); on CPU token "
                         "streams are bit-identical across backends")
    ap.add_argument("--kv-admission", default="conservative",
                    choices=["conservative", "optimistic", "predicted"],
                    help="KV-cap admission policy: 'conservative' reserves "
                         "each request's worst-case prompt+output footprint "
                         "upfront; 'optimistic' admits on current footprint "
                         "and preempts the lowest-priority running relQuery "
                         "(re-prefill restart) when decode growth hits the "
                         "cap; 'predicted' admits on the per-template "
                         "predicted output length (ALISE-style quantile of "
                         "finished siblings; worst case until history "
                         "accumulates) with preemption as the safety valve")
    ap.add_argument("--kv-cap", type=int, default=None,
                    help="override the KV-resident token cap (BatchLimits.cap)")
    ap.add_argument("--kv-tiering", default="off", choices=["on", "off"],
                    help="host-offload KV tier: under cap pressure a victim's "
                         "KV is swapped to host memory (and back, resuming "
                         "decode without re-prefill) whenever the modeled "
                         "transfer beats re-prefilling it — per-victim "
                         "cost-based reclaim; 'off' is bit-identical "
                         "recompute-only preemption. Requires a preempting "
                         "--kv-admission (optimistic or predicted)")
    ap.add_argument("--host-kv-cap", type=int, default=None,
                    help="host-tier capacity in KV tokens (with --kv-tiering "
                         "on; default 4x the device cap)")
    ap.add_argument("--swap-bandwidth", type=float, default=None,
                    help="modeled device<->host link bandwidth in GB/s for "
                         "the swap cost model (with --kv-tiering on; "
                         "default 32). Concurrent swaps in one tick queue "
                         "against this shared budget")
    ap.add_argument("--proactive-offload", default="off",
                    choices=["on", "off"],
                    help="FastServe-style proactive KV offload (with "
                         "--kv-tiering on): each tick, idle-tail victims — "
                         "requests of parked relQueries, stragglers past the "
                         "decode batch width, and (under pre-pressure) "
                         "requests whose predicted remaining work exceeds "
                         "--idle-horizon — are swapped to the host tier "
                         "before the pressure valve is forced to act. "
                         "Timing-only: token streams are bit-identical "
                         "on vs off")
    ap.add_argument("--idle-horizon", type=float, default=None,
                    help="predicted-remaining-work threshold in seconds for "
                         "the proactive-offload idle-tail victim class (with "
                         "--proactive-offload on; default 1.0)")
    ap.add_argument("--swap-prefetch", default="off", choices=["on", "off"],
                    help="ALISE-style swap-in prefetch (with --kv-tiering "
                         "on): the next resume candidate's host->device copy "
                         "is issued a tick early and rides under compute, so "
                         "the resume commits with zero stall. Timing-only: "
                         "token streams are bit-identical on vs off")
    ap.add_argument("--debug-invariants", action="store_true",
                    help="assert scheduler-ledger / block-pool / shared-"
                         "ledger invariants after every tick (slow; CI smoke)")
    ap.add_argument("--prefix-sharing", default="off", choices=["on", "off"],
                    help="prefix-sharing-aware scheduling: warm-then-follow "
                         "prefill candidates and shared-block KV admission "
                         "(shared template prefixes count once against the "
                         "cap); 'off' is bit-identical to the pre-sharing "
                         "scheduler")
    ap.add_argument("--dpu-exact-probe", action="store_true",
                    help="DPU prices priorities with a full prefix-cache "
                         "probe (realized sharing) instead of Eq. 11's "
                         "sampled miss ratio")
    ap.add_argument("--engine-loop", default="serial",
                    choices=["serial", "pipelined"],
                    help="engine tick loop: 'serial' schedules then executes; "
                         "'pipelined' splits the executor into dispatch/wait "
                         "and schedules the next batch against a projected "
                         "ledger while the current one runs on device — token "
                         "streams and simulated-clock reports are "
                         "bit-identical either way")
    ap.add_argument("--starvation-threshold", type=float, default=None)
    ap.add_argument("--autoscale", action="store_true",
                    help="attach the queue-depth/p50 autoscaler: replicas are "
                         "added under backlog and gracefully drained (migrate "
                         "waiting relQueries, finish resident work, retire) "
                         "when idle, between --min-replicas and "
                         "--max-replicas (simulate, closed-loop)")
    ap.add_argument("--min-replicas", type=int, default=None,
                    help="autoscaler floor (default 1)")
    ap.add_argument("--max-replicas", type=int, default=None,
                    help="autoscaler ceiling (default max(4, 2x "
                         "--num-replicas))")
    ap.add_argument("--crash-at", type=float, default=None,
                    help="deterministic fault injection: kill the busiest "
                         "admitting replica at this simulated time; its "
                         "in-flight relQueries fail over to the survivors "
                         "(rewound to the last periodic snapshot when one "
                         "exists) with final streams bit-identical to a "
                         "crash-free run (simulate, closed-loop, "
                         ">= 2 replicas)")
    ap.add_argument("--snapshot-every", type=int, default=None,
                    help="periodic per-replica scheduler snapshot cadence in "
                         "batches — the crash-recovery anchor (default 20 "
                         "with --crash-at, else 0 = off)")
    ap.add_argument("--metrics-log", default=None, metavar="PATH",
                    help="write periodic cluster metrics_snapshot samples "
                         "(per-replica queue depth, KV device/host occupancy, "
                         "preemptions, swaps, prefix-hit ratio, router "
                         "spills) as JSONL (simulate, closed-loop)")
    ap.add_argument("--metrics-interval", type=float, default=5.0,
                    help="simulated seconds between --metrics-log samples")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.num_replicas < 1:
        raise SystemExit("--num-replicas must be >= 1")
    if args.rate <= 0:
        raise SystemExit(f"--rate must be > 0 relQueries/s (got {args.rate})")
    if args.num_relqueries < 1:
        raise SystemExit(
            f"--num-relqueries must be >= 1 (got {args.num_relqueries})")
    if args.max_requests < 1:
        raise SystemExit(f"--max-requests must be >= 1 (got {args.max_requests})")
    if args.kv_cap is not None and args.kv_cap < 1:
        raise SystemExit(f"--kv-cap must be >= 1 (got {args.kv_cap})")
    if not 0.0 <= args.dup_row_fraction <= 1.0:
        raise SystemExit(f"--dup-row-fraction must be in [0, 1] "
                         f"(got {args.dup_row_fraction})")
    if args.plan != "off" and args.open_loop:
        raise SystemExit("--plan rewrites a closed-loop trace replay; it does "
                         "not apply to the scripted --open-loop session")
    kv_tiering = args.kv_tiering == "on"
    if kv_tiering and args.kv_admission == "conservative":
        raise SystemExit("--kv-tiering on requires a preempting admission "
                         "mode; pass --kv-admission optimistic or predicted")
    if not kv_tiering and args.host_kv_cap is not None:
        raise SystemExit("--host-kv-cap only applies with --kv-tiering on")
    if not kv_tiering and args.swap_bandwidth is not None:
        raise SystemExit("--swap-bandwidth only applies with --kv-tiering on")
    if args.host_kv_cap is not None and args.host_kv_cap < 1:
        raise SystemExit(f"--host-kv-cap must be >= 1 (got {args.host_kv_cap})")
    if args.swap_bandwidth is not None and args.swap_bandwidth <= 0:
        raise SystemExit(f"--swap-bandwidth must be > 0 GB/s "
                         f"(got {args.swap_bandwidth})")
    proactive_offload = args.proactive_offload == "on"
    swap_prefetch = args.swap_prefetch == "on"
    if proactive_offload and not kv_tiering:
        raise SystemExit("--proactive-offload only applies with "
                         "--kv-tiering on")
    if swap_prefetch and not kv_tiering:
        raise SystemExit("--swap-prefetch only applies with --kv-tiering on")
    if args.idle_horizon is not None and not proactive_offload:
        raise SystemExit("--idle-horizon only applies with "
                         "--proactive-offload on")
    if args.idle_horizon is not None and args.idle_horizon <= 0:
        raise SystemExit(f"--idle-horizon must be > 0 s "
                         f"(got {args.idle_horizon})")
    elastic = (args.autoscale or args.crash_at is not None
               or args.metrics_log is not None)
    if elastic and not args.simulate:
        raise SystemExit("--autoscale/--crash-at/--metrics-log drive the "
                         "elastic simulated cluster; add --simulate")
    if elastic and (args.open_loop or args.plan != "off"):
        raise SystemExit("--autoscale/--crash-at/--metrics-log run the "
                         "closed-loop elastic replay; drop --open-loop/--plan")
    if args.crash_at is not None and args.crash_at <= 0:
        raise SystemExit(f"--crash-at must be > 0 s (got {args.crash_at})")
    if args.crash_at is not None and args.num_replicas < 2:
        raise SystemExit("--crash-at needs --num-replicas >= 2: the failed "
                         "replica's work must have a survivor to fail over to")
    if (args.min_replicas is not None or args.max_replicas is not None) \
            and not args.autoscale:
        raise SystemExit("--min-replicas/--max-replicas only apply with "
                         "--autoscale")
    if args.snapshot_every is not None and args.snapshot_every < 0:
        raise SystemExit(f"--snapshot-every must be >= 0 batches "
                         f"(got {args.snapshot_every})")
    if args.snapshot_every is not None and not args.simulate:
        raise SystemExit("--snapshot-every only applies with --simulate")
    if args.metrics_interval <= 0:
        raise SystemExit(f"--metrics-interval must be > 0 s "
                         f"(got {args.metrics_interval})")
    min_replicas = args.min_replicas if args.min_replicas is not None else 1
    max_replicas = args.max_replicas if args.max_replicas is not None \
        else max(4, 2 * args.num_replicas)
    if args.autoscale and not (min_replicas <= args.num_replicas
                               <= max_replicas):
        raise SystemExit(f"--autoscale needs --min-replicas <= --num-replicas "
                         f"<= --max-replicas (got {min_replicas} / "
                         f"{args.num_replicas} / {max_replicas})")
    snapshot_every = args.snapshot_every if args.snapshot_every is not None \
        else (20 if args.crash_at is not None else 0)
    lm = a100_opt13b()
    limits = BatchLimits() if args.kv_cap is None else BatchLimits(cap=args.kv_cap)
    prefix_sharing = args.prefix_sharing == "on"
    host_kv_cap = args.host_kv_cap if args.host_kv_cap is not None \
        else 4 * limits.cap
    swap_bandwidth = args.swap_bandwidth if args.swap_bandwidth is not None \
        else 32.0
    tiering_kw = dict(kv_tiering=kv_tiering,
                      host_kv_cap=host_kv_cap if kv_tiering else 0,
                      swap_bandwidth_gbps=swap_bandwidth,
                      proactive_offload=proactive_offload,
                      idle_horizon_s=args.idle_horizon,
                      swap_prefetch=swap_prefetch,
                      debug_invariants=args.debug_invariants)

    if args.simulate:
        ds = make_dataset(args.dataset, num_rows=10_000, seed=args.seed)
        trace = build_trace(ds, TraceConfig(
            num_relqueries=args.num_relqueries, rate=args.rate, seed=args.seed,
            max_requests=args.max_requests,
            dup_row_fraction=args.dup_row_fraction))
        dpu = DPUConfig(starvation_threshold=args.starvation_threshold,
                        exact_probe=args.dpu_exact_probe)
        cluster = build_simulated_cluster(
            args.num_replicas, scheduler=args.scheduler, latency_model=lm,
            router_policy=args.router, dpu_config=dpu, seed=args.seed,
            limits=limits, kv_admission=args.kv_admission,
            prefix_sharing=prefix_sharing, engine_loop=args.engine_loop,
            snapshot_every=snapshot_every, **tiering_kw)
        print(f"scheduler={args.scheduler} replicas={args.num_replicas} "
              f"router={args.router} kv-admission={args.kv_admission} "
              f"prefix-sharing={args.prefix_sharing} "
              f"engine-loop={args.engine_loop} kv-tiering={args.kv_tiering}")
        if args.open_loop:
            report = run_open_loop(Frontend(cluster), trace)
            _print_report("open-loop", report)
        elif args.plan != "off":
            report = run_planned(Frontend(cluster), trace, args.plan)
            _print_report("planned", report)
        elif elastic:
            if args.autoscale:
                cluster.attach_autoscaler(Autoscaler(cluster, AutoscaleConfig(
                    min_replicas=min_replicas, max_replicas=max_replicas)))
            fe = Frontend(cluster)
            try:
                result = run_elastic_replay(
                    fe, cluster, trace, crash_at=args.crash_at,
                    metrics_log=args.metrics_log,
                    metrics_interval=args.metrics_interval)
            finally:
                fe.close()
            for i, rep in enumerate(result.per_replica):
                _print_report(f"replica {i}", rep)
            _print_report("merged", result.merged)
            report = result.merged
            if result.scale_events:
                adds = sum(1 for e in result.scale_events
                           if e["action"] == "add")
                drains = sum(1 for e in result.scale_events
                             if e["action"] == "drain")
                print(f"[autoscale] {adds} replicas added, {drains} drained; "
                      f"final fleet {result.replica_states}")
        else:
            result = cluster.run_trace(trace)
            for i, rep in enumerate(result.per_replica):
                _print_report(f"replica {i}", rep)
            _print_report("merged", result.merged)
            report = result.merged
        if args.num_replicas > 1 or elastic:
            stats = cluster.router.stats
            print(f"router: {stats['routed']} routed, "
                  f"{stats['spilled']} spilled, "
                  f"{stats['template_homes']} live template homes "
                  f"({stats['template_homes_created']} created)")
    else:
        import jax

        from repro.configs import get_smoke_config
        from repro.engine.tokenizer import HashTokenizer
        from repro.models.registry import build_model
        from repro.serving import build_real_engine

        if args.num_replicas != 1:
            raise SystemExit("real-JAX mode runs a single replica on this host; "
                             "use --simulate for --num-replicas > 1")
        cfg = get_smoke_config(args.arch)
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(args.seed))
        tok = HashTokenizer(vocab_size=cfg.vocab_size - 2)
        ds = make_dataset(args.dataset, num_rows=1000, seed=args.seed)
        # output_token_cap keeps CPU decoding affordable without mutating the
        # built trace (relQueries are immutable once constructed)
        trace = build_trace(ds, TraceConfig(
            num_relqueries=min(args.num_relqueries, 8), rate=args.rate,
            seed=args.seed, max_requests=min(args.max_requests, 8),
            output_token_cap=8,
            dup_row_fraction=args.dup_row_fraction), tokenizer=tok)
        try:
            engine = build_real_engine(
                args.arch, args.scheduler, args.kv_backend, limits=limits,
                latency_model=lm, kv_admission=args.kv_admission,
                prefix_sharing=prefix_sharing, max_slots=64, max_len=1024,
                model=model, params=params, engine_loop=args.engine_loop,
                dpu_config=DPUConfig(
                    starvation_threshold=args.starvation_threshold,
                    exact_probe=args.dpu_exact_probe)
                if args.scheduler.startswith("relserve") else None,
                **tiering_kw)
        except NotImplementedError as e:
            raise SystemExit(f"--kv-backend {args.kv_backend}: {e}")
        print(f"scheduler={args.scheduler} kv-backend={args.kv_backend} "
              f"engine-loop={args.engine_loop} kv-tiering={args.kv_tiering}")
        if args.open_loop:
            report = run_open_loop(Frontend(engine), trace)
            _print_report("open-loop", report)
        elif args.plan != "off":
            report = run_planned(Frontend(engine), trace, args.plan,
                                 tokenizer=tok)
            _print_report("planned", report)
        else:
            report = engine.run_trace(trace)
            _print_report("merged", report)

    print(f"overheads: DPU {report.dpu_time:.3f}s  ABA {report.aba_time:.3f}s  "
          f"schedule {report.schedule_time:.3f}s  "
          f"retry {report.schedule_retry_time:.3f}s "
          f"({report.schedule_retries} retries)")
    if report.overlap_hidden_time:
        print(f"overlap: {report.overlap_hidden_time:.3f}s of scheduler work "
              f"hidden behind device compute (pipelined loop)")


if __name__ == "__main__":
    main()
