"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state. Single pod: 16x16 = 256 chips (data x model). Multi-pod:
2x16x16 = 512 chips (pod x data x model); the pod axis is pure DP for serving
and the outer gradient-reduction tier for training. Scaling to more pods is a
mesh-shape change only.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Degenerate 1-device mesh for smoke-scale runs."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
