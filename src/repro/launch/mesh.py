"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state. Single pod: 16x16 = 256 chips (data x model). Multi-pod:
2x16x16 = 512 chips (pod x data x model); the pod axis is pure DP for serving
and the outer gradient-reduction tier for training. Scaling to more pods is a
mesh-shape change only.

``compat_make_mesh`` is the one mesh constructor everything (production
meshes, the subprocess sharding tests) routes through: ``jax.sharding.
AxisType`` only exists from jax 0.5; on older installs (e.g. the 0.4.x in
this image) ``axis_types`` must simply not be passed — the default is Auto
either way.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax


def supports_axis_types() -> bool:
    """Whether the installed jax has ``jax.sharding.AxisType`` (>= 0.5)."""
    return hasattr(jax.sharding, "AxisType")


def compat_make_mesh(shape: Sequence[int], axis_names: Tuple[str, ...]):
    """``jax.make_mesh`` with explicit-Auto axis types where supported, and
    the (equivalent) implicit default where ``AxisType`` does not exist."""
    if supports_axis_types():
        return jax.make_mesh(
            tuple(shape), tuple(axis_names),
            axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names))
    return jax.make_mesh(tuple(shape), tuple(axis_names))


def compat_set_mesh(mesh) -> None:
    """Install ``mesh`` as the ambient mesh. ``jax.set_mesh`` only exists on
    newer jax; on 0.4.x the equivalent is entering the mesh's resource-env
    context (which ``Mesh`` exposes as a context manager) for the rest of the
    process — the idiom the subprocess sharding tests rely on."""
    if hasattr(jax, "set_mesh"):
        jax.set_mesh(mesh)
    else:
        mesh.__enter__()


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1-device mesh for smoke-scale runs."""
    return compat_make_mesh((1, 1), ("data", "model"))
