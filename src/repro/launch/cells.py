"""(architecture x shape x mesh) cell construction: step function, abstract
``input_specs()`` (ShapeDtypeStruct stand-ins, no allocation), and shardings.

Every cell lowers one of:
  train_step  — fwd+bwd+AdamW (microbatched, remat, ZeRO-1)   [train_4k]
  prefill     — full-context prefill returning logits+cache   [prefill_32k]
  serve_step  — one decode token against a seq_len KV cache   [decode_32k, long_500k]
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, get_shape
from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import ParallelConfig
from repro.models.registry import build_model
from repro.training.optimizer import abstract_opt_state, opt_state_specs
from repro.training.train_step import TrainConfig, make_train_step

WHISPER_PROMPT_LEN = 64          # decoder prompt tokens at prefill

# per-arch gradient accumulation for train_4k (fit-to-HBM knob; see DESIGN.md)
TRAIN_GRAD_ACCUM: Dict[str, int] = {
    "qwen2.5-32b": 4,
    "internvl2-26b": 4,
    "gemma3-12b": 2,
    "qwen3-moe-30b-a3b": 2,
    "rwkv6-7b": 2,
    "hymba-1.5b": 2,
    "qwen3-1.7b": 2,
}


def effective_pc(mesh, global_batch: int) -> ParallelConfig:
    """Drop DP batch sharding when the batch doesn't divide it (long_500k B=1)."""
    pc = ParallelConfig.from_mesh(mesh)
    if global_batch % max(pc.dp, 1) != 0:
        return ParallelConfig(dp_axes=(), tp_axis=pc.tp_axis, tp=pc.tp, dp=1)
    return pc


def fsdp_pc(mesh) -> ParallelConfig:
    """Pure-FSDP layout (§Perf): every mesh axis carries batch; parameters are
    fully sharded (zero1_spec over all axes) and gathered per layer. Removes
    TP activation all-reduces entirely — the train-cell collective fix."""
    import numpy as np
    names = tuple(mesh.axis_names)
    return ParallelConfig(dp_axes=names, tp_axis=None, tp=1,
                          dp=int(np.prod(mesh.devices.shape)))


@dataclass
class Cell:
    arch: str
    shape: ShapeConfig
    kind: str                    # train | prefill | serve
    fn: Any
    args: Tuple                  # ShapeDtypeStruct trees
    in_shardings: Optional[Tuple]
    donate_argnums: Tuple[int, ...]
    model: Any
    pc: ParallelConfig


def _shard(mesh, spec: P):
    return NamedSharding(mesh, spec) if mesh is not None else None


def _tree_shardings(mesh, abstract, specs):
    if mesh is None:
        return None
    return jax.tree.map(lambda a, s: NamedSharding(mesh, s), abstract, specs)


def _dict_shardings(mesh, struct: Dict, specs: Dict):
    if mesh is None:
        return None
    return {k: NamedSharding(mesh, specs[k]) for k in struct}


def build_cell(arch: str, shape_name: str, mesh=None,
               cfg_override: Optional[ModelConfig] = None,
               train_layout: str = "tp", compress_grads: bool = False) -> Cell:
    cfg = cfg_override or get_config(arch)
    shape = get_shape(shape_name)
    if not cfg.supports_shape(shape):
        raise ValueError(f"{arch} skips {shape_name} (see DESIGN.md §5)")
    if mesh is None:
        pc = ParallelConfig.single_device()
    elif shape.kind == "train" and train_layout == "fsdp":
        pc = fsdp_pc(mesh)
        assert shape.global_batch % pc.dp == 0, "FSDP needs batch % devices == 0"
    else:
        pc = effective_pc(mesh, shape.global_batch)
    model = build_model(cfg, pc)
    model.mesh = mesh   # shard_map paths (MoE local-EP dispatch) need it
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    bs = pc.spec("batch", None)
    bs1 = pc.spec("batch")
    bs3 = pc.spec("batch", None, None)

    params = model.abstract_params()
    params_sh = model.param_shardings(mesh) if mesh is not None else None

    if shape.kind == "train":
        ga = 1 if train_layout == "fsdp" else TRAIN_GRAD_ACCUM.get(arch, 1)
        tc = TrainConfig(grad_accum=ga, compress_grads=compress_grads)
        step = make_train_step(model, tc)
        opt = abstract_opt_state(params)
        p_specs = model.param_specs()
        if train_layout == "fsdp" and mesh is not None:
            from repro.training.optimizer import zero1_spec
            p_specs = jax.tree.map(lambda sp, a: zero1_spec(sp, a.shape, pc),
                                   p_specs, params)
            params_sh = _tree_shardings(mesh, params, p_specs)
        opt_sh = _tree_shardings(mesh, opt, opt_state_specs(p_specs, params, pc))
        batch, batch_sh = _train_batch(cfg, model, B, S, pc, mesh)
        return Cell(arch, shape, "train", step, (params, opt, batch),
                    (params_sh, opt_sh, batch_sh) if mesh is not None else None,
                    (0, 1), model, pc)

    if shape.kind == "prefill":
        return _prefill_cell(arch, cfg, model, shape, B, S, pc, mesh, params, params_sh)

    # decode / long_decode -> serve_step
    cache = model.cache_struct(B, S)
    cache_sh = _dict_shardings(mesh, cache, model.cache_specs())
    tokens = jax.ShapeDtypeStruct((B,), i32)
    positions = jax.ShapeDtypeStruct((B,), i32)

    def serve_step(p, c, t, pos):
        return model.decode_step(p, c, t, pos)

    in_sh = (params_sh, cache_sh, _shard(mesh, bs1), _shard(mesh, bs1)) \
        if mesh is not None else None
    return Cell(arch, shape, "serve", serve_step, (params, cache, tokens, positions),
                in_sh, (1,), model, pc)


def _train_batch(cfg, model, B, S, pc, mesh):
    i32 = jnp.int32
    bs = pc.spec("batch", None)
    bs3 = pc.spec("batch", None, None)
    if cfg.is_encoder_decoder:
        T = cfg.max_target_len
        batch = {
            "frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16),
            "tokens": jax.ShapeDtypeStruct((B, T), i32),
            "labels": jax.ShapeDtypeStruct((B, T), i32),
        }
        sh = {"frames": _shard(mesh, bs3), "tokens": _shard(mesh, bs),
              "labels": _shard(mesh, bs)} if mesh is not None else None
        return batch, sh
    if cfg.num_vision_patches > 0:
        Pch = cfg.num_vision_patches
        batch = {
            "tokens": jax.ShapeDtypeStruct((B, S - Pch), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
            "extra_embeds": jax.ShapeDtypeStruct((B, Pch, cfg.d_model), jnp.bfloat16),
        }
        sh = {"tokens": _shard(mesh, bs), "labels": _shard(mesh, bs),
              "extra_embeds": _shard(mesh, bs3)} if mesh is not None else None
        return batch, sh
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), i32),
             "labels": jax.ShapeDtypeStruct((B, S), i32)}
    sh = {"tokens": _shard(mesh, bs), "labels": _shard(mesh, bs)} \
        if mesh is not None else None
    return batch, sh


def _prefill_cell(arch, cfg, model, shape, B, S, pc, mesh, params, params_sh):
    i32 = jnp.int32
    bs = pc.spec("batch", None)
    bs1 = pc.spec("batch")
    bs3 = pc.spec("batch", None, None)
    seq_lens = jax.ShapeDtypeStruct((B,), i32)

    if cfg.is_encoder_decoder:
        frames = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
        tokens = jax.ShapeDtypeStruct((B, WHISPER_PROMPT_LEN), i32)

        def prefill(p, t, f, sl):
            return model.prefill(p, t, frames=f, seq_lens=sl)

        in_sh = (params_sh, _shard(mesh, bs), _shard(mesh, bs3), _shard(mesh, bs1)) \
            if mesh is not None else None
        return Cell(arch, shape, "prefill", prefill, (params, tokens, frames, seq_lens),
                    in_sh, (), model, pc)

    if cfg.num_vision_patches > 0:
        Pch = cfg.num_vision_patches
        tokens = jax.ShapeDtypeStruct((B, S - Pch), i32)
        extra = jax.ShapeDtypeStruct((B, Pch, cfg.d_model), jnp.bfloat16)

        def prefill(p, t, e, sl):
            return model.prefill(p, t, extra_embeds=e, seq_lens=sl, max_len=S)

        in_sh = (params_sh, _shard(mesh, bs), _shard(mesh, bs3), _shard(mesh, bs1)) \
            if mesh is not None else None
        return Cell(arch, shape, "prefill", prefill, (params, tokens, extra, seq_lens),
                    in_sh, (), model, pc)

    tokens = jax.ShapeDtypeStruct((B, S), i32)

    def prefill(p, t, sl):
        return model.prefill(p, t, seq_lens=sl, max_len=S)

    in_sh = (params_sh, _shard(mesh, bs), _shard(mesh, bs1)) \
        if mesh is not None else None
    return Cell(arch, shape, "prefill", prefill, (params, tokens, seq_lens),
                in_sh, (), model, pc)


def lower_cell(cell: Cell, mesh=None):
    """jit + lower (AOT, no allocation). Caller compiles."""
    jitted = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                     donate_argnums=cell.donate_argnums) \
        if cell.in_shardings is not None else jax.jit(cell.fn)
    if mesh is not None:
        with mesh:
            return jitted.lower(*cell.args)
    return jitted.lower(*cell.args)
