import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
on the production meshes and record memory/cost/collective statistics.

The 512 placeholder host devices exist ONLY here (set before any jax import,
which locks the device count at first init). Smoke tests and benchmarks see
the real single CPU device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --all
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2.5-32b --shape decode_32k --multi-pod
Results append to experiments/dryrun_results.json.
"""

import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax

from repro.configs import ARCH_IDS, get_config, get_shape
from repro.configs.base import ALL_SHAPES
from repro.launch.cells import build_cell, lower_cell
from repro.launch.hlo_stats import collective_stats
from repro.launch.mesh import make_production_mesh

RESULTS_PATH = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "experiments", "dryrun_results.json")


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             verbose: bool = True) -> Dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    row: Dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "kind": shape.kind}
    if not cfg.supports_shape(shape):
        row["status"] = "skipped"
        row["reason"] = "full-attention arch skips long_500k (DESIGN.md §5)"
        return row
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        cell = build_cell(arch, shape_name, mesh)
        lowered = lower_cell(cell, mesh)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        hlo_text = compiled.as_text()
        colls = collective_stats(hlo_text)
        from repro.launch.hlo_stats import dot_flops
        dflops = dot_flops(hlo_text)
        row.update({
            "status": "ok",
            "lower_s": round(t1 - t0, 2),
            "compile_s": round(t2 - t1, 2),
            "argument_bytes_per_device": int(ma.argument_size_in_bytes),
            "output_bytes_per_device": int(ma.output_size_in_bytes),
            "temp_bytes_per_device": int(ma.temp_size_in_bytes),
            "alias_bytes_per_device": int(getattr(ma, "alias_size_in_bytes", 0)),
            "peak_bytes_per_device": int(ma.argument_size_in_bytes
                                         + ma.output_size_in_bytes
                                         + ma.temp_size_in_bytes
                                         - getattr(ma, "alias_size_in_bytes", 0)),
            "hlo_flops_per_device": float(ca.get("flops", 0.0)),
            "dot_flops_per_device": float(dflops),
            "hlo_bytes_per_device": float(ca.get("bytes accessed", 0.0)),
            "collective_out_bytes": dict(colls.out_bytes),
            "collective_wire_bytes": {k: round(v) for k, v in colls.wire_bytes.items()},
            "collective_counts": dict(colls.counts),
            "num_devices": int(len(mesh.devices.ravel())),
        })
        if verbose:
            print(f"  memory_analysis: args={ma.argument_size_in_bytes/1e9:.2f}GB "
                  f"temp={ma.temp_size_in_bytes/1e9:.2f}GB "
                  f"out={ma.output_size_in_bytes/1e9:.2f}GB "
                  f"alias={getattr(ma, 'alias_size_in_bytes', 0)/1e9:.2f}GB")
            print(f"  cost_analysis: flops={ca.get('flops', 0):.3e} "
                  f"bytes={ca.get('bytes accessed', 0):.3e} (scan body counted once)")
            print(f"  collectives(out bytes): {dict(colls.out_bytes)}")
    except Exception as e:  # noqa: BLE001 — a failing cell is a reportable bug
        row["status"] = "failed"
        row["error"] = f"{type(e).__name__}: {e}"
        row["traceback"] = traceback.format_exc(limit=8)
    return row


def save_rows(rows, path: str = RESULTS_PATH) -> None:
    path = os.path.abspath(path)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    existing = []
    if os.path.exists(path):
        with open(path) as f:
            existing = json.load(f)
    keyed = {(r["arch"], r["shape"], r["mesh"]): r for r in existing}
    for r in rows:
        keyed[(r["arch"], r["shape"], r["mesh"])] = r
    with open(path, "w") as f:
        json.dump(list(keyed.values()), f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCH_IDS + [None])
    ap.add_argument("--shape", default=None,
                    choices=[s.name for s in ALL_SHAPES] + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells already ok/skipped in the results file")
    ap.add_argument("--out", default=RESULTS_PATH)
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else [s.name for s in ALL_SHAPES]
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]

    done = set()
    out_abs = os.path.abspath(args.out)
    if args.resume and os.path.exists(out_abs):
        with open(out_abs) as f:
            for r in json.load(f):
                if r.get("status") in ("ok", "skipped"):
                    done.add((r["arch"], r["shape"], r["mesh"]))

    rows = []
    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                if (arch, shape, mesh_name) in done:
                    continue
                tag = f"{arch} x {shape} x {mesh_name}"
                print(f"[dryrun] {tag}", flush=True)
                row = run_cell(arch, shape, mp)
                rows.append(row)
                if row["status"] == "failed":
                    n_fail += 1
                    print(f"  FAILED: {row['error']}", flush=True)
                elif row["status"] == "skipped":
                    print(f"  skipped: {row['reason']}", flush=True)
                else:
                    print(f"  ok (lower {row['lower_s']}s compile {row['compile_s']}s, "
                          f"peak {row['peak_bytes_per_device']/1e9:.2f} GB/device)",
                          flush=True)
                save_rows(rows, args.out)
    print(f"\n{len(rows)} cells, {n_fail} failures")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
