"""Parse compiled (SPMD-partitioned, per-device) HLO text for collective ops.

``cost_analysis()`` gives FLOPs/bytes but not collective traffic; we sum the
result shapes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute in the module (entry + nested computations) and derive
wire-byte estimates from replica-group sizes.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_OP_RE = re.compile(
    r"=\s*(?P<shape>\([^=]*?\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"(?P<kind>all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=(\{\{[^}]*\}[^,]*\}|\[[0-9,]+\]<=\[[0-9,]+\])")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if not m:
        return 1
    g = m.group(1)
    if g.startswith("{{"):
        first = g[2:].split("}")[0]
        return len([x for x in first.split(",") if x.strip() != ""])
    # iota form: replica_groups=[G,N]<=[TOTAL] -> groups of size N
    dims = g[1:g.index("]")].split(",")
    return int(dims[-1]) if dims else 1


@dataclass
class CollectiveStats:
    """Per-kind output bytes + wire-byte estimates (per device)."""
    out_bytes: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    wire_bytes: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    counts: Dict[str, int] = field(default_factory=lambda: defaultdict(int))

    @property
    def total_out_bytes(self) -> int:
        return sum(self.out_bytes.values())

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes.values())

    def scaled(self, factor: float) -> "CollectiveStats":
        s = CollectiveStats()
        for k in self.out_bytes:
            s.out_bytes[k] = int(self.out_bytes[k] * factor)
            s.wire_bytes[k] = self.wire_bytes[k] * factor
            s.counts[k] = int(self.counts[k] * factor)
        return s

    def add(self, other: "CollectiveStats", factor: float = 1.0) -> "CollectiveStats":
        s = CollectiveStats()
        for k in set(self.out_bytes) | set(other.out_bytes):
            s.out_bytes[k] = self.out_bytes[k] + int(other.out_bytes[k] * factor)
            s.wire_bytes[k] = self.wire_bytes[k] + other.wire_bytes[k] * factor
            s.counts[k] = self.counts[k] + int(other.counts[k] * factor)
        return s


_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*([a-z0-9]+)\[([0-9,]*)\]")
_DOT_LINE_RE = re.compile(
    r"=\s*[a-z0-9]+\[(?P<odims>[0-9,]*)\][^=]*?\sdot\((?P<operands>[^)]*)\)"
    r".*?lhs_contracting_dims=\{(?P<lc>[0-9,]*)\}")
_NAME_RE = re.compile(r"(%[\w\.\-]+)")


def dot_flops(hlo_text: str) -> float:
    """Exact MXU flops: 2 x prod(output dims) x prod(lhs contracting dims),
    summed over every dot in the module (incl. fusion bodies). Immune to the
    XLA:CPU bf16 float-normalization converts that pollute
    cost_analysis()['flops'] (see DESIGN.md §3)."""
    shapes: Dict[str, List[int]] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m and m.group(2) in _DTYPE_BYTES:
            shapes[m.group(1)] = [int(d) for d in m.group(3).split(",") if d]
    total = 0.0
    for line in hlo_text.splitlines():
        if " dot(" not in line:
            continue
        m = _DOT_LINE_RE.search(line)
        if not m:
            continue
        out_dims = [int(d) for d in m.group("odims").split(",") if d]
        names = _NAME_RE.findall(m.group("operands"))
        if not names:
            continue
        l_dims = shapes.get(names[0], [])
        lc = [int(d) for d in m.group("lc").split(",") if d]
        out_n = 1
        for d in out_dims:
            out_n *= d
        k = 1
        for i in lc:
            if i < len(l_dims):
                k *= l_dims[i]
        total += 2.0 * out_n * k
    return total


def collective_stats(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group("kind").replace("-start", "")
        out_b = _shape_bytes(m.group("shape"))
        n = max(1, _group_size(line))
        if kind == "all-gather":
            wire = out_b * (n - 1) / n
        elif kind == "all-reduce":
            wire = 2 * out_b * (n - 1) / n
        elif kind == "reduce-scatter":
            wire = out_b * (n - 1)            # input = n x output
        elif kind == "all-to-all":
            wire = out_b * (n - 1) / n
        else:  # collective-permute
            wire = out_b
        stats.out_bytes[kind] += out_b
        stats.wire_bytes[kind] += wire
        stats.counts[kind] += 1
    return stats
