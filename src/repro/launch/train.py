"""End-to-end training driver (CPU-scale; the same code path drives a mesh).

Trains an --arch model (smoke config by default; --layers/--d-model override)
on synthetic relational text, with checkpoint/restart via
repro.distributed.fault_tolerance — kill it mid-run and rerun with the same
--ckpt-dir to resume.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --steps 50
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.data.datasets import make_dataset
from repro.distributed.fault_tolerance import latest_step, load_checkpoint, save_checkpoint
from repro.engine.tokenizer import HashTokenizer
from repro.models.registry import build_model
from repro.training.optimizer import AdamWConfig, init_opt_state
from repro.training.train_step import TrainConfig, make_train_step


def token_stream(dataset, tokenizer, batch: int, seq: int, seed: int):
    """Pack rendered relational rows into fixed-length LM batches."""
    rng = np.random.RandomState(seed)
    buf = []
    while True:
        tpl = dataset.templates[rng.randint(len(dataset.templates))]
        row = dataset.table.rows[rng.randint(len(dataset.table))]
        buf.extend(tokenizer.encode(tpl.render(row)))
        if len(buf) >= batch * (seq + 1):
            arr = np.asarray(buf[: batch * (seq + 1)], np.int32).reshape(batch, seq + 1)
            buf = buf[batch * (seq + 1):]
            yield {"tokens": arr[:, :-1], "labels": arr[:, 1:]}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    if args.layers:
        cfg = cfg.replace(num_layers=args.layers)
    if args.d_model:
        cfg = cfg.replace(d_model=args.d_model)
    model = build_model(cfg)
    print(f"arch={cfg.name} params={model.param_count()/1e6:.1f}M")

    params = model.init_params(jax.random.PRNGKey(args.seed))
    opt = init_opt_state(params)
    start = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        start, trees = load_checkpoint(
            args.ckpt_dir, template_trees={"params": params, "opt": opt})
        params, opt = trees["params"], trees["opt"]
        print(f"resumed from step {start}")

    tc = TrainConfig(grad_accum=args.grad_accum, adamw=AdamWConfig(lr=args.lr))
    step_fn = jax.jit(make_train_step(model, tc), donate_argnums=(0, 1))
    ds = make_dataset("rotten", num_rows=2000, seed=args.seed)
    tok = HashTokenizer(vocab_size=cfg.vocab_size - 2)
    stream = token_stream(ds, tok, args.batch, args.seq, args.seed + start)

    t0 = time.time()
    for step in range(start, args.steps):
        batch = next(stream)
        params, opt, metrics = step_fn(params, opt,
                                       jax.tree.map(jnp.asarray, batch))
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"({(time.time()-t0):.1f}s)")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, step + 1,
                            {"params": params, "opt": opt},
                            {"arch": cfg.name})
            print(f"  checkpointed step {step + 1}")


if __name__ == "__main__":
    main()
