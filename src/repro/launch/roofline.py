"""Roofline analysis from compiled dry-run artifacts (no TPU on this host —
TPU v5e is the *target*: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI).

Three terms per (arch x shape x mesh), in seconds per step:
  compute    = HLO dot-flops / peak_flops          (per-device program)
  memory     = bytes / hbm_bw                      (analytic min + XLA view)
  collective = wire bytes / ici_bw

Scan-body correction: XLA's cost analysis counts a lax.scan body ONCE
(verified empirically), so every metric is composed as
  total = full + (n_groups - 1) x (cost(1-group model) - cost(0-layer model))
which is exact for homogeneous layer stacks. Decode steps are fully unrolled
in the model code, so their numbers need no correction.

XLA:CPU caveat (DESIGN.md §3): float normalization rewrites bf16 arithmetic to
f32, inflating cost_analysis 'flops'/'bytes accessed' and temp memory with
convert artifacts that do not exist on TPU. We therefore use (a) dot-flops
parsed from the HLO (exact, convert-free) for the compute term and (b) an
analytic bytes model for the memory term, reporting raw XLA numbers alongside.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from dataclasses import dataclass
from typing import Dict, Optional

from repro.configs import get_config, get_shape
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.cells import TRAIN_GRAD_ACCUM, build_cell, lower_cell
from repro.launch.hlo_stats import collective_stats, dot_flops

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link


@dataclass
class CellStats:
    dot_flops: float
    xla_flops: float
    xla_bytes: float
    coll_wire: float
    coll_out: float

    def combine(self, body: "CellStats", mult: float) -> "CellStats":
        return CellStats(
            self.dot_flops + mult * body.dot_flops,
            self.xla_flops + mult * body.xla_flops,
            self.xla_bytes + mult * body.xla_bytes,
            self.coll_wire + mult * body.coll_wire,
            self.coll_out + mult * body.coll_out,
        )

    @staticmethod
    def diff(a: "CellStats", b: "CellStats") -> "CellStats":
        return CellStats(a.dot_flops - b.dot_flops, a.xla_flops - b.xla_flops,
                         a.xla_bytes - b.xla_bytes, a.coll_wire - b.coll_wire,
                         a.coll_out - b.coll_out)


def _extract(compiled) -> CellStats:
    txt = compiled.as_text()
    ca = compiled.cost_analysis() or {}
    colls = collective_stats(txt)
    return CellStats(dot_flops(txt), float(ca.get("flops", 0.0)),
                     float(ca.get("bytes accessed", 0.0)),
                     colls.total_wire_bytes, colls.total_out_bytes)


def corrected_stats(arch: str, shape_name: str, mesh,
                    dryrun_row: Optional[Dict] = None) -> Dict:
    """Compose exact totals from the full-cell stats plus 1-group/0-layer
    variant compiles. When a dry-run row is supplied the (expensive) full-cell
    compile is reused from it instead of repeated."""
    cfg = get_config(arch)
    cell = build_cell(arch, shape_name, mesh)
    model = cell.model
    if dryrun_row is not None:
        full = CellStats(
            dryrun_row["dot_flops_per_device"],
            dryrun_row["hlo_flops_per_device"],
            dryrun_row["hlo_bytes_per_device"],
            float(sum(dryrun_row["collective_wire_bytes"].values())),
            float(sum(dryrun_row["collective_out_bytes"].values())),
        )
        peak = dryrun_row["peak_bytes_per_device"]
    else:
        compiled = lower_cell(cell, mesh).compile()
        full = _extract(compiled)
        ma = compiled.memory_analysis()
        peak = int(ma.argument_size_in_bytes + ma.output_size_in_bytes
                   + ma.temp_size_in_bytes - getattr(ma, "alias_size_in_bytes", 0))
    out = {
        "arch": arch, "shape": shape_name,
        "n_groups": model.scan_trip_count,
        "peak_bytes_per_device": peak,
    }
    shape = get_shape(shape_name)
    needs_correction = shape.kind in ("train", "prefill")  # decode is unrolled
    if needs_correction and model.scan_trip_count > 1:
        group = model.layers_per_scan_step
        c1 = build_cell(arch, shape_name, mesh,
                        cfg_override=cfg.replace(num_layers=group))
        c0 = build_cell(arch, shape_name, mesh,
                        cfg_override=cfg.replace(num_layers=0))
        s1 = _extract(lower_cell(c1, mesh).compile())
        s0 = _extract(lower_cell(c0, mesh).compile())
        body = CellStats.diff(s1, s0)
        total = full.combine(body, model.scan_trip_count - 1)
        out["scan_corrected"] = True
    else:
        total = full
        out["scan_corrected"] = False
    out["stats"] = dataclasses.asdict(total)
    out["stats_uncorrected"] = dataclasses.asdict(full)
    return out


# --------------------------------------------------------------------------
# analytic models (per-device; global figures divided by device count)
# --------------------------------------------------------------------------
def analytic_model_flops(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, float]:
    """Global MODEL_FLOPS: the spec's 6·N·D / 6·N_active·D parameter term plus
    an attention-context term reported separately (decode reads O(S) cache)."""
    n = cfg.num_params()
    n_act = cfg.num_active_params()
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        tokens = B * S
        param_term = 6.0 * n_act * tokens
        attn_mult = 3.0      # fwd + bwd
        ctx = S / 2          # causal average context
    elif shape.kind == "prefill":
        tokens = B * S
        param_term = 2.0 * n_act * tokens
        attn_mult = 1.0
        ctx = S / 2
    else:  # decode: one token per sequence against an S-token context
        tokens = B
        param_term = 2.0 * n_act * tokens
        attn_mult = 1.0
        ctx = S
    if cfg.attn_kind == "linear":
        attn = 0.0           # rwkv context cost folded into its param projections
    else:
        L_attn = cfg.num_layers
        window = cfg.sliding_window
        if cfg.attn_kind == "local_global" and window:
            n_local = cfg.num_layers * cfg.local_global_pattern // (cfg.local_global_pattern + 1)
            n_global = cfg.num_layers - n_local
            eff_ctx = (n_local * min(ctx, window) + n_global * ctx) / cfg.num_layers
        elif cfg.attn_kind == "swa" and window:
            eff_ctx = min(ctx, window)
        else:
            eff_ctx = ctx
        attn = attn_mult * 4.0 * tokens * cfg.num_heads * cfg.head_dim * eff_ctx * L_attn
    return {"param_flops": param_term, "attn_flops": attn,
            "model_flops": param_term + attn}


def analytic_memory_bytes(cfg: ModelConfig, shape: ShapeConfig, model,
                          n_devices: int, tp: int) -> float:
    """Per-device HBM traffic lower bound for one step (bf16 storage)."""
    param_bytes = model.param_count() * 2 / tp     # weights read once
    B = shape.global_batch
    dp = max(1, n_devices // tp)
    if shape.is_decode:
        try:
            cache = model.cache_struct(B, shape.seq_len)
            cache_bytes = sum(
                math.prod(s.shape) * s.dtype.itemsize
                for s in cache.values()) / n_devices
        except Exception:
            cache_bytes = 0.0
        return param_bytes + cache_bytes           # read cache once + weights
    act = B * shape.seq_len * cfg.d_model * 2 * cfg.num_layers * 4 / n_devices
    if shape.kind == "train":
        opt = model.param_count() * 4 * 3 * 2 / n_devices   # m,v,master r+w (ZeRO)
        return param_bytes * 2 + opt + act * 3
    return param_bytes + act


def roofline_row(arch: str, shape_name: str, mesh, dryrun_row: Optional[Dict] = None,
                 cell_stats: Optional[Dict] = None) -> Dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    n_dev = len(mesh.devices.ravel())
    cs = cell_stats or corrected_stats(arch, shape_name, mesh, dryrun_row=dryrun_row)
    stats = cs["stats"]
    cell = build_cell(arch, shape_name, mesh)
    tp = cell.pc.tp

    compute_term = stats["dot_flops"] / PEAK_FLOPS
    mem_bytes = analytic_memory_bytes(cfg, shape, cell.model, n_dev, tp)
    memory_term = mem_bytes / HBM_BW
    collective_term = stats["coll_wire"] / ICI_BW
    model = analytic_model_flops(cfg, shape)
    model_per_dev = model["model_flops"] / n_dev
    terms = {"compute": compute_term, "memory": memory_term,
             "collective": collective_term}
    bottleneck = max(terms, key=terms.get)
    step_time = max(terms.values())
    return {
        "arch": arch, "shape": shape_name, "mesh": "x".join(map(str, mesh.devices.shape)),
        "compute_term_s": compute_term,
        "memory_term_s": memory_term,
        "collective_term_s": collective_term,
        "bottleneck": bottleneck,
        "step_time_bound_s": step_time,
        "dot_flops_per_device": stats["dot_flops"],
        "model_flops_global": model["model_flops"],
        "model_param_flops_global": model["param_flops"],
        "useful_ratio": model_per_dev / stats["dot_flops"] if stats["dot_flops"] else 0.0,
        "analytic_mem_bytes_per_device": mem_bytes,
        "xla_bytes_per_device": stats["xla_bytes"],
        "xla_flops_per_device": stats["xla_flops"],
        "coll_wire_bytes_per_device": stats["coll_wire"],
        "mfu_at_bound": (model_per_dev / PEAK_FLOPS) / step_time if step_time else 0.0,
        "scan_corrected": cs.get("scan_corrected", False),
        "peak_bytes_per_device": cs.get("peak_bytes_per_device", 0),
    }
