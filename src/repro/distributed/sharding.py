"""Logical-axis sharding rules + GQA tensor-parallel head packing.

Models annotate tensors with *logical* axis names; ``ParallelConfig`` resolves
them to mesh ``PartitionSpec``s. The production mesh is ``(pod, data, model)``:
``batch → (pod, data)`` and all model-parallel dims → ``model``.

GQA packing: JAX rejects uneven input shardings, so Q/KV heads are packed into a
``[KVp, q_per_slot, head_dim]`` layout where ``KVp`` is a TP multiple. KV heads
are *duplicated* (not zero-padded) across slots so every slot computes real
attention; Q-head slots beyond the true count carry zero weights (exact math).
See DESIGN.md §3.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

import numpy as np
from jax.sharding import PartitionSpec as P

# logical axis name -> role
_TP_AXES = frozenset({
    "heads", "kv_heads", "ff", "vocab", "expert", "d_inner", "wkv_heads", "q_slots",
})
_DP_AXES = frozenset({"batch"})


@dataclass(frozen=True)
class ParallelConfig:
    """Resolved parallelism layout for one mesh."""

    dp_axes: Tuple[str, ...] = ()       # mesh axes carrying the batch (e.g. ('pod','data'))
    tp_axis: Optional[str] = None       # mesh axis carrying model parallelism
    tp: int = 1                         # size of tp_axis
    dp: int = 1                         # product size of dp_axes

    @staticmethod
    def single_device() -> "ParallelConfig":
        return ParallelConfig()

    @staticmethod
    def from_mesh(mesh) -> "ParallelConfig":
        names = tuple(mesh.axis_names)
        sizes = dict(zip(names, mesh.devices.shape))
        tp_axis = "model" if "model" in names else None
        dp_axes = tuple(n for n in names if n != "model")
        dp = int(np.prod([sizes[n] for n in dp_axes])) if dp_axes else 1
        return ParallelConfig(dp_axes=dp_axes, tp_axis=tp_axis,
                              tp=sizes.get("model", 1), dp=dp)

    def spec(self, *logical: Optional[str]) -> P:
        """Resolve a tuple of logical axis names to a PartitionSpec."""
        out = []
        for name in logical:
            if name is None:
                out.append(None)
            elif name in _DP_AXES:
                out.append(self.dp_axes if len(self.dp_axes) != 1 else self.dp_axes[0])
                if not self.dp_axes:
                    out[-1] = None
            elif name in _TP_AXES:
                out.append(self.tp_axis)
            else:
                raise ValueError(f"unknown logical axis {name!r}")
        return P(*out)


def round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class GQALayout:
    """Padded/duplicated GQA head layout for a given TP degree.

    - ``kv_slots`` (KVp): KV head slots, divisible by tp. ``dup_map[s]`` gives the
      true KV head stored in slot ``s`` (duplication, exact).
    - ``q_per_slot`` (qps): Q heads per slot; ``q_map[s, j]`` gives the true Q head
      index or -1 for a zero-weight pad slot.
    """

    num_heads: int
    num_kv_heads: int
    tp: int
    kv_slots: int
    q_per_slot: int
    dup_map: Tuple[int, ...]
    q_map: Tuple[Tuple[int, ...], ...]

    @property
    def padded_q_heads(self) -> int:
        return self.kv_slots * self.q_per_slot

    @property
    def q_flop_waste(self) -> float:
        """Fraction of attention Q-side compute spent on padding."""
        return self.padded_q_heads / self.num_heads - 1.0

    def dup_array(self) -> np.ndarray:
        return np.asarray(self.dup_map, dtype=np.int32)

    def q_array(self) -> np.ndarray:
        return np.asarray(self.q_map, dtype=np.int32)


def gqa_layout(num_heads: int, num_kv_heads: int, tp: int) -> GQALayout:
    qpk = num_heads // num_kv_heads
    assert num_heads == qpk * num_kv_heads, "num_heads must be a multiple of num_kv_heads"
    if tp <= 1:
        dup = tuple(range(num_kv_heads))
        qmap = tuple(tuple(k * qpk + j for j in range(qpk)) for k in range(num_kv_heads))
        return GQALayout(num_heads, num_kv_heads, 1, num_kv_heads, qpk, dup, qmap)
    kvp = round_up(num_kv_heads, tp)
    # distribute slots over true KV heads as evenly as possible, monotone
    dup = tuple(s * num_kv_heads // kvp for s in range(kvp))
    counts = [0] * num_kv_heads
    for k in dup:
        counts[k] += 1
    min_slots = min(counts)
    qps = math.ceil(qpk / min_slots)
    qmap = []
    first_slot = {}
    for s, k in enumerate(dup):
        if k not in first_slot:
            first_slot[k] = s
        rank = s - first_slot[k]
        row = []
        for j in range(qps):
            p = rank * qps + j
            row.append(k * qpk + p if p < qpk else -1)
        qmap.append(tuple(row))
    return GQALayout(num_heads, num_kv_heads, tp, kvp, qps, dup, tuple(qmap))


def pack_q_weight(w: np.ndarray, layout: GQALayout, head_axis: int = 1) -> np.ndarray:
    """Pack canonical per-Q-head weight ``[..., H, ...]`` to ``[..., KVp*qps, ...]``.

    Pad slots get zeros — with zero output-projection rows the math is exact.
    """
    w = np.moveaxis(w, head_axis, 0)
    out = np.zeros((layout.padded_q_heads,) + w.shape[1:], dtype=w.dtype)
    for s in range(layout.kv_slots):
        for j in range(layout.q_per_slot):
            src = layout.q_map[s][j]
            if src >= 0:
                out[s * layout.q_per_slot + j] = w[src]
    return np.moveaxis(out, 0, head_axis)


def pack_kv_weight(w: np.ndarray, layout: GQALayout, head_axis: int = 1) -> np.ndarray:
    """Duplicate canonical per-KV-head weight ``[..., KV, ...]`` into slots."""
    w = np.moveaxis(w, head_axis, 0)
    out = w[layout.dup_array()]
    return np.moveaxis(out, 0, head_axis)


def unpack_q_output(o: np.ndarray, layout: GQALayout, head_axis: int = 1) -> np.ndarray:
    """Inverse of pack_q_weight for comparing against canonical reference."""
    o = np.moveaxis(o, head_axis, 0)
    out = np.zeros((layout.num_heads,) + o.shape[1:], dtype=o.dtype)
    for s in range(layout.kv_slots):
        for j in range(layout.q_per_slot):
            src = layout.q_map[s][j]
            if src >= 0:
                out[src] = o[s * layout.q_per_slot + j]
    return np.moveaxis(out, 0, head_axis)


def shardable(dim: int, tp: int) -> bool:
    return tp <= 1 or dim % tp == 0


def tp_dim(logical_size: int, pc: ParallelConfig) -> Optional[str]:
    """Return 'ff'-style tp logical name only when the dim divides the TP degree."""
    return "ff" if shardable(logical_size, pc.tp) else None
