"""Elastic scaling: reshard checkpointed state onto a different mesh.

Checkpoints store logical shapes (mesh-independent), so growing/shrinking the
pod count between restarts is a reshard: rebuild shardings for the new mesh
from the same logical specs and ``jax.device_put`` each leaf. GSPMD handles
the gather/slice; at real scale this is the standard resume-on-new-topology
path (the data loader skips to the checkpointed step).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
from jax.sharding import NamedSharding

from repro.distributed.sharding import ParallelConfig


def reshard_tree(tree, mesh, specs):
    """Place every leaf of ``tree`` according to ``specs`` on ``mesh``."""
    shardings = jax.tree.map(lambda sp: NamedSharding(mesh, sp), specs,
                             is_leaf=lambda x: not isinstance(x, dict))
    return jax.tree.map(jax.device_put, tree, shardings)


def elastic_restore(model_builder, cfg, new_mesh, checkpoint_trees: Dict[str, Any]):
    """Rebuild a model + shardings for ``new_mesh`` and place restored arrays.

    model_builder: (cfg, ParallelConfig) -> model. Returns (model, placed trees).
    """
    pc = ParallelConfig.from_mesh(new_mesh)
    model = model_builder(cfg, pc)
    placed = {}
    if "params" in checkpoint_trees:
        placed["params"] = reshard_tree(checkpoint_trees["params"], new_mesh,
                                        model.param_specs())
    for name, tree in checkpoint_trees.items():
        if name not in placed:
            placed[name] = tree
    return model, placed
