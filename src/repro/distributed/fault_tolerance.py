"""Checkpoint/restore + engine-state snapshots.

Training: per-leaf ``.npy`` files under an atomically-renamed step directory
plus a JSON manifest (tree structure, shapes, dtypes, mesh axes) — resumable
and reshardable. At multi-host scale each host writes its addressable shards;
in this single-process container that degenerates to full arrays, same layout.

Serving: scheduler queues + relQuery progress serialize to JSON; the KV cache
is deliberately NOT checkpointed — it is recomputable via prefix replay, which
the prefix cache makes cheap (DESIGN.md §6).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.relquery import RelQuery, Request, RequestState


# --------------------------------------------------------------------------
# training checkpoints
# --------------------------------------------------------------------------
def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
             for path, _ in flat]
    return paths, [leaf for _, leaf in flat], treedef


def save_checkpoint(path: str, step: int, trees: Dict[str, Any],
                    metadata: Optional[Dict] = None) -> str:
    """Write ``trees`` (e.g. {'params': ..., 'opt': ...}) under path/step_N."""
    final = os.path.join(path, f"step_{step}")
    tmp = tempfile.mkdtemp(prefix=".ckpt_tmp_", dir=path if os.path.isdir(path) else None)
    os.makedirs(path, exist_ok=True)
    manifest = {"step": step, "metadata": metadata or {}, "trees": {}}
    try:
        for name, tree in trees.items():
            paths, leaves, _ = _flatten_with_paths(tree)
            entries = []
            for i, (p, leaf) in enumerate(zip(paths, leaves)):
                arr = np.asarray(leaf)
                logical_dtype = str(arr.dtype)
                if arr.dtype.kind not in "fiub":   # ml_dtypes (bf16/f8): store raw bits
                    arr = arr.view(np.uint16 if arr.dtype.itemsize == 2 else np.uint8)
                fn = f"{name}__{i:05d}.npy"
                np.save(os.path.join(tmp, fn), arr)
                entries.append({"path": p, "file": fn,
                                "shape": list(arr.shape), "dtype": logical_dtype})
            manifest["trees"][name] = entries
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)   # atomic publish
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(path)
             if d.startswith("step_") and d.split("_")[1].isdigit()]
    return max(steps) if steps else None


def load_checkpoint(path: str, step: Optional[int] = None,
                    template_trees: Optional[Dict[str, Any]] = None
                    ) -> Tuple[int, Dict[str, Any]]:
    """Load trees; if ``template_trees`` given, restore exact pytree structure
    (otherwise returns {name: {leaf_path: array}})."""
    step = step if step is not None else latest_step(path)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {path}")
    d = os.path.join(path, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    import ml_dtypes

    out = {}
    for name, entries in manifest["trees"].items():
        arrays = []
        for e in entries:
            a = np.load(os.path.join(d, e["file"]), allow_pickle=False)
            want = e["dtype"]
            if str(a.dtype) != want:               # raw-bit stored ml_dtype
                a = a.view(np.dtype(getattr(ml_dtypes, want, want)))
            arrays.append(a)
        if template_trees and name in template_trees:
            flat, treedef = jax.tree_util.tree_flatten(template_trees[name])
            assert len(flat) == len(arrays), f"tree arity mismatch for {name}"
            import jax.numpy as jnp
            arrays = [jnp.asarray(a) for a in arrays]
            out[name] = jax.tree_util.tree_unflatten(treedef, arrays)
        else:
            out[name] = {e["path"]: a for e, a in zip(entries, arrays)}
    return manifest["step"], out


# --------------------------------------------------------------------------
# serving-engine state snapshots
# --------------------------------------------------------------------------
def snapshot_scheduler(sched) -> Dict:
    """Serialize queue + progress state. In-flight requests replay their
    prefill on restore (idempotent; prefix cache makes the replay cheap)."""
    rqs = []
    for rq in sched.relqueries.values():
        rqs.append({
            "rel_id": rq.rel_id,
            "arrival_time": rq.arrival_time,
            "max_output_tokens": rq.max_output_tokens,
            "template_id": rq.template_id,
            "first_prefill_start": rq.first_prefill_start,
            "last_prefill_end": rq.last_prefill_end,
            "finish_time": rq.finish_time,
            "priority": rq.priority,
            "requests": [{
                "req_id": r.req_id,
                "tokens": list(r.tokens),
                "max_output_tokens": r.max_output_tokens,
                "state": r.state.value,
                "output_tokens": list(r.output_tokens),
                "prefilled": r.prefilled,
                "eos_token": r.eos_token,
                "sim_output_len": getattr(r, "sim_output_len", None),
            } for r in rq.requests],
        })
    return {"iteration": sched.iteration, "relqueries": rqs}


def restore_scheduler(sched, snap: Dict) -> None:
    """Rebuild queues from a snapshot: RUNNING requests are demoted to WAITING
    (their KV is gone after a failure) and will re-prefill on first schedule."""
    sched.iteration = snap["iteration"]
    for q in snap["relqueries"]:
        reqs = []
        for rd in q["requests"]:
            r = Request(rel_id=q["rel_id"], tokens=tuple(rd["tokens"]),
                        max_output_tokens=rd["max_output_tokens"],
                        req_id=rd["req_id"], eos_token=rd["eos_token"])
            if rd.get("sim_output_len") is not None:
                r.sim_output_len = rd["sim_output_len"]
            r.output_tokens = list(rd["output_tokens"])
            if rd["state"] == "finished":
                r.state = RequestState.FINISHED
                r.prefilled = True
            else:
                r.state = RequestState.WAITING   # replay prefill after failure
                r.prefilled = False
                r.output_tokens = []
            reqs.append(r)
        rq = RelQuery(rel_id=q["rel_id"], requests=reqs,
                      arrival_time=q["arrival_time"],
                      max_output_tokens=q["max_output_tokens"],
                      template_id=q["template_id"])
        rq.first_prefill_start = q["first_prefill_start"]
        rq.last_prefill_end = q["last_prefill_end"]
        rq.finish_time = q["finish_time"]
        rq.priority = q["priority"]
        sched.relqueries[rq.rel_id] = rq
        waiting = [r for r in reqs if r.state == RequestState.WAITING]
        if waiting:
            sched._waiting_of[rq.rel_id] = waiting
        if not rq.is_finished():
            sched._unfinished += 1
        else:
            sched.finished_relqueries.append(rq)
        sched.tokens_in_use += sum(r.total_tokens for r in reqs
                                   if r.state == RequestState.RUNNING)
