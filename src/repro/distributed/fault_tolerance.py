"""Checkpoint/restore + engine-state snapshots.

Training: per-leaf ``.npy`` files under an atomically-renamed step directory
plus a JSON manifest (tree structure, shapes, dtypes, mesh axes) — resumable
and reshardable. At multi-host scale each host writes its addressable shards;
in this single-process container that degenerates to full arrays, same layout.

Serving: scheduler queues + relQuery progress serialize to JSON; the KV cache
is deliberately NOT checkpointed — it is recomputable via prefix replay, which
the prefix cache makes cheap (DESIGN.md §6).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.relquery import RelQuery, Request, RequestState


# --------------------------------------------------------------------------
# training checkpoints
# --------------------------------------------------------------------------
def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
             for path, _ in flat]
    return paths, [leaf for _, leaf in flat], treedef


def save_checkpoint(path: str, step: int, trees: Dict[str, Any],
                    metadata: Optional[Dict] = None) -> str:
    """Write ``trees`` (e.g. {'params': ..., 'opt': ...}) under path/step_N."""
    final = os.path.join(path, f"step_{step}")
    # The staging dir must live under ``path`` so the final os.replace is a
    # same-filesystem rename: mkdtemp(dir=None) falls back to the system
    # tmpdir, and publishing across filesystems raises EXDEV.
    os.makedirs(path, exist_ok=True)
    tmp = tempfile.mkdtemp(prefix=".ckpt_tmp_", dir=path)
    manifest = {"step": step, "metadata": metadata or {}, "trees": {}}
    try:
        for name, tree in trees.items():
            paths, leaves, _ = _flatten_with_paths(tree)
            entries = []
            for i, (p, leaf) in enumerate(zip(paths, leaves)):
                arr = np.asarray(leaf)
                logical_dtype = str(arr.dtype)
                if arr.dtype.kind not in "fiub":   # ml_dtypes (bf16/f8): store raw bits
                    arr = arr.view(np.uint16 if arr.dtype.itemsize == 2 else np.uint8)
                fn = f"{name}__{i:05d}.npy"
                np.save(os.path.join(tmp, fn), arr)
                entries.append({"path": p, "file": fn,
                                "shape": list(arr.shape), "dtype": logical_dtype})
            manifest["trees"][name] = entries
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)   # atomic publish
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(path)
             if d.startswith("step_") and d.split("_")[1].isdigit()]
    return max(steps) if steps else None


def load_checkpoint(path: str, step: Optional[int] = None,
                    template_trees: Optional[Dict[str, Any]] = None
                    ) -> Tuple[int, Dict[str, Any]]:
    """Load trees; if ``template_trees`` given, restore exact pytree structure
    (otherwise returns {name: {leaf_path: array}})."""
    step = step if step is not None else latest_step(path)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {path}")
    d = os.path.join(path, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    import ml_dtypes

    out = {}
    for name, entries in manifest["trees"].items():
        arrays = []
        for e in entries:
            a = np.load(os.path.join(d, e["file"]), allow_pickle=False)
            want = e["dtype"]
            if str(a.dtype) != want:               # raw-bit stored ml_dtype
                a = a.view(np.dtype(getattr(ml_dtypes, want, want)))
            arrays.append(a)
        if template_trees and name in template_trees:
            flat, treedef = jax.tree_util.tree_flatten(template_trees[name])
            assert len(flat) == len(arrays), f"tree arity mismatch for {name}"
            import jax.numpy as jnp
            arrays = [jnp.asarray(a) for a in arrays]
            out[name] = jax.tree_util.tree_unflatten(treedef, arrays)
        else:
            out[name] = {e["path"]: a for e, a in zip(entries, arrays)}
    return manifest["step"], out


# --------------------------------------------------------------------------
# serving-engine state snapshots
# --------------------------------------------------------------------------
# v2: full queue/ledger/predictor/DPU state with per-request streamed-token
# high-water marks. v1 snapshots (no version field) predate preemption, prefix
# sharing, and the host KV tier and are not restorable.
SNAPSHOT_VERSION = 2

# Scheduler counters that survive a snapshot round-trip (everything a
# ServiceReport reads from the scheduler besides the queues themselves).
_SCHED_COUNTERS = (
    "preemptions", "preempted_tokens", "missing_decode_outputs",
    "shared_tokens_saved", "swap_outs", "swap_ins", "swapped_out_tokens",
    "swapped_in_tokens", "swap_bytes_moved", "reclaim_swap_decisions",
    "reclaim_recompute_decisions", "proactive_offloads", "swap_prefetches",
    "prefetch_cancelled",
)


def _snapshot_request(sched, r: Request) -> Dict:
    return {
        "req_id": r.req_id,
        "tokens": list(r.tokens),
        "max_output_tokens": r.max_output_tokens,
        "eos_token": r.eos_token,
        "sim_output_len": getattr(r, "sim_output_len", None),
        "state": r.state.value,
        "output_tokens": list(r.output_tokens),
        "prefilled": r.prefilled,
        "prefilled_tokens": r.prefilled_tokens,
        "preserved_output_tokens": r.preserved_output_tokens,
        "finish_time": r.finish_time,
        # Predicted-footprint charge (kv_admission=predicted): the charge is
        # prediction-dependent at admission time, so it must travel with the
        # snapshot — recomputing it on restore could disagree with the debit
        # taken when the request finishes.
        "footprint": sched._footprint_of.get(r.req_id),
    }


def snapshot_relquery(sched, rq: RelQuery,
                      delivered: Optional[Dict[str, int]] = None) -> Dict:
    """Serialize one relQuery with full progress state. ``delivered`` maps
    req_id -> tokens already streamed to the client; absent entries default to
    everything generated so far, so a restored replica never re-emits tokens a
    Frontend may have delivered."""
    d = delivered or {}
    snap = {
        "rel_id": rq.rel_id,
        "arrival_time": rq.arrival_time,
        "max_output_tokens": rq.max_output_tokens,
        "template_id": rq.template_id,
        "first_prefill_start": rq.first_prefill_start,
        "last_prefill_end": rq.last_prefill_end,
        "finish_time": rq.finish_time,
        "cancel_time": rq.cancel_time,
        "priority": rq.priority,
        "priority_fresh": rq.priority_fresh,
        "was_all_waiting": rq._was_all_waiting,
        "cache_miss_ratio": rq.cache_miss_ratio,
        "preemptions": rq.preemptions,
        "requests": [_snapshot_request(sched, r) for r in rq.requests],
    }
    for rd in snap["requests"]:
        rd["streamed"] = d.get(rd["req_id"], len(rd["output_tokens"]))
    return snap


def _snapshot_predictor(p) -> Optional[Dict]:
    if p is None:
        return None
    return {"quantile": p.quantile, "window": p.window,
            "observations": p.observations,
            # JSON objects key on strings; template fingerprints are ints
            "obs": {str(k): list(v) for k, v in p._obs.items()}}


def _restore_predictor(sched, d: Optional[Dict]) -> None:
    if d is None:
        return
    p = sched.predictor
    if p is None:
        from repro.core.predictor import OutputLenPredictor
        p = OutputLenPredictor(quantile=d["quantile"], window=d["window"])
        sched.predictor = p
        dpu = getattr(sched, "dpu", None)
        if dpu is not None and getattr(dpu, "predictor", None) is None:
            dpu.predictor = p
    p.quantile = d["quantile"]
    p.window = d["window"]
    p.observations = d["observations"]
    p._obs = {int(k): list(v) for k, v in d["obs"].items()}


def _snapshot_dpu(dpu) -> Optional[Dict]:
    if dpu is None:
        return None
    version, state, gauss = dpu._rng.getstate()
    return {"rng": [version, list(state), gauss],
            "iteration": dpu._iteration,
            "last_sampled": dict(dpu._last_sampled),
            "stats": dict(dpu.stats)}
    # _phase_memo is a pure memo keyed on _phase_version; it rebuilds on the
    # first refresh after restore and is deliberately not captured.


def _restore_dpu(dpu, d: Optional[Dict]) -> None:
    if dpu is None or d is None:
        return
    version, state, gauss = d["rng"]
    dpu._rng.setstate((version, tuple(state), gauss))
    dpu._iteration = d["iteration"]
    dpu._last_sampled = dict(d["last_sampled"])
    dpu.stats = dict(d["stats"])
    dpu._phase_memo = {}


def snapshot_scheduler(sched,
                       delivered: Optional[Dict[str, int]] = None) -> Dict:
    """Serialize the complete scheduler state: every relQuery with per-request
    progress (mid-chunk prefill, preemption restarts, swapped-out residents,
    cancellations), queue orders, ledger-relevant footprints, report counters,
    the output-length predictor's observation windows, and — for RelServe —
    the DPU's RNG/resample state. The snapshot is pure JSON (json.dumps-safe).

    The KV cache itself is deliberately NOT captured: token content is
    recomputable via prefill replay, and the prefix cache makes the replay
    cheap (DESIGN.md §6). ``delivered`` pins streamed-token high-water marks
    so a restoring replica knows what the Frontend already emitted."""
    return {
        "version": SNAPSHOT_VERSION,
        "iteration": sched.iteration,
        "counters": {k: getattr(sched, k) for k in _SCHED_COUNTERS
                     if hasattr(sched, k)},
        "relqueries": [snapshot_relquery(sched, rq, delivered)
                       for rq in sched.relqueries.values()],
        "waiting_order": {rel_id: [r.req_id for r in lst]
                          for rel_id, lst in sched._waiting_of.items()},
        "running_order": [r.req_id for r in sched._running],
        "swapped_order": [r.req_id for r in sched._swapped],
        "predictor": _snapshot_predictor(sched.predictor),
        "dpu": _snapshot_dpu(getattr(sched, "dpu", None)),
    }


def restore_scheduler(sched, snap: Dict, *, kv_lost: bool = True) -> Dict:
    """Rebuild a (fresh, empty) scheduler from a v2 snapshot.

    ``kv_lost=True`` — crash semantics: the device and host KV died with the
    replica, so every resident request (RUNNING, SWAPPED, or mid-chunk
    prefill) restarts preemption-style — generated tokens are preserved and
    recomputed by the next prefill pass, landed-but-unfinished chunks are
    dropped, and the ledgers rebuild to a zero-resident state.

    ``kv_lost=False`` — lossless round-trip: queue orders, states, mid-chunk
    progress, host-tier residency, and footprint charges restore exactly.
    Legitimate when the KV survives the scheduler object (the simulated
    executor derives KV purely from these ledgers; a live migration that
    moves KV pages would use this mode too).

    All ledgers are rebuilt through ``sched.audit_ledgers(repair=True)`` —
    the same audited derivation ``--debug-invariants`` checks per tick.
    Returns ``{"delivered": {req_id: streamed}, "requeued": n, ...}`` so the
    caller can seed Frontend dedup floors."""
    if snap.get("version") != SNAPSHOT_VERSION:
        raise ValueError(
            f"unsupported scheduler snapshot version {snap.get('version')!r} "
            f"(want {SNAPSHOT_VERSION})")
    if sched.relqueries:
        raise ValueError("restore_scheduler requires an empty scheduler")
    sched.iteration = snap["iteration"]
    for k, v in snap.get("counters", {}).items():
        setattr(sched, k, v)

    delivered: Dict[str, int] = {}
    by_req: Dict[str, Request] = {}
    requeued = 0
    for q in snap["relqueries"]:
        reqs: List[Request] = []
        for rd in q["requests"]:
            r = Request(rel_id=q["rel_id"], tokens=tuple(rd["tokens"]),
                        max_output_tokens=rd["max_output_tokens"],
                        req_id=rd["req_id"], eos_token=rd["eos_token"])
            if rd.get("sim_output_len") is not None:
                r.sim_output_len = rd["sim_output_len"]
            r.state = RequestState(rd["state"])
            r.output_tokens = list(rd["output_tokens"])
            r.prefilled = rd["prefilled"]
            r.prefilled_tokens = rd["prefilled_tokens"]
            r.preserved_output_tokens = rd["preserved_output_tokens"]
            r.finish_time = rd["finish_time"]
            delivered[r.req_id] = rd.get("streamed", len(r.output_tokens))
            if r.state in (RequestState.RUNNING, RequestState.SWAPPED):
                if kv_lost:
                    r.preserved_output_tokens = len(r.output_tokens)
                    r.prefilled = False
                    r.prefilled_tokens = 0
                    r.state = RequestState.PREEMPTED
                    requeued += 1
                elif rd.get("footprint") is not None \
                        and r.state is RequestState.RUNNING:
                    sched._footprint_of[r.req_id] = rd["footprint"]
            elif r.state is RequestState.WAITING and r.prefilled_tokens:
                if kv_lost:
                    r.prefilled_tokens = 0   # landed chunks died with the KV
                elif rd.get("footprint") is not None:
                    sched._footprint_of[r.req_id] = rd["footprint"]
            by_req[r.req_id] = r
            reqs.append(r)
        rq = RelQuery(rel_id=q["rel_id"], requests=reqs,
                      arrival_time=q["arrival_time"],
                      max_output_tokens=q["max_output_tokens"],
                      template_id=q["template_id"])
        rq.first_prefill_start = q["first_prefill_start"]
        rq.last_prefill_end = q["last_prefill_end"]
        rq.finish_time = q["finish_time"]
        rq.cancel_time = q.get("cancel_time")
        rq.priority = q["priority"]
        rq.priority_fresh = q.get("priority_fresh", False)
        rq._was_all_waiting = q.get("was_all_waiting", False)
        rq.cache_miss_ratio = q.get("cache_miss_ratio", 1.0)
        rq.preemptions = q.get("preemptions", 0)
        sched.relqueries[rq.rel_id] = rq
        if rq.finish_time is not None and rq.cancel_time is None:
            sched.finished_relqueries.append(rq)

    # Queues rebuild in snapshot order. Under kv_lost the demoted residents
    # (running first, then swapped) go to the FRONT of their relQuery's
    # waiting list, mirroring what live preemption does.
    waiting_of = {rel_id: [by_req[i] for i in ids]
                  for rel_id, ids in snap["waiting_order"].items()}
    if kv_lost:
        demoted = [by_req[i] for i in
                   (*snap["running_order"], *snap["swapped_order"])]
        for r in reversed(demoted):
            waiting_of.setdefault(r.rel_id, []).insert(0, r)
    else:
        sched._running = [by_req[i] for i in snap["running_order"]]
        sched._swapped = [by_req[i] for i in snap["swapped_order"]]
    sched._waiting_of = {k: v for k, v in waiting_of.items() if v}
    sched._queue_version += 1
    sched.audit_ledgers(repair=True)

    _restore_predictor(sched, snap.get("predictor"))
    _restore_dpu(getattr(sched, "dpu", None), snap.get("dpu"))
    return {"delivered": delivered, "requeued": requeued,
            "relqueries": len(snap["relqueries"])}


# --------------------------------------------------------------------------
# in-process failover: rewind live relQuery objects
# --------------------------------------------------------------------------
def rewind_relquery_to_snapshot(rq: RelQuery, rq_snap: Dict) -> int:
    """Crash failover for the in-process Cluster: rewind a live relQuery to
    its last snapshot. Tokens generated after the snapshot died with the
    replica — the deterministic executor regenerates them bit-identically on
    the surviving replica, and Frontend high-water marks suppress re-emission
    of anything already streamed. Requests the snapshot saw as terminal keep
    their outcome. Returns the number of output tokens preserved."""
    by_id = {rd["req_id"]: rd for rd in rq_snap["requests"]}
    kept = 0
    for r in rq.requests:
        rd = by_id[r.req_id]
        if RequestState(rd["state"]) in (RequestState.FINISHED,
                                         RequestState.CANCELLED):
            continue   # outcome predates the snapshot: durable
        del r.output_tokens[len(rd["output_tokens"]):]
        r.state = RequestState.WAITING
        r.prefilled = False
        r.prefilled_tokens = 0
        r.preserved_output_tokens = 0
        r.finish_time = None
        kept += len(r.output_tokens)
    rq.finish_time = None
    rq.note_phase_change()
    return kept


def reset_relquery_for_recovery(rq: RelQuery) -> int:
    """From-scratch failover (no snapshot): everything the crashed replica
    generated for still-unfinished requests is lost and will be recomputed
    from the prompt. Returns the number of output tokens dropped."""
    lost = 0
    for r in rq.requests:
        if r.is_terminal():
            continue
        lost += len(r.output_tokens)
        r.output_tokens = []
        r.state = RequestState.WAITING
        r.prefilled = False
        r.prefilled_tokens = 0
        r.preserved_output_tokens = 0
        r.finish_time = None
    rq.finish_time = None
    rq.note_phase_change()
    return lost
