"""AdamW with fp32 master weights and ZeRO-1 optimizer-state sharding.

Optimizer state (m, v, master) is sharded over the data-parallel axes on the
first free (unsharded, divisible) dimension of each tensor, on top of the
parameter's tensor-parallel sharding — GSPMD then lowers the update into
reduce-scatter(grads) -> local update -> all-gather(params), the standard
ZeRO-1 schedule.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import ParallelConfig


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def init_opt_state(params):
    f32 = lambda t: t.astype(jnp.float32)
    return {
        "m": jax.tree.map(lambda t: jnp.zeros(t.shape, jnp.float32), params),
        "v": jax.tree.map(lambda t: jnp.zeros(t.shape, jnp.float32), params),
        "master": jax.tree.map(f32, params),
        "step": jnp.zeros((), jnp.int32),
        "err": None,  # gradient-compression error feedback (enabled on demand)
    }


def abstract_opt_state(abstract_params):
    f32 = lambda t: jax.ShapeDtypeStruct(t.shape, jnp.float32)
    return {
        "m": jax.tree.map(f32, abstract_params),
        "v": jax.tree.map(f32, abstract_params),
        "master": jax.tree.map(f32, abstract_params),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
        "err": None,
    }


def zero1_spec(spec: P, shape: Tuple[int, ...], pc: ParallelConfig) -> P:
    """Add DP sharding on the first free divisible dim of a param spec."""
    if not pc.dp_axes or pc.dp <= 1:
        return spec
    used = set()
    for e in spec:
        for a in (e if isinstance(e, (tuple, list)) else (e,)):
            used.add(a)
    if any(a in used for a in pc.dp_axes):
        return spec   # already DP-sharded (e.g. FSDP params)
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for i, (s, dim) in enumerate(zip(entries, shape)):
        if s is None and dim % pc.dp == 0 and dim >= pc.dp:
            entries[i] = pc.dp_axes if len(pc.dp_axes) > 1 else pc.dp_axes[0]
            return P(*entries)
    return spec  # nothing shardable: stay param-sharded (small tensor)


def opt_state_specs(param_specs, abstract_params, pc: ParallelConfig):
    zp = jax.tree.map(
        lambda sp, t: zero1_spec(sp, t.shape, pc), param_specs, abstract_params)
    return {"m": zp, "v": zp, "master": zp, "step": P(), "err": None}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """One AdamW step on fp32 masters; returns (bf16 params, new state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))

    def upd(g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / (1 - cfg.b1 ** step.astype(jnp.float32))
        vhat = v / (1 - cfg.b2 ** step.astype(jnp.float32))
        new_master = master - cfg.lr * (
            mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * master)
        return m, v, new_master

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_w = treedef.flatten_up_to(state["master"])
    out = [upd(g, m, v, w) for g, m, v, w in zip(flat_g, flat_m, flat_v, flat_w)]
    new_m = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_w = jax.tree.unflatten(treedef, [o[2] for o in out])
    dtype = jax.tree.leaves(params)[0].dtype
    new_params = jax.tree.map(lambda w: w.astype(dtype), new_w)
    new_state = {"m": new_m, "v": new_v, "master": new_w, "step": step,
                 "err": state.get("err")}
    return new_params, new_state, {"grad_norm": gnorm}
