"""Training step: microbatched gradient accumulation (fit-to-HBM knob), remat
through the layer scan, optional bf16 gradient compression with error
feedback, AdamW on ZeRO-1-sharded state.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.training.optimizer import AdamWConfig, adamw_update


@dataclass(frozen=True)
class TrainConfig:
    grad_accum: int = 1
    compress_grads: bool = False     # bf16 all-reduce with error feedback
    remat: bool = True
    adamw: AdamWConfig = AdamWConfig()


def _loss_fn(model, params, batch, remat):
    loss, metrics = model.train_loss(params, batch, remat=remat)
    return loss, metrics


def make_train_step(model, tc: TrainConfig):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    The microbatch loop is a lax.scan over the leading batch split; gradients
    accumulate in fp32 (or bf16 with error feedback when compress_grads).
    """
    grad_fn = jax.value_and_grad(partial(_loss_fn, model), argnums=0, has_aux=True)

    def train_step(params, opt_state, batch):
        ga = tc.grad_accum
        acc_dtype = jnp.bfloat16 if tc.compress_grads else jnp.float32

        if ga == 1:
            (loss, metrics), grads = grad_fn(params, batch, tc.remat)
            grads = jax.tree.map(lambda g: g.astype(acc_dtype), grads)
        else:
            # unrolled microbatch loop (python, not lax.scan): ga is small
            # (<= 4) and unrolling keeps every FLOP visible to HLO cost
            # analysis — the roofline scan-correction only compensates the
            # *layer* scan (DESIGN.md §3)
            def split(x):
                b = x.shape[0]
                return x.reshape(ga, b // ga, *x.shape[1:])
            micro = jax.tree.map(split, batch)
            grads = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dtype), params)
            loss = jnp.zeros((), jnp.float32)
            for i in range(ga):
                mb = jax.tree.map(lambda x: x[i], micro)
                (l_i, _), g_i = grad_fn(params, mb, tc.remat)
                grads = jax.tree.map(
                    lambda a, g: a + g.astype(acc_dtype), grads, g_i)
                loss = loss + l_i
            grads = jax.tree.map(lambda g: g / ga, grads)
            loss = loss / ga
            metrics = {}

        if tc.compress_grads:
            # bf16 gradient compression with error feedback: the quantization
            # error re-enters the next step's gradients instead of vanishing.
            err = opt_state.get("err")
            if err is None:
                err = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
            g32 = jax.tree.map(lambda g, e: g.astype(jnp.float32) + e, grads, err)
            gq = jax.tree.map(lambda g: g.astype(jnp.bfloat16), g32)
            new_err = jax.tree.map(lambda g, q: g - q.astype(jnp.float32), g32, gq)
            grads = gq
            opt_state = dict(opt_state, err=new_err)

        new_params, new_opt, opt_metrics = adamw_update(params, grads, opt_state,
                                                        tc.adamw)
        out_metrics = {"loss": loss, **opt_metrics}
        return new_params, new_opt, out_metrics

    return train_step
