"""Sequence-parallel (context-parallel) decode — beyond-paper optimization.

The baseline TP decode shards the KV cache on *kv-head slots*, which forces
head duplication/padding when kv_heads < TP (qwen2.5-32b: KV 8 -> 16 slots =
2x KV memory; Q 40 -> 48 heads = 1.2x attention compute). Here the cache is
sharded on the *sequence* dim instead (flash-decoding style): every model rank
holds S/TP tokens of ALL true kv heads, computes partial attention for all
true Q heads over its chunk, and ranks merge with the numerically-exact
log-sum-exp combine (pmax + psum). Wins:

  - KV cache bytes/device: x kv_dup smaller (2x for kv=8 @ TP16) -> the decode
    memory-roofline term drops proportionally (decode is KV-read bound);
  - zero padded-Q compute (exact head counts);
  - projections stay tensor-parallel: qkv weights shard the *input* D dim,
    o-projection shards the H*hd contraction dim (divisible for every arch).

Cost: two small psums per layer (qkv partials + attention merge) — negligible
against the KV read. Prefill continues on the baseline packed path; a cache
reshard (`reshard_cache_from_packed`) converts its output layout once.
"""
from __future__ import annotations

import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ParallelConfig
from repro.models import layers as L
from repro.models.param_utils import t
from repro.models.transformer import LOCAL_ROPE_THETA, DenseTransformer


class SeqParallelDenseTransformer(DenseTransformer):
    """Decode-path variant with sequence-sharded KV cache (serve_step only)."""

    def __init__(self, cfg: ModelConfig, pc: Optional[ParallelConfig] = None,
                 mesh=None):
        super().__init__(cfg, pc)
        self.mesh = mesh
        assert (cfg.num_heads * cfg.head_dim) % max(self.pc.tp, 1) == 0, \
            "o-projection contraction dim must divide TP"

    # ------------------------------------------------------------- params
    def templates(self):
        base = super().templates()
        cfg = self.cfg
        G, Pg, D = self.n_groups, self.group, cfg.d_model
        H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        # canonical (unpacked, unduplicated) attention weights; model-parallel
        # on the *contraction* dims ('ff' resolves to the model axis)
        blocks = base["blocks"]
        blocks["wq"] = t((G, Pg, D, H, hd), (None, None, "ff", None, None), fan_in=D)
        blocks["wk"] = t((G, Pg, D, KV, hd), (None, None, "ff", None, None), fan_in=D)
        blocks["wv"] = t((G, Pg, D, KV, hd), (None, None, "ff", None, None), fan_in=D)
        blocks["wo"] = t((G, Pg, H * hd, D), (None, None, "ff", None),
                         fan_in=H * hd)
        if cfg.qkv_bias:
            blocks["bq"] = t((G, Pg, H, hd), (None, None, None, None), "zeros")
            blocks["bk"] = t((G, Pg, KV, hd), (None, None, None, None), "zeros")
            blocks["bv"] = t((G, Pg, KV, hd), (None, None, None, None), "zeros")
        return base

    # ------------------------------------------------------------- cache
    def cache_struct(self, batch: int, max_len: int):
        cfg = self.cfg
        G, hd = self.n_groups, cfg.head_dim
        KV = cfg.num_kv_heads
        W = min(cfg.sliding_window or max_len, max_len)
        out = {}
        if self.n_full:
            shp = (G, self.n_full, batch, max_len, KV, hd)
            out["k_full"] = jax.ShapeDtypeStruct(shp, self._dtype)
            out["v_full"] = jax.ShapeDtypeStruct(shp, self._dtype)
        if self.n_win:
            shp = (G, self.n_win, batch, W, KV, hd)
            out["k_win"] = jax.ShapeDtypeStruct(shp, self._dtype)
            out["v_win"] = jax.ShapeDtypeStruct(shp, self._dtype)
        return out

    def cache_specs(self):
        # sequence dim sharded over the model axis; true kv heads unsharded
        spec = self.pc.spec(None, None, "batch", "ff", None, None)
        return jax.tree.map(lambda _: spec, self.cache_struct(1, 1))

    # ------------------------------------------------------------- decode
    def _sp_attention(self, q, k_new, v_new, kc, vc, positions, window: int):
        """Distributed attention + in-chunk cache write via shard_map.

        q: [B, H, hd] (replicated over model); k/v_new: [B, KV, hd];
        kc/vc: [B, S, KV, hd] sequence-sharded over the model axis."""
        cfg = self.cfg
        H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        qpk = H // KV
        tp_axis = self.pc.tp_axis or "model"
        if not self.pc.dp_axes:
            dp0 = None
        elif len(self.pc.dp_axes) == 1:
            dp0 = self.pc.dp_axes[0]
        else:
            dp0 = self.pc.dp_axes

        def body(q, k_new, v_new, kc, vc, positions):
            # local shapes: q [b,H,hd], kc [b,s_loc,KV,hd], positions [b]
            ax = jax.lax.axis_index(tp_axis)
            b, s_loc = kc.shape[0], kc.shape[1]
            local_pos = positions.astype(jnp.int32) - ax * s_loc
            if window > 0:
                local_pos = (positions % window).astype(jnp.int32) - ax * s_loc
            in_range = (local_pos >= 0) & (local_pos < s_loc)
            slot = jnp.clip(local_pos, 0, s_loc - 1)
            bidx = jnp.arange(b)
            k_w = jnp.where(in_range[:, None, None], k_new, kc[bidx, slot])
            v_w = jnp.where(in_range[:, None, None], v_new, vc[bidx, slot])
            kc2 = kc.at[bidx, slot].set(k_w)
            vc2 = vc.at[bidx, slot].set(v_w)
            # local masked attention over my chunk
            qg = q.reshape(b, KV, qpk, hd)
            scale = 1.0 / math.sqrt(hd)
            s = jnp.einsum("bgqh,btgh->bgqt", (qg * scale).astype(qg.dtype),
                           kc2, preferred_element_type=jnp.float32)
            gidx = ax * s_loc + jnp.arange(s_loc)
            if window > 0:
                valid = (gidx[None, :] <= (positions % window)[:, None]) | \
                        (positions[:, None] >= window)
            else:
                valid = gidx[None, :] <= positions[:, None]
            s = jnp.where(valid[:, None, None, :], s, L.NEG_INF)
            m_loc = jnp.max(s, axis=-1)                          # [b,KV,qpk]
            p = jnp.exp(s - m_loc[..., None])
            den = jnp.sum(p, axis=-1)
            num = jnp.einsum("bgqt,btgh->bgqh", p.astype(vc2.dtype), vc2,
                             preferred_element_type=jnp.float32)
            m_glob = jax.lax.pmax(m_loc, tp_axis)
            corr = jnp.exp(m_loc - m_glob)
            num = jax.lax.psum(num * corr[..., None], tp_axis)
            den = jax.lax.psum(den * corr, tp_axis)
            o = (num / jnp.maximum(den, 1e-30)[..., None]).astype(q.dtype)
            return o.reshape(b, H * hd), kc2, vc2

        cache_spec = P(dp0, tp_axis, None, None)
        rep3 = P(dp0, None, None)
        return shard_map(
            body, mesh=self.mesh,
            in_specs=(rep3, rep3, rep3, cache_spec, cache_spec, P(dp0)),
            out_specs=(P(dp0, None), cache_spec, cache_spec),
            check_rep=False,
        )(q, k_new, v_new, kc, vc, positions)

    def decode_step(self, params, cache, tokens, positions):
        cfg = self.cfg
        H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        x = self.embed_tokens(params, tokens)
        cache = dict(cache)
        for g in range(self.n_groups):
            pp = jax.tree.map(lambda a: a[g], params["blocks"])
            for p in range(self.group):
                kind = self.kinds[p]
                h = L.rmsnorm(x, pp["ln1"][p], cfg.norm_eps)
                q = jnp.einsum("bd,dHh->bHh", h, pp["wq"][p])
                k = jnp.einsum("bd,dgh->bgh", h, pp["wk"][p])
                v = jnp.einsum("bd,dgh->bgh", h, pp["wv"][p])
                if cfg.qkv_bias:
                    q = q + pp["bq"][p]
                    k = k + pp["bk"][p]
                    v = v + pp["bv"][p]
                if cfg.qk_norm:
                    q = L.rmsnorm(q, pp["q_norm"][p], cfg.norm_eps)
                    k = L.rmsnorm(k, pp["k_norm"][p], cfg.norm_eps)
                theta = LOCAL_ROPE_THETA if (kind == "local" and
                                             cfg.attn_kind == "local_global") \
                    else cfg.rope_theta
                q = L.apply_rope(q, positions[:, None], theta)   # q: [B, H, hd]
                k = L.apply_rope(k, positions[:, None], theta)
                if kind == "global":
                    i, kk, vk, win = self.full_idx[p], "k_full", "v_full", 0
                else:
                    i, kk, vk = self.win_idx[p], "k_win", "v_win"
                    win = cfg.sliding_window
                o, kc2, vc2 = self._sp_attention(
                    q, k, v, cache[kk][g, i], cache[vk][g, i], positions, win)
                cache[kk] = cache[kk].at[g, i].set(kc2)
                cache[vk] = cache[vk].at[g, i].set(vc2)
                x = x + o @ pp["wo"][p]
                h2 = L.rmsnorm(x, pp["ln2"][p], cfg.norm_eps)
                mlp, _ = self._mlp(pp, p, h2)
                x = x + mlp
                x = self._constrain(x, "batch", None)
        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        return self.logits(params, x), cache

    def prefill(self, *a, **kw):
        raise NotImplementedError(
            "seq-parallel variant optimizes the decode path; prefill runs on "
            "the baseline packed layout and reshard_cache_from_packed converts")

    def train_loss(self, *a, **kw):
        raise NotImplementedError("decode-serving optimization only")


def reshard_cache_from_packed(packed_cache: Dict, model: DenseTransformer,
                              sp_model: SeqParallelDenseTransformer) -> Dict:
    """Convert a baseline packed-slot cache ([.., KVp slots, hd], duplicated kv
    heads) to the canonical seq-sharded layout ([.., KV, hd]). Pure gather —
    slot s of true kv head k holds identical values, so taking each head's
    first slot is exact."""
    lay = model.layout
    first_slot = {}
    for s, kv in enumerate(lay.dup_map):
        first_slot.setdefault(kv, s)
    idx = jnp.asarray([first_slot[k] for k in range(lay.num_kv_heads)])
    out = {}
    for key, arr in packed_cache.items():
        out[key] = jnp.take(arr, idx, axis=4)
    return out
