"""Parameter templates: one declaration drives abstract shapes, shardings, init.

A template tree mirrors the parameter pytree; leaves are ``ParamTemplate``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.sharding import ParallelConfig


@dataclass(frozen=True)
class ParamTemplate:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | custom
    fan_in: Optional[int] = None  # overrides scale for 'normal'
    custom: Optional[Callable] = None  # key -> np/jnp array (used for packed weights)

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def t(shape, logical, init="normal", fan_in=None, custom=None) -> ParamTemplate:
    return ParamTemplate(tuple(shape), tuple(logical), init, fan_in, custom)


def abstract_params(templates, dtype=jnp.bfloat16):
    return jax.tree.map(
        lambda tm: jax.ShapeDtypeStruct(tm.shape, dtype),
        templates, is_leaf=lambda x: isinstance(x, ParamTemplate))


def param_specs(templates, pc: ParallelConfig):
    return jax.tree.map(
        lambda tm: pc.spec(*tm.logical),
        templates, is_leaf=lambda x: isinstance(x, ParamTemplate))


def param_shardings(templates, pc: ParallelConfig, mesh):
    return jax.tree.map(
        lambda tm: NamedSharding(mesh, pc.spec(*tm.logical)),
        templates, is_leaf=lambda x: isinstance(x, ParamTemplate))


def init_params(templates, key, dtype=jnp.bfloat16):
    leaves, treedef = jax.tree.flatten(
        templates, is_leaf=lambda x: isinstance(x, ParamTemplate))
    keys = jax.random.split(key, len(leaves))
    out = []
    for tm, k in zip(leaves, keys):
        if tm.custom is not None:
            out.append(jnp.asarray(tm.custom(k), dtype=dtype))
        elif tm.init == "zeros":
            out.append(jnp.zeros(tm.shape, dtype))
        elif tm.init == "ones":
            out.append(jnp.ones(tm.shape, dtype))
        else:
            fan_in = tm.fan_in if tm.fan_in is not None else (tm.shape[-2] if len(tm.shape) >= 2 else tm.shape[-1])
            std = 1.0 / math.sqrt(max(1, fan_in))
            out.append((jax.random.normal(k, tm.shape, jnp.float32) * std).astype(dtype))
    return jax.tree.unflatten(treedef, out)


def count_params(templates) -> int:
    leaves = jax.tree.leaves(templates, is_leaf=lambda x: isinstance(x, ParamTemplate))
    return int(sum(np.prod(tm.shape) for tm in leaves))
