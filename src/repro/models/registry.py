"""Model registry: arch family -> model class; ``build_model`` is the single
entry point used by the engine, launchers, tests, and benchmarks."""
from __future__ import annotations

from typing import Optional

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ParallelConfig
from repro.models.hymba import HymbaModel
from repro.models.moe import MoETransformer
from repro.models.rwkv6 import RWKV6Model
from repro.models.transformer import DenseTransformer
from repro.models.whisper import WhisperModel

_FAMILIES = {
    "dense": DenseTransformer,
    "vlm": DenseTransformer,     # LM backbone; patch embeddings via extra_embeds
    "moe": MoETransformer,
    "hybrid": HymbaModel,
    "ssm": RWKV6Model,
    "audio": WhisperModel,
}


def build_model(cfg: ModelConfig, pc: Optional[ParallelConfig] = None):
    try:
        cls = _FAMILIES[cfg.family]
    except KeyError:
        raise KeyError(f"unknown family {cfg.family!r} for arch {cfg.name!r}")
    return cls(cfg, pc)
