"""RWKV6 "Finch": attention-free token mixing with data-dependent per-channel
decay. Implements the chunked-recurrence form — intra-chunk contributions via
masked decay-weighted products, inter-chunk via a [K, V] state per head — so
prefill/train cost is O(S · c · K) per head with bounded exponents (all
exponentials have non-positive arguments; see DESIGN.md §3).

The chunk loop is a *python* (unrolled) loop so every FLOP is visible to HLO
cost analysis; only the layer stack uses lax.scan.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ParallelConfig
from repro.models import layers as L
from repro.models.param_utils import (
    abstract_params, count_params, init_params, param_shardings, param_specs, t,
)

MIX_NAMES = ("w", "k", "v", "r", "g")


def _chunk_size(seq: int) -> int:
    # <=128 unrolled chunk steps; chunks of at least 16 tokens
    c = max(16, seq // 128)
    while seq % c:
        c //= 2
    return max(c, 1)


def wkv6_chunk(
    r: jax.Array,      # [B, c, H, K]
    k: jax.Array,      # [B, c, H, K]
    v: jax.Array,      # [B, c, H, V]
    logw: jax.Array,   # [B, c, H, K]  log decay, <= 0
    u: jax.Array,      # [H, K] bonus
    state: jax.Array,  # [B, H, K, V]
) -> Tuple[jax.Array, jax.Array]:
    """One chunk of the WKV6 recurrence. Returns (out [B,c,H,V], new_state)."""
    f32 = jnp.float32
    r, k, v, logw = (x.astype(f32) for x in (r, k, v, logw))
    state = state.astype(f32)
    c = r.shape[1]
    ldi = jnp.cumsum(logw, axis=1)            # inclusive decay log-sums
    lde = ldi - logw                          # exclusive
    # inter-chunk: state contribution
    o_inter = jnp.einsum("bthk,bhkv->bthv", r * jnp.exp(lde), state)
    # intra-chunk: A[t,j] = sum_k r[t,k] k[j,k] exp(lde[t]-ldi[j]),  j < t
    diff = lde[:, :, None] - ldi[:, None, :]  # [B, t, j, H, K]
    tri = (jnp.arange(c)[:, None] > jnp.arange(c)[None, :])[None, :, :, None, None]
    w_decay = jnp.where(tri, jnp.exp(jnp.minimum(diff, 0.0)), 0.0)
    A = jnp.einsum("bthk,bjhk,btjhk->bthj", r, k, w_decay)
    diag = jnp.einsum("bthk,bthk,hk->bth", r, k, u)
    A = A + jnp.eye(c)[None, :, None, :] * diag[..., None]
    o = o_inter + jnp.einsum("bthj,bjhv->bthv", A, v)
    # state update: S' = diag(d_total) S + sum_j (k_j * exp(ldi[-1]-ldi[j])) v_j^T
    d_total = jnp.exp(ldi[:, -1])             # [B, H, K]
    k_scaled = k * jnp.exp(ldi[:, -1][:, None] - ldi)
    new_state = state * d_total[..., None] + jnp.einsum("bjhk,bjhv->bhkv", k_scaled, v)
    return o, new_state


def wkv6_decode(r, k, v, logw, u, state):
    """Single-token WKV6 step. r/k/v/logw: [B, H, K]; state: [B, H, K, V]."""
    f32 = jnp.float32
    r, k, v, logw = (x.astype(f32) for x in (r, k, v, logw))
    state = state.astype(f32)
    kv = k[..., :, None] * v[..., None, :]            # [B, H, K, V]
    out = jnp.einsum("bhk,bhkv->bhv", r, state + u[None, :, :, None] * kv)
    new_state = state * jnp.exp(logw)[..., None] + kv
    return out, new_state


class RWKV6Model:
    def __init__(self, cfg: ModelConfig, pc: Optional[ParallelConfig] = None):
        self.cfg = cfg
        self.pc = pc or ParallelConfig.single_device()
        assert cfg.d_model % cfg.rwkv_head_dim == 0
        self.n_heads = cfg.d_model // cfg.rwkv_head_dim
        self.n_groups = cfg.num_layers
        self.group = 1

    # ---------------------------------------------------------------- params
    def templates(self):
        cfg = self.cfg
        Lyr, D, F = cfg.num_layers, cfg.d_model, cfg.d_ff
        mlo, dlo = cfg.rwkv_mix_lora, cfg.rwkv_decay_lora
        blocks = {
            "ln1_s": t((Lyr, D), (None, None), "ones"),
            "ln1_b": t((Lyr, D), (None, None), "zeros"),
            "ln2_s": t((Lyr, D), (None, None), "ones"),
            "ln2_b": t((Lyr, D), (None, None), "zeros"),
            # time-mix ddlerp
            "mu_base": t((Lyr, D), (None, None), "zeros"),
            "mu": t((Lyr, 5, D), (None, None, None), "zeros"),
            "lora_a": t((Lyr, D, 5 * mlo), (None, None, None), fan_in=D),
            "lora_b": t((Lyr, 5, mlo, D), (None, None, None, None), "zeros"),
            # projections
            "w_r": t((Lyr, D, D), (None, None, "ff"), fan_in=D),
            "w_k": t((Lyr, D, D), (None, None, "ff"), fan_in=D),
            "w_v": t((Lyr, D, D), (None, None, "ff"), fan_in=D),
            "w_g": t((Lyr, D, D), (None, None, "ff"), fan_in=D),
            "w_o": t((Lyr, D, D), (None, "ff", None), fan_in=D),
            # decay
            "w0": t((Lyr, D), (None, None), "zeros"),
            "wd1": t((Lyr, D, dlo), (None, None, None), fan_in=D),
            "wd2": t((Lyr, dlo, D), (None, None, None), "zeros"),
            "bonus": t((Lyr, D), (None, None), "zeros"),
            "gn": t((Lyr, D), (None, None), "ones"),
            # channel-mix
            "mu_ck": t((Lyr, D), (None, None), "zeros"),
            "mu_cr": t((Lyr, D), (None, None), "zeros"),
            "wc_k": t((Lyr, D, F), (None, None, "ff"), fan_in=D),
            "wc_v": t((Lyr, F, D), (None, "ff", None), fan_in=F),
            "wc_r": t((Lyr, D, D), (None, None, "ff"), fan_in=D),
        }
        Vp = cfg.padded_vocab(self.pc.tp)
        return {
            "embed": t((Vp, D), ("vocab", None), fan_in=D),
            "ln0_s": t((D,), (None,), "ones"),
            "ln0_b": t((D,), (None,), "zeros"),
            "blocks": blocks,
            "final_norm": t((D,), (None,), "zeros"),
            "lm_head": t((D, Vp), (None, "vocab"), fan_in=D),
        }

    def abstract_params(self):
        return abstract_params(self.templates(), self._dtype)

    def init_params(self, key):
        return init_params(self.templates(), key, self._dtype)

    def param_specs(self):
        return param_specs(self.templates(), self.pc)

    def param_shardings(self, mesh):
        return param_shardings(self.templates(), self.pc, mesh)

    def param_count(self):
        return count_params(self.templates())

    @property
    def _dtype(self):
        return jnp.dtype(self.cfg.dtype)

    # ---------------------------------------------------------------- cache
    def cache_struct(self, batch: int, max_len: int = 0):
        cfg = self.cfg
        H, K = self.n_heads, cfg.rwkv_head_dim
        Lyr = cfg.num_layers
        return {
            "state": jax.ShapeDtypeStruct((Lyr, batch, H, K, K), jnp.float32),
            "tm_shift": jax.ShapeDtypeStruct((Lyr, batch, cfg.d_model), self._dtype),
            "cm_shift": jax.ShapeDtypeStruct((Lyr, batch, cfg.d_model), self._dtype),
        }

    def init_cache(self, batch: int, max_len: int = 0):
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.cache_struct(batch, max_len))

    def cache_specs(self):
        return {
            "state": self.pc.spec(None, "batch", "heads", None, None),
            "tm_shift": self.pc.spec(None, "batch", None),
            "cm_shift": self.pc.spec(None, "batch", None),
        }

    # ------------------------------------------------------------- internals
    def _constrain(self, x, *logical):
        if self.pc.dp_axes or self.pc.tp_axis:
            return jax.lax.with_sharding_constraint(x, self.pc.spec(*logical))
        return x

    def _ddlerp(self, pp, x, x_prev):
        """Data-dependent token-shift interpolation -> dict of mixed inputs."""
        dx = x_prev - x
        base = x + dx * pp["mu_base"]
        lora = jnp.tanh(base @ pp["lora_a"])
        mlo = self.cfg.rwkv_mix_lora
        mixed = {}
        for i, name in enumerate(MIX_NAMES):
            delta = lora[..., i * mlo:(i + 1) * mlo] @ pp["lora_b"][i]
            mixed[name] = x + dx * (pp["mu"][i] + delta)
        return mixed

    def _decay(self, pp, mix_w):
        dw = pp["w0"].astype(jnp.float32) + (
            jnp.tanh(mix_w @ pp["wd1"]) @ pp["wd2"]).astype(jnp.float32)
        # log decay in [-~20, -1e-9]: w = exp(-exp(dw))
        return -jnp.exp(jnp.clip(dw, -20.0, 10.0))

    def _heads(self, x):
        H, K = self.n_heads, self.cfg.rwkv_head_dim
        return x.reshape(x.shape[:-1] + (H, K))

    def _time_mix_seq(self, pp, x, boundary, valid=None):
        """x: [B, S, D] post-ln1; boundary: [B, D] last token of previous
        context; valid: [B, S] mask — pad tokens leave the WKV state untouched
        (k := 0 kills their contribution, log w := 0 freezes decay)."""
        cfg = self.cfg
        B, S, D = x.shape
        x_prev = jnp.concatenate([boundary[:, None], x[:, :-1]], axis=1)
        m = self._ddlerp(pp, x, x_prev)
        r = self._heads(m["r"] @ pp["w_r"])
        k = self._heads(m["k"] @ pp["w_k"])
        v = self._heads(m["v"] @ pp["w_v"])
        g = m["g"] @ pp["w_g"]
        logw = self._heads(self._decay(pp, m["w"]))
        if valid is not None:
            vm = valid[:, :, None, None]
            k = k * vm.astype(k.dtype)
            logw = logw * vm
        u = self._heads(pp["bonus"].astype(jnp.float32))
        c = _chunk_size(S)
        state = jnp.zeros((B, self.n_heads, cfg.rwkv_head_dim, cfg.rwkv_head_dim),
                          jnp.float32)
        outs = []
        for i in range(S // c):
            sl = slice(i * c, (i + 1) * c)
            o, state = wkv6_chunk(r[:, sl], k[:, sl], v[:, sl], logw[:, sl], u, state)
            outs.append(o)
        o = jnp.concatenate(outs, axis=1).reshape(B, S, D)
        o = L.groupnorm_heads(self._heads(o), jnp.ones((), jnp.float32)).reshape(B, S, D)
        o = (o * pp["gn"].astype(jnp.float32)).astype(self._dtype)
        o = o * jax.nn.silu(g.astype(jnp.float32)).astype(self._dtype)
        return o @ pp["w_o"], state, x[:, -1]

    def _channel_mix_seq(self, pp, x, boundary):
        x_prev = jnp.concatenate([boundary[:, None], x[:, :-1]], axis=1)
        mk = x + (x_prev - x) * pp["mu_ck"]
        mr = x + (x_prev - x) * pp["mu_cr"]
        kk = jnp.square(jax.nn.relu(mk @ pp["wc_k"]))
        return jax.nn.sigmoid(mr @ pp["wc_r"]) * (kk @ pp["wc_v"]), x[:, -1]

    def _block_seq(self, carry, pp, collect: bool, seq_lens=None):
        x, aux = carry
        cfg = self.cfg
        valid = None
        if seq_lens is not None:
            valid = (jnp.arange(x.shape[1])[None, :] < seq_lens[:, None]).astype(jnp.float32)
        h = L.layernorm(x, pp["ln1_s"], pp["ln1_b"], cfg.norm_eps)
        tm, state, tm_b = self._time_mix_seq(pp, h, jnp.zeros_like(h[:, 0]), valid)
        x = x + tm
        h2 = L.layernorm(x, pp["ln2_s"], pp["ln2_b"], cfg.norm_eps)
        cm, cm_b = self._channel_mix_seq(pp, h2, jnp.zeros_like(h2[:, 0]))
        x = x + cm
        x = self._constrain(x, "batch", None, None)
        if collect:
            if seq_lens is not None:  # token-shift boundaries at the last *valid* token
                idx = (seq_lens - 1)[:, None, None].astype(jnp.int32)
                tm_b = jnp.take_along_axis(h, idx, axis=1)[:, 0]
                cm_b = jnp.take_along_axis(h2, idx, axis=1)[:, 0]
            caches = {"state": state, "tm_shift": tm_b, "cm_shift": cm_b}
        else:
            caches = {}
        return (x, aux), caches

    # ------------------------------------------------------------- public steps
    def forward_hidden(self, params, embeds, *, collect_cache=False, remat=False,
                       seq_lens=None):
        x = L.layernorm(embeds, params["ln0_s"], params["ln0_b"], self.cfg.norm_eps)
        x = self._constrain(x, "batch", None, None)
        body = partial(self._block_seq, collect=collect_cache, seq_lens=seq_lens)
        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        (x, aux), caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                        params["blocks"])
        x = L.rmsnorm(x, params["final_norm"], self.cfg.norm_eps)
        return x, aux, caches

    def embed_tokens(self, params, tokens):
        return jnp.take(params["embed"], tokens, axis=0).astype(self._dtype)

    def logits(self, params, hidden):
        lg = hidden @ params["lm_head"]
        V, Vp = self.cfg.vocab_size, lg.shape[-1]
        if Vp > V:
            lg = jnp.where(jnp.arange(Vp) < V, lg, -1e30)
        return lg

    def train_loss(self, params, batch, *, remat=True):
        embeds = self.embed_tokens(params, batch["tokens"])
        hidden, _, _ = self.forward_hidden(params, embeds, remat=remat)
        total, count = L.chunked_softmax_xent(hidden, params["lm_head"], batch["labels"],
                                              vocab_valid=self.cfg.vocab_size)
        loss = total / jnp.maximum(count, 1.0)
        return loss, {"xent": loss}

    def prefill(self, params, tokens, *, seq_lens=None, max_len: int = 0,
                extra_embeds=None):
        embeds = self.embed_tokens(params, tokens)
        hidden, _, caches = self.forward_hidden(params, embeds, collect_cache=True,
                                                seq_lens=seq_lens)
        if seq_lens is not None:
            last = jnp.take_along_axis(
                hidden, (seq_lens - 1)[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        else:
            last = hidden[:, -1]
        return self.logits(params, last), caches

    def _block_decode(self, x, pp, cache):
        cfg = self.cfg
        new = dict(cache)
        h = L.layernorm(x, pp["ln1_s"], pp["ln1_b"], cfg.norm_eps)
        m = self._ddlerp(pp, h, cache["tm_shift"])
        r = self._heads(m["r"] @ pp["w_r"])
        k = self._heads(m["k"] @ pp["w_k"])
        v = self._heads(m["v"] @ pp["w_v"])
        g = m["g"] @ pp["w_g"]
        logw = self._heads(self._decay(pp, m["w"]))
        u = self._heads(pp["bonus"].astype(jnp.float32))
        o, new_state = wkv6_decode(r, k, v, logw, u, cache["state"])
        o = L.groupnorm_heads(o, jnp.ones((), jnp.float32)).reshape(x.shape)
        o = (o * pp["gn"].astype(jnp.float32)).astype(self._dtype)
        o = o * jax.nn.silu(g.astype(jnp.float32)).astype(self._dtype)
        x = x + o @ pp["w_o"]
        new["state"], new["tm_shift"] = new_state, h

        h2 = L.layernorm(x, pp["ln2_s"], pp["ln2_b"], cfg.norm_eps)
        mk = h2 + (cache["cm_shift"] - h2) * pp["mu_ck"]
        mr = h2 + (cache["cm_shift"] - h2) * pp["mu_cr"]
        kk = jnp.square(jax.nn.relu(mk @ pp["wc_k"]))
        x = x + jax.nn.sigmoid(mr @ pp["wc_r"]) * (kk @ pp["wc_v"])
        new["cm_shift"] = h2
        x = self._constrain(x, "batch", None)
        return x, new

    def decode_step(self, params, cache, tokens, positions):
        """Unrolled layer loop: in-place per-layer state updates on the
        donated cache (see DenseTransformer.decode_step)."""
        x = self.embed_tokens(params, tokens)
        x = L.layernorm(x, params["ln0_s"], params["ln0_b"], self.cfg.norm_eps)
        cache = dict(cache)
        for g in range(self.cfg.num_layers):
            pp = jax.tree.map(lambda a: a[g], params["blocks"])
            cache_g = {k: cache[k][g] for k in ("state", "tm_shift", "cm_shift")}
            x, new_g = self._block_decode(x, pp, cache_g)
            for k in ("state", "tm_shift", "cm_shift"):
                cache[k] = cache[k].at[g].set(new_g[k])
        x = L.rmsnorm(x, params["final_norm"], self.cfg.norm_eps)
        return self.logits(params, x), cache

    def with_layers(self, num_layers: int) -> "RWKV6Model":
        return type(self)(self.cfg.replace(num_layers=num_layers), self.pc)

    @property
    def scan_trip_count(self) -> int:
        return self.n_groups

    @property
    def layers_per_scan_step(self) -> int:
        return 1
