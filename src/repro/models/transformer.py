"""Dense decoder-only transformer (GQA, optional qk-norm / QKV-bias /
local:global sliding-window pattern). Also serves the VLM backbone (patch
embeddings prepended by the stub frontend).

Layer stacking uses ``lax.scan`` over *groups* of layers (a group is the
local:global repeat pattern — 1 for uniform archs, 6 for gemma3) so HLO stays
small and compile fast at 512 devices; the roofline harness compensates for
XLA's count-scan-body-once cost analysis compositionally (see DESIGN.md §3).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed.sharding import GQALayout, ParallelConfig, gqa_layout
from repro.models import layers as L
from repro.models.param_utils import (
    abstract_params, count_params, init_params, param_shardings, param_specs, t,
)

LOCAL_ROPE_THETA = 10_000.0  # gemma3 uses short-rope on sliding-window layers


class DenseTransformer:
    """Functional model: params are an explicit pytree, methods are pure."""

    # prefill attention implementation: 'block' (pure-XLA blockwise flash,
    # the default) or 'flash' (the Pallas flash_prefill kernel — interpret
    # mode on CPU). Instance-level; see with_prefill_attn().
    prefill_attn_impl = "block"

    def __init__(self, cfg: ModelConfig, pc: Optional[ParallelConfig] = None):
        self.cfg = cfg
        self.pc = pc or ParallelConfig.single_device()
        self.layout: GQALayout = gqa_layout(cfg.num_heads, cfg.num_kv_heads, self.pc.tp)
        if cfg.attn_kind == "local_global":
            self.group = cfg.local_global_pattern + 1
            assert cfg.num_layers % self.group == 0
            self.kinds = ["local"] * cfg.local_global_pattern + ["global"]
        elif cfg.attn_kind == "swa":
            self.group, self.kinds = 1, ["local"]
        else:
            self.group, self.kinds = 1, ["global"]
        self.n_groups = cfg.num_layers // self.group
        self.full_idx = {p: i for i, p in enumerate(
            [p for p in range(self.group) if self.kinds[p] == "global"])}
        self.win_idx = {p: i for i, p in enumerate(
            [p for p in range(self.group) if self.kinds[p] == "local"])}
        self.n_full = len(self.full_idx)
        self.n_win = len(self.win_idx)
        self.embed_scale = math.sqrt(cfg.d_model) if "gemma" in cfg.name else 1.0

    # ---------------------------------------------------------------- params
    def templates(self):
        cfg, lay = self.cfg, self.layout
        G, Pg, D, F = self.n_groups, self.group, cfg.d_model, cfg.d_ff
        KVs, Qp, hd = lay.kv_slots, lay.q_per_slot, cfg.head_dim
        KV = lay.num_kv_heads
        qmask = jnp.asarray(lay.q_array() >= 0)          # [KVs, Qp] pad-slot mask
        dup = jnp.asarray(lay.dup_array())

        def init_wq(key):  # packed layout: zero weights on pad Q slots (exact math)
            w = jax.random.normal(key, (G, Pg, D, KVs, Qp, hd), jnp.float32) / math.sqrt(D)
            return w * qmask[None, None, None, :, :, None]

        def init_wo(key):
            w = jax.random.normal(key, (G, Pg, KVs, Qp, hd, D), jnp.float32) \
                / math.sqrt(lay.num_heads * hd)
            return w * qmask[None, None, :, :, None, None]

        def init_kv(key):  # canonical KV heads, then duplicate into slots
            w = jax.random.normal(key, (G, Pg, D, KV, hd), jnp.float32) / math.sqrt(D)
            return jnp.take(w, dup, axis=3)

        blocks: Dict[str, Any] = {
            "ln1": t((G, Pg, D), (None, None, None), "zeros"),
            "ln2": t((G, Pg, D), (None, None, None), "zeros"),
            "wq": t((G, Pg, D, KVs, Qp, hd), (None, None, None, "kv_heads", None, None),
                    custom=init_wq),
            "wk": t((G, Pg, D, KVs, hd), (None, None, None, "kv_heads", None), custom=init_kv),
            "wv": t((G, Pg, D, KVs, hd), (None, None, None, "kv_heads", None), custom=init_kv),
            "wo": t((G, Pg, KVs, Qp, hd, D), (None, None, "kv_heads", None, None, None),
                    custom=init_wo),
        }
        if cfg.qkv_bias:
            blocks["bq"] = t((G, Pg, KVs, Qp, hd), (None, None, "kv_heads", None, None), "zeros")
            blocks["bk"] = t((G, Pg, KVs, hd), (None, None, "kv_heads", None), "zeros")
            blocks["bv"] = t((G, Pg, KVs, hd), (None, None, "kv_heads", None), "zeros")
        if cfg.qk_norm:
            blocks["q_norm"] = t((G, Pg, hd), (None, None, None), "zeros")
            blocks["k_norm"] = t((G, Pg, hd), (None, None, None), "zeros")
        blocks.update(self._mlp_templates())
        Vp = cfg.padded_vocab(self.pc.tp)
        tree = {
            "embed": t((Vp, D), ("vocab", None), fan_in=D),
            "blocks": blocks,
            "final_norm": t((D,), (None,), "zeros"),
        }
        if not cfg.tie_embeddings:
            tree["lm_head"] = t((D, Vp), (None, "vocab"), fan_in=D)
        return tree

    def _mlp_templates(self):
        cfg = self.cfg
        G, Pg, D, F = self.n_groups, self.group, cfg.d_model, cfg.d_ff
        return {
            "w_gate": t((G, Pg, D, F), (None, None, None, "ff"), fan_in=D),
            "w_up": t((G, Pg, D, F), (None, None, None, "ff"), fan_in=D),
            "w_down": t((G, Pg, F, D), (None, None, "ff", None), fan_in=F),
        }

    def abstract_params(self):
        return abstract_params(self.templates(), self._dtype)

    def init_params(self, key):
        return init_params(self.templates(), key, self._dtype)

    def param_specs(self):
        return param_specs(self.templates(), self.pc)

    def param_shardings(self, mesh):
        return param_shardings(self.templates(), self.pc, mesh)

    def param_count(self) -> int:
        return count_params(self.templates())

    @property
    def _dtype(self):
        return jnp.dtype(self.cfg.dtype)

    # ---------------------------------------------------------------- cache
    def cache_struct(self, batch: int, max_len: int):
        """Abstract KV cache pytree for decode. Window layers use ring buffers."""
        cfg, lay = self.cfg, self.layout
        G, hd = self.n_groups, cfg.head_dim
        W = min(cfg.sliding_window or max_len, max_len)
        out = {}
        if self.n_full:
            shp = (G, self.n_full, batch, max_len, lay.kv_slots, hd)
            out["k_full"] = jax.ShapeDtypeStruct(shp, self._dtype)
            out["v_full"] = jax.ShapeDtypeStruct(shp, self._dtype)
        if self.n_win:
            shp = (G, self.n_win, batch, W, lay.kv_slots, hd)
            out["k_win"] = jax.ShapeDtypeStruct(shp, self._dtype)
            out["v_win"] = jax.ShapeDtypeStruct(shp, self._dtype)
        return out

    def init_cache(self, batch: int, max_len: int):
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.cache_struct(batch, max_len))

    def cache_specs(self):
        spec = self.pc.spec(None, None, "batch", None, "kv_heads", None)
        return jax.tree.map(lambda _: spec, self.cache_struct(1, 1))

    # ---------------------------------------------------------------- paged cache
    def supports_paged(self) -> bool:
        """Whether the block-paged KV path covers this arch: every layer must
        be full (global) attention — ring-buffer window layers have no paged
        layout (yet), and hybrid/ssm families override this to False."""
        return self.n_win == 0

    def init_paged_pools(self, num_blocks: int, block_size: int):
        """Block-paged KV pools: one ``[num_blocks, block_size, KVs, hd]``
        K and V pool per layer, stacked over (group, layer-in-group) so the
        whole cache is two arrays. Block id ``num_blocks - 1`` is conventionally
        the executor's scratch block (pad rows / pad table entries)."""
        if not self.supports_paged():
            raise NotImplementedError(
                f"{self.cfg.name}: paged KV supports full-attention archs only "
                f"(this arch has {self.n_win} window layer(s) per group)")
        shp = (self.n_groups, self.n_full, num_blocks, block_size,
               self.layout.kv_slots, self.cfg.head_dim)
        return {"k": jnp.zeros(shp, self._dtype),
                "v": jnp.zeros(shp, self._dtype)}

    def scatter_prefill_pools(self, pools, caches, block_tables):
        """Write a (padded, batched) prefill's dense per-sequence caches into
        the paged pools. ``caches`` is the ``prefill(...)`` cache pytree with
        k/v_full ``[G, n_full, B, L, KVs, hd]`` (L a multiple of block_size);
        ``block_tables`` ``[B, L // block_size]`` routes each block — pad rows
        and pad blocks should point at the scratch block."""
        bs = pools["k"].shape[3]
        for name in ("k", "v"):
            c = caches[f"{name}_full"]
            G, NF, B, L, KVs, hd = c.shape
            c = c.reshape(G, NF, B, L // bs, bs, KVs, hd)
            pools[name] = pools[name].at[:, :, block_tables].set(
                c.astype(pools[name].dtype))
        return pools

    def decode_step_paged(self, params, pools, tokens, positions,
                          block_tables, context_lens, *,
                          attn_impl: str = "ref"):
        """One decode step against the block-paged KV pools.

        tokens/positions: [B] int32; block_tables: [B, max_blocks] int32;
        context_lens: [B] int32 (== positions + 1 for live rows). Each layer
        scatters the new token's K/V into its pool at (block_tables[b,
        pos // bs], pos % bs) then attends through ``paged_attention``
        (``attn_impl='pallas'``/'pallas-interpret') or the pure-jnp reference
        (``'ref'`` — the CPU fallback CI exercises). Returns (logits, pools);
        pools should be donated by the jit wrapper.
        """
        from repro.kernels.paged_attention import paged_attention

        cfg = self.cfg
        bs = pools["k"].shape[3]
        B = tokens.shape[0]
        x = self.embed_tokens(params, tokens)
        pools = dict(pools)
        rows = jnp.arange(B)
        bids = block_tables[rows, positions // bs]
        offs = positions % bs
        for g in range(self.n_groups):
            pp = jax.tree.map(lambda a: a[g], params["blocks"])
            for p in range(self.group):
                h = L.rmsnorm(x, pp["ln1"][p], cfg.norm_eps)
                q, k, v = self._qkv(pp, p, h, positions, "global")
                i = self.full_idx[p]
                pools["k"] = pools["k"].at[g, i, bids, offs].set(
                    k.astype(pools["k"].dtype))
                pools["v"] = pools["v"].at[g, i, bids, offs].set(
                    v.astype(pools["v"].dtype))
                if attn_impl == "ref":
                    # CPU fallback: gather the sequence's pages into a dense
                    # [B, T, G, hd] view and run the *exact* dense decode
                    # recipe (same dtype roundings, same masking) — paged and
                    # dense backends then emit bit-identical tokens even in
                    # bf16, while T stays the bucketed block span instead of
                    # max_len.
                    kg = pools["k"][g, i][block_tables]   # [B, NB, bs, KVs, hd]
                    vg = pools["v"][g, i][block_tables]
                    Bq, NB, bsz, KVs, hd = kg.shape
                    o = L.decode_attention(
                        q, kg.reshape(Bq, NB * bsz, KVs, hd),
                        vg.reshape(Bq, NB * bsz, KVs, hd), positions)
                else:
                    o = paged_attention(q, pools["k"][g, i], pools["v"][g, i],
                                        block_tables, context_lens,
                                        interpret=attn_impl != "pallas")
                x = x + jnp.einsum("bgqh,gqhd->bd", o, pp["wo"][p])
                h = L.rmsnorm(x, pp["ln2"][p], cfg.norm_eps)
                mlp, _ = self._mlp(pp, p, h)
                x = x + mlp
                x = self._constrain(x, "batch", None)
        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        return self.logits(params, x), pools

    # ------------------------------------------------------------- building blocks
    def _constrain(self, x, *logical):
        if self.pc.dp_axes or self.pc.tp_axis:
            return jax.lax.with_sharding_constraint(x, self.pc.spec(*logical))
        return x

    def _qkv(self, pp, p: int, x, positions, kind: str):
        """x: [B, (S,) D] -> q [..., G, Qp, hd], k/v [..., G, hd] with rope applied."""
        cfg = self.cfg
        q = jnp.einsum("...d,dgqh->...gqh", x, pp["wq"][p])
        k = jnp.einsum("...d,dgh->...gh", x, pp["wk"][p])
        v = jnp.einsum("...d,dgh->...gh", x, pp["wv"][p])
        if cfg.qkv_bias:
            q = q + pp["bq"][p]
            k = k + pp["bk"][p]
            v = v + pp["bv"][p]
        if cfg.qk_norm:
            q = L.rmsnorm(q, pp["q_norm"][p], cfg.norm_eps)
            k = L.rmsnorm(k, pp["k_norm"][p], cfg.norm_eps)
        theta = LOCAL_ROPE_THETA if (kind == "local" and cfg.attn_kind == "local_global") \
            else cfg.rope_theta
        if x.ndim == 3:  # [B, S, D]
            q = L.apply_rope(q, positions[:, :, None, None], theta)
            k = L.apply_rope(k, positions[:, :, None], theta)
        else:            # [B, D] decode
            q = L.apply_rope(q, positions[:, None, None], theta)
            k = L.apply_rope(k, positions[:, None], theta)
        return q, k, v

    def _mlp(self, pp, p: int, x):
        out = L.swiglu_mlp(x, pp["w_gate"][p], pp["w_up"][p], pp["w_down"][p], self.cfg.act)
        return out, jnp.zeros((), jnp.float32)

    def _mixer_seq(self, pp, p: int, x, positions, seq_lens, kind: str, state):
        """Sequence-mode token mixer (attention). Returns (out, cache_entry)."""
        cfg = self.cfg
        q, k, v = self._qkv(pp, p, x, positions, kind)
        window = cfg.sliding_window if kind == "local" else 0
        if self.prefill_attn_impl == "flash":
            # Pallas flash_prefill kernel: causal masking alone suffices for
            # ragged batches — rows past a sequence's length attend only pad
            # keys in their own causal past and are never read (the last-token
            # gather uses seq_lens). Layout swap: [B,S,G,Qp,hd] <-> [B,G,S,R,hd].
            from repro.kernels.flash_prefill import flash_prefill
            o = flash_prefill(jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2),
                              jnp.moveaxis(v, 1, 2), causal=True, window=window,
                              interpret=jax.default_backend() == "cpu")
            o = jnp.moveaxis(o, 2, 1)
        else:
            o = L.block_attention(q, k, v, causal=True, window=window,
                                  seq_lens=seq_lens)
        out = jnp.einsum("bsgqh,gqhd->bsd", o, pp["wo"][p])
        return out, (k, v)

    def with_prefill_attn(self, impl: str) -> "DenseTransformer":
        """A sibling model instance (same config/params pytree) whose prefill
        attention runs via ``impl`` ('block' | 'flash') — lets an executor opt
        into the kernel path without mutating a shared model object."""
        if impl not in ("block", "flash"):
            raise ValueError(f"unknown prefill attention impl {impl!r}")
        m = type(self)(self.cfg, self.pc)
        m.prefill_attn_impl = impl
        return m

    def _mixer_decode(self, pp, p: int, x, positions, kind: str, cache_kv):
        """cache_kv: (k_cache, v_cache) already containing the new token."""
        cfg = self.cfg
        q, k, v = self._qkv(pp, p, x, positions, kind)
        window = cfg.sliding_window if kind == "local" else 0
        kc, vc = cache_kv
        kc = L.cache_write(kc, k, positions, window=window)
        vc = L.cache_write(vc, v, positions, window=window)
        o = L.decode_attention(q, kc, vc, positions, window=window)
        out = jnp.einsum("bgqh,gqhd->bd", o, pp["wo"][p])
        return out, (kc, vc)

    def _attn_decode_inplace(self, pp, p: int, x, positions, kind: str,
                             cache, g: int):
        """Decode attention with scatter-in-place KV writes on the full cache."""
        cfg = self.cfg
        q, k, v = self._qkv(pp, p, x, positions, kind)
        window = cfg.sliding_window if kind == "local" else 0
        if kind == "global":
            i, kk, vk = self.full_idx[p], "k_full", "v_full"
        else:
            i, kk, vk = self.win_idx[p], "k_win", "v_win"
        cache[kk] = L.cache_write_full(cache[kk], g, i, k, positions, window)
        cache[vk] = L.cache_write_full(cache[vk], g, i, v, positions, window)
        o = L.decode_attention(q, cache[kk][g, i], cache[vk][g, i],
                               positions, window=window)
        out = jnp.einsum("bgqh,gqhd->bd", o, pp["wo"][p])
        return out, cache

    # ------------------------------------------------------------- forward (seq mode)
    def _group_seq(self, carry, pp, positions, seq_lens, collect: bool, max_len: int):
        x, aux = carry
        kf, vf, kw, vw = [], [], [], []
        cfg = self.cfg
        W = min(cfg.sliding_window or max_len, max_len)
        for p in range(self.group):
            kind = self.kinds[p]
            h = L.rmsnorm(x, pp["ln1"][p], cfg.norm_eps)
            attn, (k, v) = self._mixer_seq(pp, p, h, positions, seq_lens, kind, None)
            x = x + attn
            h = L.rmsnorm(x, pp["ln2"][p], cfg.norm_eps)
            mlp, a = self._mlp(pp, p, h)
            x = x + mlp
            aux = aux + a
            x = self._constrain(x, "batch", None, None)
            if collect:
                if kind == "global":
                    S = k.shape[1]
                    pad = max_len - S
                    if pad:
                        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
                    kf.append(k)
                    vf.append(v)
                else:
                    kw.append(L.ring_from_sequence(k, W, seq_lens))
                    vw.append(L.ring_from_sequence(v, W, seq_lens))
        caches = {}
        if collect and kf:
            caches["k_full"], caches["v_full"] = jnp.stack(kf), jnp.stack(vf)
        if collect and kw:
            caches["k_win"], caches["v_win"] = jnp.stack(kw), jnp.stack(vw)
        return (x, aux), caches

    def forward_hidden(self, params, embeds, positions, seq_lens=None, *,
                       collect_cache=False, max_len: int = 0, remat=False):
        """embeds: [B, S, D] -> (hidden [B, S, D], aux, cache | {})."""
        cfg = self.cfg
        x = self._constrain(embeds, "batch", None, None)
        body = partial(self._group_seq, positions=positions, seq_lens=seq_lens,
                       collect=collect_cache, max_len=max_len or embeds.shape[1])
        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        (x, aux), caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                        params["blocks"])
        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        return x, aux, caches

    def embed_tokens(self, params, tokens):
        e = jnp.take(params["embed"], tokens, axis=0)
        return (e * self.embed_scale).astype(self._dtype)

    def logits(self, params, hidden):
        if self.cfg.tie_embeddings:
            lg = jnp.einsum("...d,vd->...v", hidden, params["embed"])
        else:
            lg = hidden @ params["lm_head"]
        V, Vp = self.cfg.vocab_size, lg.shape[-1]
        if Vp > V:   # vocab padded to the TP multiple: mask pad columns
            lg = jnp.where(jnp.arange(Vp) < V, lg, L.NEG_INF)
        return lg

    # ------------------------------------------------------------- public steps
    def train_loss(self, params, batch, *, remat=True):
        """batch: {'tokens': [B,S_text], 'labels': [B,S_total] (-1 pad),
        'extra_embeds': optional [B,P,D] patch/frame stub embeddings}."""
        tokens = batch["tokens"]
        embeds = self.embed_tokens(params, tokens)
        if batch.get("extra_embeds") is not None:
            embeds = jnp.concatenate(
                [batch["extra_embeds"].astype(self._dtype), embeds], axis=1)
        B, S = embeds.shape[:2]
        positions = L.causal_positions(S, B)
        hidden, aux, _ = self.forward_hidden(params, embeds, positions, remat=remat)
        w_vocab = params["embed"].T if self.cfg.tie_embeddings else params["lm_head"]
        total, count = L.chunked_softmax_xent(hidden, w_vocab, batch["labels"],
                                              vocab_valid=self.cfg.vocab_size)
        loss = total / jnp.maximum(count, 1.0)
        loss = loss + self._aux_weight() * aux / max(1, self.cfg.num_layers)
        return loss, {"xent": total / jnp.maximum(count, 1.0), "aux": aux}

    def _aux_weight(self) -> float:
        return 0.0

    def prefill(self, params, tokens, *, seq_lens=None, max_len: int = 0,
                extra_embeds=None):
        """Returns (last-token logits [B, V], cache). ``extra_embeds`` are
        prepended patch/frame embeddings (VLM stub frontend)."""
        B, S_tok = tokens.shape
        embeds = self.embed_tokens(params, tokens)
        if extra_embeds is not None:
            embeds = jnp.concatenate([extra_embeds.astype(self._dtype), embeds], axis=1)
        S = embeds.shape[1]
        positions = L.causal_positions(S, B)
        max_len = max_len or S
        hidden, _, caches = self.forward_hidden(
            params, embeds, positions, seq_lens, collect_cache=True, max_len=max_len)
        if seq_lens is not None:
            last = jnp.take_along_axis(
                hidden, (seq_lens - 1)[:, None, None].astype(jnp.int32), axis=1)[:, 0]
        else:
            last = hidden[:, -1]
        return self.logits(params, last), caches

    def decode_step(self, params, cache, tokens, positions):
        """tokens: [B] int32, positions: [B] -> (logits [B, V], new cache).

        The layer loop is *unrolled* (decode graphs are small): each layer's
        KV write is an in-place dynamic-update-slice on the donated cache —
        no scan ys double-buffering of the multi-GB cache arrays.
        """
        cfg = self.cfg
        x = self.embed_tokens(params, tokens)
        cache = dict(cache)
        for g in range(self.n_groups):
            pp = jax.tree.map(lambda a: a[g], params["blocks"])
            for p in range(self.group):
                kind = self.kinds[p]
                h = L.rmsnorm(x, pp["ln1"][p], cfg.norm_eps)
                attn, cache = self._attn_decode_inplace(pp, p, h, positions,
                                                        kind, cache, g)
                x = x + attn
                h = L.rmsnorm(x, pp["ln2"][p], cfg.norm_eps)
                mlp, _ = self._mlp(pp, p, h)
                x = x + mlp
                x = self._constrain(x, "batch", None)
        x = L.rmsnorm(x, params["final_norm"], self.cfg.norm_eps)
        return self.logits(params, x), cache

    # ------------------------------------------------------------- roofline support
    def with_layers(self, num_layers: int) -> "DenseTransformer":
        """Same arch with a different layer count (roofline composition)."""
        return type(self)(self.cfg.replace(num_layers=num_layers), self.pc)

    @property
    def scan_trip_count(self) -> int:
        return self.n_groups

    @property
    def layers_per_scan_step(self) -> int:
        return self.group
