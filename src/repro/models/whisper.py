"""Whisper-style encoder-decoder backbone. The conv/mel frontend is a stub per
the assignment: the model consumes precomputed frame embeddings [B, S, D]
(sinusoidal positions added here). Decoder: causal self-attention (cached) +
cross-attention against per-layer encoder KV (computed once at prefill).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ParallelConfig, gqa_layout
from repro.models import layers as L
from repro.models.param_utils import (
    abstract_params, count_params, init_params, param_shardings, param_specs, t,
)


def sinusoids(length: int, channels: int) -> jnp.ndarray:
    log_timescale = math.log(10_000) / (channels // 2 - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(channels // 2, dtype=jnp.float32))
    scaled = jnp.arange(length, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(scaled), jnp.cos(scaled)], axis=1)


class WhisperModel:
    def __init__(self, cfg: ModelConfig, pc: Optional[ParallelConfig] = None):
        self.cfg = cfg
        self.pc = pc or ParallelConfig.single_device()
        self.layout = gqa_layout(cfg.num_heads, cfg.num_kv_heads, self.pc.tp)
        self.n_groups = cfg.num_layers
        self.group = 1

    @property
    def _dtype(self):
        return jnp.dtype(self.cfg.dtype)

    # ---------------------------------------------------------------- params
    def _attn_templates(self, Lyr: int, cross: bool = False):
        cfg, lay = self.cfg, self.layout
        D, KVs, Qp, hd = cfg.d_model, lay.kv_slots, lay.q_per_slot, cfg.head_dim
        qmask = jnp.asarray(lay.q_array() >= 0)
        dup = jnp.asarray(lay.dup_array())

        def init_wq(key):
            w = jax.random.normal(key, (Lyr, D, KVs, Qp, hd), jnp.float32) / math.sqrt(D)
            return w * qmask[None, None, :, :, None]

        def init_wo(key):
            w = jax.random.normal(key, (Lyr, KVs, Qp, hd, D), jnp.float32) \
                / math.sqrt(lay.num_heads * hd)
            return w * qmask[None, :, :, None, None]

        def init_kv(key):
            w = jax.random.normal(key, (Lyr, D, lay.num_kv_heads, hd),
                                  jnp.float32) / math.sqrt(D)
            return jnp.take(w, dup, axis=2)

        return {
            "wq": t((Lyr, D, KVs, Qp, hd), (None, None, "kv_heads", None, None),
                    custom=init_wq),
            "bq": t((Lyr, KVs, Qp, hd), (None, "kv_heads", None, None), "zeros"),
            "wk": t((Lyr, D, KVs, hd), (None, None, "kv_heads", None), custom=init_kv),
            "wv": t((Lyr, D, KVs, hd), (None, None, "kv_heads", None), custom=init_kv),
            "bv": t((Lyr, KVs, hd), (None, "kv_heads", None), "zeros"),
            "wo": t((Lyr, KVs, Qp, hd, D), (None, "kv_heads", None, None, None),
                    custom=init_wo),
            "bo": t((Lyr, D), (None, None), "zeros"),
        }

    def _mlp_templates(self, Lyr: int):
        D, F = self.cfg.d_model, self.cfg.d_ff
        return {
            "w_in": t((Lyr, D, F), (None, None, "ff"), fan_in=D),
            "b_in": t((Lyr, F), (None, "ff"), "zeros"),
            "w_out": t((Lyr, F, D), (None, "ff", None), fan_in=F),
            "b_out": t((Lyr, D), (None, None), "zeros"),
        }

    def templates(self):
        cfg = self.cfg
        Le, Ld, D = cfg.num_encoder_layers, cfg.num_layers, cfg.d_model
        enc = {
            "ln1_s": t((Le, D), (None, None), "ones"),
            "ln1_b": t((Le, D), (None, None), "zeros"),
            "ln2_s": t((Le, D), (None, None), "ones"),
            "ln2_b": t((Le, D), (None, None), "zeros"),
        }
        enc.update({f"sa_{k}": v for k, v in self._attn_templates(Le).items()})
        enc.update(self._mlp_templates(Le))
        dec = {
            "ln1_s": t((Ld, D), (None, None), "ones"),
            "ln1_b": t((Ld, D), (None, None), "zeros"),
            "ln2_s": t((Ld, D), (None, None), "ones"),
            "ln2_b": t((Ld, D), (None, None), "zeros"),
            "ln3_s": t((Ld, D), (None, None), "ones"),
            "ln3_b": t((Ld, D), (None, None), "zeros"),
        }
        dec.update({f"sa_{k}": v for k, v in self._attn_templates(Ld).items()})
        dec.update({f"xa_{k}": v for k, v in self._attn_templates(Ld).items()})
        dec.update(self._mlp_templates(Ld))
        return {
            "embed": t((cfg.padded_vocab(self.pc.tp), D), ("vocab", None), fan_in=D),
            "pos_dec": t((cfg.max_target_len, D), (None, None), fan_in=D),
            "enc": enc,
            "dec": dec,
            "enc_norm_s": t((D,), (None,), "ones"),
            "enc_norm_b": t((D,), (None,), "zeros"),
            "dec_norm_s": t((D,), (None,), "ones"),
            "dec_norm_b": t((D,), (None,), "zeros"),
        }

    def abstract_params(self):
        return abstract_params(self.templates(), self._dtype)

    def init_params(self, key):
        return init_params(self.templates(), key, self._dtype)

    def param_specs(self):
        return param_specs(self.templates(), self.pc)

    def param_shardings(self, mesh):
        return param_shardings(self.templates(), self.pc, mesh)

    def param_count(self):
        return count_params(self.templates())

    # ---------------------------------------------------------------- cache
    def cache_struct(self, batch: int, max_len: int):
        """max_len here is the *encoder* length; self cache uses max_target_len."""
        cfg, lay = self.cfg, self.layout
        hd = cfg.head_dim
        Ld = cfg.num_layers
        T = cfg.max_target_len
        return {
            "k_self": jax.ShapeDtypeStruct((Ld, batch, T, lay.kv_slots, hd), self._dtype),
            "v_self": jax.ShapeDtypeStruct((Ld, batch, T, lay.kv_slots, hd), self._dtype),
            "k_cross": jax.ShapeDtypeStruct((Ld, batch, max_len, lay.kv_slots, hd), self._dtype),
            "v_cross": jax.ShapeDtypeStruct((Ld, batch, max_len, lay.kv_slots, hd), self._dtype),
            "frame_lens": jax.ShapeDtypeStruct((batch,), jnp.int32),
        }

    def init_cache(self, batch: int, max_len: int):
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.cache_struct(batch, max_len))

    def cache_specs(self):
        kv = self.pc.spec(None, "batch", None, "kv_heads", None)
        return {"k_self": kv, "v_self": kv, "k_cross": kv, "v_cross": kv,
                "frame_lens": self.pc.spec("batch")}

    # ---------------------------------------------------------------- blocks
    def _constrain(self, x, *logical):
        if self.pc.dp_axes or self.pc.tp_axis:
            return jax.lax.with_sharding_constraint(x, self.pc.spec(*logical))
        return x

    def _qkv(self, pp, prefix, x):
        q = jnp.einsum("...d,dgqh->...gqh", x, pp[f"{prefix}_wq"]) + pp[f"{prefix}_bq"]
        k = jnp.einsum("...d,dgh->...gh", x, pp[f"{prefix}_wk"])
        v = jnp.einsum("...d,dgh->...gh", x, pp[f"{prefix}_wv"]) + pp[f"{prefix}_bv"]
        return q, k, v

    def _proj_out(self, pp, prefix, o):
        if o.ndim == 5:
            return jnp.einsum("bsgqh,gqhd->bsd", o, pp[f"{prefix}_wo"]) + pp[f"{prefix}_bo"]
        return jnp.einsum("bgqh,gqhd->bd", o, pp[f"{prefix}_wo"]) + pp[f"{prefix}_bo"]

    def _enc_block(self, x, pp, frame_lens):
        cfg = self.cfg
        h = L.layernorm(x, pp["ln1_s"], pp["ln1_b"], cfg.norm_eps)
        q, k, v = self._qkv(pp, "sa", h)
        o = L.block_attention(q, k, v, causal=False, seq_lens=frame_lens)
        x = x + self._proj_out(pp, "sa", o)
        h = L.layernorm(x, pp["ln2_s"], pp["ln2_b"], cfg.norm_eps)
        x = x + L.gelu_mlp(h, pp["w_in"], pp["b_in"], pp["w_out"], pp["b_out"])
        return self._constrain(x, "batch", None, None), None

    def encode(self, params, frames, frame_lens=None):
        """frames: [B, S, D] stub frontend embeddings -> encoder hidden."""
        S = frames.shape[1]
        x = frames.astype(self._dtype) + sinusoids(S, self.cfg.d_model).astype(self._dtype)
        x = self._constrain(x, "batch", None, None)
        x, _ = jax.lax.scan(partial(self._enc_block, frame_lens=frame_lens),
                            x, params["enc"])
        return L.layernorm(x, params["enc_norm_s"], params["enc_norm_b"], self.cfg.norm_eps)

    def _dec_block_seq(self, x, pp, enc_out, frame_lens, collect):
        cfg = self.cfg
        h = L.layernorm(x, pp["ln1_s"], pp["ln1_b"], cfg.norm_eps)
        q, k, v = self._qkv(pp, "sa", h)
        o = L.block_attention(q, k, v, causal=True)
        x = x + self._proj_out(pp, "sa", o)
        h = L.layernorm(x, pp["ln2_s"], pp["ln2_b"], cfg.norm_eps)
        qx = jnp.einsum("...d,dgqh->...gqh", h, pp["xa_wq"]) + pp["xa_bq"]
        kx = jnp.einsum("...d,dgh->...gh", enc_out, pp["xa_wk"])
        vx = jnp.einsum("...d,dgh->...gh", enc_out, pp["xa_wv"]) + pp["xa_bv"]
        ox = L.block_attention(qx, kx, vx, causal=False, seq_lens=frame_lens)
        x = x + self._proj_out(pp, "xa", ox)
        h = L.layernorm(x, pp["ln3_s"], pp["ln3_b"], cfg.norm_eps)
        x = x + L.gelu_mlp(h, pp["w_in"], pp["b_in"], pp["w_out"], pp["b_out"])
        x = self._constrain(x, "batch", None, None)
        cache = (k, v, kx, vx) if collect else None
        return x, cache

    def _decode_tokens(self, params, tokens, enc_out, frame_lens, collect):
        B, T = tokens.shape
        x = jnp.take(params["embed"], tokens, axis=0).astype(self._dtype)
        x = x + params["pos_dec"][:T][None]
        body = partial(self._dec_block_seq, enc_out=enc_out,
                       frame_lens=frame_lens, collect=collect)
        x, caches = jax.lax.scan(body, x, params["dec"])
        x = L.layernorm(x, params["dec_norm_s"], params["dec_norm_b"], self.cfg.norm_eps)
        return x, caches

    def logits(self, params, hidden):
        lg = jnp.einsum("...d,vd->...v", hidden, params["embed"])
        V, Vp = self.cfg.vocab_size, lg.shape[-1]
        if Vp > V:
            lg = jnp.where(jnp.arange(Vp) < V, lg, -1e30)
        return lg

    # ---------------------------------------------------------------- steps
    def train_loss(self, params, batch, *, remat=True):
        """batch: {'frames': [B,S,D], 'tokens': [B,T], 'labels': [B,T]}."""
        enc_out = self.encode(params, batch["frames"], batch.get("frame_lens"))
        hidden, _ = self._decode_tokens(params, batch["tokens"], enc_out,
                                        batch.get("frame_lens"), collect=False)
        total, count = L.chunked_softmax_xent(
            hidden, params["embed"].T, batch["labels"], num_chunks=4,
            vocab_valid=self.cfg.vocab_size)
        loss = total / jnp.maximum(count, 1.0)
        return loss, {"xent": loss}

    def prefill(self, params, tokens, *, frames=None, seq_lens=None, max_len: int = 0,
                extra_embeds=None):
        """tokens: decoder prompt [B, Tp]; frames/extra_embeds: [B, S, D]."""
        frames = frames if frames is not None else extra_embeds
        B, Tp = tokens.shape
        enc_out = self.encode(params, frames, seq_lens)
        hidden, caches = self._decode_tokens(params, tokens, enc_out, seq_lens,
                                             collect=True)
        k_self, v_self, k_cross, v_cross = caches
        T = self.cfg.max_target_len
        pad = ((0, 0), (0, 0), (0, T - Tp), (0, 0), (0, 0))
        cache = {
            "k_self": jnp.pad(k_self, pad), "v_self": jnp.pad(v_self, pad),
            "k_cross": k_cross, "v_cross": v_cross,
            "frame_lens": seq_lens if seq_lens is not None
            else jnp.full((B,), frames.shape[1], jnp.int32),
        }
        return self.logits(params, hidden[:, -1]), cache

    def _dec_block_step(self, x, xs, positions):
        pp, cache = xs
        cfg = self.cfg
        new = dict(cache)
        h = L.layernorm(x, pp["ln1_s"], pp["ln1_b"], cfg.norm_eps)
        q, k, v = self._qkv(pp, "sa", h)
        kc = L.cache_write(new["k_self"], k, positions)
        vc = L.cache_write(new["v_self"], v, positions)
        new["k_self"], new["v_self"] = kc, vc
        o = L.decode_attention(q, kc, vc, positions)
        x = x + self._proj_out(pp, "sa", o)
        h = L.layernorm(x, pp["ln2_s"], pp["ln2_b"], cfg.norm_eps)
        qx = jnp.einsum("bd,dgqh->bgqh", h, pp["xa_wq"]) + pp["xa_bq"]
        ox = L.decode_attention(qx, new["k_cross"], new["v_cross"],
                                cache["frame_lens"] - 1)
        x = x + self._proj_out(pp, "xa", ox)
        h = L.layernorm(x, pp["ln3_s"], pp["ln3_b"], cfg.norm_eps)
        x = x + L.gelu_mlp(h, pp["w_in"], pp["b_in"], pp["w_out"], pp["b_out"])
        x = self._constrain(x, "batch", None)
        return x, new

    def decode_step(self, params, cache, tokens, positions):
        """tokens/positions: [B] — positions index the *decoder* sequence."""
        x = jnp.take(params["embed"], tokens, axis=0).astype(self._dtype)
        x = x + jnp.take(params["pos_dec"], jnp.minimum(
            positions, self.cfg.max_target_len - 1), axis=0)
        frame_lens = cache["frame_lens"]
        cache = dict(cache)
        # unrolled layer loop: in-place per-layer KV writes on the donated cache
        for g in range(self.cfg.num_layers):
            pp = jax.tree.map(lambda a: a[g], params["dec"])
            cl = {k: cache[k][g] for k in ("k_self", "v_self", "k_cross", "v_cross")}
            cl["frame_lens"] = frame_lens
            x, new = self._dec_block_step(x, (pp, cl), positions)
            for k in ("k_self", "v_self"):
                cache[k] = cache[k].at[g].set(new[k])
        x = L.layernorm(x, params["dec_norm_s"], params["dec_norm_b"], self.cfg.norm_eps)
        return self.logits(params, x), cache

    def with_layers(self, num_layers: int) -> "WhisperModel":
        return type(self)(self.cfg.replace(
            num_layers=num_layers, num_encoder_layers=num_layers), self.pc)

    @property
    def scan_trip_count(self) -> int:
        return self.n_groups

    @property
    def layers_per_scan_step(self) -> int:
        return 1
