"""Shared model primitives: norms, RoPE, blockwise flash attention (pure-XLA
path used for dry-run lowering; Pallas kernels provide the TPU-optimized path),
decode attention against dense/ring KV caches, MLPs, chunked cross-entropy.

All attention here uses the packed GQA layout from ``repro.distributed.sharding``:
q ``[B, S, G, Qp, hd]``, k/v ``[B, S, G, hd]`` with G = kv_slots, Qp = q_per_slot.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


# --------------------------------------------------------------------------
# norms & activations
# --------------------------------------------------------------------------
def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def groupnorm_heads(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    """Per-head groupnorm over the trailing head_dim (used by RWKV6)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (((x - mu) * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


def act_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return partial(jax.nn.gelu, approximate=True)
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq?, hd] with positions broadcastable to x.shape[:-1]."""
    if theta <= 0:
        return x
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # [hd/2]
    angles = positions.astype(jnp.float32)[..., None] * freqs  # [..., hd/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# blockwise (flash-style) attention — pure XLA, unrolled over blocks
# --------------------------------------------------------------------------
def _pick_block(seq: int, target_blocks: int = 8, floor: int = 512) -> int:
    blk = max(floor, seq // target_blocks)
    while seq % blk != 0:  # shapes in this project are powers of two; be safe anyway
        blk //= 2
        if blk < 16:
            return seq
    return blk


def block_attention(
    q: jax.Array,                # [B, S, G, Qp, hd]
    k: jax.Array,                # [B, T, G, hd]
    v: jax.Array,                # [B, T, G, hd]
    *,
    causal: bool = True,
    window: int = 0,             # sliding window size (0 = unlimited)
    q_offset: int = 0,           # absolute position of q[0] relative to k[0]
    seq_lens: Optional[jax.Array] = None,   # [B] valid key lengths
    q_block: Optional[int] = None,
    kv_block: Optional[int] = None,
) -> jax.Array:
    """Online-softmax attention, unrolled over (q-block, kv-block) pairs.

    Unrolling (vs lax.scan) keeps every FLOP visible to HLO cost analysis and
    lets causal/window-sloped block pairs be skipped *statically* — sliding-
    window layers really do cost O(S·W).
    """
    B, S, G, Qp, hd = q.shape
    T = k.shape[1]
    qb = q_block or _pick_block(S)
    kb = kv_block or _pick_block(T)
    scale = 1.0 / math.sqrt(hd)
    nq, nk = S // qb, T // kb

    out = []
    for i in range(nq):
        qi = (q[:, i * qb:(i + 1) * qb] * scale).astype(q.dtype)
        q_pos_lo = q_offset + i * qb
        q_pos_hi = q_pos_lo + qb - 1
        m = jnp.full((B, G, Qp, qb), NEG_INF, jnp.float32)
        l = jnp.zeros((B, G, Qp, qb), jnp.float32)
        acc = jnp.zeros((B, G, Qp, qb, hd), jnp.float32)
        for j in range(nk):
            k_pos_lo, k_pos_hi = j * kb, (j + 1) * kb - 1
            if causal and k_pos_lo > q_pos_hi:
                continue  # entirely in the future
            if window > 0 and k_pos_hi < q_pos_lo - window + 1:
                continue  # entirely outside the sliding window
            kj = k[:, j * kb:(j + 1) * kb]
            vj = v[:, j * kb:(j + 1) * kb]
            s_blk = jnp.einsum("bqgph,bkgh->bgpqk", qi, kj,
                               preferred_element_type=jnp.float32)
            qpos = q_pos_lo + jnp.arange(qb)
            kpos = k_pos_lo + jnp.arange(kb)
            mask = jnp.ones((qb, kb), bool)
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window > 0:
                mask &= kpos[None, :] > qpos[:, None] - window
            mask_b = mask[None, None, None]
            if seq_lens is not None:
                mask_b = mask_b & (kpos[None, None, None, None, :] < seq_lens[:, None, None, None, None])
            s_blk = jnp.where(mask_b, s_blk, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s_blk, axis=-1))
            p = jnp.exp(s_blk - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bgpqk,bkgh->bgpqh", p.astype(v.dtype), vj,
                preferred_element_type=jnp.float32)
            m = m_new
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        out.append(jnp.moveaxis(o, (1, 2), (2, 3)))  # -> [B, qb, G, Qp, hd]
    return jnp.concatenate(out, axis=1).astype(q.dtype)


# --------------------------------------------------------------------------
# decode attention against a dense KV cache (one new token per sequence)
# --------------------------------------------------------------------------
def decode_attention(
    q: jax.Array,          # [B, G, Qp, hd]
    k_cache: jax.Array,    # [B, T, G, hd]
    v_cache: jax.Array,    # [B, T, G, hd]
    positions: jax.Array,  # [B] current token position (already written to cache)
    *,
    window: int = 0,       # if > 0, cache is a ring buffer of size T == window
) -> jax.Array:
    B, T, G, hd = k_cache.shape
    scale = 1.0 / math.sqrt(hd)
    # keep the cache in its storage dtype (bf16) and accumulate in f32 on the
    # MXU — casting the cache to f32 would materialize a 2x copy of multi-GB
    # cache slices per layer.
    s = jnp.einsum("bgph,btgh->bgpt", (q * scale).astype(q.dtype), k_cache,
                   preferred_element_type=jnp.float32)
    idx = jnp.arange(T)
    if window > 0:
        # ring buffer: slot t holds absolute position p with p % T == t and
        # p in (pos - T, pos]; valid once written, i.e. slot index <= pos for
        # the un-wrapped prefix, everything valid after wrap-around.
        valid = (idx[None, :] <= positions[:, None]) | (positions[:, None] >= T)
    else:
        valid = idx[None, :] <= positions[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgpt,btgh->bgph", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.astype(q.dtype)


def cache_write(cache: jax.Array, new: jax.Array, positions: jax.Array,
                window: int = 0) -> jax.Array:
    """Scatter one token per sequence into a dense or ring KV cache.

    cache: [B, T, G, hd]; new: [B, G, hd]; positions: [B].
    """
    T = cache.shape[1]
    slots = positions % T if window > 0 else positions
    return cache.at[jnp.arange(cache.shape[0]), slots].set(new.astype(cache.dtype))


def cache_write_full(full: jax.Array, g: int, i: int, new: jax.Array,
                     positions: jax.Array, window: int = 0) -> jax.Array:
    """Scatter one token per sequence directly into the *full* stacked cache
    ``[G, n, B, T, KVs, hd]`` — a small scatter XLA keeps in place on a donated
    buffer (no per-layer read-modify-write of multi-GB slices).
    """
    T = full.shape[3]
    B = full.shape[2]
    slots = positions % T if window > 0 else positions
    return full.at[g, i, jnp.arange(B), slots].set(new.astype(full.dtype))


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------
def swiglu_mlp(x, w_gate, w_up, w_down, act="silu"):
    f = act_fn(act)
    h = f(x @ w_gate) * (x @ w_up)
    return h @ w_down


def gelu_mlp(x, w_in, b_in, w_out, b_out):
    h = jax.nn.gelu(x @ w_in + b_in, approximate=True)
    return h @ w_out + b_out


# --------------------------------------------------------------------------
# chunked cross-entropy: never materializes [B, S, V]
# --------------------------------------------------------------------------
def chunked_softmax_xent(
    x: jax.Array,         # [B, S, D] final hidden states
    w_vocab: jax.Array,   # [D, Vp] (tp-sharded on V, possibly padded)
    labels: jax.Array,    # [B, S] int32; -1 = padding
    *,
    num_chunks: int = 8,
    z_loss: float = 0.0,
    vocab_valid: int = 0,   # true vocab size; pad columns masked out of the lse
) -> Tuple[jax.Array, jax.Array]:
    """Returns (sum_loss, num_valid). Chunked over the sequence axis."""
    B, S, D = x.shape
    Vp = w_vocab.shape[-1]
    cs = max(1, S // num_chunks)
    while S % cs:
        cs //= 2
    total = jnp.zeros((), jnp.float32)
    count = jnp.zeros((), jnp.float32)
    for i in range(S // cs):
        xc = x[:, i * cs:(i + 1) * cs]
        yc = labels[:, i * cs:(i + 1) * cs]
        logits = (xc @ w_vocab).astype(jnp.float32)          # [B, cs, Vp]
        if vocab_valid and vocab_valid < Vp:
            logits = jnp.where(jnp.arange(Vp) < vocab_valid, logits, NEG_INF)
        lse = jax.nn.logsumexp(logits, axis=-1)
        hit = jnp.take_along_axis(logits, jnp.maximum(yc, 0)[..., None], axis=-1)[..., 0]
        valid = (yc >= 0).astype(jnp.float32)
        loss = (lse - hit) * valid
        if z_loss > 0:
            loss = loss + z_loss * jnp.square(lse) * valid
        total = total + jnp.sum(loss)
        count = count + jnp.sum(valid)
    return total, count


# --------------------------------------------------------------------------
# misc
# --------------------------------------------------------------------------
def causal_positions(seq_len: int, batch: int) -> jax.Array:
    return jnp.broadcast_to(jnp.arange(seq_len, dtype=jnp.int32), (batch, seq_len))


def ring_from_sequence(k: jax.Array, window: int,
                       seq_lens: Optional[jax.Array] = None) -> jax.Array:
    """Arrange the last ``window`` *valid* positions of ``k`` [B, S, ...] into
    ring-buffer slot order (slot i holds the latest valid position p with
    p % window == i), so a prefill of any (possibly padded) length hands decode
    a consistent ring cache."""
    B, S = k.shape[:2]
    if seq_lens is None:
        if S < window:
            pad = [(0, 0)] * k.ndim
            pad[1] = (0, window - S)
            return jnp.pad(k, pad)
        slots = np.arange(window)
        pos = (S - 1) - ((S - 1 - slots) % window)
        return jnp.take(k, jnp.asarray(pos), axis=1)
    slots = jnp.arange(window)
    last = (seq_lens - 1)[:, None]                      # [B, 1]
    pos = last - ((last - slots[None, :]) % window)     # [B, W]
    valid = pos >= 0
    pos = jnp.clip(pos, 0, S - 1)
    idx = pos.reshape(B, window, *([1] * (k.ndim - 2)))
    gathered = jnp.take_along_axis(k, idx.astype(jnp.int32), axis=1)
    mask = valid.reshape(B, window, *([1] * (k.ndim - 2)))
    return jnp.where(mask, gathered, 0)
