"""Mixture-of-Experts transformer (qwen3-moe, granite-moe).

Expert dispatch uses the *grouped-capacity* scheme: tokens are sorted by their
assigned expert, packed into an ``[E, C, D]`` buffer (capacity C from the
capacity factor; overflow drops, standard for capacity-based MoE), processed as
a batched matmul ``[E, C, D] x [E, D, F]`` (expert dim sharded over the TP/EP
axis), and scattered back with router combine weights. FLOPs ≈ top_k × cf ×
ideal — no dense all-expert compute, no [T, E, C] one-hot dispatch tensors.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import round_up
from repro.models import layers as L
from repro.models.param_utils import t
from repro.models.transformer import DenseTransformer


def moe_dispatch(
    x: jax.Array,            # [T, D] tokens (flattened batch*seq)
    router_w: jax.Array,     # [D, E] true experts only
    w_gate: jax.Array,       # [Ep, D, F] Ep = experts padded to a TP multiple
    w_up: jax.Array,         # [Ep, D, F]
    w_down: jax.Array,       # [Ep, F, D]
    *,
    top_k: int,
    capacity_factor: float,
    act: str = "silu",
    constrain=None,   # sharding constraint for the [E, C, D] grouped buffers
) -> Tuple[jax.Array, jax.Array]:
    """Returns (out [T, D], aux load-balancing loss). Pad experts (index >= E)
    exist only in the grouped matmul (zero weights, never routed to)."""
    T, D = x.shape
    E = router_w.shape[1]
    Ep = w_gate.shape[0]
    logits = (x @ router_w).astype(jnp.float32)           # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, top_k)            # [T, K]
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # ---- aux loss (switch-style load balancing) ----
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top_i[:, 0], E, dtype=jnp.float32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs)

    # ---- sort token-expert assignments by expert ----
    TK = T * top_k
    eid = top_i.reshape(TK)                               # expert per slot
    tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), top_k)
    wgt = top_w.reshape(TK)
    order = jnp.argsort(eid, stable=True)
    eid_s, tok_s, wgt_s = eid[order], tok[order], wgt[order]

    # rank of each slot within its expert group (sorted -> searchsorted works)
    first = jnp.searchsorted(eid_s, jnp.arange(E, dtype=eid_s.dtype))
    rank = jnp.arange(TK, dtype=jnp.int32) - first[eid_s].astype(jnp.int32)

    C = int(round_up(max(8, math.ceil(T * top_k / E * capacity_factor)), 8))
    keep = rank < C
    dest = jnp.where(keep, eid_s.astype(jnp.int32) * C + rank, Ep * C)  # Ep*C = drop bin

    # pack tokens into expert groups [Ep, C, D]
    src = x[tok_s] * keep[:, None].astype(x.dtype)
    buf = jnp.zeros((Ep * C + 1, D), x.dtype).at[dest].set(src)[:-1]
    grouped = buf.reshape(Ep, C, D)
    if constrain is not None:
        grouped = constrain(grouped)                      # [E('model'), C, D]

    f = L.act_fn(act)
    h = f(jnp.einsum("ecd,edf->ecf", grouped, w_gate)) * jnp.einsum(
        "ecd,edf->ecf", grouped, w_up)
    out_g = jnp.einsum("ecf,efd->ecd", h, w_down)
    if constrain is not None:
        out_g = constrain(out_g)
    out_g = out_g.reshape(Ep * C, D)

    # gather each slot's expert output and combine back per token
    gathered = jnp.where(keep[:, None], out_g[jnp.minimum(dest, Ep * C - 1)], 0)
    out = jnp.zeros((T, D), x.dtype).at[tok_s].add(
        gathered * wgt_s[:, None].astype(x.dtype))
    return out, aux


def moe_dispatch_local_ep(
    x: jax.Array,            # [T, D] tokens (dp-sharded over batch axes)
    router_w: jax.Array,     # [D, E]
    w_gate: jax.Array,       # [Ep, D, F] expert-sharded over the model axis
    w_up: jax.Array,
    w_down: jax.Array,
    *,
    top_k: int,
    capacity_factor: float,
    act: str,
    mesh,
    pc,
) -> Tuple[jax.Array, jax.Array]:
    """Expert-parallel dispatch with ZERO cross-device token exchange.

    Key insight (§Perf cell B): activations are replicated over the model axis
    in this TP layout, so every (data, model) rank already holds its data
    shard's tokens — it can locally select the tokens routed to *its* experts,
    run the grouped matmul, and a single psum over the model axis combines the
    per-expert partial outputs. That psum is the same traffic as a dense TP
    FFN's all-reduce — versus GSPMD's replicated-scatter fallback for the
    naive dispatch, which all-gathers ~[E*C, D] buffers every layer
    (measured 12.4 TB/device at 32k prefill)."""
    tp_axis = pc.tp_axis
    E = router_w.shape[1]
    Ep = w_gate.shape[0]
    tp = pc.tp
    E_loc = Ep // tp
    if not pc.dp_axes:
        dp0 = None
    elif len(pc.dp_axes) == 1:
        dp0 = pc.dp_axes[0]
    else:
        dp0 = pc.dp_axes

    def body(x, router_w, w_gate, w_up, w_down):
        T_loc, D = x.shape
        m = jax.lax.axis_index(tp_axis)
        logits = (x @ router_w).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, top_i = jax.lax.top_k(probs, top_k)
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
        frac_tokens = jnp.mean(jax.nn.one_hot(top_i[:, 0], E, dtype=jnp.float32), 0)
        aux = E * jnp.sum(frac_tokens * jnp.mean(probs, axis=0))

        TK = T_loc * top_k
        eid = top_i.reshape(TK)
        tok = jnp.repeat(jnp.arange(T_loc, dtype=jnp.int32), top_k)
        wgt = top_w.reshape(TK)
        mine = (eid >= m * E_loc) & (eid < (m + 1) * E_loc)
        eloc = jnp.where(mine, eid - m * E_loc, E_loc)      # E_loc = drop bin
        order = jnp.argsort(eloc, stable=True)
        eid_s, tok_s, wgt_s = eloc[order], tok[order], wgt[order]
        first = jnp.searchsorted(eid_s, jnp.arange(E_loc + 1, dtype=eid_s.dtype))
        rank = jnp.arange(TK, dtype=jnp.int32) - first[jnp.minimum(eid_s, E_loc)].astype(jnp.int32)
        C = int(round_up(max(8, math.ceil(T_loc * top_k / E * capacity_factor)), 8))
        keep = (eid_s < E_loc) & (rank < C)
        dest = jnp.where(keep, eid_s.astype(jnp.int32) * C + rank, E_loc * C)
        src = x[tok_s] * keep[:, None].astype(x.dtype)
        buf = jnp.zeros((E_loc * C + 1, D), x.dtype).at[dest].set(src)[:-1]
        grouped = buf.reshape(E_loc, C, D)
        f = L.act_fn(act)
        h = f(jnp.einsum("ecd,edf->ecf", grouped, w_gate)) * jnp.einsum(
            "ecd,edf->ecf", grouped, w_up)
        out_g = jnp.einsum("ecf,efd->ecd", h, w_down).reshape(E_loc * C, D)
        gathered = jnp.where(keep[:, None], out_g[jnp.minimum(dest, E_loc * C - 1)], 0)
        out = jnp.zeros((T_loc, D), x.dtype).at[tok_s].add(
            gathered * wgt_s[:, None].astype(x.dtype))
        out = jax.lax.psum(out, tp_axis)                    # combine experts
        aux = jax.lax.pmean(aux, tp_axis)
        return out, aux

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(dp0, None), P(None, None), P(tp_axis, None, None),
                  P(tp_axis, None, None), P(tp_axis, None, None)),
        out_specs=(P(dp0, None), P()),
        check_rep=False,
    )(x, router_w, w_gate, w_up, w_down)


class MoETransformer(DenseTransformer):
    """Dense transformer with the MLP swapped for grouped-capacity MoE."""

    mesh = None   # set by the launcher for the shard_map dispatch path

    @property
    def padded_experts(self) -> int:
        e = self.cfg.num_experts
        return round_up(e, self.pc.tp) if self.pc.tp > 1 else e

    def _mlp_templates(self):
        cfg = self.cfg
        G, Pg, D, F = self.n_groups, self.group, cfg.d_model, cfg.d_ff
        E, Ep = cfg.num_experts, self.padded_experts

        def init_expert(fan_in):
            def f(key):  # pad experts (index >= E) carry zero weights
                shape = (G, Pg, Ep, D, F) if fan_in == D else (G, Pg, Ep, F, D)
                w = jax.random.normal(key, shape, jnp.float32) / math.sqrt(fan_in)
                mask = (jnp.arange(Ep) < E).astype(jnp.float32)
                return w * mask[None, None, :, None, None]
            return f

        return {
            "router": t((G, Pg, D, E), (None, None, None, None), fan_in=D),
            "w_gate": t((G, Pg, Ep, D, F), (None, None, "expert", None, None),
                        custom=init_expert(D)),
            "w_up": t((G, Pg, Ep, D, F), (None, None, "expert", None, None),
                      custom=init_expert(D)),
            "w_down": t((G, Pg, Ep, F, D), (None, None, "expert", None, None),
                        custom=init_expert(F)),
        }

    def _aux_weight(self) -> float:
        return 0.01

    def _mlp(self, pp, p: int, x):
        cfg = self.cfg
        shape = x.shape
        x2d = x.reshape(-1, cfg.d_model)
        if self.pc.tp_axis is not None and self.mesh is not None:
            # local expert-parallel dispatch, zero token exchange (§Perf B)
            x2d = jax.lax.with_sharding_constraint(x2d, self.pc.spec("batch", None))
            out, aux = moe_dispatch_local_ep(
                x2d, pp["router"][p], pp["w_gate"][p], pp["w_up"][p],
                pp["w_down"][p], top_k=cfg.num_experts_per_tok,
                capacity_factor=cfg.moe_capacity_factor, act=cfg.act,
                mesh=self.mesh, pc=self.pc)
            return out.reshape(shape), aux
        constrain = None
        if self.pc.tp_axis is not None:
            x2d = jax.lax.with_sharding_constraint(x2d, self.pc.spec("batch", None))
            constrain = lambda g: jax.lax.with_sharding_constraint(
                g, self.pc.spec("expert", None, None))
        out, aux = moe_dispatch(
            x2d, pp["router"][p], pp["w_gate"][p], pp["w_up"][p], pp["w_down"][p],
            top_k=cfg.num_experts_per_tok, capacity_factor=cfg.moe_capacity_factor,
            act=cfg.act, constrain=constrain)
        return out.reshape(shape), aux
