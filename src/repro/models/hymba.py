"""Hymba: each layer runs sliding-window attention heads and Mamba (selective
SSM) heads in parallel on the same input; branch outputs are normalized and
averaged (arXiv:2411.13676). Constant-size SSM state + windowed KV make the
arch long-context viable (long_500k runs).

The selective scan is chunked: causal conv runs over the full sequence (cheap),
the SSM recurrence uses an unrolled chunk loop with an associative scan inside
each chunk (log-depth, fully visible to HLO cost analysis).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.param_utils import t
from repro.models.transformer import DenseTransformer


def _ssm_chunk_size(seq: int) -> int:
    c = max(64, seq // 128)
    while seq % c:
        c //= 2
    return max(c, 1)


def selective_scan_chunked(ssm_inputs_fn, x_conv, h0):
    """Chunked selective scan. ``ssm_inputs_fn(x_chunk, offset) -> (dA, dBx, C)``
    is evaluated *per chunk* so the [B, c, Di, N] discretization tensors never
    materialize for the whole sequence (at 4k x d_inner x N that would be tens
    of GB). Returns (y [B, S, Di], h_final)."""
    B, S = x_conv.shape[:2]
    c = _ssm_chunk_size(S)
    ys = []
    h = h0

    def combine(a, b):
        a1, b1 = a
        a2, b2 = b
        return a1 * a2, b1 * a2 + b2

    for i in range(S // c):
        sl = slice(i * c, (i + 1) * c)
        dA, dBx, C = ssm_inputs_fn(x_conv[:, sl], i * c)
        A_cum, B_cum = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
        hs = A_cum * h[:, None] + B_cum                     # [B, c, Di, N]
        ys.append(jnp.einsum("bsdn,bsn->bsd", hs, C))
        h = hs[:, -1]
    return jnp.concatenate(ys, axis=1), h


class HymbaModel(DenseTransformer):
    """DenseTransformer (swa attention) + parallel Mamba branch per layer."""

    def supports_paged(self) -> bool:
        return False   # hybrid cache (ring attention + ssm state), not paged

    def __init__(self, cfg, pc=None):
        super().__init__(cfg, pc)
        self.d_inner = cfg.ssm_expand * cfg.d_model
        self.dt_rank = max(16, cfg.d_model // 16)

    # ---------------------------------------------------------------- params
    def templates(self):
        base = super().templates()
        cfg = self.cfg
        G, Pg, D = self.n_groups, self.group, cfg.d_model
        Di, N, ck, dtr = self.d_inner, cfg.ssm_state, cfg.ssm_conv, self.dt_rank
        base["blocks"].update({
            "m_in": t((G, Pg, D, 2 * Di), (None, None, None, "d_inner"), fan_in=D),
            "m_conv_w": t((G, Pg, Di, ck), (None, None, "d_inner", None), fan_in=ck),
            "m_conv_b": t((G, Pg, Di), (None, None, "d_inner"), "zeros"),
            "m_alog": t((G, Pg, Di, N), (None, None, "d_inner", None), "zeros"),
            "m_wx": t((G, Pg, Di, dtr + 2 * N), (None, None, "d_inner", None), fan_in=Di),
            "m_wdt": t((G, Pg, dtr, Di), (None, None, None, "d_inner"), fan_in=dtr),
            "m_bdt": t((G, Pg, Di), (None, None, "d_inner"), "zeros"),
            "m_dskip": t((G, Pg, Di), (None, None, "d_inner"), "ones"),
            "m_out": t((G, Pg, Di, D), (None, None, "d_inner", None), fan_in=Di),
            "fuse_na": t((G, Pg, D), (None, None, None), "zeros"),
            "fuse_nm": t((G, Pg, D), (None, None, None), "zeros"),
        })
        return base

    # ---------------------------------------------------------------- cache
    def cache_struct(self, batch: int, max_len: int):
        out = super().cache_struct(batch, max_len)
        cfg = self.cfg
        G = self.n_groups
        out["conv"] = jax.ShapeDtypeStruct(
            (G, batch, self.d_inner, cfg.ssm_conv - 1), self._dtype)
        out["ssm"] = jax.ShapeDtypeStruct(
            (G, batch, self.d_inner, cfg.ssm_state), jnp.float32)
        return out

    def cache_specs(self):
        specs = super().cache_specs()
        specs["conv"] = self.pc.spec(None, "batch", "d_inner", None)
        specs["ssm"] = self.pc.spec(None, "batch", "d_inner", None)
        return specs

    # ---------------------------------------------------------------- mamba branch
    def _mamba_proj(self, pp, p, x):
        xz = x @ pp["m_in"][p]
        return jnp.split(xz, 2, axis=-1)  # x_m, z each [..., Di]

    def _mamba_ssm_inputs(self, pp, p, x_conv, seq_lens=None, offset: int = 0):
        """x_conv: [..., Di] post-conv post-silu -> (dA, dBx pieces, C)."""
        cfg = self.cfg
        N, dtr = cfg.ssm_state, self.dt_rank
        xp = x_conv @ pp["m_wx"][p]
        dt = jax.nn.softplus(
            (xp[..., :dtr] @ pp["m_wdt"][p]).astype(jnp.float32)
            + pp["m_bdt"][p].astype(jnp.float32))                  # [..., Di]
        if seq_lens is not None:
            valid = offset + jnp.arange(x_conv.shape[1])[None, :] < seq_lens[:, None]
            dt = dt * valid[..., None].astype(jnp.float32)
        Bt = xp[..., dtr:dtr + N].astype(jnp.float32)
        Ct = xp[..., dtr + N:].astype(jnp.float32)
        A = -jnp.exp(pp["m_alog"][p].astype(jnp.float32))          # [Di, N]
        dA = jnp.exp(dt[..., None] * A)                            # [..., Di, N]
        dBx = dt[..., None] * Bt[..., None, :] * x_conv.astype(jnp.float32)[..., None]
        return dA, dBx, Ct

    def _mamba_seq(self, pp, p, x, seq_lens=None):
        """x: [B, S, D] -> (out [B, S, D], conv_tail, h_final). Pad tokens
        freeze the SSM state (dt := 0 -> dA = 1, dBx = 0)."""
        cfg = self.cfg
        B, S, D = x.shape
        x_m, z = self._mamba_proj(pp, p, x)
        ck = cfg.ssm_conv
        pad = jnp.pad(x_m, ((0, 0), (ck - 1, 0), (0, 0)))
        conv = sum(pad[:, i:i + S] * pp["m_conv_w"][p][:, i] for i in range(ck))
        x_conv = jax.nn.silu((conv + pp["m_conv_b"][p]).astype(jnp.float32)).astype(x.dtype)
        h0 = jnp.zeros((B, self.d_inner, cfg.ssm_state), jnp.float32)
        y, hS = selective_scan_chunked(
            lambda xc, off: self._mamba_ssm_inputs(pp, p, xc, seq_lens=seq_lens,
                                                   offset=off),
            x_conv, h0)
        y = y + pp["m_dskip"][p].astype(jnp.float32) * x_conv.astype(jnp.float32)
        out = (y.astype(x.dtype) * jax.nn.silu(z)) @ pp["m_out"][p]
        if seq_lens is None:
            conv_tail = x_m[:, S - (ck - 1):].transpose(0, 2, 1) if S >= ck - 1 else \
                jnp.pad(x_m, ((0, 0), (ck - 1 - S, 0), (0, 0))).transpose(0, 2, 1)
        else:
            # last ck-1 *valid* inputs per sequence
            offs = jnp.arange(ck - 1) - (ck - 1)
            idx = jnp.clip(seq_lens[:, None] + offs[None, :], 0, S - 1)  # [B, ck-1]
            tail = jnp.take_along_axis(x_m, idx[..., None].astype(jnp.int32), axis=1)
            mask = (seq_lens[:, None] + offs[None, :]) >= 0
            tail = jnp.where(mask[..., None], tail, 0)
            conv_tail = tail.transpose(0, 2, 1)
        return out, conv_tail.astype(self._dtype), hS

    def _mamba_decode(self, pp, p, x, conv_state, h):
        """x: [B, D]; conv_state: [B, Di, ck-1]; h: [B, Di, N]."""
        cfg = self.cfg
        ck = cfg.ssm_conv
        x_m, z = self._mamba_proj(pp, p, x)
        window = jnp.concatenate([conv_state, x_m[..., None]], axis=-1)  # [B, Di, ck]
        conv = jnp.einsum("bdk,dk->bd", window.astype(jnp.float32),
                          pp["m_conv_w"][p].astype(jnp.float32))
        x_conv = jax.nn.silu(conv + pp["m_conv_b"][p].astype(jnp.float32)).astype(x.dtype)
        dA, dBx, Ct = self._mamba_ssm_inputs(pp, p, x_conv)
        h_new = dA * h + dBx                                       # [B, Di, N]
        y = jnp.einsum("bdn,bn->bd", h_new, Ct)
        y = y + pp["m_dskip"][p].astype(jnp.float32) * x_conv.astype(jnp.float32)
        out = (y.astype(x.dtype) * jax.nn.silu(z)) @ pp["m_out"][p]
        return out, window[..., 1:].astype(self._dtype), h_new

    # ---------------------------------------------------------------- fused blocks
    def _group_seq(self, carry, pp, positions, seq_lens, collect: bool, max_len: int):
        x, aux = carry
        cfg = self.cfg
        W = min(cfg.sliding_window or max_len, max_len)
        kw, vw, convs, ssms = [], [], [], []
        for p in range(self.group):
            h = L.rmsnorm(x, pp["ln1"][p], cfg.norm_eps)
            attn, (k, v) = self._mixer_seq(pp, p, h, positions, seq_lens, "local", None)
            mamba, conv_tail, hS = self._mamba_seq(pp, p, h, seq_lens=seq_lens)
            fused = 0.5 * (L.rmsnorm(attn, pp["fuse_na"][p], cfg.norm_eps)
                           + L.rmsnorm(mamba, pp["fuse_nm"][p], cfg.norm_eps))
            x = x + fused
            h2 = L.rmsnorm(x, pp["ln2"][p], cfg.norm_eps)
            mlp, a = self._mlp(pp, p, h2)
            x = x + mlp
            aux = aux + a
            x = self._constrain(x, "batch", None, None)
            if collect:
                kw.append(L.ring_from_sequence(k, W, seq_lens))
                vw.append(L.ring_from_sequence(v, W, seq_lens))
                convs.append(conv_tail)
                ssms.append(hS)
        caches = {}
        if collect:
            caches["k_win"], caches["v_win"] = jnp.stack(kw), jnp.stack(vw)
            caches["conv"], caches["ssm"] = convs[0], ssms[0]
        return (x, aux), caches

    def decode_step(self, params, cache, tokens, positions):
        """Unrolled layer loop (see DenseTransformer.decode_step)."""
        cfg = self.cfg
        x = self.embed_tokens(params, tokens)
        cache = dict(cache)
        for g in range(self.n_groups):
            pp = jax.tree.map(lambda a: a[g], params["blocks"])
            p = 0
            h = L.rmsnorm(x, pp["ln1"][p], cfg.norm_eps)
            attn, cache = self._attn_decode_inplace(pp, p, h, positions,
                                                    "local", cache, g)
            mamba, conv_new, h_new = self._mamba_decode(
                pp, p, h, cache["conv"][g], cache["ssm"][g])
            cache["conv"] = cache["conv"].at[g].set(conv_new)
            cache["ssm"] = cache["ssm"].at[g].set(h_new)
            fused = 0.5 * (L.rmsnorm(attn, pp["fuse_na"][p], cfg.norm_eps)
                           + L.rmsnorm(mamba, pp["fuse_nm"][p], cfg.norm_eps))
            x = x + fused
            h2 = L.rmsnorm(x, pp["ln2"][p], cfg.norm_eps)
            mlp, _ = self._mlp(pp, p, h2)
            x = x + mlp
            x = self._constrain(x, "batch", None)
        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        return self.logits(params, x), cache
