"""Bench-regression gate for CI: compare freshly produced ``BENCH_*.json``
artifacts against the committed baselines and fail the job on regression.

Two kinds of checks:

* **Invariants** (always, on the fresh artifact): no cell may report a
  deadlock, and any summary verdict booleans (``optimistic_wins``,
  ``paged_decode_wins``, ``streams_identical``, ``deadlocks == 0``) must
  hold. These are machine-independent by construction.
* **Latency comparison** (only when the fresh run's ``config`` matches the
  baseline's, and the bench runs on the *simulated* clock): every
  ``avg_latency_s`` / ``p99_latency_s`` metric must stay within
  ``--tolerance`` of the committed value. Simulated-clock benches are
  deterministic across machines (seeded traces, crc32 keys), so the default
  tolerance only absorbs float/library drift. Wall-clock benches
  (``real_executor``) are never latency-compared — their verdict booleans
  carry the regression signal instead.

When ``$GITHUB_STEP_SUMMARY`` is set (GitHub Actions), the gate also appends
a markdown verdict table — one row per verdict boolean, one per latency
metric vs its baseline — so the evidence renders on the run page.

    PYTHONPATH=src python -m benchmarks.check_regression \
        --baseline-dir benchmarks/baselines --fresh-dir bench_fresh
    PYTHONPATH=src python -m benchmarks.check_regression --self-test
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path
from typing import Dict, List, Tuple

# benches whose latency metrics are host wall-clock (never compared);
# their summary verdicts are still invariant-checked
WALL_CLOCK_BENCHES = {"real_executor", "async_engine"}

LATENCY_KEYS = ("avg_latency_s", "p99_latency_s")
VERDICT_TRUE_KEYS = ("optimistic_wins", "paged_decode_wins",
                     "streams_identical", "sharing_wins", "pipelined_wins",
                     "planned_wins", "dag_ok", "tiering_wins",
                     "tiering_streams_identical", "recovery_wins",
                     "streams_identical_after_crash", "zero_duplicate_tokens",
                     "autoscale_ok", "proactive_wins",
                     "proactive_streams_identical")


def _walk(node, path=""):
    """Yield (dotted_path, key, value) for every leaf in a JSON tree."""
    if isinstance(node, dict):
        for k, v in node.items():
            yield from _walk(v, f"{path}.{k}" if path else k)
    elif isinstance(node, list):
        for i, v in enumerate(node):
            yield from _walk(v, f"{path}[{i}]")
    else:
        key = path.rsplit(".", 1)[-1]
        yield path, key, node


def check_invariants(name: str, fresh: dict) -> List[str]:
    problems = []
    for path, key, value in _walk(fresh):
        if key == "deadlock" and value:
            problems.append(f"{name}: {path} reports a deadlock")
        elif key == "deadlocks" and value:
            problems.append(f"{name}: {path} = {value} (must be 0)")
        elif key in VERDICT_TRUE_KEYS and value is not True:
            problems.append(f"{name}: verdict {path} = {value} (must be true)")
    return problems


def check_latencies(name: str, baseline: dict, fresh: dict,
                    tolerance: float) -> Tuple[List[str], List[str], List[dict]]:
    """Returns (problems, notes, rows). Latency metrics are matched by path;
    ``rows`` carries per-metric baseline/fresh pairs for the job summary."""
    if name in WALL_CLOCK_BENCHES:
        return [], [f"{name}: wall-clock bench — latency comparison skipped"], []
    if baseline.get("config") != fresh.get("config"):
        return [], [f"{name}: config drift (baseline vs fresh run differ) — "
                    f"latency comparison skipped"], []
    base_vals: Dict[str, float] = {
        path: v for path, key, v in _walk(baseline)
        if key in LATENCY_KEYS and isinstance(v, (int, float))}
    problems, notes, rows = [], [], []
    fresh_vals = {path: v for path, key, v in _walk(fresh)
                  if key in LATENCY_KEYS and isinstance(v, (int, float))}
    for path, base in sorted(base_vals.items()):
        cur = fresh_vals.get(path)
        if cur is None:
            problems.append(f"{name}: metric {path} vanished from fresh run")
            rows.append({"bench": name, "metric": path, "baseline": base,
                         "fresh": None, "ok": False})
            continue
        ok = not (base > 0 and cur > base * (1.0 + tolerance))
        rows.append({"bench": name, "metric": path, "baseline": base,
                     "fresh": cur, "ok": ok})
        if not ok:
            problems.append(
                f"{name}: {path} regressed {base:.4f}s -> {cur:.4f}s "
                f"(+{(cur / base - 1) * 100:.1f}% > {tolerance * 100:.0f}% "
                f"tolerance)")
    notes.append(f"{name}: {len(base_vals)} latency metrics within "
                 f"{tolerance * 100:.0f}%"
                 if not problems else f"{name}: LATENCY REGRESSION")
    return problems, notes, rows


def collect_verdicts(name: str, fresh: dict) -> List[dict]:
    """Every verdict boolean in the artifact, for the job-summary table."""
    return [{"bench": name, "verdict": path, "value": value}
            for path, key, value in _walk(fresh) if key in VERDICT_TRUE_KEYS]


def write_step_summary(verdict_rows: List[dict], lat_rows: List[dict],
                       problems: List[str], tolerance: float) -> None:
    """Append a markdown verdict table to ``$GITHUB_STEP_SUMMARY`` so the
    gate's evidence shows up on the Actions run page. No-op outside CI."""
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    if not path:
        return
    ok = "✅" if not problems else "❌"
    lines = [f"## Bench regression gate {ok} "
             f"({len(problems)} problem(s))", ""]
    if verdict_rows:
        lines += ["### Verdicts", "",
                  "| bench | verdict | holds |", "|---|---|---|"]
        for row in verdict_rows:
            mark = "✅" if row["value"] is True else "❌"
            lines.append(f"| {row['bench']} | `{row['verdict']}` | {mark} |")
        lines.append("")
    if lat_rows:
        lines += [f"### Latencies vs baseline (tolerance "
                  f"{tolerance * 100:.0f}%)", "",
                  "| bench | metric | baseline | fresh | Δ | ok |",
                  "|---|---|---|---|---|---|"]
        for row in lat_rows:
            base, cur = row["baseline"], row["fresh"]
            if cur is None:
                delta, fresh_s = "—", "missing"
            else:
                delta = (f"{(cur / base - 1) * 100:+.1f}%" if base > 0
                         else "—")
                fresh_s = f"{cur:.3f}s"
            mark = "✅" if row["ok"] else "❌"
            lines.append(f"| {row['bench']} | `{row['metric']}` | "
                         f"{base:.3f}s | {fresh_s} | {delta} | {mark} |")
        lines.append("")
    if problems:
        lines += ["### Problems", ""]
        lines += [f"- {p}" for p in problems]
        lines.append("")
    with open(path, "a", encoding="utf-8") as fh:
        fh.write("\n".join(lines) + "\n")


def run_gate(baseline_dir: Path, fresh_dir: Path, tolerance: float) -> int:
    baselines = sorted(baseline_dir.glob("BENCH_*.json"))
    if not baselines:
        print(f"[check_regression] no BENCH_*.json baselines under "
              f"{baseline_dir}", file=sys.stderr)
        return 2
    # union: a fresh artifact with no committed baseline still gets its
    # invariants checked (a brand-new bench must not slip through green
    # while its own JSON reports deadlocks)
    names = sorted({p.name for p in baselines} |
                   {p.name for p in fresh_dir.glob("BENCH_*.json")})
    problems: List[str] = []
    verdict_rows: List[dict] = []
    lat_rows: List[dict] = []
    for fname in names:
        name = fname[len("BENCH_"):-len(".json")]
        bpath, fpath = baseline_dir / fname, fresh_dir / fname
        if not fpath.exists():
            problems.append(f"{name}: fresh artifact {fpath} missing — did "
                            f"the bench run?")
            continue
        fresh = json.loads(fpath.read_text())
        problems += check_invariants(name, fresh)
        verdict_rows += collect_verdicts(name, fresh)
        if not bpath.exists():
            print(f"[check_regression] {name}: no baseline committed — "
                  f"invariants only (commit {bpath} to start tracking)",
                  flush=True)
            continue
        baseline = json.loads(bpath.read_text())
        lat_problems, notes, rows = check_latencies(name, baseline, fresh,
                                                    tolerance)
        problems += lat_problems
        lat_rows += rows
        for note in notes:
            print(f"[check_regression] {note}", flush=True)
    write_step_summary(verdict_rows, lat_rows, problems, tolerance)
    if problems:
        print(f"[check_regression] {len(problems)} problem(s):",
              file=sys.stderr)
        for p in problems:
            print(f"  REGRESSION  {p}", file=sys.stderr)
        return 1
    print(f"[check_regression] OK — {len(names)} bench artifact(s) "
          f"within tolerance, zero deadlocks")
    return 0


# --------------------------------------------------------------------------
# self-test: the gate must catch an injected synthetic regression
# --------------------------------------------------------------------------
def self_test() -> int:
    import tempfile

    cfg = {"seed": 0, "smoke": True}
    baseline = {"config": cfg,
                "cells": {"a": {"avg_latency_s": 1.0, "p99_latency_s": 2.0,
                                "deadlock": False}},
                "summary": {"verdict": {"x": {
                    "optimistic_wins": True, "deadlocks": 0,
                    "tiering_wins": True,
                    "tiering_streams_identical": True,
                    "recovery_wins": True,
                    "streams_identical_after_crash": True,
                    "proactive_wins": True,
                    "proactive_streams_identical": True}}}}

    def gate_with(fresh, summary_path=None) -> int:
        old = os.environ.pop("GITHUB_STEP_SUMMARY", None)
        if summary_path is not None:
            os.environ["GITHUB_STEP_SUMMARY"] = str(summary_path)
        try:
            with tempfile.TemporaryDirectory() as td:
                bdir, fdir = Path(td, "base"), Path(td, "fresh")
                bdir.mkdir(), fdir.mkdir()
                (bdir / "BENCH_selftest.json").write_text(json.dumps(baseline))
                (fdir / "BENCH_selftest.json").write_text(json.dumps(fresh))
                return run_gate(bdir, fdir, tolerance=0.10)
        finally:
            os.environ.pop("GITHUB_STEP_SUMMARY", None)
            if old is not None:
                os.environ["GITHUB_STEP_SUMMARY"] = old

    import copy
    clean = copy.deepcopy(baseline)
    clean["cells"]["a"]["avg_latency_s"] = 1.05      # inside tolerance
    assert gate_with(clean) == 0, "self-test: clean run must pass"

    slow = copy.deepcopy(baseline)
    slow["cells"]["a"]["avg_latency_s"] = 1.5        # +50%: regression
    assert gate_with(slow) == 1, \
        "self-test: injected latency regression must fail the gate"

    dead = copy.deepcopy(baseline)
    dead["cells"]["a"]["deadlock"] = True
    assert gate_with(dead) == 1, \
        "self-test: injected deadlock must fail the gate"

    lost = copy.deepcopy(baseline)
    lost["summary"]["verdict"]["x"]["optimistic_wins"] = False
    assert gate_with(lost) == 1, \
        "self-test: flipped verdict boolean must fail the gate"

    # injected swap regression: the tiered lane stops beating recompute-only
    # (e.g. the cost model broke and every reclaim recomputes) ...
    noswap = copy.deepcopy(baseline)
    noswap["summary"]["verdict"]["x"]["tiering_wins"] = False
    assert gate_with(noswap) == 1, \
        "self-test: injected swap regression (tiering_wins=false) must fail"

    # ... or the host round trip corrupts KV and the streams diverge
    corrupt = copy.deepcopy(baseline)
    corrupt["summary"]["verdict"]["x"]["tiering_streams_identical"] = False
    assert gate_with(corrupt) == 1, \
        "self-test: diverged tiering streams must fail the gate"

    # crash-recovery regressions: snapshot failover stops beating the
    # from-scratch rerun ...
    slow_rec = copy.deepcopy(baseline)
    slow_rec["summary"]["verdict"]["x"]["recovery_wins"] = False
    assert gate_with(slow_rec) == 1, \
        "self-test: injected recovery regression (recovery_wins=false) " \
        "must fail"

    # ... or failover replays/drops tokens and the post-crash streams diverge
    replay = copy.deepcopy(baseline)
    replay["summary"]["verdict"]["x"]["streams_identical_after_crash"] = False
    assert gate_with(replay) == 1, \
        "self-test: diverged post-crash streams must fail the gate"

    # proactive-tiering regressions: the proactive+prefetch lane stops
    # beating reactive tiering (e.g. the prefetch stopped landing zero-stall
    # or the idle-horizon offloads thrash the swap channel) ...
    noproactive = copy.deepcopy(baseline)
    noproactive["summary"]["verdict"]["x"]["proactive_wins"] = False
    assert gate_with(noproactive) == 1, \
        "self-test: injected proactive regression (proactive_wins=false) " \
        "must fail"

    # ... or the prefetch staging corrupts KV and the streams diverge
    pcorrupt = copy.deepcopy(baseline)
    pcorrupt["summary"]["verdict"]["x"]["proactive_streams_identical"] = False
    assert gate_with(pcorrupt) == 1, \
        "self-test: diverged proactive streams must fail the gate"

    # the markdown job summary lands in $GITHUB_STEP_SUMMARY with one row
    # per verdict boolean and one per latency metric
    with tempfile.TemporaryDirectory() as td:
        summary = Path(td, "step_summary.md")
        assert gate_with(clean, summary_path=summary) == 0
        text = summary.read_text(encoding="utf-8")
        assert "Bench regression gate ✅" in text, text
        assert "`summary.verdict.x.proactive_wins`" in text, text
        assert "`cells.a.avg_latency_s`" in text, text
        assert "❌" not in text, text
    with tempfile.TemporaryDirectory() as td:
        summary = Path(td, "step_summary.md")
        assert gate_with(noproactive, summary_path=summary) == 1
        text = summary.read_text(encoding="utf-8")
        assert "Bench regression gate ❌" in text, text
        assert "| selftest | `summary.verdict.x.proactive_wins` | ❌ |" \
            in text, text
        assert "### Problems" in text, text

    drift = copy.deepcopy(baseline)
    drift["config"] = {"seed": 1, "smoke": True}
    drift["cells"]["a"]["avg_latency_s"] = 99.0      # ignored: config drift
    assert gate_with(drift) == 0, \
        "self-test: config drift must skip latency comparison, not fail"

    missing_rc = 0
    with tempfile.TemporaryDirectory() as td:
        bdir, fdir = Path(td, "base"), Path(td, "fresh")
        bdir.mkdir(), fdir.mkdir()
        (bdir / "BENCH_selftest.json").write_text(json.dumps(baseline))
        missing_rc = run_gate(bdir, fdir, tolerance=0.10)
    assert missing_rc == 1, "self-test: missing fresh artifact must fail"

    # a fresh artifact with no committed baseline is still invariant-checked
    with tempfile.TemporaryDirectory() as td:
        bdir, fdir = Path(td, "base"), Path(td, "fresh")
        bdir.mkdir(), fdir.mkdir()
        (bdir / "BENCH_selftest.json").write_text(json.dumps(baseline))
        (fdir / "BENCH_selftest.json").write_text(json.dumps(baseline))
        newdead = copy.deepcopy(baseline)
        newdead["cells"]["a"]["deadlock"] = True
        (fdir / "BENCH_brandnew.json").write_text(json.dumps(newdead))
        assert run_gate(bdir, fdir, tolerance=0.10) == 1, \
            "self-test: baseline-less fresh artifact must still be " \
            "invariant-checked"

    print("CHECK-REGRESSION SELF-TEST OK: gate fails on injected latency "
          "regression, deadlock, flipped verdict (incl. tiering_wins / "
          "tiering_streams_identical / recovery_wins / "
          "streams_identical_after_crash / proactive_wins / "
          "proactive_streams_identical) and missing artifact; passes "
          "clean runs, skips config drift, and writes the markdown "
          "verdict table to $GITHUB_STEP_SUMMARY")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline-dir", default="benchmarks/baselines",
                    help="directory holding the committed BENCH_*.json "
                         "baselines")
    ap.add_argument("--fresh-dir", default=".",
                    help="directory holding the freshly produced artifacts "
                         "(point BENCH_OUT_DIR here when running the benches)")
    ap.add_argument("--tolerance", type=float, default=0.10,
                    help="allowed fractional slowdown for simulated-clock "
                         "latency metrics (default 10%%)")
    ap.add_argument("--self-test", action="store_true",
                    help="verify the gate catches an injected synthetic "
                         "regression, then exit")
    args = ap.parse_args()
    if args.self_test:
        raise SystemExit(self_test())
    raise SystemExit(run_gate(Path(args.baseline_dir), Path(args.fresh_dir),
                              args.tolerance))


if __name__ == "__main__":
    main()
