"""Replica scaling: avg relQuery latency vs number of data-parallel engine
replicas behind the relQuery-affine router, on one shared arrival trace.

At paper-scale load a single replica saturates (queueing dominates); adding
affine replicas splits the relQuery stream while keeping each relQuery's
requests — and therefore its prefix-cache hits — on one engine, so average
latency must be monotonically non-increasing as replicas are added.

  PYTHONPATH=src python -m benchmarks.replica_scaling
"""
from __future__ import annotations

import copy
from typing import List

from benchmarks.common import (csv_row, report_metrics, shared_trace,
                               write_bench_json)
from repro.serving import build_simulated_cluster


def run_replicas(trace, num_replicas: int, scheduler: str = "relserve",
                 router_policy: str = "affinity_spill", seed: int = 0):
    cluster = build_simulated_cluster(num_replicas, scheduler=scheduler,
                                      router_policy=router_policy, seed=seed)
    return cluster.run_trace(copy.deepcopy(trace))


def run(dataset: str = "rotten", rate: float = 2.0, num_relqueries: int = 120,
        replica_counts=(1, 2, 3, 4), scheduler: str = "relserve",
        router_policy: str = "affinity_spill", seed: int = 0,
        quiet: bool = False, strict: bool = False,
        write_json: bool = True) -> List[str]:
    """Sweep replica counts on one trace. With ``strict`` (the default-trace
    acceptance check in ``__main__``) a latency regression between counts is
    an error; custom sweeps report the rows and let the caller judge —
    statistical monotonicity need not be pointwise at every rate/seed.
    Unless ``write_json`` is off, the sweep also lands a machine-readable
    ``BENCH_replica_scaling.json`` artifact."""
    trace = shared_trace(dataset, rate, num_relqueries, seed)
    rows = []
    cells = []
    prev = None
    for n in replica_counts:
        result = run_replicas(trace, n, scheduler, router_policy, seed)
        rep = result.merged
        cells.append({"replicas": n, "spilled": result.router_stats["spilled"],
                      **report_metrics(rep)})
        note = ""
        if prev is not None:
            note = f"speedup_vs_prev={prev / rep.avg_latency:.2f}x"
            if rep.avg_latency > prev * (1 + 1e-9):
                note += " REGRESSION"
                if strict:
                    raise AssertionError(
                        f"avg latency regressed at {n} replicas: "
                        f"{rep.avg_latency:.3f}s > {prev:.3f}s")
        prev = rep.avg_latency
        rows.append(csv_row(
            f"replica_scaling/{scheduler}/{dataset}/rate{rate}/replicas{n}",
            rep.avg_latency * 1e6,
            f"p99={rep.percentile(99):.2f}s max={rep.max_latency:.2f}s "
            f"e2e={rep.end_to_end:.1f}s spilled={result.router_stats['spilled']} "
            f"{note}".strip()))
        if not quiet:
            print(rows[-1], flush=True)
    if write_json:
        write_bench_json("replica_scaling", {
            "bench": "replica_scaling",
            "config": {"dataset": dataset, "rate": rate,
                       "num_relqueries": num_relqueries,
                       "scheduler": scheduler, "router": router_policy,
                       "seed": seed},
            "cells": cells,
        })
    return rows


if __name__ == "__main__":
    run(strict=True)
