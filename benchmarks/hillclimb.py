import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb runner: lowers the optimized variants of the three chosen
cells and reports roofline terms against the recorded baselines.

  A  qwen2.5-32b x decode_32k   sequence-parallel KV decode (shard_map)
  B  qwen3-moe   x prefill_32k  expert-parallel dispatch constraints
  C  gemma3-12b  x train_4k     pure-FSDP training layout

  PYTHONPATH=src python -m benchmarks.hillclimb [--cell A|B|C]
Results append to experiments/hillclimb.json.
"""

import argparse
import dataclasses
import json

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs import get_config, get_shape
from repro.launch.cells import build_cell, lower_cell
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (
    HBM_BW, ICI_BW, PEAK_FLOPS, CellStats, _extract, analytic_memory_bytes,
    analytic_model_flops, corrected_stats,
)

OUT = os.path.join(os.path.dirname(__file__), "..", "experiments",
                   "hillclimb.json")


def terms_from(stats: CellStats, cfg, shape, model, n_dev, tp, peak_bytes):
    mem = analytic_memory_bytes(cfg, shape, model, n_dev, tp)
    m = analytic_model_flops(cfg, shape)
    out = {
        "compute_term_s": stats.dot_flops / PEAK_FLOPS,
        "memory_term_s": mem / HBM_BW,
        "collective_term_s": stats.coll_wire / ICI_BW,
        "dot_flops_per_device": stats.dot_flops,
        "coll_wire_bytes_per_device": stats.coll_wire,
        "analytic_mem_bytes_per_device": mem,
        "useful_ratio": (m["model_flops"] / n_dev) / stats.dot_flops
        if stats.dot_flops else 0.0,
        "peak_bytes_per_device": peak_bytes,
    }
    t = {k: out[k] for k in ("compute_term_s", "memory_term_s", "collective_term_s")}
    out["bottleneck"] = max(t, key=lambda k: t[k]).replace("_term_s", "")
    out["step_time_bound_s"] = max(t.values())
    return out


def run_A(mesh):
    """Sequence-parallel KV decode for qwen2.5-32b decode_32k."""
    from repro.distributed.sharding import ParallelConfig
    from repro.models.seq_parallel import SeqParallelDenseTransformer
    arch, shape_name = "qwen2.5-32b", "decode_32k"
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    pc = ParallelConfig.from_mesh(mesh)
    model = SeqParallelDenseTransformer(cfg, pc, mesh=mesh)
    B, S = shape.global_batch, shape.seq_len
    params = model.abstract_params()
    params_sh = model.param_shardings(mesh)
    cache = model.cache_struct(B, S)
    cache_sh = {k: NamedSharding(mesh, model.cache_specs()[k]) for k in cache}
    toks = jax.ShapeDtypeStruct((B,), jnp.int32)
    pos = jax.ShapeDtypeStruct((B,), jnp.int32)
    bs1 = NamedSharding(mesh, pc.spec("batch"))

    with mesh:
        lowered = jax.jit(model.decode_step,
                          in_shardings=(params_sh, cache_sh, bs1, bs1),
                          donate_argnums=(1,)).lower(params, cache, toks, pos)
        compiled = lowered.compile()
    stats = _extract(compiled)
    ma = compiled.memory_analysis()
    peak = int(ma.argument_size_in_bytes + ma.output_size_in_bytes
               + ma.temp_size_in_bytes - getattr(ma, "alias_size_in_bytes", 0))
    n_dev = len(mesh.devices.ravel())
    row = terms_from(stats, cfg, shape, model, n_dev, pc.tp, peak)
    row.update({"cell": "A", "arch": arch, "shape": shape_name,
                "variant": "seq_parallel_kv_decode"})
    return row


def run_B(mesh):
    """MoE dispatch with expert-parallel buffer constraints (now default)."""
    arch, shape_name = "qwen3-moe-30b-a3b", "prefill_32k"
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    cs = corrected_stats(arch, shape_name, mesh)      # recompiles with the fix
    cell = build_cell(arch, shape_name, mesh)
    stats = CellStats(**cs["stats"])
    n_dev = len(mesh.devices.ravel())
    row = terms_from(stats, cfg, shape, cell.model, n_dev, cell.pc.tp,
                     cs["peak_bytes_per_device"])
    row.update({"cell": "B", "arch": arch, "shape": shape_name,
                "variant": "local_ep_dispatch_shardmap"})
    return row


def run_C(mesh, compress=False):
    """Pure-FSDP training layout for gemma3-12b train_4k."""
    arch, shape_name = "gemma3-12b", "train_4k"
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    cell = build_cell(arch, shape_name, mesh, train_layout="fsdp",
                      compress_grads=compress)
    compiled = lower_cell(cell, mesh).compile()
    full = _extract(compiled)
    ma = compiled.memory_analysis()
    peak = int(ma.argument_size_in_bytes + ma.output_size_in_bytes
               + ma.temp_size_in_bytes - getattr(ma, "alias_size_in_bytes", 0))
    # scan-correct the layer stack (same composition as the baseline harness)
    c1 = build_cell(arch, shape_name, mesh, train_layout="fsdp", compress_grads=compress,
                    cfg_override=cfg.replace(num_layers=cell.model.layers_per_scan_step))
    c0 = build_cell(arch, shape_name, mesh, train_layout="fsdp", compress_grads=compress,
                    cfg_override=cfg.replace(num_layers=0))
    s1 = _extract(lower_cell(c1, mesh).compile())
    s0 = _extract(lower_cell(c0, mesh).compile())
    body = CellStats.diff(s1, s0)
    total = full.combine(body, cell.model.scan_trip_count - 1)
    n_dev = len(mesh.devices.ravel())
    row = terms_from(total, cfg, shape, cell.model, n_dev, 1, peak)
    row.update({"cell": "C", "arch": arch, "shape": shape_name,
                "variant": "fsdp_training_layout" + ("_bf16grads" if compress else "")})
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None, choices=["A", "B", "C", "C2"])
    args = ap.parse_args()
    mesh = make_production_mesh(multi_pod=False)
    runners = {"A": run_A, "B": run_B, "C": run_C,
               "C2": lambda m: run_C(m, compress=True)}
    cells = [args.cell] if args.cell else ["A", "B", "C", "C2"]
    rows = []
    if os.path.exists(OUT):
        with open(OUT) as f:
            rows = json.load(f)
    keyed = {r["cell"]: r for r in rows}
    for c in cells:
        print(f"[hillclimb {c}] lowering...", flush=True)
        try:
            row = runners[c](mesh)
            keyed[c] = row
            print(json.dumps(row, indent=1), flush=True)
        except Exception as e:  # noqa: BLE001
            import traceback
            print(f"cell {c} FAILED: {e}")
            traceback.print_exc()
        with open(os.path.abspath(OUT), "w") as f:
            json.dump(list(keyed.values()), f, indent=1)


if __name__ == "__main__":
    main()
